//! Integration tests for the event-sourced tracing layer (ISSUE 6).
//!
//! The load-bearing claims, checked end-to-end through real app runs:
//!
//! * **Reconciliation** — the event log is not a parallel estimate but
//!   the *same* accounting the aggregate [`RunReport`] scalars come
//!   from: per-cause wait durations sum to the per-rank `wait` vector,
//!   `OpRetire` counts match `ops_executed`, `MsgPost` counts match
//!   `n_messages`, and the sync/admission cause buckets match their
//!   dedicated report counters — across all three scheduling policies.
//! * **Exporter validity** — the Perfetto timeline renders to JSON that
//!   parses back (with the crate's own parser) into a non-empty
//!   `traceEvents` array.
//! * **Critical path** — the four classes cover the makespan exactly.
//! * **Zero-cost disabled** — tracing off is bit-identical to tracing
//!   on, and records nothing.

use distnumpy::apps::{AppId, AppParams};
use distnumpy::cluster::MachineSpec;
use distnumpy::flow::FlowCfg;
use distnumpy::harness::run_once_traced;
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg, SyncMode};
use distnumpy::trace::{critical, export, TraceEvent, TraceSink, WaitCause};
use distnumpy::util::json::Json;

fn traced_cfg(p: u32) -> SchedCfg {
    let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
    cfg.trace.enabled = true;
    cfg
}

fn close(a: f64, b: f64, label: &str) {
    let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
    assert!((a - b).abs() <= tol, "{label}: {a} vs {b}");
}

/// Fold the event log and check every count/duration invariant against
/// the aggregate report.
fn reconcile(rep: &RunReport, sink: &TraceSink, p: u32, label: &str) {
    assert_eq!(sink.dropped(), 0, "{label}: tiny runs must not wrap the ring");
    assert!(!sink.is_empty(), "{label}: a traced run must record events");

    let mut wait = vec![0.0f64; p as usize];
    let mut barrier = 0.0f64;
    let mut cone_like = 0.0f64;
    let mut admission = 0.0f64;
    let mut retires = 0u64;
    let mut posts = 0u64;
    let mut delivers = 0u64;
    let mut windows = 0u64;
    for ev in sink.events() {
        match *ev {
            TraceEvent::Wait {
                rank,
                cause,
                t0,
                t1,
                ..
            } => {
                let d = t1 - t0;
                match cause {
                    WaitCause::Admission => admission += d,
                    WaitCause::Barrier => {
                        barrier += d;
                        wait[rank.idx()] += d;
                    }
                    WaitCause::Cone | WaitCause::Collective => {
                        cone_like += d;
                        wait[rank.idx()] += d;
                    }
                    _ => wait[rank.idx()] += d,
                }
            }
            TraceEvent::OpRetire { .. } => retires += 1,
            TraceEvent::MsgPost { .. } => posts += 1,
            TraceEvent::MsgDeliver { .. } => delivers += 1,
            TraceEvent::Window { .. } => windows += 1,
            _ => {}
        }
    }

    assert_eq!(retires, rep.ops_executed, "{label}: OpRetire vs ops_executed");
    assert_eq!(posts, rep.n_messages, "{label}: MsgPost vs n_messages");
    assert_eq!(delivers, posts, "{label}: every posted message delivers once");
    assert_eq!(windows, rep.window_decisions, "{label}: Window vs window_decisions");
    for (r, &w) in wait.iter().enumerate() {
        close(w, rep.wait[r], &format!("{label}: wait attribution for rank {r}"));
    }
    close(barrier, rep.wait_at_barrier, &format!("{label}: barrier bucket"));
    close(cone_like, rep.wait_at_cone, &format!("{label}: cone+collective bucket"));
    close(admission, rep.wait_at_admission, &format!("{label}: admission bucket"));
}

/// The acceptance run: pipelined Jacobi stencil at P = 16 under
/// latency hiding, plus the blocking scheduler on a smaller grid. Both
/// event logs must reconcile exactly with their reports.
#[test]
fn wait_attribution_reconciles_for_lh_and_blocking() {
    let params = AppParams {
        scale: 0.25,
        iters: 2,
    };
    let (rep, _, sink) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, traced_cfg(16));
    assert!(rep.n_messages > 0, "stencil at P=16 must communicate");
    reconcile(&rep, &sink, 16, "lh/jacobi_stencil/p16");

    let params = AppParams {
        scale: 0.1,
        iters: 2,
    };
    let (rep, _, sink) =
        run_once_traced(AppId::JacobiStencil, Policy::Blocking, &params, traced_cfg(8));
    assert!(rep.n_messages > 0, "stencil at P=8 must communicate");
    reconcile(&rep, &sink, 8, "blocking/jacobi_stencil/p8");
}

/// The naive strawman deadlocks on multi-iteration stencils (Fig. 6),
/// so it gets a program it completes: a comm-free elementwise add plus
/// a forced reduction read (flat fan-in to the root, then a settle).
#[test]
fn wait_attribution_reconciles_for_naive() {
    let mut ctx = Context::sim(traced_cfg(4), Policy::Naive);
    let x = ctx.zeros(&[64], 4);
    let y = ctx.zeros(&[64], 4);
    ctx.add(&y, &x, &x);
    ctx.sum(&x).expect("flat reduce completes under naive");
    let (rep, sink) = ctx.finish_traced().expect("naive run completes");
    assert!(rep.ops_executed > 0, "the program must execute");
    reconcile(&rep, &sink, 4, "naive/add+sum/p4");
}

/// The sync-engine causes land in the right report buckets: under the
/// global join, forced convergence reads charge [`WaitCause::Barrier`];
/// under targeted settles they charge [`WaitCause::Cone`] /
/// [`WaitCause::Collective`].
#[test]
fn sync_causes_fill_the_matching_buckets() {
    let params = AppParams {
        scale: 0.1,
        iters: 3,
    };
    let mut cfg = traced_cfg(4);
    cfg.sync = SyncMode::Barrier;
    let (rep, _, sink) = run_once_traced(AppId::Jacobi, Policy::LatencyHiding, &params, cfg);
    assert!(rep.wait_at_barrier > 0.0, "forced reads must hit the barrier");
    reconcile(&rep, &sink, 4, "barrier/jacobi/p4");

    let mut cfg = traced_cfg(4);
    cfg.sync = SyncMode::Cone;
    let (rep, _, sink) = run_once_traced(AppId::Jacobi, Policy::LatencyHiding, &params, cfg);
    assert!(rep.wait_at_cone > 0.0, "forced reads must settle the cone");
    reconcile(&rep, &sink, 4, "cone/jacobi/p4");
}

/// Streaming admission: `Admit` events appear, the admission-gate cause
/// reconciles with `wait_at_admission`, adaptive-window decisions
/// reconcile with `window_decisions`, and the per-epoch time-series has
/// one well-formed entry per admitted epoch.
#[test]
fn sliding_admission_traces_and_epoch_series() {
    let params = AppParams {
        scale: 0.25,
        iters: 3,
    };
    let mut cfg = traced_cfg(8);
    cfg.flow = FlowCfg::sliding_auto();
    cfg.flush_threshold = 32;
    let (rep, _, sink) = run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, cfg);
    reconcile(&rep, &sink, 8, "sliding/jacobi_stencil/p8");

    let admits = sink
        .events()
        .filter(|e| matches!(e, TraceEvent::Admit { .. }))
        .count();
    assert!(admits >= 2, "threshold flushes must admit multiple epochs, got {admits}");

    let series = critical::epoch_series(&sink, 8);
    let rows = series.as_arr().expect("epoch series is an array");
    assert!(!rows.is_empty(), "one row per admitted epoch");
    for row in rows {
        for key in ["epoch", "n_ops", "in_flight", "wait", "wait_pct", "span"] {
            assert!(row.get(key).is_some(), "epoch-series row missing {key}");
        }
    }
}

/// The Perfetto exporter emits JSON that parses back (with the crate's
/// own parser) into the Chrome-trace shape: a non-empty `traceEvents`
/// array of objects with phase tags, including slices, metadata, and
/// the flow arrows that tie sends to receives.
#[test]
fn perfetto_export_round_trips_as_json() {
    let params = AppParams {
        scale: 0.1,
        iters: 2,
    };
    let (rep, _, sink) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, traced_cfg(8));
    assert!(rep.n_messages > 0);

    let text = export::perfetto(&sink, 8).render();
    let back = Json::parse(&text).expect("exporter must emit valid JSON");
    let events = back
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(!events.is_empty());

    let mut phases: Vec<&str> = Vec::new();
    for e in events {
        let ph = e
            .get("ph")
            .and_then(|p| p.as_str())
            .expect("every trace event carries a phase");
        if !phases.contains(&ph) {
            phases.push(ph);
        }
    }
    for need in ["X", "M", "s", "f"] {
        assert!(phases.contains(&need), "missing phase {need} in {phases:?}");
    }
    assert!(
        back.get("otherData").and_then(|o| o.get("dropped_events")).is_some(),
        "drop counter must surface in the export"
    );
}

/// Critical-path acceptance: the four classes cover 100% of the
/// makespan (to fp rounding) and the top-op list is populated.
#[test]
fn critical_path_classes_cover_makespan() {
    let params = AppParams {
        scale: 0.25,
        iters: 2,
    };
    let (rep, _, sink) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, traced_cfg(16));
    let cp = critical::critical_path(&sink, 16, rep.makespan);
    assert!(cp.makespan > 0.0);
    let covered = cp.compute + cp.comm + cp.wait + cp.overhead;
    let tol = 1e-6 * cp.makespan;
    assert!(
        (covered - cp.makespan).abs() <= tol,
        "classes must cover the makespan: {} + {} + {} + {} = {covered} vs {}",
        cp.compute,
        cp.comm,
        cp.wait,
        cp.overhead,
        cp.makespan
    );
    assert!(cp.compute > 0.0, "a stencil's critical path crosses compute");
    assert!(!cp.top_ops.is_empty(), "top ops must be attributed");
    let json = cp.to_json().render();
    assert!(json.contains("compute_pct") && json.contains("top_ops"));
}

/// A trace ring too small for the run must wrap, and the overflow must
/// surface in the report (and hence the run JSON) as `trace_dropped` —
/// not just in the Perfetto export's `otherData`.
#[test]
fn dropped_events_surface_in_the_report() {
    let params = AppParams {
        scale: 0.25,
        iters: 2,
    };
    let mut cfg = traced_cfg(16);
    cfg.trace.capacity = 4;
    let (rep, _, sink) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, cfg);
    assert!(sink.dropped() > 0, "a 4-slot ring must wrap on a real run");
    assert_eq!(rep.trace_dropped, sink.dropped(), "report mirrors the sink");
    let json = rep.to_json().render();
    assert!(
        json.contains(&format!("\"trace_dropped\":{}", rep.trace_dropped)),
        "{json}"
    );

    // An untraced run reports zero.
    let (rep, _, _) = run_once_traced(
        AppId::JacobiStencil,
        Policy::LatencyHiding,
        &params,
        SchedCfg::new(MachineSpec::tiny(), 16),
    );
    assert_eq!(rep.trace_dropped, 0);
}

/// Zero-cost disabled: the same run with tracing off is bit-identical
/// (same makespan bits, same wait vector bits, same counters) and its
/// sink holds nothing.
#[test]
fn disabled_tracing_is_bit_identical_and_records_nothing() {
    let params = AppParams {
        scale: 0.1,
        iters: 2,
    };
    let run = |enabled: bool| {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), 8);
        cfg.trace.enabled = enabled;
        let (rep, _, sink) =
            run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, cfg);
        (rep, sink)
    };
    let (on_rep, on_sink) = run(true);
    let (off_rep, off_sink) = run(false);

    assert!(off_sink.is_empty() && off_sink.dropped() == 0, "disabled sink records nothing");
    assert!(!on_sink.is_empty());
    assert_eq!(off_rep.makespan.to_bits(), on_rep.makespan.to_bits(), "makespan");
    assert_eq!(off_rep.ops_executed, on_rep.ops_executed);
    assert_eq!(off_rep.n_messages, on_rep.n_messages);
    assert_eq!(off_rep.wait.len(), on_rep.wait.len());
    for (r, (a, b)) in off_rep.wait.iter().zip(&on_rep.wait).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "wait[{r}]");
    }
    assert_eq!(
        off_rep.wait_at_cone.to_bits(),
        on_rep.wait_at_cone.to_bits(),
        "wait_at_cone"
    );
}
