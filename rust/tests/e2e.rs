//! End-to-end integration tests across the three layers.
//!
//! The native half exercises the full stack (lazy context, schedulers,
//! simulated network, real numerics) with the built-in Rust kernels and
//! always runs. The PJRT half drives the AOT HLO artifacts (L1/L2)
//! through the scheduler (L3); it needs the `pjrt` cargo feature and
//! `make artifacts`, and panics with a clear message when the artifacts
//! are missing rather than silently passing.

use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::comm::Collective;
use distnumpy::exec::NativeBackend;
use distnumpy::lazy::Context;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::util::rng::Rng;

/// Reductions flow partials over the simulated network correctly —
/// under both the paper's flat gather and the binomial tree, with and
/// without message aggregation.
#[test]
fn distributed_reduction_matches_serial_sum() {
    for p in [1u32, 2, 3, 4] {
        for collective in [Collective::Flat, Collective::Tree] {
            for aggregation in [0usize, 8] {
                let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
                cfg.collective = collective;
                cfg.aggregation = aggregation;
                let backend = NativeBackend::new(ClusterStore::new(p));
                let mut ctx = Context::new(cfg, Policy::LatencyHiding, Box::new(backend));
                let mut rng = Rng::new(p as u64);
                let data = rng.fill_f32(1000, -1.0, 1.0);
                let x = ctx.array(&[1000], 32, &data);
                let got = ctx.sum(&x).expect("flush must complete");
                let want: f64 = data.iter().map(|&v| v as f64).sum();
                assert!(
                    (got - want).abs() < 1e-3,
                    "P={p} {collective:?} agg={aggregation}: distributed sum {got} vs serial {want}"
                );
                ctx.finish().unwrap();
            }
        }
    }
}

/// The paper's Fig. 3 stencil with real numerics through the native
/// backend, gathered back through the recorded collective schedules.
#[test]
fn fig3_stencil_native_roundtrip() {
    for policy in [Policy::LatencyHiding, Policy::Blocking] {
        for collective in [Collective::Flat, Collective::Tree] {
            let mut cfg = SchedCfg::new(MachineSpec::tiny(), 2);
            cfg.collective = collective;
            let backend = NativeBackend::new(ClusterStore::new(2));
            let mut ctx = Context::new(cfg, policy, Box::new(backend));
            let m = ctx.array(&[6], 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            let n = ctx.zeros(&[6], 3);
            let a = m.slice(&[(2, 6)]);
            let b = m.slice(&[(0, 4)]);
            let c = n.slice(&[(1, 5)]);
            ctx.add(&c, &a, &b);
            ctx.flush();
            let got = ctx
                .gather(n.base)
                .expect("flush must complete")
                .expect("data backend materializes");
            assert_eq!(
                got,
                vec![0.0, 4.0, 6.0, 8.0, 10.0, 0.0],
                "{policy:?} {collective:?}"
            );
            ctx.finish().unwrap();
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use distnumpy::exec::kernels;
    use distnumpy::runtime::{
        artifact_dir, artifact_inputs, PjrtBackend, PjrtEngine, ARTIFACT_NAMES,
    };
    use distnumpy::ufunc::Kernel;

    fn engine() -> PjrtEngine {
        PjrtEngine::load(&artifact_dir())
            .expect("PJRT engine must load — run `make artifacts` first")
    }

    #[test]
    fn all_artifacts_load_and_compile() {
        let e = engine();
        assert_eq!(
            e.loaded(),
            ARTIFACT_NAMES.len(),
            "every artifact in the contract must compile — run `make artifacts`"
        );
    }

    #[test]
    fn manifest_matches_rust_contracts() {
        let manifest = std::fs::read_to_string(artifact_dir().join("manifest.json"))
            .expect("manifest.json — run `make artifacts`");
        for name in ARTIFACT_NAMES {
            assert!(
                manifest.contains(&format!("\"{name}\"")),
                "{name} missing from manifest"
            );
            // Shape spot-check: every declared input length appears.
            for dims in artifact_inputs(name) {
                let len: usize = dims.iter().product();
                assert!(len > 0, "{name}: degenerate contract");
            }
        }
    }

    /// Each single-output artifact agrees with the native Rust kernel on
    /// random inputs — the L1 (Pallas) ↔ L3 (native) correctness chain,
    /// on the Rust side (pytest covers Pallas ↔ pure-jnp).
    #[test]
    fn artifacts_agree_with_native_kernels() {
        let e = engine();
        let mut rng = Rng::new(2012);
        // (artifact, kernel, positive-only inputs)
        let cases: Vec<(&str, Kernel, bool)> = vec![
            ("add1d", Kernel::Add, false),
            ("add2d", Kernel::Add, false),
            ("sub2d", Kernel::Sub, false),
            ("mul2d", Kernel::Mul, false),
            ("axpy1d", Kernel::Axpy(0.2), false),
            ("stencil5v", Kernel::Stencil5, false),
            ("black_scholes", Kernel::BlackScholes, true),
            ("fractal", Kernel::Fractal(32), false),
            (
                "matmul",
                Kernel::MatmulAcc {
                    n: 64,
                    k: 64,
                    m: 64,
                },
                false,
            ),
        ];
        for (name, kernel, positive) in cases {
            let shapes = artifact_inputs(name);
            let inputs: Vec<Vec<f32>> = shapes
                .iter()
                .map(|dims| {
                    let len: usize = dims.iter().product();
                    if positive {
                        rng.fill_f32(len, 0.5, 2.0)
                    } else {
                        rng.fill_f32(len, -1.0, 1.0)
                    }
                })
                .collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let got = e.execute(name, &refs).expect(name);
            let elems = got.len();
            let want = kernels::run(kernel, &refs, elems);
            assert_eq!(got.len(), want.len(), "{name}: output length");
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                    "{name}[{i}]: PJRT {g} vs native {w}"
                );
            }
        }
    }

    /// The full stack on the paper's Fig. 3 program with real numerics
    /// through PJRT, all policies that terminate.
    #[test]
    fn fig3_stencil_through_pjrt_matches_native() {
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
            let backend = PjrtBackend::new(ClusterStore::new(2), engine());
            let mut ctx = Context::new(cfg, policy, Box::new(backend));
            let m = ctx.array(&[6], 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            let n = ctx.zeros(&[6], 3);
            let a = m.slice(&[(2, 6)]);
            let b = m.slice(&[(0, 4)]);
            let c = n.slice(&[(1, 5)]);
            ctx.add(&c, &a, &b);
            ctx.flush();
            let got = ctx
                .gather(n.base)
                .expect("flush must complete")
                .expect("data backend materializes");
            assert_eq!(got, vec![0.0, 4.0, 6.0, 8.0, 10.0, 0.0], "{policy:?}");
            ctx.finish().unwrap();
        }
    }

    /// Aligned 1-D ufuncs at the artifact block size dispatch through
    /// PJRT (not the native fallback) and still match the native result.
    #[test]
    fn aligned_blocks_dispatch_to_pjrt() {
        const N: u64 = 16_384;
        const BR: u64 = 4_096;
        let mut rng = Rng::new(7);
        let xs = rng.fill_f32(N as usize, -2.0, 2.0);
        let ys = rng.fill_f32(N as usize, -2.0, 2.0);

        let run = |use_pjrt: bool| -> (Vec<f32>, u64) {
            let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
            let mut ctx = if use_pjrt {
                Context::new(
                    cfg,
                    Policy::LatencyHiding,
                    Box::new(PjrtBackend::new(ClusterStore::new(4), engine())),
                )
            } else {
                Context::new(
                    cfg,
                    Policy::LatencyHiding,
                    Box::new(NativeBackend::new(ClusterStore::new(4))),
                )
            };
            let x = ctx.array(&[N], BR, &xs);
            let y = ctx.array(&[N], BR, &ys);
            let z = ctx.zeros(&[N], BR);
            ctx.add(&z, &x, &y);
            ctx.ufunc(Kernel::Axpy(0.2), &z, &[&z, &x]);
            ctx.flush();
            let out = ctx
                .gather(z.base)
                .expect("flush must complete")
                .expect("data backend materializes");
            let dispatched = ctx
                .backend
                .as_any()
                .downcast_ref::<PjrtBackend>()
                .map(|b| b.dispatched)
                .unwrap_or(0);
            ctx.finish().unwrap();
            (out, dispatched)
        };

        let (pjrt_out, dispatched) = run(true);
        let (native_out, _) = run(false);
        assert_eq!(
            dispatched,
            2 * (N / BR),
            "both aligned ufuncs must dispatch on every block"
        );
        for (g, w) in pjrt_out.iter().zip(&native_out) {
            assert!((g - w).abs() <= 1e-5, "{g} vs {w}");
        }
    }
}
