//! Property-based integration tests (in-repo driver; the offline
//! environment has no proptest crate — randomized trials with a
//! deterministic seeded RNG play its role).
//!
//! Invariants checked over randomized layouts and operation streams:
//!
//! * layout partition — every view element belongs to exactly one
//!   sub-view-block, owned by exactly one rank;
//! * dependency-system equivalence — the full-DAG and the heuristic
//!   admit identical ready-set evolutions (the paper's §5.7.2 claim
//!   that the heuristic is an *optimization*, not a relaxation);
//! * schedule independence — latency-hiding and blocking execution of
//!   the same random program produce bit-identical numerics;
//! * accounting — every scheduler executes every op, waits are
//!   non-negative, makespan bounds every rank's busy+wait time.

use distnumpy::array::{ClusterStore, Registry};
use distnumpy::cluster::MachineSpec;
use distnumpy::comm::{aggregate, allgather_ring, Collective};
use distnumpy::deps::{DagDeps, DepSystem, HeuristicDeps};
use distnumpy::exec::{Backend, NativeBackend, SimBackend};
use distnumpy::layout::{sub_view_blocks, ViewSpec};
use distnumpy::lazy::Context;
use distnumpy::sched::{execute, Policy, SchedCfg, SchedError};
use distnumpy::types::{DType, OpId, Rank, Tag};
use distnumpy::ufunc::{
    Access, ComputeTask, Dst, Kernel, OpBuilder, OpNode, OpPayload, Operand, Region, SendSrc,
};
use distnumpy::util::rng::Rng;

// ---------------------------------------------------------------------
// Layout partition properties
// ---------------------------------------------------------------------

#[test]
fn prop_view_rows_partition_into_sub_view_blocks() {
    let mut rng = Rng::new(0xA11CE);
    for _ in 0..200 {
        let p = rng.range(1, 9) as u32;
        let rows = rng.range(1, 200) as u64;
        let br = rng.range(1, 40) as u64;
        let mut reg = Registry::new(p);
        let base = reg.alloc(vec![rows], br, DType::F32);
        let layout = reg.layout(base);

        let lo = rng.below(rows);
        let hi = lo + 1 + rng.below(rows - lo);
        let view = reg.full_view(base).slice(&[(lo, hi)]);

        let svbs = sub_view_blocks(layout, &view);
        // Every view row appears in exactly one sub-view-block.
        let mut covered = vec![0u32; (hi - lo) as usize];
        for s in &svbs {
            assert_eq!(layout.owner(s.block), s.owner, "owner consistency");
            for r in s.view_rows.0..s.view_rows.1 {
                covered[r as usize] += 1;
            }
        }
        assert!(
            covered.iter().all(|&c| c == 1),
            "rows covered exactly once: {covered:?} (rows={rows} br={br} view=({lo},{hi}))"
        );
    }
}

#[test]
fn prop_block_ownership_is_cyclic_partition() {
    let mut rng = Rng::new(0xB10C);
    for _ in 0..200 {
        let p = rng.range(1, 17) as u32;
        let rows = rng.range(1, 500) as u64;
        let br = rng.range(1, 64) as u64;
        let mut reg = Registry::new(p);
        let base = reg.alloc(vec![rows], br, DType::F32);
        let layout = reg.layout(base);
        let mut seen = vec![false; layout.nblocks() as usize];
        for r in 0..p {
            for b in layout.blocks_of(distnumpy::types::Rank(r)) {
                assert!(!seen[b as usize], "block {b} owned twice");
                seen[b as usize] = true;
                assert_eq!(layout.owner(b).0, r);
            }
        }
        assert!(seen.iter().all(|&s| s), "every block owned");
        // Row -> block -> row-range roundtrip.
        for _ in 0..20 {
            let row = rng.below(rows);
            let b = layout.block_of_row(row);
            let (lo, hi) = layout.block_rows_range(b);
            assert!(lo <= row && row < hi);
        }
    }
}

// ---------------------------------------------------------------------
// Random program generator
// ---------------------------------------------------------------------

/// A random DistNumPy-like program over a few shared arrays: slices,
/// elementwise ufuncs, reductions — the op mix of the paper's apps.
///
/// Input views that *partially* overlap the output view of the same
/// base are avoided: like NumPy 1.3 itself, an in-place ufunc over
/// partially-overlapping slices has implementation-defined results
/// (the apps use `circshift`-style staging instead, see
/// `apps::lbm`), so the schedule-independence property only holds for
/// well-defined programs. Identical out==in views are fine.
fn random_program(rng: &mut Rng, p: u32) -> (Registry, Vec<OpNode>, Vec<distnumpy::types::BaseId>) {
    let rows = 8 + rng.below(120);
    let br = 1 + rng.below(16);
    let n_arrays = rng.range(2, 5);
    let mut reg = Registry::new(p);
    let bases: Vec<_> = (0..n_arrays)
        .map(|_| reg.alloc(vec![rows], br, DType::F32))
        .collect();
    let mut bld = OpBuilder::new();
    let n_ops = rng.range(1, 12);
    for _ in 0..n_ops {
        let len = 1 + rng.below(rows);
        let pick_view = |rng: &mut Rng, reg: &Registry| -> ViewSpec {
            let b = bases[rng.range(0, bases.len())];
            let off = rng.below(rows - len + 1);
            reg.full_view(b).slice(&[(off, off + len)])
        };
        // An input must not partially overlap `out` on the same base.
        let pick_input = |rng: &mut Rng, reg: &Registry, out: &ViewSpec| -> ViewSpec {
            for _ in 0..8 {
                let v = pick_view(rng, reg);
                let partial_overlap = v.base == out.base
                    && v.offset != out.offset
                    && v.offset[0] < out.offset[0] + len
                    && out.offset[0] < v.offset[0] + len;
                if !partial_overlap {
                    return v;
                }
            }
            out.clone() // fall back to the (safe) identical view
        };
        match rng.range(0, 10) {
            0..=6 => {
                let out = pick_view(rng, &reg);
                let a = pick_input(rng, &reg, &out);
                let b = pick_input(rng, &reg, &out);
                let kernel = match rng.range(0, 4) {
                    0 => Kernel::Add,
                    1 => Kernel::Sub,
                    2 => Kernel::Mul,
                    _ => Kernel::Axpy(0.5),
                };
                bld.ufunc(&reg, kernel, &out, &[&a, &b]);
            }
            7..=8 => {
                let out = pick_view(rng, &reg);
                let a = pick_input(rng, &reg, &out);
                bld.ufunc(&reg, Kernel::Copy, &out, &[&a]);
            }
            _ => {
                let a = pick_view(rng, &reg);
                // Alternate fan-in schedules so the random streams
                // exercise both collective paths.
                let collective = if rng.range(0, 2) == 0 {
                    Collective::Flat
                } else {
                    Collective::Tree
                };
                bld.reduce(&reg, Kernel::PartialSum, &[&a], collective);
            }
        }
    }
    (reg, bld.finish(), bases)
}

// ---------------------------------------------------------------------
// Dependency-system equivalence
// ---------------------------------------------------------------------

/// Drain both systems in lock-step; their ready sets must agree at every
/// step (same conflict semantics => same legal schedules).
#[test]
fn prop_heuristic_and_dag_admit_identical_schedules() {
    let mut rng = Rng::new(0xDE95);
    for trial in 0..120 {
        let p = 1 + (trial % 4) as u32;
        let (_, ops, _) = random_program(&mut rng, p);
        let mut heu = HeuristicDeps::new();
        let mut dag = DagDeps::new();
        heu.insert_all(&ops);
        dag.insert_all(&ops);
        let mut done = 0;
        loop {
            let mut rh: Vec<OpId> = heu.take_ready();
            let mut rd: Vec<OpId> = dag.take_ready();
            rh.sort_by_key(|o| o.0);
            rd.sort_by_key(|o| o.0);
            assert_eq!(rh, rd, "ready sets diverge at step {done} (trial {trial})");
            if rh.is_empty() {
                break;
            }
            for id in rh {
                heu.complete(id);
                dag.complete(id);
                done += 1;
            }
        }
        assert_eq!(done, ops.len(), "full drain (trial {trial})");
        assert_eq!(heu.pending(), 0);
        assert_eq!(dag.pending(), 0);
    }
}

// ---------------------------------------------------------------------
// Scheduler properties
// ---------------------------------------------------------------------

/// Latency-hiding and blocking must produce identical numerics on the
/// same program — scheduling is invisible to the result (§5: the user
/// sees sequential semantics).
#[test]
fn prop_schedule_independent_numerics() {
    let mut rng = Rng::new(0x5EED);
    for trial in 0..60 {
        let p = 1 + (trial % 4) as u32;
        let (reg, ops, bases) = random_program(&mut rng, p);

        let mut gathers: Vec<Vec<f32>> = Vec::new();
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let mut store = ClusterStore::new(p);
            let mut data_rng = Rng::new(42); // same initial data each policy
            for &b in &bases {
                store.alloc_base(reg.layout(b));
                let rows = reg.layout(b).rows();
                let d = data_rng.fill_f32(rows as usize, -1.0, 1.0);
                store.scatter(reg.layout(b), &d);
            }
            let mut be = NativeBackend::new(store);
            let cfg = SchedCfg::new(MachineSpec::tiny(), p);
            execute(policy, &ops, &cfg, &mut be).unwrap();
            let mut all = Vec::new();
            for &b in &bases {
                all.extend(be.store.gather(reg.layout(b)));
            }
            gathers.push(all);
        }
        assert_eq!(
            gathers[0], gathers[1],
            "policies disagree on trial {trial}"
        );
    }
}

/// Accounting invariants on random programs, all sizes of cluster.
#[test]
fn prop_scheduler_accounting() {
    let mut rng = Rng::new(0xACC0);
    for trial in 0..80 {
        let p = 1 + (trial % 8) as u32;
        let (_, ops, _) = random_program(&mut rng, p);
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let cfg = SchedCfg::new(MachineSpec::paper(), p);
            let rep = execute(policy, &ops, &cfg, &mut SimBackend).unwrap();
            assert_eq!(rep.ops_executed, ops.len() as u64, "{policy:?}");
            assert_eq!(rep.n_compute + rep.n_comm, ops.len() as u64);
            assert!(rep.wait.iter().all(|&w| w >= 0.0), "negative wait");
            assert!(rep.busy.iter().all(|&b| b >= 0.0), "negative busy");
            for r in 0..p as usize {
                assert!(
                    rep.busy[r] + rep.wait[r] <= rep.makespan + 1e-9,
                    "{policy:?}: rank {r} busy+wait exceeds makespan (trial {trial})"
                );
            }
            // Comm ops come in send/recv pairs.
            assert_eq!(rep.n_comm % 2, 0, "unpaired transfer");
        }
    }
}

/// The latency-hiding scheduler never loses to blocking by more than
/// the dependency-system overhead on communication-heavy stencil
/// programs — and its *waiting* time never exceeds blocking's.
#[test]
fn prop_lh_waits_no_more_than_blocking_on_stencils() {
    let mut rng = Rng::new(0x57E4);
    for _ in 0..40 {
        let p = 2 + rng.below(6) as u32;
        let rows = 64 + rng.below(512);
        let br = 1 + rng.below(8);
        let mut reg = Registry::new(p);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let mut bld = OpBuilder::new();
        for _ in 0..3 {
            let a = mv.slice(&[(2, rows)]);
            let b = mv.slice(&[(0, rows - 2)]);
            let c = nv.slice(&[(1, rows - 1)]);
            bld.ufunc(&reg, Kernel::Add, &c, &[&a, &b]);
            bld.ufunc(&reg, Kernel::Copy, &mv.slice(&[(1, rows - 1)]), &[&c]);
        }
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::paper(), p);
        let lh = execute(Policy::LatencyHiding, &ops, &cfg, &mut SimBackend).unwrap();
        let bl = execute(Policy::Blocking, &ops, &cfg, &mut SimBackend).unwrap();
        let lw: f64 = lh.wait.iter().sum();
        let bw: f64 = bl.wait.iter().sum();
        assert!(
            lw <= bw + 1e-9,
            "LH waited more than blocking: {lw} vs {bw} (P={p} rows={rows} br={br})"
        );
    }
}

// ---------------------------------------------------------------------
// Collective-engine properties (comm/)
// ---------------------------------------------------------------------

/// Tree reduce over the native backend matches the sequential reference
/// and the old flat fan-in for randomized shapes, rank counts and view
/// slices, under all three policies — and is bit-identical across
/// policies (fixed combine order).
#[test]
fn prop_tree_reduce_matches_reference_and_flat_fanin() {
    let mut rng = Rng::new(0x7EE5);
    for trial in 0..40 {
        let p = 1 + rng.below(8) as u32;
        let rows = 8 + rng.below(300);
        let br = 1 + rng.below(12);
        let lo = rng.below(rows);
        let hi = lo + 1 + rng.below(rows - lo);

        let mut reg = Registry::new(p);
        let base = reg.alloc(vec![rows], br, DType::F32);
        let view = reg.full_view(base).slice(&[(lo, hi)]);
        let mut rng_data = Rng::new(trial as u64 + 1);
        let data = rng_data.fill_f32(rows as usize, -1.0, 1.0);
        let want: f64 = data[lo as usize..hi as usize]
            .iter()
            .map(|&v| v as f64)
            .sum();

        let run = |collective: Collective, policy: Policy| -> f64 {
            let mut store = ClusterStore::new(p);
            store.alloc_base(reg.layout(base));
            store.scatter(reg.layout(base), &data);
            let mut bld = OpBuilder::new();
            let tag = bld.reduce(&reg, Kernel::PartialSum, &[&view], collective);
            let ops = bld.finish();
            let mut be = NativeBackend::new(store);
            let cfg = SchedCfg::new(MachineSpec::tiny(), p);
            execute(policy, &ops, &cfg, &mut be)
                .unwrap_or_else(|e| panic!("{policy:?}/{collective:?} trial {trial}: {e}"));
            be.staged_scalar(Rank(0), tag).expect("result staged on root")
        };

        let tol = 1e-3 * want.abs().max(1.0);
        let flat = run(Collective::Flat, Policy::LatencyHiding);
        let mut tree_results = Vec::new();
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            let tree = run(Collective::Tree, policy);
            assert!(
                (tree - want).abs() < tol,
                "trial {trial} {policy:?}: tree {tree} vs reference {want}"
            );
            tree_results.push(tree);
        }
        assert!(
            (flat - want).abs() < tol,
            "trial {trial}: flat {flat} vs reference {want}"
        );
        assert!(
            (tree_results[0] - flat).abs() < tol,
            "trial {trial}: tree {} vs flat {flat}",
            tree_results[0]
        );
        // Fixed combine order: the tree result is *bit-identical*
        // across policies.
        assert!(
            tree_results.iter().all(|&t| t == tree_results[0]),
            "trial {trial}: tree results diverge across policies: {tree_results:?}"
        );
    }
}

/// Ring allgather delivers every remote block, bit-exact, for
/// randomized layouts under latency-hiding and blocking.
#[test]
fn prop_ring_allgather_delivers_all_blocks() {
    let mut rng = Rng::new(0x41A6);
    for trial in 0..30 {
        let p = 2 + rng.below(6) as u32;
        let rows = p as u64 + rng.below(200);
        let br = 1 + rng.below(10);
        let mut reg = Registry::new(p);
        let base = reg.alloc(vec![rows], br, DType::F32);
        let layout = reg.layout(base).clone();
        let mut rng_data = Rng::new(0xDA7A + trial as u64);
        let data = rng_data.fill_f32(rows as usize, -1.0, 1.0);

        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let mut store = ClusterStore::new(p);
            store.alloc_base(&layout);
            store.scatter(&layout, &data);
            let mut bld = OpBuilder::new();
            let tags = allgather_ring(&mut bld, &reg, base);
            let ops = bld.finish();
            let mut be = NativeBackend::new(store);
            let cfg = SchedCfg::new(MachineSpec::tiny(), p);
            execute(policy, &ops, &cfg, &mut be)
                .unwrap_or_else(|e| panic!("{policy:?} trial {trial}: {e}"));
            for r in 0..p {
                for b in 0..layout.nblocks() {
                    let (blo, bhi) = layout.block_rows_range(b);
                    let want = &data[blo as usize..bhi as usize];
                    match tags[r as usize][b as usize] {
                        None => assert_eq!(layout.owner(b), Rank(r)),
                        Some(t) => assert_eq!(
                            be.store.ranks[r as usize].stage(t),
                            want,
                            "{policy:?} trial {trial}: rank {r} block {b}"
                        ),
                    }
                }
            }
        }
    }
}

/// The multi-round ring parks every rank on a becoming-ready receive
/// under the naive evaluator — Fig. 6 all over again. It must report a
/// deadlock (with its blocked receives counted), not hang.
#[test]
fn ring_allgather_deadlocks_naive_with_report() {
    let mut reg = Registry::new(3);
    let base = reg.alloc(vec![3], 1, DType::F32);
    let mut bld = OpBuilder::new();
    let _ = allgather_ring(&mut bld, &reg, base);
    let ops = bld.finish();
    let cfg = SchedCfg::new(MachineSpec::tiny(), 3);
    assert!(
        execute(Policy::LatencyHiding, &ops, &cfg, &mut SimBackend).is_ok(),
        "latency-hiding completes the ring"
    );
    match execute(Policy::Naive, &ops, &cfg, &mut SimBackend) {
        Err(SchedError::Deadlock {
            executed,
            total,
            blocked_recvs,
            cycle,
        }) => {
            assert!(executed < total);
            assert!(blocked_recvs > 0);
            assert!(
                cycle.contains("waits on recv") && cycle.contains("rank"),
                "the deadlock names its wait chain: {cycle}"
            );
        }
        other => panic!("naive must deadlock on the multi-round ring, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Message-aggregation properties (comm/aggregate)
// ---------------------------------------------------------------------

/// Aggregation is invisible to the numerics: random programs produce
/// bit-identical results with and without it, under latency-hiding and
/// blocking, while never increasing the wire-message count.
#[test]
fn prop_aggregation_preserves_numerics() {
    let mut rng = Rng::new(0xA660);
    for trial in 0..40 {
        let p = 1 + (trial % 4) as u32;
        let (reg, ops, bases) = random_program(&mut rng, p);
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let mut gathers: Vec<Vec<f32>> = Vec::new();
            let mut messages: Vec<u64> = Vec::new();
            for aggregation in [0usize, 4] {
                let mut store = ClusterStore::new(p);
                let mut data_rng = Rng::new(77);
                for &b in &bases {
                    store.alloc_base(reg.layout(b));
                    let rows = reg.layout(b).rows();
                    let d = data_rng.fill_f32(rows as usize, -1.0, 1.0);
                    store.scatter(reg.layout(b), &d);
                }
                let mut be = NativeBackend::new(store);
                let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
                cfg.aggregation = aggregation;
                let rep = execute(policy, &ops, &cfg, &mut be)
                    .unwrap_or_else(|e| panic!("{policy:?} agg={aggregation}: {e}"));
                messages.push(rep.n_messages);
                let mut all = Vec::new();
                for &b in &bases {
                    all.extend(be.store.gather(reg.layout(b)));
                }
                gathers.push(all);
            }
            assert_eq!(
                gathers[0], gathers[1],
                "{policy:?} trial {trial}: aggregation changed the numerics"
            );
            assert!(
                messages[1] <= messages[0],
                "{policy:?} trial {trial}: aggregation added messages"
            );
        }
    }
}

/// Regression (naive + aggregation): a coalesced send whose
/// constituents span a blocked receive forms a cycle — rank 1 parks on
/// the packed envelope receive while the packed send on rank 0 waits
/// for a compute fed by rank 1's unreached send. The naive evaluator
/// must detect and report this, not hang; latency-hiding completes the
/// very same stream.
#[test]
fn naive_reports_cycle_through_aggregated_message() {
    let b = distnumpy::types::BaseId(0);
    let region = |row: u64| Region {
        base: b,
        block: 0,
        row0: row,
        nrows: 1,
        col0: 0,
        ncols: 4,
        row_stride: 4,
    };
    let read_iv = |row: u64| (row * 4, row * 4 + 4);
    // Recorded stream (2 ranks, one base block on rank 0):
    //   id0  rank0: Recv  Ta   <- rank1            (group 0)
    //   id1  rank0: Compute    reads stage Ta, writes block A (group 0)
    //   id2  rank0: Send  T1   -> rank1, region A[0]   (group 1)
    //   id3  rank1: Recv  T1
    //   id4  rank0: Send  T2   -> rank1, region A[1]   (group 1)
    //   id5  rank1: Recv  T2
    //   id6  rank1: Send  Ta   -> rank0, region B      (group 1)
    let ta = Tag(100);
    let ops = vec![
        OpNode {
            id: OpId(0),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Recv {
                peer: Rank(1),
                tag: ta,
                bytes: 16,
            },
            accesses: vec![Access::write_stage(ta)],
        },
        OpNode {
            id: OpId(1),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::Copy,
                inputs: vec![Operand::Staged(ta)],
                dst: Dst::Block(region(0)),
                elems: 4,
            }),
            accesses: vec![Access::read_stage(ta), Access::write_block(b, 0, (0, 8))],
        },
        OpNode {
            id: OpId(2),
            rank: Rank(0),
            group: 1,
            payload: OpPayload::Send {
                peer: Rank(1),
                tag: Tag(0),
                bytes: 16,
                src: SendSrc::Region(region(0)),
            },
            accesses: vec![Access::read_block(b, 0, read_iv(0))],
        },
        OpNode {
            id: OpId(3),
            rank: Rank(1),
            group: 1,
            payload: OpPayload::Recv {
                peer: Rank(0),
                tag: Tag(0),
                bytes: 16,
            },
            accesses: vec![Access::write_stage(Tag(0))],
        },
        OpNode {
            id: OpId(4),
            rank: Rank(0),
            group: 1,
            payload: OpPayload::Send {
                peer: Rank(1),
                tag: Tag(1),
                bytes: 16,
                src: SendSrc::Region(region(1)),
            },
            accesses: vec![Access::read_block(b, 0, read_iv(1))],
        },
        OpNode {
            id: OpId(5),
            rank: Rank(1),
            group: 1,
            payload: OpPayload::Recv {
                peer: Rank(0),
                tag: Tag(1),
                bytes: 16,
            },
            accesses: vec![Access::write_stage(Tag(1))],
        },
        OpNode {
            id: OpId(6),
            rank: Rank(1),
            group: 1,
            payload: OpPayload::Send {
                peer: Rank(0),
                tag: ta,
                bytes: 16,
                src: SendSrc::Region(Region {
                    base: distnumpy::types::BaseId(1),
                    block: 0,
                    row0: 0,
                    nrows: 1,
                    col0: 0,
                    ncols: 4,
                    row_stride: 4,
                }),
            },
            accesses: vec![Access::read_block(distnumpy::types::BaseId(1), 0, (0, 4))],
        },
    ];

    // The two rank0 -> rank1 sends coalesce (their sources were written
    // before the anchor's group; no hazard in between).
    let (packed, stats) = aggregate(&ops, 4);
    assert_eq!(stats.packed_msgs, 1, "the two block sends must coalesce");
    assert_eq!(stats.packed_parts, 2);
    assert_eq!(packed.len(), 5, "7 ops collapse to 5");

    let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
    // Latency-hiding initiates every ready communication before
    // blocking on anything (invariant 2) and completes.
    let rep = execute(Policy::LatencyHiding, &packed, &cfg, &mut SimBackend)
        .expect("latency-hiding completes the aggregated stream");
    assert_eq!(rep.ops_executed, packed.len() as u64);
    // The naive evaluator parks rank 1 on the envelope receive (it
    // became ready before Sa) and rank 0 on Ta: a cycle through the
    // coalesced send. Must be reported as a deadlock, promptly.
    match execute(Policy::Naive, &packed, &cfg, &mut SimBackend) {
        Err(SchedError::Deadlock {
            executed,
            total,
            blocked_recvs,
            cycle,
        }) => {
            assert_eq!(executed, 0);
            assert_eq!(total, packed.len() as u64);
            assert_eq!(blocked_recvs, 2, "both parked receives reported");
            // Satellite (ISSUE 7): the runtime error now carries the
            // predictor's wait-chain witness, threaded through the
            // coalesced envelope.
            assert!(
                cycle.contains("Tag(100)"),
                "the witness names the staged-recv tag: {cycle}"
            );
            assert!(
                cycle.contains("cycle"),
                "the chain closes back on itself: {cycle}"
            );
        }
        other => panic!("naive must report the aggregated cycle, got {other:?}"),
    }

    // The static predictor reaches the same verdict from the recorded
    // stream alone — no event loop, no clocks.
    let pred = distnumpy::analyze::stalls::predict_naive(&packed)
        .expect("the aggregation cycle must be predicted statically");
    assert_eq!(pred.executed, 0);
    assert_eq!(pred.total, packed.len() as u64);
    assert_eq!(pred.blocked.len(), 2, "same parked receives as the runtime");
    assert!(
        pred.blocked.contains(&(Rank(0), Tag(100))),
        "rank 0 parks on the staged recv: {:?}",
        pred.blocked
    );
    assert!(
        pred.cycle.contains("Tag(100)") && pred.cycle.contains("cycle"),
        "predictor and runtime agree on the witness: {}",
        pred.cycle
    );
    // Latency-hiding is statically clean on the same stream.
    assert!(distnumpy::analyze::stalls::predict(Policy::LatencyHiding, &packed).is_none());
}

// ---------------------------------------------------------------------
// Schedule-analyzer properties (analyze/)
// ---------------------------------------------------------------------

/// The soundness claim of §5.7.2, fuzzed: on randomized op streams the
/// heuristic's happens-before closure covers the exact conflict
/// closure (anything less is a data race the oracle must refuse), and
/// the full DAG records *exactly* the direct conflict edges. On fresh
/// insert-only replays neither system adds spurious order.
#[test]
fn prop_dep_systems_cover_the_exact_conflict_closure() {
    use distnumpy::analyze::hazards::{check, dep_direct_preds, exact_direct_preds};
    use distnumpy::sched::DepsKind;

    let mut rng = Rng::new(0x0AC1E);
    let mut with_edges = 0;
    for trial in 0..120 {
        let p = 1 + (trial % 4) as u32;
        let (_, ops, _) = random_program(&mut rng, p);
        for kind in [DepsKind::Heuristic, DepsKind::Dag] {
            let stats = check(&ops, kind)
                .unwrap_or_else(|r| panic!("trial {trial} {kind:?}: {r}"));
            assert_eq!(stats.ops, ops.len());
            assert_eq!(
                stats.excess_edges, 0,
                "trial {trial} {kind:?}: insert-only replays record only conflict edges"
            );
            assert_eq!(
                stats.serialized_pairs, 0,
                "trial {trial} {kind:?}: no op pair is serialized without a conflict path"
            );
            if stats.exact_edges > 0 {
                with_edges += 1;
            }
        }
        assert_eq!(
            dep_direct_preds(&ops, DepsKind::Dag),
            exact_direct_preds(&ops),
            "trial {trial}: the DAG's direct preds are the exact conflict preds"
        );
    }
    assert!(
        with_edges > 60,
        "the generator must produce real conflicts ({with_edges} edge-carrying checks)"
    );
}

/// Seeded mutation: delete one recorded dependency edge and the oracle
/// must report it as a data race naming exactly the unordered pair.
/// Dropping op j's *maximum* direct pred i is never covered
/// transitively (any other path i -> k -> j needs k > i in j's list).
#[test]
fn prop_dropping_one_dep_edge_is_detected_as_a_race() {
    use distnumpy::analyze::hazards::{check_preds, exact_direct_preds};

    let mut rng = Rng::new(0xFA57);
    let mut mutated = 0;
    for trial in 0..60 {
        let p = 1 + (trial % 4) as u32;
        let (_, ops, _) = random_program(&mut rng, p);
        let exact = exact_direct_preds(&ops);
        let Some(j) = (0..ops.len()).rev().find(|&j| !exact[j].is_empty()) else {
            continue;
        };
        let i = *exact[j].last().expect("non-empty by construction");
        let mut dep = exact.clone();
        dep[j].pop();
        let err = check_preds(&ops, &dep)
            .expect_err("a dropped max-pred edge cannot be covered transitively");
        assert_eq!(err.pred, OpId(i), "trial {trial}: race names the missed pred");
        assert_eq!(err.succ, OpId(j as u32), "trial {trial}: race names the successor");
        let msg = err.to_string();
        assert!(msg.contains("data race"), "trial {trial}: {msg}");
        assert!(
            msg.contains(&format!("op {j}")),
            "trial {trial}: provenance names the op: {msg}"
        );
        mutated += 1;
    }
    assert!(
        mutated >= 30,
        "most random programs must carry a droppable edge ({mutated})"
    );
}

/// Regression (id recycling): once the heuristic's tables reset for a
/// new epoch, cone queries for ids beyond the recycled table must fall
/// back to the conservative whole-epoch [`Cone::Prefix`] — never panic,
/// never answer an exact cone from stale spans — while live recycled
/// ids keep answering exactly.
#[test]
fn heuristic_cone_prefix_fallback_on_recycled_ids() {
    use distnumpy::sync::{Cone, ConeSource};

    let rows = 16u64;
    let mut reg = Registry::new(2);
    let m = reg.alloc(vec![rows], 4, DType::F32);
    let mv = reg.full_view(m);
    let mut bld = OpBuilder::new();
    bld.ufunc(
        &reg,
        Kernel::Add,
        &mv.slice(&[(1, rows - 1)]),
        &[&mv.slice(&[(2, rows)]), &mv.slice(&[(0, rows - 2)])],
    );
    let epoch1 = bld.finish();
    let mut heu = HeuristicDeps::new();
    heu.insert_all(&epoch1);
    let mut done = 0;
    loop {
        let ready = heu.take_ready();
        if ready.is_empty() {
            break;
        }
        for id in ready {
            heu.complete(id);
            done += 1;
        }
    }
    assert_eq!(done, epoch1.len(), "epoch 1 drains");
    assert_eq!(heu.pending(), 0);

    // Epoch 2 recycles ids from 0; its first insert resets the tables.
    let mut bld2 = OpBuilder::new();
    bld2.ufunc(&reg, Kernel::Copy, &mv.slice(&[(0, 4)]), &[&mv.slice(&[(4, 8)])]);
    let epoch2 = bld2.finish();
    assert!(
        epoch2.len() < epoch1.len(),
        "epoch 2 must be shorter so an epoch-1 id lands out of range \
         ({} vs {})",
        epoch2.len(),
        epoch1.len()
    );
    heu.insert_all(&epoch2);

    let stale = OpId(epoch1.len() as u32 - 1);
    assert!(
        matches!(heu.cone_of(stale), Cone::Prefix),
        "an already-recycled id answers with the conservative prefix"
    );
    assert!(
        heu.direct_preds(stale).is_empty(),
        "stale ids report no preds instead of reading another op's spans"
    );
    match heu.cone_of(epoch2[0].id) {
        Cone::Exact(c) => assert!(c.contains(&epoch2[0].id), "the target is in its own cone"),
        other => panic!("live recycled ids answer exactly, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Epoch / flush-boundary properties
// ---------------------------------------------------------------------

/// Numerics on data backends are invariant to where the flush
/// boundaries fall and to how scalars are read: one big flush vs many
/// small epochs, immediate (barrier-per-read) vs deferred futures —
/// under all three policies and both collective schedules. The programs
/// are aligned (full-view ufuncs + reductions), which every policy
/// completes; scheduling and epoch partitioning must be invisible to
/// the results (§5: the user sees sequential semantics).
#[test]
fn prop_numerics_invariant_to_flush_threshold_and_deferral() {
    use distnumpy::lazy::ScalarFuture;

    let mut rng = Rng::new(0xE90C);
    for trial in 0..25 {
        let p = 1 + (trial % 4) as u32;
        let rows = 8 + rng.below(120);
        let br = 1 + rng.below(12);
        let n_arrays = 2 + rng.range(0, 2);
        // Program script: shared across configs.
        #[derive(Clone, Copy)]
        enum Step {
            Ufunc(usize, usize, usize, u8), // out, a, b, kernel id
            Sum(usize),
        }
        let n_steps = rng.range(3, 10);
        let steps: Vec<Step> = (0..n_steps)
            .map(|_| {
                if rng.chance(0.3) {
                    Step::Sum(rng.range(0, n_arrays))
                } else {
                    Step::Ufunc(
                        rng.range(0, n_arrays),
                        rng.range(0, n_arrays),
                        rng.range(0, n_arrays),
                        rng.range(0, 3) as u8,
                    )
                }
            })
            .collect();
        let data: Vec<Vec<f32>> = {
            let mut data_rng = Rng::new(0xDA7A + trial as u64);
            (0..n_arrays)
                .map(|_| data_rng.fill_f32(rows as usize, -1.0, 1.0))
                .collect()
        };

        let run = |policy: Policy,
                   collective: Collective,
                   threshold: usize,
                   deferred: bool|
         -> (Vec<Vec<f32>>, Vec<f64>) {
            let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
            cfg.collective = collective;
            let mut ctx = Context::new(
                cfg,
                policy,
                Box::new(NativeBackend::new(ClusterStore::new(p))),
            );
            ctx.flush_threshold = threshold;
            let views: Vec<_> = data.iter().map(|d| ctx.array(&[rows], br, d)).collect();
            let mut pending: Vec<ScalarFuture> = Vec::new();
            let mut sums = Vec::new();
            for s in &steps {
                match *s {
                    Step::Ufunc(o, a, b, k) => {
                        let kernel = match k {
                            0 => Kernel::Add,
                            1 => Kernel::Mul,
                            _ => Kernel::Axpy(0.25),
                        };
                        ctx.ufunc(kernel, &views[o], &[&views[a], &views[b]]);
                    }
                    Step::Sum(a) => {
                        if deferred {
                            pending.push(ctx.sum_deferred(&views[a]));
                        } else {
                            sums.push(ctx.sum(&views[a]).unwrap_or_else(|e| {
                                panic!("{policy:?}/{collective:?} trial {trial}: {e}")
                            }));
                        }
                    }
                }
            }
            for f in pending {
                sums.push(ctx.wait_scalar(&f).unwrap_or_else(|e| {
                    panic!("{policy:?}/{collective:?} trial {trial}: {e}")
                }));
            }
            ctx.flush();
            assert!(
                ctx.error.is_none(),
                "{policy:?}/{collective:?} trial {trial}: aligned program must complete"
            );
            // Read the final blocks straight from the store (recording a
            // gather collective here would add a ring allgather, which
            // the naive evaluator legitimately deadlocks on at P >= 3 —
            // that behaviour has its own tests).
            let gathers = views
                .iter()
                .map(|v| {
                    ctx.backend
                        .gather(ctx.reg.layout(v.base))
                        .expect("data backend")
                })
                .collect();
            (gathers, sums)
        };

        for collective in [Collective::Flat, Collective::Tree] {
            let want = run(Policy::LatencyHiding, collective, usize::MAX, false);
            for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
                for (threshold, deferred) in
                    [(usize::MAX, false), (usize::MAX, true), (3, false), (3, true)]
                {
                    let got = run(policy, collective, threshold, deferred);
                    assert_eq!(
                        got.0, want.0,
                        "trial {trial} {policy:?}/{collective:?} \
                         threshold={threshold} deferred={deferred}: arrays diverge"
                    );
                    assert_eq!(
                        got.1, want.1,
                        "trial {trial} {policy:?}/{collective:?} \
                         threshold={threshold} deferred={deferred}: sums diverge"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Targeted-synchronization properties (sync/)
// ---------------------------------------------------------------------

/// Forced values — scalars *and* gathered arrays — are bit-identical
/// under the global barrier and the targeted cone wait, across all
/// three policies and both dependency systems: synchronization strategy
/// is pure timing, invisible to the numerics (§5 sequential semantics).
/// Programs are aligned (full-view ufuncs + flat-collective reductions
/// and gathers), which every policy completes.
#[test]
fn prop_forced_values_identical_under_barrier_and_cone() {
    use distnumpy::sched::{DepsKind, SyncMode};

    let mut rng = Rng::new(0xC03E);
    for trial in 0..15 {
        let p = 1 + (trial % 4) as u32;
        let rows = 8 + rng.below(100);
        let br = 1 + rng.below(10);
        let n_arrays = 2usize;
        #[derive(Clone, Copy)]
        enum Step {
            Ufunc(usize, usize, usize, u8),
            Sum(usize),
        }
        let n_steps = rng.range(3, 9);
        let steps: Vec<Step> = (0..n_steps)
            .map(|_| {
                if rng.chance(0.3) {
                    Step::Sum(rng.range(0, n_arrays))
                } else {
                    Step::Ufunc(
                        rng.range(0, n_arrays),
                        rng.range(0, n_arrays),
                        rng.range(0, n_arrays),
                        rng.range(0, 3) as u8,
                    )
                }
            })
            .collect();
        let data: Vec<Vec<f32>> = {
            let mut data_rng = Rng::new(0x5EAF + trial as u64);
            (0..n_arrays)
                .map(|_| data_rng.fill_f32(rows as usize, -1.0, 1.0))
                .collect()
        };

        let run = |policy: Policy, deps: DepsKind, sync: SyncMode| -> (Vec<f64>, Vec<f32>) {
            let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
            cfg.deps = deps;
            cfg.sync = sync;
            let mut ctx = Context::new(
                cfg,
                policy,
                Box::new(NativeBackend::new(ClusterStore::new(p))),
            );
            ctx.flush_threshold = 6; // small epochs: cross-epoch futures
            let views: Vec<_> = data.iter().map(|d| ctx.array(&[rows], br, d)).collect();
            let mut sums = Vec::new();
            for s in &steps {
                match *s {
                    Step::Ufunc(o, a, b, k) => {
                        let kernel = match k {
                            0 => Kernel::Add,
                            1 => Kernel::Mul,
                            _ => Kernel::Axpy(0.25),
                        };
                        ctx.ufunc(kernel, &views[o], &[&views[a], &views[b]]);
                    }
                    Step::Sum(a) => {
                        sums.push(ctx.sum(&views[a]).unwrap_or_else(|e| {
                            panic!("{policy:?}/{deps:?}/{sync:?} trial {trial}: {e}")
                        }));
                    }
                }
            }
            // A forced whole-array read through the ArrayFuture path.
            let gathered = ctx
                .gather(views[0].base)
                .unwrap_or_else(|e| panic!("{policy:?}/{deps:?}/{sync:?} trial {trial}: {e}"))
                .expect("data backend");
            (sums, gathered)
        };

        let want = run(Policy::LatencyHiding, DepsKind::Heuristic, SyncMode::Barrier);
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            for deps in [DepsKind::Heuristic, DepsKind::Dag] {
                for sync in [SyncMode::Barrier, SyncMode::Cone] {
                    let got = run(policy, deps, sync);
                    assert_eq!(
                        got.0, want.0,
                        "trial {trial} {policy:?}/{deps:?}/{sync:?}: scalars diverge"
                    );
                    assert_eq!(
                        got.1, want.1,
                        "trial {trial} {policy:?}/{deps:?}/{sync:?}: arrays diverge"
                    );
                }
            }
        }
    }
}

/// Regression: reference-counted stage reclamation must never drop a
/// stage a live future still reads. A deferred scalar and a deferred
/// gather are recorded, then several epochs of unrelated stencil work
/// create and reclaim their own stages; forcing the futures afterwards
/// must still read the correct values.
#[test]
fn stage_reclamation_never_drops_a_live_futures_stage() {
    let p = 2u32;
    let rows = 24u64;
    let mut ctx = Context::new(
        SchedCfg::new(MachineSpec::tiny(), p),
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let mut rng = Rng::new(0x91A);
    let data = rng.fill_f32(rows as usize, -1.0, 1.0);
    let x = ctx.array(&[rows], 3, &data);
    let scratch = ctx.zeros(&[rows], 3);
    let want_sum: f64 = data.iter().map(|&v| v as f64).sum();

    let scalar = ctx.sum_deferred(&x);
    let array = ctx.gather_deferred(x.base);
    ctx.flush();

    // Stencil epochs churn halo stages (created AND reclaimed) while
    // the futures stay pinned — and deliberately OVERWRITE `x`, the
    // futures' source: both futures captured their operands at record
    // position, so the mutations must be invisible to them.
    let dropped_before = ctx.state.stages.dropped;
    for _ in 0..5 {
        ctx.copy(&scratch.slice(&[(1, rows - 1)]), &x.slice(&[(0, rows - 2)]));
        ctx.add(
            &scratch.slice(&[(1, rows - 1)]),
            &scratch.slice(&[(2, rows)]),
            &x.slice(&[(2, rows)]),
        );
        ctx.ufunc(Kernel::Scale(2.0), &x, &[&x]);
        ctx.flush();
    }
    assert!(
        ctx.state.stages.dropped > dropped_before,
        "the stencil epochs must exercise reclamation"
    );

    // The pinned futures survived every reclamation pass, and read the
    // record-position data despite the later overwrites of `x`.
    let got_sum = ctx.wait_scalar(&scalar).expect("pinned scalar readable");
    let tol = 1e-3 * want_sum.abs().max(1.0);
    assert!((got_sum - want_sum).abs() < tol, "deferred sum {got_sum} vs reference {want_sum}");
    let got = ctx
        .wait_array(&array)
        .expect("pinned gather readable")
        .expect("data backend");
    assert_eq!(got, data, "gathered array reads the record-position snapshot");

    // Forcing released the pins: a second wait on a data backend is a
    // loud error, not a stale read.
    assert!(
        ctx.wait_scalar(&scalar).is_err(),
        "a consumed future must not read reclaimed stages silently"
    );
}

// ---------------------------------------------------------------------
// Incremental flush engine properties (flow/)
// ---------------------------------------------------------------------

/// Streaming admission is pure timing: random aligned programs produce
/// bit-identical scalars and arrays under Batch, quantized Flow
/// (windows 2 and 4) and Sliding (windows 2 and 4), across all three
/// policies and both dependency systems. Small flush thresholds force
/// many threshold submits, so waves genuinely merge multiple epochs
/// and the sliding session genuinely splices mid-run.
#[test]
fn prop_flow_and_batch_bit_identical() {
    use distnumpy::flow::FlowCfg;
    use distnumpy::sched::DepsKind;

    let mut rng = Rng::new(0xF10);
    for trial in 0..12 {
        let p = 1 + (trial % 4) as u32;
        let rows = 8 + rng.below(100);
        let br = 1 + rng.below(10);
        let n_arrays = 2usize;
        #[derive(Clone, Copy)]
        enum Step {
            Ufunc(usize, usize, usize, u8),
            Sum(usize),
        }
        let n_steps = rng.range(4, 10);
        let steps: Vec<Step> = (0..n_steps)
            .map(|_| {
                if rng.chance(0.3) {
                    Step::Sum(rng.range(0, n_arrays))
                } else {
                    Step::Ufunc(
                        rng.range(0, n_arrays),
                        rng.range(0, n_arrays),
                        rng.range(0, n_arrays),
                        rng.range(0, 3) as u8,
                    )
                }
            })
            .collect();
        let data: Vec<Vec<f32>> = {
            let mut data_rng = Rng::new(0xF10D + trial as u64);
            (0..n_arrays)
                .map(|_| data_rng.fill_f32(rows as usize, -1.0, 1.0))
                .collect()
        };

        let run = |policy: Policy, deps: DepsKind, flow: FlowCfg| -> (Vec<f64>, Vec<Vec<f32>>) {
            let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
            cfg.deps = deps;
            cfg.flow = flow;
            cfg.flush_threshold = 6; // many threshold submits per run
            // ISSUE 7: run the hazard oracle on every drained wave of
            // every config — soundness holds at each flush boundary,
            // and the bit-identity assertions below double as proof
            // that verification is timing-invisible.
            cfg.verify_deps = true;
            let mut ctx = Context::new(
                cfg,
                policy,
                Box::new(NativeBackend::new(ClusterStore::new(p))),
            );
            let views: Vec<_> = data.iter().map(|d| ctx.array(&[rows], br, d)).collect();
            let mut pending = Vec::new();
            let mut sums = Vec::new();
            for s in &steps {
                match *s {
                    Step::Ufunc(o, a, b, k) => {
                        let kernel = match k {
                            0 => Kernel::Add,
                            1 => Kernel::Mul,
                            _ => Kernel::Axpy(0.25),
                        };
                        ctx.ufunc(kernel, &views[o], &[&views[a], &views[b]]);
                    }
                    Step::Sum(a) => pending.push(ctx.sum_deferred(&views[a])),
                }
            }
            for f in pending {
                sums.push(ctx.wait_scalar(&f).unwrap_or_else(|e| {
                    panic!("{policy:?}/{deps:?}/{flow:?} trial {trial}: {e}")
                }));
            }
            ctx.flush();
            assert!(
                ctx.error.is_none(),
                "{policy:?}/{deps:?}/{flow:?} trial {trial}: aligned program must complete"
            );
            let gathers = views
                .iter()
                .map(|v| {
                    ctx.backend
                        .gather(ctx.reg.layout(v.base))
                        .expect("data backend")
                })
                .collect();
            (sums, gathers)
        };

        let want = run(Policy::LatencyHiding, DepsKind::Heuristic, FlowCfg::default());
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            for deps in [DepsKind::Heuristic, DepsKind::Dag] {
                for flow in [
                    FlowCfg::default(),
                    FlowCfg::flow(2),
                    FlowCfg::flow(4),
                    FlowCfg::sliding(2),
                    FlowCfg::sliding(4),
                ] {
                    let got = run(policy, deps, flow);
                    assert_eq!(
                        got.0, want.0,
                        "trial {trial} {policy:?}/{deps:?}/{flow:?}: scalars diverge"
                    );
                    assert_eq!(
                        got.1, want.1,
                        "trial {trial} {policy:?}/{deps:?}/{flow:?}: arrays diverge"
                    );
                }
            }
        }
    }
}

/// The sharded engine is pure host-side mechanics: for every policy ×
/// flow mode (batch, quantized flow, sliding), running the same
/// halo-exchanging stencil program under `--workers {2, 4}` renders the
/// exact same run-report JSON as the serial reference engine
/// (`--workers 1`), and on the native data backend the final grid and
/// convergence deltas are bit-identical. The hazard oracle stays on
/// throughout, so the sharded pop order is also re-verified race-free
/// at every drain.
#[test]
fn prop_sharded_workers_bit_identical() {
    use distnumpy::flow::FlowCfg;

    const ROWS: u64 = 32;
    const COLS: u64 = 8;
    const ITERS: u32 = 4;
    let p = 4u32;

    // One-row blocks: 32 row-actors over 4 ranks, up/down halo traffic
    // on every interior row, deltas fanning into rank 0 — real
    // transfers on every path the engines schedule.
    fn record(ctx: &mut Context) -> (Vec<distnumpy::lazy::ScalarFuture>, ViewSpec) {
        let g = ctx.zeros(&[ROWS, COLS], 1);
        let work = ctx.zeros(&[ROWS - 2, COLS - 2], 1);
        let c = g.slice(&[(1, ROWS - 1), (1, COLS - 1)]);
        let u = g.slice(&[(0, ROWS - 2), (1, COLS - 1)]);
        let d = g.slice(&[(2, ROWS), (1, COLS - 1)]);
        let l = g.slice(&[(1, ROWS - 1), (0, COLS - 2)]);
        let r = g.slice(&[(1, ROWS - 1), (2, COLS)]);
        let mut deltas = Vec::new();
        for it in 0..ITERS {
            ctx.ufunc(Kernel::Stencil5, &work, &[&c, &u, &d, &l, &r]);
            if it % 2 == 0 {
                deltas.push(ctx.sum_absdiff_deferred(&c, &work));
            }
            ctx.copy(&c, &work);
        }
        ctx.flush();
        (deltas, g)
    }

    let report = |policy: Policy, flow: FlowCfg, workers: usize| -> String {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
        cfg.workers = workers;
        cfg.flow = flow;
        cfg.flush_threshold = 16; // several threshold submits per run
        cfg.verify_deps = true;
        let mut ctx = Context::sim(cfg, policy);
        let _ = record(&mut ctx);
        ctx.finish()
            .unwrap_or_else(|e| panic!("{policy:?}/{flow:?}/workers={workers}: {e}"))
            .to_json()
            .render()
    };

    for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
        for flow in [FlowCfg::default(), FlowCfg::flow(2), FlowCfg::sliding(2)] {
            let want = report(policy, flow, 1);
            for workers in [2usize, 4] {
                assert_eq!(
                    report(policy, flow, workers),
                    want,
                    "{policy:?}/{flow:?}: workers={workers} diverged from serial"
                );
            }
        }
    }

    // Real numerics: the data backend sees the same grid and the same
    // resolved deltas whichever engine drove it.
    let data_run = |workers: usize| -> (Vec<f64>, Vec<f32>) {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
        cfg.workers = workers;
        cfg.verify_deps = true;
        let mut ctx = Context::new(
            cfg,
            Policy::LatencyHiding,
            Box::new(NativeBackend::new(ClusterStore::new(p))),
        );
        let (futures, g) = record(&mut ctx);
        let deltas = futures
            .iter()
            .map(|f| ctx.wait_scalar(f).expect("delta resolves"))
            .collect();
        let grid = ctx
            .gather(g.base)
            .expect("no deadlock")
            .expect("data backend");
        (deltas, grid)
    };
    let want = data_run(1);
    for workers in [2usize, 4] {
        assert_eq!(data_run(workers), want, "workers={workers}: numerics diverged");
    }
}

/// Regression: a future forced while its producing epoch is still *in
/// flight* — submitted into the flow window, not yet executed — settles
/// correctly: the force drains the window, reads the right value, and
/// the record-position snapshot semantics survive later overwrites that
/// were part of the same drained wave.
#[test]
fn flow_future_forced_against_in_flight_epoch_settles() {
    use distnumpy::flow::FlowCfg;

    let p = 2u32;
    let rows = 24u64;
    let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
    cfg.flow = FlowCfg::flow(8); // wide window: submits stay in flight
    let mut ctx = Context::new(
        cfg,
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let mut rng = Rng::new(0xF1F);
    let data = rng.fill_f32(rows as usize, -1.0, 1.0);
    let x = ctx.array(&[rows], 3, &data);
    let want_sum: f64 = data.iter().map(|&v| v as f64).sum();

    let scalar = ctx.sum_deferred(&x);
    let array = ctx.gather_deferred(x.base);
    ctx.submit();
    assert!(ctx.flow.pending() > 0, "the futures' epoch is in flight");

    // A second in-flight epoch overwrites the source *after* the
    // futures' record position — still nothing has executed.
    ctx.ufunc(Kernel::Scale(2.0), &x, &[&x]);
    ctx.submit();
    assert!(ctx.flow.pending() > 0, "both epochs in flight");
    assert_eq!(ctx.state.ops_executed, 0, "nothing executed yet");

    let got_sum = ctx.wait_scalar(&scalar).expect("in-flight scalar settles");
    assert_eq!(ctx.flow.pending(), 0, "forcing drained the window");
    let tol = 1e-3 * want_sum.abs().max(1.0);
    assert!(
        (got_sum - want_sum).abs() < tol,
        "deferred sum {got_sum} vs reference {want_sum}"
    );
    let got = ctx
        .wait_array(&array)
        .expect("in-flight gather settles")
        .expect("data backend");
    assert_eq!(
        got, data,
        "record-position snapshot despite the same-wave overwrite"
    );
    // And the overwrite itself executed: the base now holds 2·data.
    let now = ctx.backend.gather(ctx.reg.layout(x.base)).expect("data");
    let want_now: Vec<f32> = data.iter().map(|v| v * 2.0).collect();
    assert_eq!(now, want_now, "the overwriting epoch also executed");
}

/// Regression (PR 5): submitting into a *quiescent-but-unfinished*
/// sliding session — the previous epoch's events drained or still
/// outstanding, every rank idle — must wake the live event loop rather
/// than stranding the new epoch (which would surface as a deadlock at
/// the forced read). The numerics must match the Batch reference bit
/// for bit.
#[test]
fn sliding_inject_wakes_quiescent_session() {
    use distnumpy::flow::FlowCfg;

    let p = 2u32;
    let rows = 24u64;
    let run = |flow: FlowCfg| -> (f64, Vec<f32>) {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
        cfg.flow = flow;
        let mut ctx = Context::new(
            cfg,
            Policy::LatencyHiding,
            Box::new(NativeBackend::new(ClusterStore::new(p))),
        );
        let mut rng = Rng::new(0x51D);
        let data = rng.fill_f32(rows as usize, -1.0, 1.0);
        let x = ctx.array(&[rows], 3, &data);
        // Epoch 1: a stencil with real transfers; submitted alone, the
        // sliding session quiesces with the epoch's transfer tail the
        // only thing in flight.
        ctx.ufunc(
            Kernel::Add,
            &x.slice(&[(1, rows - 1)]),
            &[&x.slice(&[(2, rows)]), &x.slice(&[(0, rows - 2)])],
        );
        ctx.submit();
        // Epoch 2 splices into that quiescent session...
        ctx.ufunc(Kernel::Scale(2.0), &x, &[&x]);
        ctx.submit();
        // ...and epoch 3 (the reduce) rides the forced read.
        let s = ctx
            .sum(&x)
            .expect("a quiescent sliding session must wake, not strand epochs");
        let grid = ctx
            .backend
            .gather(ctx.reg.layout(x.base))
            .expect("data backend");
        (s, grid)
    };
    let (batch_sum, batch_grid) = run(FlowCfg::default());
    for window in [1usize, 2, 8] {
        let (s, grid) = run(FlowCfg::sliding(window));
        assert_eq!(s, batch_sum, "w={window}: scalars diverge");
        assert_eq!(grid, batch_grid, "w={window}: grids diverge");
    }
}

// ---------------------------------------------------------------------
// Lazy-evaluation context properties
// ---------------------------------------------------------------------

/// Random programs through the full Context (recording, flush triggers,
/// threshold) complete and flush deterministically.
#[test]
fn prop_context_flush_thresholds() {
    let mut rng = Rng::new(0xF1A5);
    for _ in 0..30 {
        let p = 1 + rng.below(4) as u32;
        let threshold = 4 + rng.below(64) as usize;
        let mut ctx = Context::sim(SchedCfg::new(MachineSpec::tiny(), p), Policy::LatencyHiding);
        ctx.flush_threshold = threshold;
        let rows = 32 + rng.below(64);
        let br = 1 + rng.below(8);
        let x = ctx.zeros(&[rows], br);
        let y = ctx.zeros(&[rows], br);
        for _ in 0..rng.range(1, 20) {
            ctx.add(&y.clone(), &x, &y);
            assert!(
                ctx.builder.n_recorded() < threshold,
                "threshold flush must keep the batch below the limit"
            );
        }
        let rep = ctx.finish().unwrap();
        assert!(rep.ops_executed > 0);
    }
}
