//! Integration tests for the per-epoch run ledger and the `distnumpy
//! diff` regression explainer (ISSUE 9).
//!
//! The load-bearing claims, checked end-to-end through real app runs:
//!
//! * **Reconciliation** — the ledger is not a parallel estimate but
//!   the *same* accounting the aggregate [`RunReport`] scalars and the
//!   PR-8 histograms come from: per-cause row sums match the per-cause
//!   histogram sums, the non-admission rows sum to the per-rank `wait`
//!   vector, counters match `n_messages` / bytes / `ops_executed`, and
//!   the epoch advances plus the residual partition the makespan —
//!   across all three scheduling policies and all flow modes.
//! * **Self-diff is zero** — diffing a run JSON against itself
//!   attributes exactly nothing: no diverging epochs, zero attributed
//!   advance, zero residual delta, coverage 1.0 by convention.
//! * **A constructed regression is explained** — the flow-ablation
//!   workload (pipelined Jacobi, P = 16) diffed sliding:4 → Batch
//!   yields named epoch deltas whose sum (plus the residual delta)
//!   covers the makespan delta, and a cause-shift table whose
//!   admission row equals the `wait_at_admission` scalars exactly.
//! * **Zero-cost** — the ledger is always on and records pure
//!   bookkeeping: the simulated timeline is bit-identical whether or
//!   not the (optional) tracing layer rides along.

use distnumpy::analyze::diff::diff_runs;
use distnumpy::apps::{record_jacobi_with, AppId, AppParams, Convergence};
use distnumpy::cluster::MachineSpec;
use distnumpy::flow::FlowCfg;
use distnumpy::harness::{run_json, run_once_traced};
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::trace::WaitCause;
use distnumpy::util::json::Json;

fn close(a: f64, b: f64, label: &str) {
    let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
    assert!((a - b).abs() <= tol, "{label}: {a} vs {b}");
}

fn cfg(p: u32, flow: FlowCfg) -> SchedCfg {
    let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
    cfg.flow = flow;
    cfg
}

/// Check every ledger ↔ report identity the diff engine leans on.
fn reconcile(rep: &RunReport, label: &str) {
    let l = &rep.ledger;
    assert!(!l.rows.is_empty(), "{label}: a completed run must ledger its epochs");

    // Per-cause row sums = per-cause histogram sums.
    for (i, name) in WaitCause::LABELS.iter().enumerate() {
        let rows: f64 = l.rows.iter().map(|r| r.wait[i]).sum();
        close(rows, rep.dist.wait_by_cause[i].sum(), &format!("{label}: wait[{name}]"));
    }

    // Non-admission rows = the per-rank wait vector; the admission rows
    // = the separately-reported admission stall.
    let rank_rows: f64 = l.rows.iter().map(|r| r.wait_rank()).sum();
    close(rank_rows, rep.wait.iter().sum(), &format!("{label}: rank wait"));
    let adm = WaitCause::Admission.index();
    let adm_rows: f64 = l.rows.iter().map(|r| r.wait[adm]).sum();
    close(adm_rows, rep.wait_at_admission, &format!("{label}: admission wait"));

    // Counters.
    let msgs: u64 = l.rows.iter().map(|r| r.msgs).sum();
    assert_eq!(msgs, rep.n_messages, "{label}: msgs");
    let bytes: u64 = l.rows.iter().map(|r| r.bytes).sum();
    assert_eq!(bytes, rep.bytes_inter + rep.bytes_intra, "{label}: bytes");
    let ops: u64 = l.rows.iter().map(|r| r.ops).sum();
    assert_eq!(ops, rep.ops_executed, "{label}: ops");

    // The advances telescope to the high-water mark, and together with
    // the residual they partition the makespan.
    let advance: f64 = l.rows.iter().map(|r| r.advance).sum();
    close(advance, l.clock_hi(), &format!("{label}: advance telescopes"));
    assert!(
        l.clock_hi() <= rep.makespan + 1e-9 * rep.makespan.max(1.0),
        "{label}: retirements cannot outrun the makespan"
    );
    close(
        advance + l.residual(rep.makespan),
        rep.makespan,
        &format!("{label}: advance + residual = makespan"),
    );
}

#[test]
fn ledger_reconciles_for_lh_and_blocking_across_flow_modes() {
    let params = AppParams { scale: 0.25, iters: 2 };
    let modes = [
        ("batch", FlowCfg::default()),
        ("flow2", FlowCfg::flow(2)),
        ("sliding4", FlowCfg::sliding(4)),
    ];
    for (name, flow) in modes {
        let (rep, _, _) = run_once_traced(
            AppId::JacobiStencil,
            Policy::LatencyHiding,
            &params,
            cfg(16, flow),
        );
        assert!(rep.n_messages > 0, "lh/{name}: stencil at P=16 must communicate");
        reconcile(&rep, &format!("lh/{name}/p16"));
    }

    let params = AppParams { scale: 0.1, iters: 2 };
    let (rep, _, _) = run_once_traced(
        AppId::JacobiStencil,
        Policy::Blocking,
        &params,
        cfg(8, FlowCfg::default()),
    );
    assert!(rep.n_messages > 0);
    reconcile(&rep, "blocking/batch/p8");
}

/// The naive strawman deadlocks on multi-iteration stencils, so it gets
/// a program it completes (same shape as the tracing tests).
#[test]
fn ledger_reconciles_under_naive() {
    let mut ctx = Context::sim(cfg(4, FlowCfg::default()), Policy::Naive);
    let x = ctx.zeros(&[64], 4);
    let y = ctx.zeros(&[64], 4);
    ctx.add(&y, &x, &x);
    ctx.sum(&x).expect("flat reduce completes under naive");
    let rep = ctx.finish().expect("naive run completes");
    assert!(rep.ops_executed > 0);
    reconcile(&rep, "naive/add+sum/p4");
}

#[test]
fn self_diff_attributes_exactly_zero() {
    let (doc, rep, _) = run_json(
        AppId::JacobiStencil,
        Policy::LatencyHiding,
        &AppParams { scale: 0.1, iters: 2 },
        cfg(8, FlowCfg::sliding(4)),
    );
    // Round-trip through text, exactly as the CLI consumes run JSONs.
    let parsed = Json::parse(&doc.render()).expect("run JSON parses back");
    let d = diff_runs(&parsed, &parsed).expect("self-diff aligns");
    assert!(d.aligned, "a ledgered run diffs against itself epoch-by-epoch");
    assert_eq!(d.epochs.len(), 0, "no epoch diverges from itself");
    assert_eq!(d.attributed, 0.0, "attributed advance is exactly zero");
    assert_eq!(d.d_residual, 0.0, "residual delta is exactly zero");
    assert_eq!(d.d_makespan(), 0.0);
    assert_eq!(d.coverage(), 1.0, "zero delta is fully covered by convention");
    assert!(d.scalars.is_empty(), "no scalar moves against itself");
    for c in &d.causes {
        assert_eq!(c.delta(), 0.0, "cause {} must not shift", c.cause);
    }
    close(rep.makespan, d.base_makespan, "makespan survives the round-trip");
}

/// The flow ablation's constructed regression (`benches/ablation_flow`):
/// pipelined Jacobi at P = 16 under sliding:4 (base) vs stop-the-world
/// Batch (new). The diff must attribute the makespan delta to named
/// epochs with near-total coverage, and its cause table must reproduce
/// the admission scalars exactly.
#[test]
fn constructed_regression_is_attributed_to_epochs_and_causes() {
    let params = AppParams { scale: 0.25, iters: 8 };
    let run = |flow: FlowCfg| -> RunReport {
        let mut cfg = SchedCfg::new(MachineSpec::paper(), 16);
        cfg.flow = flow;
        cfg.flush_threshold = 2_000;
        let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
        record_jacobi_with(&mut ctx, &params, Convergence::Pipelined { every: 4 });
        ctx.finish().expect("jacobi completes under latency-hiding")
    };
    let base = run(FlowCfg::sliding(4)); // the fast configuration
    let new = run(FlowCfg::default()); // the regressed (Batch) one
    reconcile(&base, "regression/base/sliding4");
    reconcile(&new, "regression/new/batch");
    assert_eq!(new.wait_at_admission, 0.0, "Batch admits without a gate");
    assert!(
        new.wait.iter().sum::<f64>() > base.wait.iter().sum::<f64>(),
        "the ablation's asserted fact: Batch waits strictly more"
    );

    let base_doc = Json::parse(&base.to_json().render()).unwrap();
    let new_doc = Json::parse(&new.to_json().render()).unwrap();
    let d = diff_runs(&base_doc, &new_doc).expect("two ledgered runs align");
    assert!(d.aligned);

    // The epoch rows partition each makespan, so the deltas partition
    // the makespan delta: attributed + residual delta = Δmakespan.
    let dm = d.d_makespan();
    close(dm, new.makespan - base.makespan, "Δmakespan survives the round-trip");
    close(d.attributed + d.d_residual, dm, "epoch deltas partition Δmakespan");
    if dm.abs() > 1e-9 {
        assert!(
            d.coverage() >= 0.9,
            "attribution must cover ≥90% of the delta, got {:.4}",
            d.coverage()
        );
    }
    assert!(!d.epochs.is_empty(), "a real regression names diverging epochs");
    let bound = base.ledger.rows.len().max(new.ledger.rows.len());
    assert!(bound <= base.n_epochs.max(new.n_epochs) as usize + 1,
        "ledger rows {} vs {} epochs", bound, base.n_epochs.max(new.n_epochs));
    for e in &d.epochs {
        assert!(e.epoch < bound, "epoch {} out of range {bound}", e.epoch);
    }

    // Cause table = the scalar accounting, exactly.
    let shift = |name: &str| {
        d.causes
            .iter()
            .find(|c| c.cause == name)
            .map(|c| c.delta())
            .unwrap_or_else(|| panic!("cause table missing {name}"))
    };
    close(
        shift("admission"),
        new.wait_at_admission - base.wait_at_admission,
        "admission shift = the wait_at_admission scalars",
    );
    if base.wait_at_admission > 0.0 {
        assert!(
            shift("admission") < 0.0,
            "wait leaves the admission gate when streaming is turned off"
        );
    }
    // ...and reappears in the rank-visible causes (barrier/transfer/
    // collective stalls at the stop-the-world epoch tails): the
    // non-admission shift is exactly the per-rank wait delta, strictly
    // positive by the flow ablation's asserted fact.
    let rank_shift: f64 = d
        .causes
        .iter()
        .filter(|c| c.cause != "admission")
        .map(|c| c.delta())
        .sum();
    close(
        rank_shift,
        new.wait.iter().sum::<f64>() - base.wait.iter().sum::<f64>(),
        "non-admission shift = per-rank wait delta",
    );
    assert!(rank_shift > 0.0, "wait moves into the rank-visible causes");
    let (wb, wn) = d.wait_totals();
    close(
        wn - wb,
        (new.wait.iter().sum::<f64>() + new.wait_at_admission)
            - (base.wait.iter().sum::<f64>() + base.wait_at_admission),
        "total wait shift matches the report vectors",
    );

    // The renders carry the attribution.
    let text = d.render_text();
    assert!(text.contains("differential run analysis"), "{text}");
    assert!(text.contains("epoch attribution"), "{text}");
    assert!(text.contains("cause shift:"), "{text}");
    let json = d.to_json().render();
    assert!(json.contains("\"aligned\":true"), "{json}");
    assert!(json.contains("\"epochs\":["), "{json}");
}

/// The ledger must never perturb the simulated timeline: it is pure
/// bookkeeping, always on, and (like the PR-8 histograms) bit-identical
/// whether or not the optional tracing layer records alongside it.
#[test]
fn ledger_is_bitwise_invisible_to_the_timeline() {
    let params = AppParams { scale: 0.1, iters: 2 };
    let mut traced = cfg(8, FlowCfg::sliding(2));
    traced.trace.enabled = true;
    let (plain, _, _) = run_once_traced(
        AppId::JacobiStencil,
        Policy::LatencyHiding,
        &params,
        cfg(8, FlowCfg::sliding(2)),
    );
    let (with_trace, _, sink) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, traced);
    assert!(!sink.is_empty(), "the traced twin must actually record");
    assert_eq!(
        plain.makespan.to_bits(),
        with_trace.makespan.to_bits(),
        "tracing on/off must not move the clocks"
    );
    assert_eq!(
        plain.ledger.clock_hi().to_bits(),
        with_trace.ledger.clock_hi().to_bits(),
        "the ledger's high-water mark is part of the deterministic state"
    );
    assert_eq!(plain.ledger.rows.len(), with_trace.ledger.rows.len());
    for (a, b) in plain.ledger.rows.iter().zip(&with_trace.ledger.rows) {
        assert_eq!(a.advance.to_bits(), b.advance.to_bits());
        assert_eq!(a.msgs, b.msgs);
        assert_eq!(a.ops, b.ops);
    }
}
