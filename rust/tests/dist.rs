//! Integration tests for the distribution-metrics layer (ISSUE 8).
//!
//! The histograms are populated at the *same* choke points the trace
//! sink and the aggregate counters use, so they must reconcile exactly
//! (to fp tolerance) with the scalar report — across all three
//! scheduling policies — and recording them must never perturb the
//! simulated clocks.

use distnumpy::apps::{AppId, AppParams};
use distnumpy::cluster::MachineSpec;
use distnumpy::flow::FlowCfg;
use distnumpy::harness::run_once_traced;
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg, SyncMode};
use distnumpy::trace::WaitCause;

fn cfg(p: u32) -> SchedCfg {
    SchedCfg::new(MachineSpec::tiny(), p)
}

fn close(a: f64, b: f64, label: &str) {
    let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
    assert!((a - b).abs() <= tol, "{label}: {a} vs {b}");
}

/// Check every histogram-vs-scalar invariant on one finished report.
fn reconcile(rep: &RunReport, label: &str) {
    let d = &rep.dist;
    let adm = WaitCause::Admission.index();
    let sum_at = |i: usize| d.wait_by_cause[i].sum();

    // Per-cause totals, minus the off-rank admission gate, must equal
    // the per-rank wait vector they were charged alongside.
    let rank_wait: f64 = rep.wait.iter().sum();
    let cause_wait: f64 = (0..WaitCause::N).filter(|&i| i != adm).map(sum_at).sum();
    close(cause_wait, rank_wait, &format!("{label}: causes vs wait vector"));
    close(d.wait_all().sum(), rank_wait, &format!("{label}: wait_all vs wait vector"));

    // The sync/admission buckets match their dedicated counters.
    close(
        sum_at(WaitCause::Barrier.index()),
        rep.wait_at_barrier,
        &format!("{label}: barrier bucket"),
    );
    close(
        sum_at(WaitCause::Cone.index()) + sum_at(WaitCause::Collective.index()),
        rep.wait_at_cone,
        &format!("{label}: cone+collective bucket"),
    );
    close(
        sum_at(adm),
        rep.wait_at_admission,
        &format!("{label}: admission bucket"),
    );

    // Every posted message is sized exactly once.
    assert_eq!(
        d.msg_bytes.n(),
        rep.n_messages,
        "{label}: msg_bytes count vs n_messages"
    );

    // The per-epoch series is a partition of the same rank-charged wait.
    let epoch_sum: f64 = d.epoch_wait.iter().sum();
    close(epoch_sum, rank_wait, &format!("{label}: epoch series vs wait vector"));

    // Exact moments are internally consistent.
    for (i, h) in d.wait_by_cause.iter().enumerate() {
        if h.n() > 0 {
            assert!(
                h.min() <= h.p50() && h.p50() <= h.max(),
                "{label}: cause {i} quantiles inside [min, max]"
            );
        }
    }
}

#[test]
fn histograms_reconcile_under_latency_hiding() {
    let params = AppParams {
        scale: 0.25,
        iters: 2,
    };
    let (rep, _, _) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, cfg(16));
    assert!(rep.n_messages > 0, "stencil at P=16 must communicate");
    assert!(rep.dist.wait_all().n() > 0, "waits must be recorded");
    reconcile(&rep, "lh/jacobi_stencil/p16");
}

#[test]
fn histograms_reconcile_under_blocking() {
    let params = AppParams {
        scale: 0.1,
        iters: 2,
    };
    let (rep, _, _) =
        run_once_traced(AppId::JacobiStencil, Policy::Blocking, &params, cfg(8));
    assert!(rep.n_messages > 0);
    reconcile(&rep, "blocking/jacobi_stencil/p8");
}

/// The naive strawman deadlocks on multi-iteration stencils, so it gets
/// a program it completes (same shape as the tracing test).
#[test]
fn histograms_reconcile_under_naive() {
    let mut ctx = Context::sim(cfg(4), Policy::Naive);
    let x = ctx.zeros(&[64], 4);
    let y = ctx.zeros(&[64], 4);
    ctx.add(&y, &x, &x);
    ctx.sum(&x).expect("flat reduce completes under naive");
    let (rep, _) = ctx.finish_traced().expect("naive run completes");
    assert!(rep.ops_executed > 0);
    reconcile(&rep, "naive/add+sum/p4");
}

/// Sync modes and streaming admission steer wait into different cause
/// histograms; each configuration must still reconcile.
#[test]
fn histograms_reconcile_across_sync_and_flow_modes() {
    let params = AppParams {
        scale: 0.1,
        iters: 3,
    };
    let mut barrier_cfg = cfg(4);
    barrier_cfg.sync = SyncMode::Barrier;
    let (rep, _, _) = run_once_traced(AppId::Jacobi, Policy::LatencyHiding, &params, barrier_cfg);
    assert!(rep.wait_at_barrier > 0.0);
    assert!(
        rep.dist.wait_by_cause[WaitCause::Barrier.index()].n() > 0,
        "barrier waits must land in the barrier histogram"
    );
    reconcile(&rep, "barrier/jacobi/p4");

    let params = AppParams {
        scale: 0.25,
        iters: 3,
    };
    let mut flow_cfg = cfg(8);
    flow_cfg.flow = FlowCfg::sliding_auto();
    flow_cfg.flush_threshold = 32;
    let (rep, _, _) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, flow_cfg);
    assert!(rep.n_epochs > 1, "threshold flushes must split epochs");
    // One cell per epoch up to the last epoch that waited at all.
    assert!(!rep.dist.epoch_wait.is_empty(), "streamed run must wait somewhere");
    assert!(
        rep.dist.epoch_wait.len() as u64 <= rep.n_epochs,
        "epoch-wait series ({}) cannot outrun admitted epochs ({})",
        rep.dist.epoch_wait.len(),
        rep.n_epochs
    );
    reconcile(&rep, "sliding/jacobi_stencil/p8");

    // Admission-gate latency histogram mirrors the admission log. (The
    // hist-mean == scalar-mean identity is asserted per run at the unit
    // level in `flow::frontier`; absorbed reports op-weight the scalar,
    // so here the distribution must exist and be well-formed.)
    let h = &rep.admission_hist;
    assert!(h.n() > 0, "streamed epochs must log admission latency");
    assert!(h.min() >= 0.0 && h.min() <= h.max(), "latency range well-formed");
    assert!(h.mean().is_finite());
}

/// Zero-cost always-on: the distribution layer records on every run,
/// and the host profiler (off or on) never touches virtual time.
#[test]
fn profiler_toggle_is_bit_identical() {
    let params = AppParams {
        scale: 0.1,
        iters: 2,
    };
    let run = |profile: bool| {
        let mut c = cfg(8);
        c.profile.enabled = profile;
        let (rep, _, _) =
            run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, c);
        rep
    };
    let off = run(false);
    let on = run(true);

    assert!(off.host.is_none(), "profiler off leaves no host section");
    let host = on.host.as_ref().expect("profiler on reports host timings");
    assert!(host.events() > 0, "retirements must be counted");
    assert_eq!(host.events(), on.ops_executed, "one event per retired op");

    assert_eq!(off.makespan.to_bits(), on.makespan.to_bits(), "makespan");
    assert_eq!(off.ops_executed, on.ops_executed);
    assert_eq!(off.n_messages, on.n_messages);
    for (r, (a, b)) in off.wait.iter().zip(&on.wait).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "wait[{r}]");
    }
    // The distributions themselves are identical too: same choke
    // points, same virtual durations.
    assert_eq!(off.dist.wait_all().n(), on.dist.wait_all().n());
    assert_eq!(
        off.dist.wait_all().sum().to_bits(),
        on.dist.wait_all().sum().to_bits()
    );
    assert_eq!(off.dist.msg_bytes.n(), on.dist.msg_bytes.n());
}

/// The run JSON carries the new sections end-to-end.
#[test]
fn report_json_carries_dist_and_host_sections() {
    let params = AppParams {
        scale: 0.1,
        iters: 2,
    };
    let mut c = cfg(8);
    c.profile.enabled = true;
    let (rep, _, _) =
        run_once_traced(AppId::JacobiStencil, Policy::LatencyHiding, &params, c);
    let s = rep.to_json().render();
    for key in [
        "\"dist\"",
        "\"wait\"",
        "\"msg_bytes\"",
        "\"admission_latency\"",
        "\"epoch_wait\"",
        "\"wait_p99\"",
        "\"host\"",
        "\"events_per_sec\"",
        "\"dep_edges\"",
        "\"trace_dropped\"",
    ] {
        assert!(s.contains(key), "run JSON missing {key}: {s}");
    }
}
