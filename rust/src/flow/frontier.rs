//! The cross-epoch ready frontier: the continuous admission log and the
//! wave merge.
//!
//! Before the flow engine, every epoch's ready set lived and died with
//! `begin_epoch`: operation ids restarted at zero and the dependency
//! system saw one batch at a time, so an epoch boundary was a hard wall
//! in the ready frontier. The [`AdmissionLog`] replaces those per-epoch
//! frontiers with **one continuous record** of every submitted epoch —
//! when its recording started and finished (its *admission time*) and
//! when its last operation retired — which is what the engine's window
//! gate consults: recording of epoch *k* may not begin before epoch
//! *k − window* retired (bounded in-flight graph, Eijkhout's wave
//! transformation).
//!
//! [`merge`] turns a run of submitted batches into one [`Wave`]: ids
//! and §5.3 groups are renumbered into a single stream (tags are
//! already run-unique), so both dependency systems ingest the merged
//! wave exactly like a batch — cross-epoch conflicts become ordinary
//! conflict edges, and an operation becomes ready the moment its
//! predecessors complete *regardless of which epoch recorded it*. Each
//! operation carries the admission time of its epoch; the schedulers
//! gate execution on it ([`crate::sched::ExecState::gate_admission`]).

use crate::metrics::hist::Hist;
use crate::types::{Rank, VTime};
use crate::ufunc::OpNode;

/// One submitted epoch in the continuous admission log.
#[derive(Clone, Copy, Debug)]
pub struct EpochEntry {
    /// When the (replicated) recorder began recording this epoch.
    /// `NaN` for Batch-mode epochs, whose recording is charged on the
    /// rank clocks instead.
    pub record_start: VTime,
    /// When recording finished — the epoch's admission time: no
    /// operation of the epoch may execute earlier. `NaN` in Batch mode.
    pub record_done: VTime,
    /// When the epoch's last operation retired; `NaN` until the wave
    /// containing it drained.
    pub retired: VTime,
    /// Operations in the epoch (post-aggregation).
    pub n_ops: usize,
    /// Admission-pipeline depth the moment this epoch was logged (this
    /// epoch included) — the ledger's per-epoch in-flight annotation.
    pub in_flight_at_admit: u64,
    /// The epoch's streamed admission latency (what `latency_hist`
    /// records); `NaN` for Batch-mode epochs.
    pub latency: VTime,
}

/// The continuous admission log: one entry per flush epoch of the whole
/// run, either mode. Lives in [`crate::sched::ExecState`] — it is
/// execution state, shared by the engine (window gating, adaptive
/// window steering) and the metrics.
#[derive(Default)]
pub struct AdmissionLog {
    pub epochs: Vec<EpochEntry>,
    /// Operations admitted over the whole run.
    pub admitted_ops: u64,
    /// Epochs submitted whose retirement has not yet been attributed.
    pub in_flight: u64,
    /// High-water mark of `in_flight` — how deep the admission pipeline
    /// actually ran (≤ the window under quantized Flow; the sliding
    /// mode's bound is the recording gate alone).
    pub max_in_flight: u64,
    /// Adaptive-window decisions (`FlowWindow::Auto`): `(epoch index at
    /// the decision, new window)`. Empty under fixed windows.
    pub window_trace: Vec<(u64, u64)>,
    /// Distribution of the streamed per-epoch admission latencies —
    /// the same values `mean_admission_latency` averages, so a stalled
    /// epoch shows up in the tail instead of vanishing into the mean.
    pub latency_hist: Hist,
    // -- cached aggregates, maintained by `submitted` so the per-flush
    // -- report snapshot stays O(1) instead of rescanning the log --
    /// `record_done` of the most recent *streamed* epoch (recording
    /// priced on the recorder clock); 0.0 when nothing streamed.
    last_record_done: VTime,
    /// Running sum of streamed per-epoch admission latencies.
    latency_total: VTime,
    /// Streamed epochs counted into `latency_total`.
    latency_n: u64,
}

impl AdmissionLog {
    /// Log one submitted epoch; returns its index.
    pub fn submitted(&mut self, record_start: VTime, record_done: VTime, n_ops: usize) -> usize {
        let mut latency = f64::NAN;
        if record_done.is_finite() {
            // Streamed epoch: fold it into the O(1) report aggregates.
            latency = record_done - self.last_record_done;
            self.latency_total += latency;
            self.latency_n += 1;
            self.latency_hist.record(latency);
            self.last_record_done = record_done;
        }
        self.admitted_ops += n_ops as u64;
        self.in_flight += 1;
        self.max_in_flight = self.max_in_flight.max(self.in_flight);
        self.epochs.push(EpochEntry {
            record_start,
            record_done,
            retired: f64::NAN,
            n_ops,
            in_flight_at_admit: self.in_flight,
            latency,
        });
        self.epochs.len() - 1
    }

    /// The wave drained: epoch `idx`'s last operation retired at `t`.
    pub fn retire(&mut self, idx: usize, t: VTime) {
        if let Some(e) = self.epochs.get_mut(idx) {
            if e.retired.is_nan() && t.is_finite() {
                self.in_flight = self.in_flight.saturating_sub(1);
            }
            e.retired = t;
        }
    }

    /// The recorder clock as the log saw it last: when the most recent
    /// streamed epoch finished recording (0.0 when nothing streamed —
    /// Batch epochs record on the rank clocks and log `NaN`). O(1):
    /// maintained by [`AdmissionLog::submitted`], so the per-flush
    /// report snapshot never rescans the log.
    pub fn recorder_clock(&self) -> VTime {
        self.last_record_done
    }

    /// Mean per-epoch admission latency of the streamed epochs: from
    /// the moment the recorder *could* have started an epoch (the
    /// previous streamed epoch's `record_done`) to the epoch's
    /// admission — recording cost plus any window-gate stall. 0.0 when
    /// nothing streamed. O(1) (cached aggregates).
    pub fn mean_admission_latency(&self) -> VTime {
        if self.latency_n == 0 {
            0.0
        } else {
            self.latency_total / self.latency_n as f64
        }
    }

    /// Attribute epoch `idx`'s retirement from the scheduler's
    /// retirement-log slice covering its operations: the latest finite
    /// retirement time (0.0 when nothing retired — a torn epoch must
    /// never gate later recording). The single definition shared by
    /// Batch epochs and Flow waves, so the two paths cannot drift.
    pub fn retire_from(&mut self, idx: usize, retire: &[(Rank, VTime)]) {
        let mut t: VTime = 0.0;
        for &(_, rt) in retire {
            if rt.is_finite() {
                t = t.max(rt);
            }
        }
        self.retire(idx, t);
    }

    /// Window gate for the epoch about to be recorded (index
    /// `self.epochs.len()`): recording may not begin before epoch
    /// `next − window` fully retired. An epoch whose retirement is not
    /// yet known gates on its admission time instead (conservative for
    /// memory, never for causality — the gated epoch will also be gated
    /// by its own recording chain).
    pub fn window_gate(&self, window: usize) -> VTime {
        let next = self.epochs.len();
        if window == 0 || next < window {
            return 0.0;
        }
        let e = &self.epochs[next - window];
        if e.retired.is_finite() {
            e.retired
        } else if e.record_done.is_finite() {
            e.record_done
        } else {
            0.0
        }
    }
}

/// A merged run of submitted epochs, ready for one scheduler dispatch.
pub struct Wave {
    /// The merged operation stream: ids renumbered contiguously, §5.3
    /// groups offset so later epochs' groups stay strictly after
    /// earlier ones (the blocking baseline's phasing depends on it).
    pub ops: Vec<OpNode>,
    /// Per-operation admission time (indexed by merged op id).
    pub admit: Vec<VTime>,
    /// Constituent epochs: `(admission-log index, id_lo, id_hi)` — the
    /// merged-id range `[id_lo, id_hi)` each epoch contributed, used to
    /// attribute retirement times back to the log.
    pub epochs: Vec<(usize, usize, usize)>,
}

/// Incremental id/group renumbering for the *sliding* session
/// ([`crate::flow::FlowMode::Sliding`]): where [`merge`] renumbers a
/// whole wave at once, the splicer renumbers one submitted epoch at a
/// time so its ids and §5.3 groups continue a live
/// [`crate::sched::SchedSession`]'s streams — later epochs' groups stay
/// strictly after earlier ones' (the blocking baseline's phasing
/// depends on it) and ids stay contiguous (the retirement log and both
/// dependency systems index by them).
#[derive(Default)]
pub struct Splicer {
    next_id: u32,
    next_group: u32,
}

impl Splicer {
    pub fn new() -> Self {
        Splicer::default()
    }

    /// Renumber `ops` in place to continue the session's streams;
    /// returns the spliced id range `[lo, hi)`.
    pub fn splice(&mut self, ops: &mut [OpNode]) -> (usize, usize) {
        let lo = self.next_id as usize;
        let mut max_group = 0u32;
        for op in ops.iter_mut() {
            op.id = crate::types::OpId(self.next_id);
            self.next_id += 1;
            max_group = max_group.max(op.group);
            op.group += self.next_group;
        }
        if !ops.is_empty() {
            self.next_group += max_group + 1;
        }
        (lo, self.next_id as usize)
    }
}

/// Merge submitted batches into one [`Wave`]. Each element carries the
/// batch's ops, its admission-log index and its admission time.
pub fn merge(batches: Vec<(Vec<OpNode>, usize, VTime)>) -> Wave {
    let total: usize = batches.iter().map(|(ops, _, _)| ops.len()).sum();
    let mut wave = Wave {
        ops: Vec::with_capacity(total),
        admit: Vec::with_capacity(total),
        epochs: Vec::with_capacity(batches.len()),
    };
    let mut group_base = 0u32;
    for (ops, log_idx, admit_t) in batches {
        let lo = wave.ops.len();
        let mut max_group = 0u32;
        for mut op in ops {
            op.id = crate::types::OpId(wave.ops.len() as u32);
            max_group = max_group.max(op.group);
            op.group += group_base;
            wave.ops.push(op);
            wave.admit.push(admit_t);
        }
        group_base += max_group + 1;
        wave.epochs.push((log_idx, lo, wave.ops.len()));
    }
    wave
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BaseId, OpId, Rank, Tag};
    use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpPayload, Operand, Region};

    fn op(id: u32, group: u32) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(0),
            group,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::Add,
                inputs: vec![Operand::Local(Region::scalar())],
                dst: Dst::Stage(Tag(u64::MAX)),
                elems: 1,
            }),
            accesses: vec![Access::write_block(BaseId(0), 0, (0, 1))],
        }
    }

    #[test]
    fn merge_renumbers_ids_and_offsets_groups() {
        // Two batches with per-batch ids 0.. and groups 1..=2 each.
        let b0 = vec![op(0, 1), op(1, 2)];
        let b1 = vec![op(0, 1), op(1, 1), op(2, 2)];
        let wave = merge(vec![(b0, 0, 1.0), (b1, 1, 2.5)]);
        assert_eq!(wave.ops.len(), 5);
        for (i, o) in wave.ops.iter().enumerate() {
            assert_eq!(o.id, OpId(i as u32), "contiguous merged ids");
        }
        // Batch 1's groups sit strictly after batch 0's.
        let max_g0 = wave.ops[..2].iter().map(|o| o.group).max().unwrap();
        let min_g1 = wave.ops[2..].iter().map(|o| o.group).min().unwrap();
        assert!(min_g1 > max_g0, "epoch groups must not interleave");
        assert_eq!(wave.admit, vec![1.0, 1.0, 2.5, 2.5, 2.5]);
        assert_eq!(wave.epochs, vec![(0, 0, 2), (1, 2, 5)]);
    }

    #[test]
    fn window_gate_consults_retirement() {
        let mut log = AdmissionLog::default();
        assert_eq!(log.window_gate(2), 0.0, "nothing in flight yet");
        let e0 = log.submitted(0.0, 0.5, 4);
        let e1 = log.submitted(0.5, 1.0, 4);
        assert_eq!(log.window_gate(2), 0.5, "epoch 0 not retired: gate on admission");
        log.retire(e0, 7.0);
        assert_eq!(log.window_gate(2), 7.0, "window 2: gate on epoch 0's retirement");
        log.retire(e1, 9.0);
        assert_eq!(log.window_gate(1), 9.0);
        assert_eq!(log.window_gate(3), 0.0, "window wider than history: no gate");
        assert_eq!(log.admitted_ops, 8);
    }

    #[test]
    fn splicer_continues_ids_and_groups() {
        let mut s = Splicer::new();
        let mut b0 = vec![op(0, 1), op(1, 2)];
        let mut b1 = vec![op(0, 1), op(1, 1)];
        assert_eq!(s.splice(&mut b0), (0, 2));
        assert_eq!(s.splice(&mut b1), (2, 4));
        assert_eq!(b1[0].id, OpId(2), "ids continue the stream");
        let max_g0 = b0.iter().map(|o| o.group).max().unwrap();
        let min_g1 = b1.iter().map(|o| o.group).min().unwrap();
        assert!(min_g1 > max_g0, "spliced groups must not interleave");
    }

    #[test]
    fn in_flight_and_latency_tracking() {
        let mut log = AdmissionLog::default();
        let e0 = log.submitted(0.0, 0.5, 1);
        let e1 = log.submitted(0.5, 1.25, 1);
        assert_eq!(log.in_flight, 2);
        assert_eq!(log.max_in_flight, 2);
        log.retire(e0, 3.0);
        log.retire(e0, 3.0); // idempotent: no double decrement
        assert_eq!(log.in_flight, 1);
        log.retire(e1, 4.0);
        assert_eq!(log.in_flight, 0);
        assert_eq!(log.max_in_flight, 2, "peak survives retirement");
        assert_eq!(log.recorder_clock(), 1.25);
        assert!((log.mean_admission_latency() - 0.625).abs() < 1e-12);
        // The histogram sees the same per-epoch latencies the mean
        // averages: its exact sum reconciles with the O(1) aggregate.
        assert_eq!(log.latency_hist.n(), 2);
        assert!((log.latency_hist.sum() - 1.25).abs() < 1e-12);
        assert!(
            (log.latency_hist.mean() - log.mean_admission_latency()).abs() < 1e-12
        );
    }

    #[test]
    fn batch_mode_entries_keep_the_log_continuous() {
        let mut log = AdmissionLog::default();
        let i = log.submitted(f64::NAN, f64::NAN, 3);
        log.retire(i, 2.0);
        assert_eq!(log.window_gate(1), 2.0, "retirement known despite NaN recording");
    }
}
