//! The streaming admission engine: bounded window of in-flight epochs.
//!
//! [`FlowEngine`] sits between the lazy context's threshold trigger and
//! the schedulers. `submit` is non-blocking: the batch is aggregated
//! (per epoch — aggregation never crosses a flush boundary, §3), priced
//! on the recorder clock ([`super::overlap`]) and logged in the
//! continuous [`super::frontier::AdmissionLog`]. What happens next is
//! the mode's choice:
//!
//! * **Quantized Flow** queues the batch; once
//!   [`crate::flow::FlowCfg::window`] epochs are in flight the queue
//!   drains — the epochs merge into one [`super::frontier::Wave`] and
//!   execute under per-epoch admission gates through one
//!   [`crate::sched::SchedSession`]. Epoch *k+W* therefore waits at
//!   the wave boundary even when epoch *k* retired mid-wave.
//! * **Sliding** keeps one session *live* across submits: each epoch
//!   is renumbered by the [`super::frontier::Splicer`] and spliced
//!   into the running event loop the moment the admission log shows
//!   epoch *k − window* retired (the engine advances the loop just far
//!   enough to learn that retirement time), so ranks idling on a wave
//!   tail pick up the next epoch's ready fragments instead of waiting
//!   for a drain. `drain` becomes "run the session to quiescence".
//!
//! The naive evaluator is fed conservatively in both streaming modes:
//! merged waves could park it on receives the per-batch stream never
//! exposes it to, so the engine's **bounded-lookahead merge** dry-runs
//! each candidate merge on a scratch timeline and admits only
//! deadlock-free prefixes — the wave splits where the becoming-ready
//! order would deadlock, instead of degrading to single-epoch waves
//! (ROADMAP "naive under waves").
//!
//! Under [`crate::flow::FlowWindow::Auto`] the engine additionally
//! steers the window from the admission log: admission stalls with
//! stage memory to spare grow it (more in-flight epochs let the
//! recorder run further ahead), live-stage pressure shrinks it.

use crate::exec::Backend;
use crate::sched::{ExecState, Policy, SchedCfg, SchedError, SchedSession};
use crate::types::VTime;
use crate::ufunc::OpNode;

use super::frontier::{self, Splicer};
use super::overlap::{record_cost, Recorder};
use super::{FlowCfg, FlowMode, FlowWindow};

/// The incremental flush engine owned by a lazy
/// [`crate::lazy::Context`].
pub struct FlowEngine {
    pub cfg: FlowCfg,
    recorder: Recorder,
    /// Submitted, not yet executed epochs (quantized Flow and the
    /// naive lookahead): `(ops, admission-log idx)`.
    queue: Vec<(Vec<OpNode>, usize)>,
    /// Sliding mode's live resumable session, if one is open.
    session: Option<SchedSession>,
    /// Renumbering state of the live session.
    splicer: Splicer,
    /// Epochs spliced into the live session whose retirement has not
    /// yet been attributed to the admission log:
    /// `(log idx, id lo, id hi)`.
    live: Vec<(usize, usize, usize)>,
    /// The effective window (fixed, or adaptively steered under
    /// [`FlowWindow::Auto`]).
    window: usize,
    /// `wait_at_admission` at the last steering decision.
    steer_mark: VTime,
}

impl FlowEngine {
    pub fn new(cfg: FlowCfg) -> Self {
        FlowEngine {
            cfg,
            recorder: Recorder::default(),
            queue: Vec::new(),
            session: None,
            splicer: Splicer::new(),
            live: Vec::new(),
            window: cfg.window.initial(),
            steer_mark: 0.0,
        }
    }

    /// Submitted epochs not yet fully retired: queued (quantized) plus
    /// spliced into the live session but still executing (sliding).
    pub fn pending(&self) -> usize {
        self.queue.len() + self.live.len()
    }

    /// The effective admission window right now (adaptively steered
    /// under [`FlowWindow::Auto`]).
    pub fn window(&self) -> usize {
        self.window
    }

    /// The recorder clock — when the last submitted epoch finished
    /// recording.
    pub fn record_clock(&self) -> VTime {
        self.recorder.clock
    }

    /// Drop everything queued and any live session (poisoned context:
    /// later batches are dropped unexecuted, exactly like Batch mode's
    /// dropped batches).
    pub fn clear(&mut self) {
        self.queue.clear();
        self.session = None;
        self.live.clear();
        self.splicer = Splicer::new();
        debug_assert_eq!(self.pending(), 0, "a cleared engine reports zero pending");
    }

    /// Non-blocking submit: price the batch on the recorder clock and
    /// hand it to the configured admission scheme.
    pub fn submit(
        &mut self,
        ops: Vec<OpNode>,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        // Profiler phase `Admit` spans the whole admission path —
        // pricing, window gating, splicing — including any nested
        // `Inject`/`Pump` work (those phases alone feed the events/sec
        // denominator, so the overlap never double-bills).
        let t0 = state.prof.start();
        let res = self.submit_inner(ops, policy, cfg, backend, state);
        state.prof.stop(crate::profile::Phase::Admit, t0);
        res
    }

    fn submit_inner(
        &mut self,
        ops: Vec<OpNode>,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        // Aggregation is a per-flush-epoch rewrite ("ready in the same
        // flush epoch"), so it runs before any merge or splice.
        let ops = if cfg.aggregation >= 2 {
            let (packed, stats) = crate::comm::aggregate(&ops, cfg.aggregation);
            state.agg_msgs += stats.packed_msgs;
            state.agg_parts += stats.packed_parts;
            packed.into_owned()
        } else {
            ops
        };
        if !self.cfg.is_flow() {
            // Defensive: the lazy context executes Batch epochs
            // directly; keep the behaviour correct if called anyway.
            return crate::sched::execute_epoch(policy, &ops, cfg, backend, state);
        }
        self.steer_window(state);
        if policy == Policy::Naive {
            return self.submit_naive(ops, policy, cfg, backend, state);
        }
        match self.cfg.mode {
            FlowMode::Flow => self.submit_quantized(ops, policy, cfg, backend, state),
            FlowMode::Sliding => self.submit_sliding(ops, policy, cfg, backend, state),
            FlowMode::Batch => unreachable!("handled above"),
        }
    }

    /// Quantized admission: queue, and drain a merged wave once the
    /// window fills.
    fn submit_quantized(
        &mut self,
        ops: Vec<OpNode>,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        let idx = self.price(&ops, cfg, state);
        self.queue.push((ops, idx));
        if self.queue.len() >= self.window {
            self.drain_queue(policy, cfg, backend, state)?;
        }
        Ok(())
    }

    /// Sliding admission: splice the epoch into the live session the
    /// moment the admission log allows.
    fn submit_sliding(
        &mut self,
        mut ops: Vec<OpNode>,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        // The window gate needs epoch (next − window)'s retirement
        // time; the live session may still be executing it — advance
        // the event loop just far enough to learn it. Every event
        // pumped is at or before that retirement, which is at or
        // before the new epoch's admission, so the loop's prefix stays
        // causally consistent.
        self.settle_gate_epoch(backend, state);
        let idx = self.price(&ops, cfg, state);
        let admit_t = state.flow_log.epochs[idx].record_done;
        state.n_epochs += 1;
        if self.session.is_none() {
            self.session = Some(SchedSession::new(policy, cfg, state));
            self.splicer = Splicer::new();
        }
        let (lo, hi) = self.splicer.splice(&mut ops);
        let admit = vec![admit_t; ops.len()];
        let sess = self.session.as_mut().expect("session just ensured");
        if let Err(e) = sess.inject(ops, Some(&admit), cfg, backend, state) {
            self.session = None;
            self.live.clear();
            self.splicer = Splicer::new();
            state.admit = Vec::new();
            return Err(e);
        }
        self.live.push((idx, lo, hi));
        self.attribute_retired(state);
        Ok(())
    }

    /// Naive lookahead (both streaming modes): extend the pending merge
    /// only while a dry run shows the becoming-ready order completes
    /// it; otherwise drain the deadlock-free prefix first.
    ///
    /// Cost note: each submit replays the whole candidate merge (a
    /// deadlock is a whole-wave property, so validating only the
    /// extension would be unsound) — O(window² · ops) per filled
    /// window. The window is small (≤ [`super::AUTO_MAX_WINDOW`]-ish)
    /// and the naive evaluator is the deliberately-slow Fig. 6
    /// strawman that only runs in ablations, so the bound is accepted
    /// rather than engineered around.
    fn submit_naive(
        &mut self,
        ops: Vec<OpNode>,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        let idx = self.price(&ops, cfg, state);
        if !self.queue.is_empty() {
            let mut cand: Vec<(Vec<OpNode>, usize, VTime)> = self
                .queue
                .iter()
                .map(|(o, i)| (o.clone(), *i, 0.0))
                .collect();
            cand.push((ops.clone(), idx, 0.0));
            let wave = frontier::merge(cand);
            if !naive_wave_admissible(wave.ops, cfg) {
                self.drain_queue(policy, cfg, backend, state)?;
            }
        }
        self.queue.push((ops, idx));
        if self.queue.len() >= self.window {
            self.drain_queue(policy, cfg, backend, state)?;
        }
        Ok(())
    }

    /// Price one submitted epoch on the recorder clock (gated by the
    /// admission window) and log it. Returns its admission-log index.
    fn price(&mut self, ops: &[OpNode], cfg: &SchedCfg, state: &mut ExecState) -> usize {
        let gate = state.flow_log.window_gate(self.window);
        let cost = record_cost(ops, &cfg.spec);
        let (start, done) = self.recorder.record(gate, cost);
        state.overhead += cost;
        state.overhead_streamed += cost;
        let idx = state.flow_log.submitted(start, done, ops.len());
        state.trace.admit(idx as u64, start, done, ops.len() as u64);
        idx
    }

    /// Sliding: make sure the epoch the window gate consults has its
    /// retirement attributed, pumping the live session as needed.
    fn settle_gate_epoch(&mut self, backend: &mut dyn Backend, state: &mut ExecState) {
        let next = state.flow_log.epochs.len();
        if next < self.window {
            return;
        }
        let target = next - self.window;
        if let Some(pos) = self.live.iter().position(|&(i, _, _)| i == target) {
            let (_, lo, hi) = self.live[pos];
            if let Some(sess) = self.session.as_mut() {
                while range_unretired(state, lo, hi) {
                    if sess.pump_next(backend, state).is_none() {
                        break;
                    }
                }
            }
        }
        self.attribute_retired(state);
    }

    /// Attribute retirement times of fully-retired live epochs back to
    /// the continuous log — the window gate of future submits consults
    /// them.
    fn attribute_retired(&mut self, state: &mut ExecState) {
        self.live.retain(|&(idx, lo, hi)| {
            if range_unretired(state, lo, hi) {
                true
            } else {
                state.flow_log.retire_from(idx, &state.retire[lo..hi]);
                state
                    .trace
                    .epoch_retired(idx as u64, state.flow_log.epochs[idx].retired);
                false
            }
        });
    }

    /// Steer the adaptive window from the admission log: fresh
    /// admission stalls (recording not fully hidden — `overlap_pct`
    /// below 100 for the last interval) grow the window while live
    /// staging memory stays under the cap; stage pressure shrinks it.
    /// Decisions land in [`super::AdmissionLog::window_trace`].
    fn steer_window(&mut self, state: &mut ExecState) {
        let FlowWindow::Auto { max, stage_cap } = self.cfg.window else {
            return;
        };
        let stalled = state.wait_at_admission > self.steer_mark;
        self.steer_mark = state.wait_at_admission;
        let next = if state.stages.live >= stage_cap {
            self.window.saturating_sub(1).max(1)
        } else if stalled {
            (self.window + 1).min(max.max(1))
        } else {
            self.window
        };
        if next != self.window {
            self.window = next;
            state
                .flow_log
                .window_trace
                .push((state.flow_log.epochs.len() as u64, next as u64));
            state.trace.window(
                state.flow_log.epochs.len() as u64,
                next as u64,
                state.flow_log.recorder_clock(),
            );
        }
    }

    /// Execute everything queued as one merged wave (quantized Flow and
    /// the naive lookahead). No-op on an empty queue.
    fn drain_queue(
        &mut self,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        if self.queue.is_empty() {
            return Ok(());
        }
        let batches: Vec<(Vec<OpNode>, usize, f64)> = std::mem::take(&mut self.queue)
            .into_iter()
            .map(|(ops, idx)| {
                let admit = state.flow_log.epochs[idx].record_done;
                (ops, idx, admit)
            })
            .collect();
        state.n_epochs += batches.len() as u64;
        let wave = frontier::merge(batches);
        crate::sched::execute_wave(policy, wave.ops, &wave.admit, cfg, backend, state)?;
        // Attribute retirement times back to the continuous log — the
        // window gate of future submits consults them.
        for &(log_idx, lo, hi) in &wave.epochs {
            state.flow_log.retire_from(log_idx, &state.retire[lo..hi]);
            state
                .trace
                .epoch_retired(log_idx as u64, state.flow_log.epochs[log_idx].retired);
        }
        self.lift_clocks(state);
        Ok(())
    }

    /// Run everything in flight to completion: drain the queued wave
    /// and run the live sliding session to quiescence. The synchronous
    /// half every forced read keeps.
    pub fn drain(
        &mut self,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        self.drain_queue(policy, cfg, backend, state)?;
        if let Some(mut sess) = self.session.take() {
            self.splicer = Splicer::new();
            let res = sess.drain(backend, state);
            state.admit = Vec::new();
            match res {
                Ok(()) => {
                    self.attribute_retired(state);
                    debug_assert!(
                        self.live.is_empty(),
                        "a drained session retires every spliced epoch"
                    );
                    self.live.clear();
                    self.lift_clocks(state);
                }
                Err(e) => {
                    self.live.clear();
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Causality of the replicated interpreter: program time cannot run
    /// ahead of its own recording. Lift lagging rank clocks to the
    /// recorder frontier — no wait is charged (the rank's recorder was
    /// busy, not blocked; the cost is already in `overhead`).
    fn lift_clocks(&self, state: &mut ExecState) {
        for c in state.clock.iter_mut() {
            if *c < self.recorder.clock {
                *c = self.recorder.clock;
            }
        }
    }
}

/// Is `retire[lo..hi]` fully attributed (every op of the epoch retired)?
fn range_unretired(state: &ExecState, lo: usize, hi: usize) -> bool {
    state.retire[lo..hi].iter().any(|&(_, t)| t.is_nan())
}

/// Dry-run a candidate merged wave through the naive evaluator on a
/// scratch timeline: `true` if the becoming-ready order completes it.
/// The replay is exact for the insert-then-drain epoch streams the
/// apps record (readiness order is timing-independent there); if a
/// pathological stream slipped past the gate anyway, the live run
/// still fails loudly and poisons the context — never silently.
fn naive_wave_admissible(ops: Vec<OpNode>, cfg: &SchedCfg) -> bool {
    // Dry runs never trace or profile: the scratch sink would only
    // burn memory, and scratch wall time is not the real run's.
    let mut cfg = cfg.clone();
    cfg.trace.enabled = false;
    cfg.profile.enabled = false;
    let mut scratch = ExecState::new(&cfg);
    let mut sim = crate::exec::SimBackend;
    let mut session = SchedSession::new(Policy::Naive, &cfg, &mut scratch);
    match session.inject(ops, None, &cfg, &mut sim, &mut scratch) {
        Ok(()) => session.drain(&mut sim, &mut scratch).is_ok(),
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    fn batch(p: u32, rows: u64) -> Vec<OpNode> {
        let mut reg = Registry::new(p);
        let x = reg.alloc(vec![rows], 4, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Scale(2.0), &xv, &[&xv]);
        bld.finish()
    }

    #[test]
    fn submit_queues_until_window_fills() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(2));
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 1, "first submit stays in flight");
        assert_eq!(st.ops_executed, 0, "nothing executed yet");
        assert_eq!(st.flow_log.epochs.len(), 1);
        assert!(st.overhead_streamed > 0.0, "recording priced on the recorder clock");
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 0, "window of 2 drained");
        assert_eq!(st.n_epochs, 2, "both submits count as epochs");
        assert!(st.ops_executed > 0);
        assert!(
            st.flow_log.epochs.iter().all(|e| e.retired.is_finite()),
            "drain attributes retirement to every epoch"
        );
        assert_eq!(st.flow_log.max_in_flight, 2);
    }

    #[test]
    fn sliding_splices_each_submit_into_the_live_session() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::sliding(4));
        let b1 = batch(2, 32);
        let b2 = batch(2, 32);
        let total = (b1.len() + b2.len()) as u64;
        eng.submit(b1, Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(st.n_epochs, 1, "sliding counts epochs at submit");
        assert_eq!(eng.pending(), 1, "spliced epoch still executing");
        eng.submit(b2, Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(st.run_id, 1, "both epochs entered ONE live session");
        eng.drain(Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 0, "drain runs the session to quiescence");
        assert_eq!(st.ops_executed, total, "both epochs executed");
        assert!(st.admit.is_empty(), "drain clears the admission gates");
        assert!(
            st.flow_log.epochs.iter().all(|e| e.retired.is_finite()),
            "every spliced epoch's retirement attributed"
        );
    }

    #[test]
    fn sliding_window_gate_pumps_the_session_for_retirements() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::sliding(1));
        let b1 = batch(2, 32);
        let b2 = batch(2, 32);
        let total = (b1.len() + b2.len()) as u64;
        eng.submit(b1, Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert!(
            st.flow_log.epochs[0].retired.is_nan(),
            "first epoch still in flight after its own submit"
        );
        // Window 1: the second submit's recording gates on epoch 0's
        // retirement, which the engine must learn by pumping the loop.
        eng.submit(b2, Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        let e0 = &st.flow_log.epochs[0];
        let e1 = &st.flow_log.epochs[1];
        assert!(e0.retired.is_finite(), "gate forced epoch 0 retirement");
        assert!(
            e1.record_start >= e0.retired,
            "recording of epoch 1 gated on epoch 0's retirement"
        );
        eng.drain(Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(st.ops_executed, total);
    }

    /// The naive lookahead: admissible epochs merge into one wave
    /// instead of draining one by one (the pre-PR-5 degradation).
    #[test]
    fn naive_lookahead_merges_admissible_epochs() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(4));
        eng.submit(batch(2, 32), Policy::Naive, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        eng.submit(batch(2, 32), Policy::Naive, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 2, "admissible epochs keep queueing");
        assert_eq!(st.ops_executed, 0);
        eng.drain(Policy::Naive, &cfg, &mut SimBackend, &mut st).unwrap();
        assert_eq!(st.n_epochs, 2);
        assert_eq!(st.run_id, 1, "one merged wave, one scheduler run");
        assert!(st.ops_executed > 0);
    }

    /// The naive lookahead splits at a deadlock: the Fig. 6 ping-pong
    /// split across two submits would deadlock merged, so the engine
    /// drains the first epoch alone and both complete.
    #[test]
    fn naive_lookahead_splits_inadmissible_merges() {
        let rows = 12u64;
        let br = 3u64;
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(4));
        let mut reg = Registry::new(2);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let mut bld = OpBuilder::new();
        // Iteration 1: N[1:-1] = M[2:] + M[:-2]
        bld.ufunc(
            &reg,
            Kernel::Add,
            &nv.slice(&[(1, rows - 1)]),
            &[&mv.slice(&[(2, rows)]), &mv.slice(&[(0, rows - 2)])],
        );
        let iter1 = bld.finish();
        // Iteration 2: M[1:-1] = N[2:] + N[:-2] — merged with iteration
        // 1 this is the Fig. 6 stream the naive order deadlocks on.
        bld.ufunc(
            &reg,
            Kernel::Add,
            &mv.slice(&[(1, rows - 1)]),
            &[&nv.slice(&[(2, rows)]), &nv.slice(&[(0, rows - 2)])],
        );
        let iter2 = bld.finish();
        eng.submit(iter1, Policy::Naive, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 1);
        eng.submit(iter2, Policy::Naive, &cfg, &mut SimBackend, &mut st)
            .unwrap_or_else(|e| panic!("lookahead must split, not deadlock: {e}"));
        assert_eq!(eng.pending(), 1, "iteration 1 drained alone; 2 queued");
        assert!(st.ops_executed > 0, "the deadlock-free prefix executed");
        eng.drain(Policy::Naive, &cfg, &mut SimBackend, &mut st)
            .unwrap_or_else(|e| panic!("split epochs must both complete: {e}"));
        assert_eq!(st.n_epochs, 2);
    }

    #[test]
    fn drain_on_empty_queue_is_noop() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(2));
        eng.drain(Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(st.n_epochs, 0);
    }

    #[test]
    fn clocks_never_lag_the_recorder() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(1));
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        for &c in &st.clock {
            assert!(c >= eng.record_clock(), "clock {c} behind recorder {}", eng.record_clock());
        }
    }

    #[test]
    fn adaptive_window_grows_on_stall_and_shrinks_on_stage_pressure() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::sliding_auto());
        assert_eq!(eng.window(), super::super::AUTO_INITIAL_WINDOW);
        // A fresh admission stall with stage memory to spare: grow.
        st.wait_at_admission = 1.0;
        eng.steer_window(&mut st);
        assert_eq!(eng.window(), super::super::AUTO_INITIAL_WINDOW + 1);
        assert_eq!(st.flow_log.window_trace.len(), 1);
        // No new stall: hold.
        eng.steer_window(&mut st);
        assert_eq!(eng.window(), super::super::AUTO_INITIAL_WINDOW + 1);
        // Stage pressure: shrink, even while stalled.
        st.wait_at_admission = 2.0;
        st.stages.live = super::super::AUTO_STAGE_CAP;
        eng.steer_window(&mut st);
        assert_eq!(eng.window(), super::super::AUTO_INITIAL_WINDOW);
        assert_eq!(st.flow_log.window_trace.len(), 2);
        // Fixed windows never steer.
        let mut fixed = FlowEngine::new(FlowCfg::sliding(3));
        fixed.steer_window(&mut st);
        assert_eq!(fixed.window(), 3);
        assert_eq!(st.flow_log.window_trace.len(), 2);
    }

    #[test]
    fn cleared_engine_reports_zero_pending() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::sliding(4));
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert!(eng.pending() > 0);
        eng.clear();
        assert_eq!(eng.pending(), 0);
    }
}
