//! The streaming admission engine: bounded window of in-flight epochs.
//!
//! [`FlowEngine`] sits between the lazy context's threshold trigger and
//! the schedulers. `submit` is non-blocking: the batch is aggregated
//! (per epoch — aggregation never crosses a flush boundary, §3), priced
//! on the recorder clock ([`super::overlap`]), logged in the continuous
//! [`super::frontier::AdmissionLog`] and queued. Once
//! [`crate::flow::FlowCfg::window`] epochs are in flight the queue
//! drains: the epochs merge into one [`super::frontier::Wave`] and
//! execute under per-epoch admission gates, so cross-epoch dependency
//! streaming happens inside the existing discrete-event schedulers with
//! no special cases. `drain` is the synchronous half `flush` keeps.
//!
//! The naive evaluator is the exception ([`crate::flow`] module docs): merged
//! waves could park it on receives the per-batch stream never exposes
//! it to, so under [`crate::sched::Policy::Naive`] every submit drains
//! immediately — Batch wave-granularity, streamed recording clock.

use crate::exec::Backend;
use crate::sched::{ExecState, Policy, SchedCfg, SchedError};
use crate::ufunc::OpNode;

use super::frontier;
use super::overlap::{record_cost, Recorder};
use super::FlowCfg;

/// The incremental flush engine owned by a lazy
/// [`crate::lazy::Context`].
pub struct FlowEngine {
    pub cfg: FlowCfg,
    recorder: Recorder,
    /// Submitted, not yet executed epochs: `(ops, admission-log idx)`.
    queue: Vec<(Vec<OpNode>, usize)>,
}

impl FlowEngine {
    pub fn new(cfg: FlowCfg) -> Self {
        FlowEngine {
            cfg,
            recorder: Recorder::default(),
            queue: Vec::new(),
        }
    }

    /// Submitted epochs not yet executed (in flight in the queue).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The recorder clock — when the last submitted epoch finished
    /// recording.
    pub fn record_clock(&self) -> crate::types::VTime {
        self.recorder.clock
    }

    /// Drop everything queued (poisoned context: later batches are
    /// dropped unexecuted, exactly like Batch mode's dropped batches).
    pub fn clear(&mut self) {
        self.queue.clear();
    }

    /// Non-blocking submit: price the batch on the recorder clock,
    /// queue it, and execute a merged wave once the admission window
    /// is full. Under [`Policy::Naive`] the wave drains immediately
    /// (see module docs).
    pub fn submit(
        &mut self,
        ops: Vec<OpNode>,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        // Aggregation is a per-flush-epoch rewrite ("ready in the same
        // flush epoch"), so it runs before the wave merge.
        let ops = if cfg.aggregation >= 2 {
            let (packed, stats) = crate::comm::aggregate(&ops, cfg.aggregation);
            state.agg_msgs += stats.packed_msgs;
            state.agg_parts += stats.packed_parts;
            packed
        } else {
            ops
        };
        let gate = state.flow_log.window_gate(self.cfg.window);
        let cost = record_cost(&ops, &cfg.spec);
        let (start, done) = self.recorder.record(gate, cost);
        state.overhead += cost;
        state.overhead_streamed += cost;
        let idx = state.flow_log.submitted(start, done, ops.len());
        self.queue.push((ops, idx));
        if self.queue.len() >= self.cfg.window || policy == Policy::Naive {
            self.drain(policy, cfg, backend, state)?;
        }
        Ok(())
    }

    /// Execute everything queued as one merged wave. No-op on an empty
    /// queue.
    pub fn drain(
        &mut self,
        policy: Policy,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        state: &mut ExecState,
    ) -> Result<(), SchedError> {
        if self.queue.is_empty() {
            return Ok(());
        }
        let batches: Vec<(Vec<OpNode>, usize, f64)> = std::mem::take(&mut self.queue)
            .into_iter()
            .map(|(ops, idx)| {
                let admit = state.flow_log.epochs[idx].record_done;
                (ops, idx, admit)
            })
            .collect();
        state.n_epochs += batches.len() as u64;
        let wave = frontier::merge(batches);
        crate::sched::execute_wave(policy, &wave.ops, &wave.admit, cfg, backend, state)?;
        // Attribute retirement times back to the continuous log — the
        // window gate of future submits consults them.
        for &(log_idx, lo, hi) in &wave.epochs {
            state.flow_log.retire_from(log_idx, &state.retire[lo..hi]);
        }
        // Causality of the replicated interpreter: program time cannot
        // run ahead of its own recording. Lift lagging rank clocks to
        // the recorder frontier — no wait is charged (the rank's
        // recorder was busy, not blocked; the cost is already in
        // `overhead`).
        for c in state.clock.iter_mut() {
            if *c < self.recorder.clock {
                *c = self.recorder.clock;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    fn batch(p: u32, rows: u64) -> Vec<OpNode> {
        let mut reg = Registry::new(p);
        let x = reg.alloc(vec![rows], 4, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Scale(2.0), &xv, &[&xv]);
        bld.finish()
    }

    #[test]
    fn submit_queues_until_window_fills() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(2));
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 1, "first submit stays in flight");
        assert_eq!(st.ops_executed, 0, "nothing executed yet");
        assert_eq!(st.flow_log.epochs.len(), 1);
        assert!(st.overhead_streamed > 0.0, "recording priced on the recorder clock");
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 0, "window of 2 drained");
        assert_eq!(st.n_epochs, 2, "both submits count as epochs");
        assert!(st.ops_executed > 0);
        assert!(
            st.flow_log.epochs.iter().all(|e| e.retired.is_finite()),
            "drain attributes retirement to every epoch"
        );
    }

    #[test]
    fn naive_degrades_to_per_batch_waves() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(4));
        eng.submit(batch(2, 32), Policy::Naive, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(eng.pending(), 0, "naive drains every submit");
        assert_eq!(st.n_epochs, 1);
    }

    #[test]
    fn drain_on_empty_queue_is_noop() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(2));
        eng.drain(Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        assert_eq!(st.n_epochs, 0);
    }

    #[test]
    fn clocks_never_lag_the_recorder() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut eng = FlowEngine::new(FlowCfg::flow(1));
        eng.submit(batch(2, 32), Policy::LatencyHiding, &cfg, &mut SimBackend, &mut st)
            .unwrap();
        for &c in &st.clock {
            assert!(c >= eng.record_clock(), "clock {c} behind recorder {}", eng.record_clock());
        }
    }
}
