//! Incremental flush engine — overlap recording with execution.
//!
//! The paper's heuristic flushes a *batch* at a time: when the
//! threshold fires, recording stops, the whole batch is scheduled and
//! executed, then recording resumes — interpreter-side recording time
//! and simulated execution time strictly alternate on every rank's
//! clock. This module pipelines the two, following Eijkhout's
//! *Task Graph Transformations for Latency Tolerance* (split the graph
//! into waves whose execution overlaps continued graph construction,
//! arXiv:1811.05077) and the futurized-admission model of HPX-style
//! asynchronous interpreters (arXiv:1810.07591):
//!
//! * the threshold trigger becomes a **non-blocking submit**
//!   ([`crate::lazy::Context::submit`]): the batch is stamped with an
//!   *admission time* on a concurrent recorder clock and queued;
//! * up to [`FlowCfg::window`] submitted epochs are merged into one
//!   **wave** ([`frontier`]) and executed together — operations enter
//!   the dependency system the moment their predecessors are known,
//!   so a rank that would idle at an epoch tail (a draining halo
//!   transfer) computes the next epoch's ready fragments instead;
//! * recording overhead is charged **on the recorder's clock,
//!   concurrently with execution** ([`overlap`]) rather than as a lump
//!   on every rank at flush end; execution only stalls where an
//!   operation's admission gate binds (`wait_at_admission`).
//!
//! `flush` remains the synchronous operation — it is now *submit +
//! drain* ([`engine::FlowEngine::drain`]). [`FlowMode::Batch`] (the
//! default) keeps the stop-the-world reference path bit-identical to
//! the pre-flow engine; `benches/ablation_flow.rs` asserts that Flow
//! mode strictly lowers total waiting time at P ≥ 16 on
//! threshold-triggered Jacobi with bit-identical numerics.
//!
//! Policy coverage: the latency-hiding scheduler consumes whole waves
//! and realizes the overlap; the blocking baseline executes waves in
//! recorded order (it gains the streamed recording clock but, by
//! definition, never overlaps across operation boundaries); the naive
//! evaluator **degrades to Batch wave-granularity** — its
//! becoming-ready order parks ranks on receives, and handing it a
//! merged wave could manufacture deadlocks the per-batch stream does
//! not have, so each submit drains as its own single-epoch wave.

pub mod engine;
pub mod frontier;
pub mod overlap;

pub use engine::FlowEngine;
pub use frontier::{AdmissionLog, EpochEntry, Wave};
pub use overlap::Recorder;

/// How the lazy context turns a threshold trigger into execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlowMode {
    /// Stop-the-world flushing: every submit executes immediately as
    /// one epoch, recording overhead charged on every rank's clock up
    /// front. The bit-identical reference path.
    Batch,
    /// Streaming admission: submits queue into a bounded window of
    /// in-flight epochs, merged waves execute with per-epoch admission
    /// gates, recording overhead rides the concurrent recorder clock.
    Flow,
}

impl FlowMode {
    pub fn parse(s: &str) -> Option<FlowMode> {
        match s {
            "batch" => Some(FlowMode::Batch),
            "flow" => Some(FlowMode::Flow),
            _ => None,
        }
    }
}

/// Admission control of the incremental flush engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlowCfg {
    /// Maximum in-flight epochs: recording of epoch *k* may not begin
    /// before epoch *k − window* fully retired, and at most `window`
    /// submitted epochs merge into one executed wave. `window == 1`
    /// reproduces Batch pacing (every submit drains) while still
    /// paying recording on the recorder clock.
    pub window: usize,
    pub mode: FlowMode,
}

impl Default for FlowCfg {
    fn default() -> Self {
        FlowCfg {
            window: 2,
            mode: FlowMode::Batch,
        }
    }
}

impl FlowCfg {
    /// Streaming admission with the given window.
    pub fn flow(window: usize) -> Self {
        FlowCfg {
            window: window.max(1),
            mode: FlowMode::Flow,
        }
    }

    pub fn is_flow(&self) -> bool {
        self.mode == FlowMode::Flow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_batch_reference_path() {
        let cfg = FlowCfg::default();
        assert_eq!(cfg.mode, FlowMode::Batch);
        assert!(!cfg.is_flow());
    }

    #[test]
    fn flow_constructor_clamps_window() {
        assert_eq!(FlowCfg::flow(0).window, 1);
        assert_eq!(FlowCfg::flow(4).window, 4);
        assert!(FlowCfg::flow(2).is_flow());
    }

    #[test]
    fn mode_parse() {
        assert_eq!(FlowMode::parse("flow"), Some(FlowMode::Flow));
        assert_eq!(FlowMode::parse("batch"), Some(FlowMode::Batch));
        assert_eq!(FlowMode::parse("x"), None);
    }
}
