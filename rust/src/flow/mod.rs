//! Incremental flush engine — overlap recording with execution.
//!
//! The paper's heuristic flushes a *batch* at a time: when the
//! threshold fires, recording stops, the whole batch is scheduled and
//! executed, then recording resumes — interpreter-side recording time
//! and simulated execution time strictly alternate on every rank's
//! clock. This module pipelines the two, following Eijkhout's
//! *Task Graph Transformations for Latency Tolerance* (split the graph
//! into waves whose execution overlaps continued graph construction,
//! arXiv:1811.05077) and the futurized-admission model of HPX-style
//! asynchronous interpreters (arXiv:1810.07591):
//!
//! * the threshold trigger becomes a **non-blocking submit**
//!   ([`crate::lazy::Context::submit`]): the batch is stamped with an
//!   *admission time* on a concurrent recorder clock and queued;
//! * under [`FlowMode::Flow`] up to `window` submitted epochs are
//!   merged into one **wave** ([`frontier`]) and executed together —
//!   operations enter the dependency system the moment their
//!   predecessors are known, so a rank that would idle at an epoch
//!   tail (a draining halo transfer) computes the next epoch's ready
//!   fragments instead;
//! * under [`FlowMode::Sliding`] the wave quantization disappears: the
//!   engine keeps one **resumable scheduler session**
//!   ([`crate::sched::SchedSession`]) alive and splices each epoch
//!   into its *running* event loop the moment the admission log allows
//!   (epoch *k+W* enters as soon as epoch *k* retired — mid-wave, not
//!   at a wave boundary), so the wire time a quantized drain strands
//!   at each wave tail is recovered;
//! * recording overhead is charged **on the recorder's clock,
//!   concurrently with execution** ([`overlap`]) rather than as a lump
//!   on every rank at flush end; execution only stalls where an
//!   operation's admission gate binds (`wait_at_admission`).
//!
//! `flush` remains the synchronous operation — it is now *submit +
//! drain* ([`engine::FlowEngine::drain`]; under Sliding, "drain" means
//! "run the live session to quiescence"). [`FlowMode::Batch`] (the
//! default) keeps the stop-the-world reference path bit-identical to
//! the pre-flow engine; `benches/ablation_flow.rs` asserts that Flow
//! mode strictly lowers total waiting time at P ≥ 16 on
//! threshold-triggered Jacobi, and `benches/ablation_stream.rs` that
//! Sliding strictly undercuts quantized Flow at the same window — both
//! with bit-identical numerics.
//!
//! Policy coverage: the latency-hiding scheduler realizes the overlap;
//! the blocking baseline executes waves/streams in recorded order (it
//! gains the streamed recording clock but, by definition, never
//! overlaps across operation boundaries); the naive evaluator is fed
//! conservatively — its becoming-ready order parks ranks on receives,
//! so the engine's **bounded-lookahead merge** admits a merged wave
//! only after a dry run shows the naive order completes it, splitting
//! at the first epoch that would manufacture a deadlock (the Fig. 6
//! strawman now participates in the flow/sliding ablations instead of
//! degrading to single-epoch waves).
//!
//! The admission window itself may be **adaptive**
//! ([`FlowWindow::Auto`]): the engine grows it while the admission log
//! shows unhidden recording (overlap < 100%) and live staging memory
//! stays under a configurable cap, and shrinks it under stage
//! pressure; decisions are recorded in
//! [`frontier::AdmissionLog::window_trace`] and surface in the run
//! JSON metadata.

pub mod engine;
pub mod frontier;
pub mod overlap;

pub use engine::FlowEngine;
pub use frontier::{AdmissionLog, EpochEntry, Splicer, Wave};
pub use overlap::Recorder;

/// How the lazy context turns a threshold trigger into execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlowMode {
    /// Stop-the-world flushing: every submit executes immediately as
    /// one epoch, recording overhead charged on every rank's clock up
    /// front. The bit-identical reference path.
    Batch,
    /// Quantized streaming admission: submits queue into a bounded
    /// window of in-flight epochs, merged waves execute with per-epoch
    /// admission gates, recording overhead rides the concurrent
    /// recorder clock.
    Flow,
    /// True sliding admission: one resumable scheduler session stays
    /// live and each submitted epoch is spliced into its running event
    /// loop the moment the window admits it — no wave boundaries.
    Sliding,
}

impl FlowMode {
    pub fn parse(s: &str) -> Option<FlowMode> {
        match s {
            "batch" => Some(FlowMode::Batch),
            "flow" => Some(FlowMode::Flow),
            "sliding" => Some(FlowMode::Sliding),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FlowMode::Batch => "batch",
            FlowMode::Flow => "flow",
            FlowMode::Sliding => "sliding",
        }
    }
}

/// Starting window of [`FlowWindow::Auto`].
pub const AUTO_INITIAL_WINDOW: usize = 2;
/// Default growth bound of [`FlowWindow::Auto`].
pub const AUTO_MAX_WINDOW: usize = 8;
/// Default live-staging-buffer cap of [`FlowWindow::Auto`]: the window
/// stops growing (and shrinks) once this many staging buffers are live.
pub const AUTO_STAGE_CAP: u64 = 4096;

/// The admission-window policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlowWindow {
    /// A fixed window of this many in-flight epochs.
    Fixed(usize),
    /// Steered at runtime from the [`AdmissionLog`]: grow (up to `max`)
    /// while recording is not fully hidden behind execution, shrink
    /// while `stage_cap` or more staging buffers are live.
    Auto { max: usize, stage_cap: u64 },
}

impl FlowWindow {
    /// The window the engine starts from.
    pub fn initial(self) -> usize {
        match self {
            FlowWindow::Fixed(w) => w.max(1),
            FlowWindow::Auto { .. } => AUTO_INITIAL_WINDOW,
        }
    }

    pub fn is_auto(self) -> bool {
        matches!(self, FlowWindow::Auto { .. })
    }
}

/// Admission control of the incremental flush engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlowCfg {
    /// Maximum in-flight epochs: recording of epoch *k* may not begin
    /// before epoch *k − window* fully retired; under quantized Flow at
    /// most `window` submitted epochs additionally merge into one
    /// executed wave. `window == 1` reproduces Batch pacing (every
    /// submit drains) while still paying recording on the recorder
    /// clock. May be [`FlowWindow::Auto`].
    pub window: FlowWindow,
    pub mode: FlowMode,
}

impl Default for FlowCfg {
    fn default() -> Self {
        FlowCfg {
            window: FlowWindow::Fixed(2),
            mode: FlowMode::Batch,
        }
    }
}

impl FlowCfg {
    /// Quantized streaming admission with the given fixed window.
    pub fn flow(window: usize) -> Self {
        FlowCfg {
            window: FlowWindow::Fixed(window.max(1)),
            mode: FlowMode::Flow,
        }
    }

    /// Sliding admission with the given fixed window.
    pub fn sliding(window: usize) -> Self {
        FlowCfg {
            window: FlowWindow::Fixed(window.max(1)),
            mode: FlowMode::Sliding,
        }
    }

    /// Sliding admission with the adaptively-steered window.
    pub fn sliding_auto() -> Self {
        FlowCfg {
            window: FlowWindow::Auto {
                max: AUTO_MAX_WINDOW,
                stage_cap: AUTO_STAGE_CAP,
            },
            mode: FlowMode::Sliding,
        }
    }

    /// Does the threshold trigger stream through the engine (any
    /// non-Batch mode)?
    pub fn is_flow(&self) -> bool {
        self.mode != FlowMode::Batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_batch_reference_path() {
        let cfg = FlowCfg::default();
        assert_eq!(cfg.mode, FlowMode::Batch);
        assert!(!cfg.is_flow());
    }

    #[test]
    fn flow_constructor_clamps_window() {
        assert_eq!(FlowCfg::flow(0).window, FlowWindow::Fixed(1));
        assert_eq!(FlowCfg::flow(4).window, FlowWindow::Fixed(4));
        assert!(FlowCfg::flow(2).is_flow());
        assert_eq!(FlowCfg::sliding(0).window, FlowWindow::Fixed(1));
        assert_eq!(FlowCfg::sliding(3).mode, FlowMode::Sliding);
        assert!(FlowCfg::sliding(3).is_flow());
    }

    #[test]
    fn auto_window_defaults() {
        let cfg = FlowCfg::sliding_auto();
        assert!(cfg.window.is_auto());
        assert_eq!(cfg.window.initial(), AUTO_INITIAL_WINDOW);
        assert_eq!(FlowWindow::Fixed(0).initial(), 1);
        assert_eq!(FlowWindow::Fixed(5).initial(), 5);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(FlowMode::parse("flow"), Some(FlowMode::Flow));
        assert_eq!(FlowMode::parse("batch"), Some(FlowMode::Batch));
        assert_eq!(FlowMode::parse("sliding"), Some(FlowMode::Sliding));
        assert_eq!(FlowMode::parse("x"), None);
        assert_eq!(FlowMode::Sliding.name(), "sliding");
    }
}
