//! Record/execute overlap accounting — the recorder clock.
//!
//! In Batch mode the per-epoch recording/bookkeeping overhead
//! (`sched::batch_overhead`: per-fragment dependency insertion plus
//! per-array-op CPython dispatch, replicated on every rank per §5.5) is
//! charged as a lump on every rank's clock at the top of the epoch —
//! recording strictly alternates with execution. In Flow mode the model
//! assumes a dedicated recorder thread per rank (the futurized
//! interpreter of the HPX model): the same overhead is charged on a
//! separate, monotone **recorder clock**, and execution only observes
//! it through each epoch's *admission time* — an operation may not
//! start before its epoch finished recording. Recording that fits under
//! concurrent execution is thereby hidden; recording that runs long
//! shows up as `wait_at_admission` on the ranks that stall for it.
//!
//! The recorder clock is also where the engine's window gate lands:
//! recording of epoch *k* may not begin before epoch *k − window*
//! retired ([`crate::flow::frontier::AdmissionLog::window_gate`]), so
//! the recorder cannot run unboundedly ahead of execution. Under
//! sliding admission ([`crate::flow::FlowMode::Sliding`]) the gate is
//! the *only* bound: the engine advances the live session's event loop
//! just far enough to learn the gating epoch's retirement time, and
//! every event pumped that way is at or before the new epoch's
//! admission — the recorder clock and the executing timeline race, but
//! the race is resolved causally.
//!
//! The overlap actually achieved is reported as
//! [`crate::metrics::RunReport::overlap_pct`]: the share of streamed
//! recording overhead that did **not** stall admission. Batch mode
//! streams nothing, so it reports 0 by construction.

use crate::cluster::MachineSpec;
use crate::types::VTime;
use crate::ufunc::OpNode;

/// The replicated interpreter's recording timeline. Recording is
/// identical on every rank (global knowledge, §5.5), so one clock
/// serves all of them.
#[derive(Clone, Copy, Debug, Default)]
pub struct Recorder {
    /// When the recorder finishes the last epoch submitted so far.
    pub clock: VTime,
}

impl Recorder {
    /// Record one epoch costing `cost`, not starting before `gate`
    /// (the admission window). Returns `(record_start, record_done)`;
    /// `record_done` is the epoch's admission time.
    pub fn record(&mut self, gate: VTime, cost: VTime) -> (VTime, VTime) {
        let start = self.clock.max(gate);
        let done = start + cost;
        self.clock = done;
        (start, done)
    }
}

/// The virtual recording cost of one submitted batch — the same
/// quantity Batch mode charges through `ExecState::charge_overhead`,
/// with the latency-hiding per-op rate (the flow engine exists to feed
/// the dependency-tracked schedulers).
pub fn record_cost(ops: &[OpNode], spec: &MachineSpec) -> VTime {
    crate::sched::batch_overhead(ops, spec.lh_op_overhead, spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_chains_and_respects_gates() {
        let mut r = Recorder::default();
        let (s0, d0) = r.record(0.0, 0.5);
        assert_eq!((s0, d0), (0.0, 0.5));
        let (s1, d1) = r.record(0.0, 0.25);
        assert_eq!((s1, d1), (0.5, 0.75), "recording serializes on its own clock");
        let (s2, d2) = r.record(3.0, 0.1);
        assert_eq!((s2, d2), (3.0, 3.1), "window gate delays recording");
        assert_eq!(r.clock, 3.1);
    }
}
