//! Operation-nodes and access-nodes (paper Section 5.7, Fig. 7).
//!
//! An operation-node carries everything needed to execute it on a set of
//! sub-view-blocks; each of its access-nodes names one memory access
//! (read or write) to a base-block interval or a staging buffer. The
//! dependency system orders operations purely through these accesses.

use crate::types::{BaseId, OpId, Rank, Tag};

/// What an access-node points at: a base-block (with a conservative
/// flattened element interval) or a message staging buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loc {
    Block { base: BaseId, block: u64 },
    Stage(Tag),
}

/// An access-node: one read/write of an operation on one location.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub loc: Loc,
    /// Conservative element interval within the location `[lo, hi)`.
    pub lo: u64,
    pub hi: u64,
    pub write: bool,
}

impl Access {
    pub fn read_block(base: BaseId, block: u64, intra: (u64, u64)) -> Access {
        Access {
            loc: Loc::Block { base, block },
            lo: intra.0,
            hi: intra.1,
            write: false,
        }
    }

    pub fn write_block(base: BaseId, block: u64, intra: (u64, u64)) -> Access {
        Access {
            loc: Loc::Block { base, block },
            lo: intra.0,
            hi: intra.1,
            write: true,
        }
    }

    pub fn read_stage(tag: Tag) -> Access {
        Access {
            loc: Loc::Stage(tag),
            lo: 0,
            hi: u64::MAX,
            write: false,
        }
    }

    pub fn write_stage(tag: Tag) -> Access {
        Access {
            loc: Loc::Stage(tag),
            lo: 0,
            hi: u64::MAX,
            write: true,
        }
    }

    /// Two accesses conflict when they touch the same location, their
    /// intervals overlap, and at least one writes.
    #[inline]
    pub fn conflicts(&self, other: &Access) -> bool {
        self.loc == other.loc
            && (self.write || other.write)
            && self.lo < other.hi
            && other.lo < self.hi
    }
}

/// Block-level compute kernels. Elementwise kernels map 1:1 onto the L1
/// Pallas kernels (python/compile/kernels/); the Rust native backend
/// mirrors them for shapes with no AOT artifact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// out = in
    Copy,
    /// out = a + b
    Add,
    /// out = a - b
    Sub,
    /// out = a * b
    Mul,
    /// out = a / b
    Div,
    /// out = a + alpha * b
    Axpy(f32),
    /// out = alpha * a
    Scale(f32),
    /// out = |a - b|
    AbsDiff,
    /// out = 0.2 * (c + u + d + l + r)  — fused 5-point stencil
    Stencil5,
    /// out = BlackScholes(s, x, t) with fixed (r, v)
    BlackScholes,
    /// out = mandelbrot iteration count; payload = max iterations
    Fractal(u32),
    /// C += A @ B with inner dim k (inputs: [C-in? no — dst doubles as
    /// accumulator], A panel, B panel); payload = (n, k, m)
    MatmulAcc { n: u64, k: u64, m: u64 },
    /// staged scalar = sum(a)
    PartialSum,
    /// staged scalar = sum(|a - b|)
    PartialAbsDiffSum,
    /// staged scalar = sum of staged partial scalars
    AccumSum,
}

impl Kernel {
    /// Floating-point operations per output element (cost model).
    pub fn flops_per_elem(&self) -> f64 {
        match self {
            Kernel::Copy => 0.0,
            Kernel::Add | Kernel::Sub | Kernel::Mul => 1.0,
            Kernel::Div => 4.0,
            Kernel::Axpy(_) => 2.0,
            Kernel::Scale(_) => 1.0,
            Kernel::AbsDiff => 2.0,
            Kernel::Stencil5 => 5.0,
            // log, exp, sqrt, erf ~ 15 flops each in a scalar libm.
            Kernel::BlackScholes => 60.0,
            Kernel::Fractal(iters) => 14.0 * *iters as f64,
            Kernel::MatmulAcc { k, .. } => 2.0 * *k as f64,
            Kernel::PartialSum => 1.0,
            Kernel::PartialAbsDiffSum => 3.0,
            Kernel::AccumSum => 1.0,
        }
    }

    /// Memory traffic in bytes per output element (inputs + output).
    pub fn bytes_per_elem(&self, n_inputs: usize) -> f64 {
        match self {
            // Reductions read inputs, write O(1).
            Kernel::PartialSum | Kernel::PartialAbsDiffSum | Kernel::AccumSum => {
                4.0 * n_inputs as f64
            }
            // Matmul traffic accounted separately via elems ~ n*m and k.
            Kernel::MatmulAcc { .. } => 12.0,
            _ => 4.0 * (n_inputs as f64 + 1.0),
        }
    }

    pub fn is_reduction(&self) -> bool {
        matches!(
            self,
            Kernel::PartialSum | Kernel::PartialAbsDiffSum | Kernel::AccumSum
        )
    }

    /// Name of the AOT HLO artifact implementing this kernel at the
    /// artifact's block shape, if one exists.
    pub fn artifact(&self) -> Option<&'static str> {
        match self {
            Kernel::Add => Some("add1d"),
            Kernel::Sub => Some("sub2d"),
            Kernel::Div => None,
            Kernel::Mul => Some("mul2d"),
            Kernel::Axpy(_) => Some("axpy1d"),
            Kernel::Stencil5 => Some("stencil5v"),
            Kernel::BlackScholes => Some("black_scholes"),
            Kernel::Fractal(_) => Some("fractal"),
            Kernel::MatmulAcc { .. } => Some("matmul"),
            _ => None,
        }
    }
}

/// A rectangular region inside one base-block (real-data addressing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Region {
    pub base: BaseId,
    pub block: u64,
    /// First row, local to the block.
    pub row0: u64,
    pub nrows: u64,
    /// First column (flattened trailing dims) and width.
    pub col0: u64,
    pub ncols: u64,
    /// Elements per block row (stride between consecutive rows).
    pub row_stride: u64,
}

impl Region {
    pub fn elems(&self) -> u64 {
        self.nrows * self.ncols
    }

    /// Placeholder region (dummy operands in tests and defaults).
    pub fn scalar() -> Region {
        Region {
            base: BaseId(u32::MAX),
            block: 0,
            row0: 0,
            nrows: 1,
            col0: 0,
            ncols: 1,
            row_stride: 1,
        }
    }
}

/// Where a send operation's payload comes from on the sender.
#[derive(Clone, Debug, PartialEq)]
pub enum SendSrc {
    /// Serialize a rectangular region out of the sender's base-blocks.
    Region(Region),
    /// Forward the sender's staging buffer stored under this tag
    /// (reduction partials, tree-collective forwarding hops).
    Stage(Tag),
    /// An aggregated message (`comm::aggregate`): several constituent
    /// transfers packed into one wire message. Each part pairs the
    /// constituent's original staging tag with its source; the receiver
    /// unpacks every part into its own staging buffer. Parts are never
    /// themselves `Packed`.
    Packed(Vec<(Tag, SendSrc)>),
}

impl SendSrc {
    /// Number of wire-level constituents (1 except for packed messages).
    pub fn parts(&self) -> usize {
        match self {
            SendSrc::Packed(p) => p.len(),
            _ => 1,
        }
    }
}

/// A compute input: a local block region or a staged (received) buffer.
#[derive(Clone, Debug, PartialEq)]
pub enum Operand {
    Local(Region),
    Staged(Tag),
}

/// Compute destination: a local block region or a staging slot (for
/// reduction partials/results).
#[derive(Clone, Debug, PartialEq)]
pub enum Dst {
    Block(Region),
    Stage(Tag),
}

/// One block-level compute task.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeTask {
    pub kernel: Kernel,
    pub inputs: Vec<Operand>,
    pub dst: Dst,
    /// Output elements (cost model driver).
    pub elems: u64,
}

/// Payload of an operation-node.
#[derive(Clone, Debug, PartialEq)]
pub enum OpPayload {
    Compute(ComputeTask),
    Send {
        peer: Rank,
        tag: Tag,
        bytes: u64,
        /// What to serialize on the sender (real-data mode).
        src: SendSrc,
    },
    Recv {
        peer: Rank,
        tag: Tag,
        bytes: u64,
    },
}

/// An operation-node (paper Fig. 7): payload + access-nodes + owner rank.
#[derive(Clone, Debug)]
pub struct OpNode {
    pub id: OpId,
    pub rank: Rank,
    /// Array-level operation this fragment belongs to (one group per
    /// recorded ufunc/reduction/SUMMA step). The blocking baseline uses
    /// it to phase execution per §5.3: exchange all elements of an array
    /// operation, then compute it.
    pub group: u32,
    pub payload: OpPayload,
    pub accesses: Vec<Access>,
}

impl OpNode {
    #[inline]
    pub fn is_comm(&self) -> bool {
        !matches!(self.payload, OpPayload::Compute(_))
    }

    /// One-line provenance for diagnostics (the hazard oracle's race
    /// reports, deadlock messages): id, rank, epoch group and the
    /// kernel or transfer identity.
    pub fn describe(&self) -> String {
        let what = match &self.payload {
            OpPayload::Compute(t) => format!("compute {:?} ({} elems)", t.kernel, t.elems),
            OpPayload::Send { peer, tag, bytes, .. } => {
                format!("send {tag:?} -> rank {} ({bytes} B)", peer.0)
            }
            OpPayload::Recv { peer, tag, bytes } => {
                format!("recv {tag:?} <- rank {} ({bytes} B)", peer.0)
            }
        };
        format!(
            "op {} [rank {}, group {}: {what}]",
            self.id.0, self.rank.0, self.group
        )
    }

    /// (flops, memory bytes) of a compute op for the cost model.
    pub fn compute_cost(&self) -> Option<(f64, f64)> {
        match &self.payload {
            OpPayload::Compute(t) => {
                let flops = t.kernel.flops_per_elem() * t.elems as f64;
                let bytes = t.kernel.bytes_per_elem(t.inputs.len()) * t.elems as f64;
                Some((flops, bytes))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conflict_rules() {
        let b = BaseId(0);
        let r1 = Access::read_block(b, 0, (0, 10));
        let r2 = Access::read_block(b, 0, (5, 15));
        let w1 = Access::write_block(b, 0, (5, 15));
        let w2 = Access::write_block(b, 0, (20, 30));
        let other_block = Access::write_block(b, 1, (0, 10));
        assert!(!r1.conflicts(&r2), "read-read never conflicts");
        assert!(r1.conflicts(&w1), "overlapping read-write conflicts");
        assert!(w1.conflicts(&r1));
        assert!(!r1.conflicts(&w2), "disjoint intervals don't conflict");
        assert!(!w1.conflicts(&other_block), "different blocks never conflict");
        let w3 = Access::write_block(b, 0, (0, 6));
        assert!(w1.conflicts(&w3), "write-write overlapping conflicts");
    }

    #[test]
    fn stage_conflicts() {
        let w = Access::write_stage(Tag(7));
        let r = Access::read_stage(Tag(7));
        let r8 = Access::read_stage(Tag(8));
        assert!(w.conflicts(&r));
        assert!(!w.conflicts(&r8));
    }

    #[test]
    fn kernel_flops_sane() {
        assert_eq!(Kernel::Add.flops_per_elem(), 1.0);
        assert_eq!(Kernel::Stencil5.flops_per_elem(), 5.0);
        assert_eq!(
            Kernel::MatmulAcc { n: 4, k: 32, m: 4 }.flops_per_elem(),
            64.0
        );
        assert!(Kernel::Fractal(32).flops_per_elem() > 100.0);
    }

    #[test]
    fn region_elems() {
        let r = Region {
            base: BaseId(0),
            block: 0,
            row0: 1,
            nrows: 3,
            col0: 2,
            ncols: 5,
            row_stride: 10,
        };
        assert_eq!(r.elems(), 15);
    }

    #[test]
    fn send_src_parts() {
        assert_eq!(SendSrc::Stage(Tag(0)).parts(), 1);
        assert_eq!(SendSrc::Region(Region::scalar()).parts(), 1);
        let packed = SendSrc::Packed(vec![
            (Tag(1), SendSrc::Region(Region::scalar())),
            (Tag(2), SendSrc::Region(Region::scalar())),
        ]);
        assert_eq!(packed.parts(), 2);
    }

    #[test]
    fn describe_names_id_rank_and_payload() {
        let op = OpNode {
            id: OpId(3),
            rank: Rank(1),
            group: 2,
            payload: OpPayload::Recv {
                peer: Rank(0),
                tag: Tag(9),
                bytes: 64,
            },
            accesses: vec![],
        };
        let d = op.describe();
        assert!(d.contains("op 3"), "{d}");
        assert!(d.contains("rank 1"), "{d}");
        assert!(d.contains("recv"), "{d}");
        assert!(d.contains("Tag(9)"), "{d}");
    }

    #[test]
    fn boundary_touch_no_overlap() {
        let b = BaseId(0);
        let w1 = Access::write_block(b, 0, (0, 10));
        let w2 = Access::write_block(b, 0, (10, 20));
        assert!(!w1.conflicts(&w2), "half-open intervals: [0,10) vs [10,20)");
    }
}
