//! The distributed universal-function engine (paper Section 5.3).
//!
//! A ufunc applies elementwise over array-views. The engine splits each
//! recorded ufunc into *fragment tasks* — pieces that touch exactly one
//! sub-view-block of every operand — following the paper's 4-step
//! distributed-ufunc scheme:
//!
//! 1. computation is distributed by the **output** view's layout: the rank
//!    owning an output fragment computes it;
//! 2. remote input fragments become send/recv operation pairs;
//! 3. the local computation is one compute operation per fragment;
//! 4. (write-back is unnecessary here because computation is assigned at
//!    output sub-view-block granularity, so outputs are always local.)
//!
//! For aligned operands this degenerates to one compute op per base-block
//! with no communication — the paper's double-buffering case. For
//! non-aligned operands (stencil views) it produces exactly the
//! DAG of the paper's Fig. 5.

pub mod op;

pub use op::{Access, ComputeTask, Dst, Kernel, Loc, OpNode, OpPayload, Operand, Region, SendSrc};

use crate::array::Registry;
use crate::comm::Collective;
use crate::layout::{fragments, FragOperand};
use crate::layout::{sub_view_blocks, ViewSpec};
use crate::types::{OpId, Rank, Tag};

/// Builds operation-nodes from array-level requests. One builder per
/// context; operation ids and §5.3 groups restart every flush batch,
/// but **tags are unique across the whole run** — staging buffers (and
/// therefore [`crate::lazy::ScalarFuture`]s) stay addressable across
/// later flush epochs, and the persistent network never sees a tag
/// reused while its transfer could still matter. The registry is passed
/// per call so the owning context can keep allocating arrays
/// mid-recording.
#[derive(Default)]
pub struct OpBuilder {
    pub ops: Vec<OpNode>,
    next_tag: u64,
    group: u32,
}

impl OpBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drain the recorded batch, resetting ids and groups for the next
    /// one. The tag counter is *not* reset (run-unique tags, see above).
    pub fn take(&mut self) -> Vec<OpNode> {
        self.group = 0;
        std::mem::take(&mut self.ops)
    }

    pub fn fresh_tag(&mut self) -> Tag {
        let t = Tag(self.next_tag);
        self.next_tag += 1;
        t
    }

    /// Start a new array-level operation group (§5.3 phasing unit).
    pub fn begin_group(&mut self) {
        self.group += 1;
    }

    pub(crate) fn push(&mut self, rank: Rank, payload: OpPayload, accesses: Vec<Access>) -> OpId {
        let id = OpId(self.ops.len() as u32);
        self.ops.push(OpNode {
            id,
            rank,
            group: self.group,
            payload,
            accesses,
        });
        id
    }

    fn region_of(&self, reg: &Registry, fo: &FragOperand, view: &ViewSpec) -> Region {
        let layout = reg.layout(fo.base);
        let (blk_lo, _) = layout.block_rows_range(fo.block);
        let (col0, ncols) = match view.shape.len() {
            1 => (0, 1),
            2 => (view.offset[1], view.shape[1]),
            // >2-D regions only occur in simulation mode where data is
            // never materialized; collapse trailing dims conservatively.
            _ => (0, view.shape[1..].iter().product()),
        };
        Region {
            base: fo.base,
            block: fo.block,
            row0: fo.global_rows.0 - blk_lo,
            nrows: fo.global_rows.1 - fo.global_rows.0,
            col0,
            ncols,
            row_stride: if view.shape.len() == 1 {
                1
            } else {
                layout.row_elems()
            },
        }
    }

    /// Record a transfer of `region` (on its owner) to `to`; returns the
    /// staging tag the receiver can use as a compute input.
    pub fn transfer(&mut self, from: Rank, to: Rank, region: Region, intra: (u64, u64)) -> Tag {
        let tag = self.fresh_tag();
        let bytes = region.elems() * 4;
        self.push(
            from,
            OpPayload::Send {
                peer: to,
                tag,
                bytes,
                src: SendSrc::Region(region.clone()),
            },
            vec![Access::read_block(region.base, region.block, intra)],
        );
        self.push(
            to,
            OpPayload::Recv {
                peer: from,
                tag,
                bytes,
            },
            vec![Access::write_stage(tag)],
        );
        tag
    }

    /// Record one elementwise ufunc: `out = kernel(ins...)`.
    /// All views must share a shape; the output view's owner computes.
    pub fn ufunc(&mut self, reg: &Registry, kernel: Kernel, out: &ViewSpec, ins: &[&ViewSpec]) {
        self.begin_group();
        let out_layout = reg.layout(out.base).clone();
        let mut layouts = vec![&out_layout];
        let in_layouts: Vec<_> = ins.iter().map(|v| reg.layout(v.base).clone()).collect();
        for l in &in_layouts {
            layouts.push(l);
        }
        let mut views: Vec<&ViewSpec> = vec![out];
        views.extend_from_slice(ins);

        let frags = fragments(&layouts, &views);
        for f in &frags {
            let out_op = &f.operands[0];
            let rank = out_op.owner;
            let mut inputs = Vec::with_capacity(ins.len());
            let mut accesses = Vec::with_capacity(ins.len() + 1);
            let mut net_elems = 0u64;
            for (i, fo) in f.operands.iter().enumerate().skip(1) {
                let region = self.region_of(reg, fo, views[i]);
                if fo.owner == rank {
                    accesses.push(Access::read_block(fo.base, fo.block, fo.intra_block));
                    inputs.push(Operand::Local(region));
                } else {
                    let tag = self.transfer(fo.owner, rank, region, fo.intra_block);
                    accesses.push(Access::read_stage(tag));
                    inputs.push(Operand::Staged(tag));
                    net_elems += (f.view_rows.1 - f.view_rows.0)
                        * views[i].shape[1..].iter().product::<u64>().max(1);
                }
            }
            let out_region = self.region_of(reg, out_op, out);
            let elems = out_region.elems();
            accesses.push(Access::write_block(
                out_op.base,
                out_op.block,
                out_op.intra_block,
            ));
            let _ = net_elems;
            self.push(
                rank,
                OpPayload::Compute(ComputeTask {
                    kernel,
                    inputs,
                    dst: Dst::Block(out_region),
                    elems,
                }),
                accesses,
            );
        }
    }

    /// Record a full reduction `sum(kernel over view(s))` to a staged
    /// scalar on rank 0. `kernel` must be a reducing kernel
    /// ([`Kernel::PartialSum`] or [`Kernel::PartialAbsDiffSum`]).
    /// The final cross-rank fan-in is scheduled by `collective`:
    /// [`Collective::Flat`] sends every rank's partial straight to the
    /// root (the paper's gather), [`Collective::Tree`] combines them
    /// along a binomial tree ([`crate::comm`]).
    /// Returns the tag holding the final result on rank 0.
    pub fn reduce(
        &mut self,
        reg: &Registry,
        kernel: Kernel,
        views: &[&ViewSpec],
        collective: Collective,
    ) -> Tag {
        self.begin_group();
        assert!(kernel.is_reduction());
        let layouts: Vec<_> = views
            .iter()
            .map(|v| reg.layout(v.base).clone())
            .collect();
        let layout_refs: Vec<&_> = layouts.iter().collect();
        let frags = fragments(&layout_refs, views);

        // Partial per fragment on the rank owning the *first* operand.
        let mut partial_tags: Vec<(Rank, Tag)> = Vec::new();
        for f in &frags {
            let rank = f.operands[0].owner;
            let mut inputs = Vec::new();
            let mut accesses = Vec::new();
            for (i, fo) in f.operands.iter().enumerate() {
                let region = self.region_of(reg, fo, views[i]);
                if fo.owner == rank {
                    accesses.push(Access::read_block(fo.base, fo.block, fo.intra_block));
                    inputs.push(Operand::Local(region));
                } else {
                    let tag = self.transfer(fo.owner, rank, region, fo.intra_block);
                    accesses.push(Access::read_stage(tag));
                    inputs.push(Operand::Staged(tag));
                }
            }
            let ptag = self.fresh_tag();
            accesses.push(Access::write_stage(ptag));
            let elems = f.nrows() * views[0].shape[1..].iter().product::<u64>().max(1);
            self.push(
                rank,
                OpPayload::Compute(ComputeTask {
                    kernel,
                    inputs,
                    dst: Dst::Stage(ptag),
                    elems,
                }),
                accesses,
            );
            partial_tags.push((rank, ptag));
        }

        // Combine each rank's block partials into one local scalar
        // before the gather — one message per rank, not per block (the
        // root would otherwise serialize P·blocks α-latencies under
        // blocking execution). Its own group: it reads the partial
        // stages computed above.
        self.begin_group();
        let mut rank_tags: Vec<(Rank, Tag)> = Vec::new();
        for idx in 0..partial_tags.len() {
            let rank = partial_tags[idx].0;
            if partial_tags[..idx].iter().any(|(r, _)| *r == rank) {
                continue; // this rank's partials already combined
            }
            let mine: Vec<Tag> = partial_tags
                .iter()
                .filter(|(r, _)| *r == rank)
                .map(|(_, t)| *t)
                .collect();
            if mine.len() == 1 {
                rank_tags.push((rank, mine[0]));
                continue;
            }
            let ctag = self.fresh_tag();
            let mut accesses: Vec<Access> =
                mine.iter().map(|&t| Access::read_stage(t)).collect();
            accesses.push(Access::write_stage(ctag));
            let n = mine.len() as u64;
            self.push(
                rank,
                OpPayload::Compute(ComputeTask {
                    kernel: Kernel::AccumSum,
                    inputs: mine.into_iter().map(Operand::Staged).collect(),
                    dst: Dst::Stage(ctag),
                    elems: n,
                }),
                accesses,
            );
            rank_tags.push((rank, ctag));
        }

        // Fan the per-rank scalars in to rank 0 (as DistNumPy does for
        // scalar reductions) and accumulate. Separate groups: the
        // fan-in sends read the stages combined above, so §5.3 phasing
        // must not hoist them ahead of the combines.
        let root = Rank(0);
        if collective == Collective::Tree {
            return crate::comm::reduce_scalar_tree(self, &rank_tags, root);
        }
        self.begin_group();
        let partial_tags = rank_tags;
        let mut accum_inputs = Vec::new();
        let mut accum_accesses = Vec::new();
        for (rank, ptag) in partial_tags {
            if rank == root {
                accum_inputs.push(Operand::Staged(ptag));
                accum_accesses.push(Access::read_stage(ptag));
            } else {
                // The transfer reuses the partial's stage tag: data
                // backends forward the sender's stage under the
                // transfer tag itself.
                self.push(
                    rank,
                    OpPayload::Send {
                        peer: root,
                        tag: ptag,
                        bytes: 8,
                        src: SendSrc::Stage(ptag),
                    },
                    vec![Access::read_stage(ptag)],
                );
                self.push(
                    root,
                    OpPayload::Recv {
                        peer: rank,
                        tag: ptag,
                        bytes: 8,
                    },
                    vec![Access::write_stage(ptag)],
                );
                accum_inputs.push(Operand::Staged(ptag));
                accum_accesses.push(Access::read_stage(ptag));
            }
        }
        let result = self.fresh_tag();
        accum_accesses.push(Access::write_stage(result));
        let n = accum_inputs.len() as u64;
        self.push(
            root,
            OpPayload::Compute(ComputeTask {
                kernel: Kernel::AccumSum,
                inputs: accum_inputs,
                dst: Dst::Stage(result),
                elems: n,
            }),
            accum_accesses,
        );
        result
    }

    /// Broadcast a region from its owner to every other rank; returns the
    /// staging tag per rank (index = rank). Used by SUMMA.
    pub fn broadcast(
        &mut self,
        reg: &Registry,
        region: Region,
        intra: (u64, u64),
        nprocs: u32,
    ) -> Vec<Option<Tag>> {
        let owner = reg.layout(region.base).owner(region.block);
        let mut tags = vec![None; nprocs as usize];
        for r in 0..nprocs {
            let to = Rank(r);
            if to == owner {
                continue;
            }
            let tag = self.transfer(owner, to, region.clone(), intra);
            tags[r as usize] = Some(tag);
        }
        tags
    }

    /// Record an opaque local compute op (used by SUMMA and the apps for
    /// kernels that are not simple elementwise ufuncs).
    pub fn compute(
        &mut self,
        rank: Rank,
        task: ComputeTask,
        accesses: Vec<Access>,
    ) -> OpId {
        self.push(rank, OpPayload::Compute(task), accesses)
    }

    /// Convenience: all sub-view-blocks of a view with their regions
    /// and conservative intra-block intervals.
    pub fn svb_regions(&self, reg: &Registry, view: &ViewSpec) -> Vec<(Region, (u64, u64), Rank)> {
        let layout = reg.layout(view.base);
        sub_view_blocks(layout, view)
            .iter()
            .map(|s| {
                let fo = FragOperand {
                    base: view.base,
                    block: s.block,
                    owner: s.owner,
                    global_rows: s.global_rows,
                    intra_block: {
                        let (blk_lo, _) = layout.block_rows_range(s.block);
                        let re = layout.row_elems();
                        let (clo, chi) = view.col_bounds(layout);
                        (
                            (s.global_rows.0 - blk_lo) * re + clo,
                            (s.global_rows.1 - 1 - blk_lo) * re + chi + 1,
                        )
                    },
                };
                (
                    self.region_of(reg, &fo, view),
                    fo.intra_block,
                    s.owner,
                )
            })
            .collect()
    }

    pub fn finish(self) -> Vec<OpNode> {
        self.ops
    }

    pub fn n_recorded(&self) -> usize {
        self.ops.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::types::DType;

    fn setup() -> (Registry, ViewSpec, ViewSpec, ViewSpec) {
        let mut reg = Registry::new(2);
        let m = reg.alloc(vec![6], 3, DType::F32);
        let n = reg.alloc(vec![6], 3, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(n);
        let a = mv.slice(&[(2, 6)]);
        let b = mv.slice(&[(0, 4)]);
        let c = nv.slice(&[(1, 5)]);
        (reg, a, b, c)
    }

    /// The paper's Fig. 5: the 3-point stencil generates 4 compute ops and
    /// exactly one send/recv pair (M[3] from p1 to p0 for fragment 1 and
    /// M[2] from p0 to p1 for fragment 2).
    #[test]
    fn stencil3_generates_fig5_dag_ops() {
        let (reg, a, b, c) = setup();
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &c, &[&a, &b]);
        let ops = bld.finish();
        let n_compute = ops
            .iter()
            .filter(|o| matches!(o.payload, OpPayload::Compute(_)))
            .count();
        let n_send = ops
            .iter()
            .filter(|o| matches!(o.payload, OpPayload::Send { .. }))
            .count();
        let n_recv = ops
            .iter()
            .filter(|o| matches!(o.payload, OpPayload::Recv { .. }))
            .count();
        assert_eq!(n_compute, 4);
        assert_eq!(n_send, 2);
        assert_eq!(n_recv, 2);
        // Fragment computes land on the output owner.
        for o in &ops {
            if let OpPayload::Compute(t) = &o.payload {
                if let Dst::Block(r) = &t.dst {
                    assert_eq!(reg.layout(r.base).owner(r.block), o.rank);
                }
            }
        }
    }

    #[test]
    fn aligned_ufunc_no_comm() {
        let mut reg = Registry::new(4);
        let x = reg.alloc(vec![64], 4, DType::F32);
        let y = reg.alloc(vec![64], 4, DType::F32);
        let xv = reg.full_view(x);
        let yv = reg.full_view(y);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &yv, &[&xv, &yv]);
        let ops = bld.finish();
        assert!(ops
            .iter()
            .all(|o| matches!(o.payload, OpPayload::Compute(_))));
        assert_eq!(ops.len(), 16); // one per block
    }

    #[test]
    fn reduce_produces_root_result() {
        let mut reg = Registry::new(3);
        let x = reg.alloc(vec![30], 5, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        let _tag = bld.reduce(&reg, Kernel::PartialSum, &[&xv], Collective::Flat);
        let ops = bld.finish();
        // 6 block partials (2 per rank) -> 3 local combines; then one
        // message per remote rank (1, 2) and the final accumulate.
        let n_send = ops
            .iter()
            .filter(|o| matches!(o.payload, OpPayload::Send { .. }))
            .count();
        assert_eq!(n_send, 2, "one gather message per remote rank");
        let accum = ops
            .iter()
            .filter(|o| {
                matches!(&o.payload, OpPayload::Compute(t) if t.kernel == Kernel::AccumSum)
            })
            .count();
        assert_eq!(accum, 4, "3 per-rank combines + 1 root accumulate");
        // Final accum on rank 0.
        let last = ops.last().unwrap();
        assert_eq!(last.rank, Rank(0));
    }

    #[test]
    fn broadcast_sends_to_all_but_owner() {
        let mut reg = Registry::new(4);
        let x = reg.alloc(vec![16], 4, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        let regions = bld.svb_regions(&reg, &xv);
        let (r0, intra, owner) = regions[1].clone();
        assert_eq!(owner, Rank(1));
        let tags = bld.broadcast(&reg, r0, intra, 4);
        assert!(tags[1].is_none());
        assert_eq!(tags.iter().flatten().count(), 3);
        let ops = bld.finish();
        assert_eq!(ops.len(), 6); // 3 send + 3 recv
    }
}
