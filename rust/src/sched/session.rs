//! The resumable scheduler session — one dispatch *run* of the
//! discrete-event engines, open for op injection while its event loop
//! is live.
//!
//! Before PR 5 the three policies were run-to-completion functions: the
//! epoch-local scheduling state (ready queues, event heap, per-rank
//! rank-states, transfer table, costs) lived on the stack of one call
//! and died with it, so a merged Flow wave was the largest schedulable
//! unit — epoch *k+W* could not enter the schedulers until the whole
//! wave containing epoch *k* had drained. [`SchedSession`] hoists that
//! state into a struct that lives alongside [`ExecState`]:
//!
//! * [`SchedSession::inject`] splices newly-admitted operations into
//!   the *running* event loop. The already-scheduled timeline is first
//!   advanced through every event at or before the new ops' admission
//!   horizon (they cannot start earlier, so that prefix is final);
//!   the tail is then registered (transfer pairs, costs, dependency
//!   system, retirement log) and parked ranks — including ranks that
//!   ran out of work entirely — are woken at their own clocks, with
//!   any admission gap charged through [`ExecState::gate_admission`]
//!   exactly as in a merged wave.
//! * [`SchedSession::pump_next`] advances the loop one event at a time
//!   (the flow engine uses it to learn retirement times the sliding
//!   window gate needs), [`SchedSession::drain`] runs to quiescence
//!   and verifies every injected operation retired.
//!
//! A Batch epoch — and a quantized Flow wave — is simply one inject
//! followed by one drain, which reproduces the pre-session scheduler
//! behaviour operation for operation: there is no separate legacy code
//! path. Injected ops must arrive renumbered so their ids continue the
//! session's contiguous stream ([`crate::flow::frontier::Splicer`] for
//! sliding admission; [`crate::flow::frontier::merge`] for waves).

use super::blocking::BlockingSession;
use super::lh::LhSession;
use super::naive::NaiveSession;
use super::{ExecState, Policy, SchedCfg, SchedError};
use crate::exec::Backend;
use crate::profile::Phase;
use crate::types::VTime;
use crate::ufunc::OpNode;

enum Engine {
    Lh(LhSession),
    Blocking(BlockingSession),
    Naive(NaiveSession),
}

/// A live scheduler run: the op stream injected so far plus the
/// policy's resumable engine state.
pub struct SchedSession {
    pub policy: Policy,
    ops: Vec<OpNode>,
    injected: bool,
    counted: usize,
    eng: Engine,
    /// `SchedCfg::verify_deps`: run the hazard oracle over the injected
    /// stream on every drain, with this dependency system.
    verify: Option<super::DepsKind>,
    /// Stream prefix already verified/linted (the oracle re-runs on the
    /// full stream and deltas its counters against these).
    verified: crate::analyze::HazardStats,
    verified_lints: u64,
    predicted: bool,
}

impl SchedSession {
    /// Open a session. One session is one scheduler *run*: stage
    /// provenance and the retirement log are keyed on it, so opening
    /// bumps [`ExecState::run_id`].
    pub fn new(policy: Policy, cfg: &SchedCfg, st: &mut ExecState) -> Self {
        st.run_id += 1;
        let eng = match policy {
            Policy::LatencyHiding => Engine::Lh(LhSession::new(cfg)),
            Policy::Blocking => Engine::Blocking(BlockingSession::new(cfg)),
            Policy::Naive => Engine::Naive(NaiveSession::new(cfg)),
        };
        SchedSession {
            policy,
            ops: Vec::new(),
            injected: false,
            counted: 0,
            eng,
            verify: cfg.verify_deps.then_some(cfg.deps),
            verified: crate::analyze::HazardStats::default(),
            verified_lints: 0,
            predicted: false,
        }
    }

    /// Operations injected so far.
    pub fn total(&self) -> usize {
        self.ops.len()
    }

    /// Splice `ops` into the (possibly running) event loop.
    ///
    /// `admit` carries one admission time per op (streamed recording —
    /// the ops may not execute earlier; appended to [`ExecState::admit`]
    /// so the per-op gates apply), or `None` for a Batch epoch whose
    /// recording is charged on the rank clocks instead. Ids must
    /// continue the session's contiguous stream.
    pub fn inject(
        &mut self,
        ops: Vec<OpNode>,
        admit: Option<&[VTime]>,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        st: &mut ExecState,
    ) -> Result<(), SchedError> {
        // Profiler phase `Inject` spans the whole splice, including the
        // internal prefix pump (charged here, not to `Pump`).
        let t0 = st.prof.start();
        let res = self.inject_inner(ops, admit, cfg, backend, st);
        st.prof.stop(Phase::Inject, t0);
        res
    }

    fn inject_inner(
        &mut self,
        ops: Vec<OpNode>,
        admit: Option<&[VTime]>,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        st: &mut ExecState,
    ) -> Result<(), SchedError> {
        let lo = self.ops.len();
        debug_assert!(
            ops.iter()
                .enumerate()
                .all(|(k, o)| o.id.idx() == lo + k),
            "session ops must be renumbered contiguously"
        );
        if let Some(ts) = admit {
            debug_assert_eq!(ts.len(), ops.len(), "one admission time per op");
            // Advance the live loop through the timeline prefix the new
            // ops can no longer affect: everything at or before their
            // admission horizon. (Events beyond it stay pending and
            // interleave with the new ops through the shared heap.)
            let horizon = ts.iter().cloned().fold(f64::INFINITY, f64::min);
            if horizon.is_finite() {
                self.pump_raw(horizon, backend, st);
            }
        }
        if let Some(cap) = st.capture.as_mut() {
            match cap.last_mut() {
                Some((run, stream)) if *run == st.run_id => stream.extend(ops.iter().cloned()),
                _ => cap.push((st.run_id, ops.clone())),
            }
        }
        self.ops.extend(ops);
        match &mut self.eng {
            Engine::Lh(e) => e.extend(&self.ops, lo, cfg)?,
            Engine::Blocking(e) => e.extend(&self.ops, lo, cfg)?,
            Engine::Naive(e) => e.extend(&self.ops, lo, cfg)?,
        }
        if self.injected {
            st.extend_epoch(&self.ops[lo..]);
        } else {
            st.begin_epoch(&self.ops);
            self.injected = true;
        }
        if let Some(ts) = admit {
            debug_assert_eq!(st.admit.len(), lo, "admission log out of step");
            st.admit.extend_from_slice(ts);
        }
        match &mut self.eng {
            Engine::Lh(e) => e.activate(&self.ops, lo, cfg, backend, st),
            Engine::Blocking(e) => e.activate(&self.ops, lo, cfg, backend, st),
            Engine::Naive(e) => e.activate(&self.ops, lo, cfg, backend, st),
        }
        Ok(())
    }

    /// Advance the event loop through every event at or before `until`.
    pub fn pump_until(&mut self, until: VTime, backend: &mut dyn Backend, st: &mut ExecState) {
        let t0 = st.prof.start();
        self.pump_raw(until, backend, st);
        st.prof.stop(Phase::Pump, t0);
    }

    /// [`SchedSession::pump_until`] without the profiler phase — the
    /// body, shared with `inject` (whose prefix pump bills to `Inject`).
    fn pump_raw(&mut self, until: VTime, backend: &mut dyn Backend, st: &mut ExecState) {
        match &mut self.eng {
            Engine::Lh(e) => e.pump_until(&self.ops, st, backend, until),
            Engine::Blocking(e) => e.pump_until(&self.ops, st, backend, until),
            Engine::Naive(e) => e.pump_until(&self.ops, st, backend, until),
        }
    }

    /// Process the earliest pending event; returns its virtual time, or
    /// `None` when the loop is quiescent (which, mid-session, just
    /// means "waiting for the next inject", not "finished").
    pub fn pump_next(&mut self, backend: &mut dyn Backend, st: &mut ExecState) -> Option<VTime> {
        let t0 = st.prof.start();
        let res = match &mut self.eng {
            Engine::Lh(e) => e.pump_next(&self.ops, st, backend),
            Engine::Blocking(e) => e.pump_next(&self.ops, st, backend),
            Engine::Naive(e) => e.pump_next(&self.ops, st, backend),
        };
        st.prof.stop(Phase::Pump, t0);
        res
    }

    /// Run the session to quiescence and verify every injected
    /// operation retired; fold the run's operation counters into the
    /// state. The session stays usable: further injects revive the
    /// loop (the callers that keep one alive drop it themselves when
    /// the run ends).
    pub fn drain(&mut self, backend: &mut dyn Backend, st: &mut ExecState) -> Result<(), SchedError> {
        // Profiler phase `Drain` spans the run-to-quiescence plus the
        // nested `Verify` phase (the events/sec denominator counts
        // `Drain` alone, so nesting never double-bills).
        let t0 = st.prof.start();
        let res = self.drain_inner(backend, st);
        st.prof.stop(Phase::Drain, t0);
        res
    }

    fn drain_inner(
        &mut self,
        backend: &mut dyn Backend,
        st: &mut ExecState,
    ) -> Result<(), SchedError> {
        let pool = match &mut self.eng {
            Engine::Lh(e) => {
                e.pump_all(&self.ops, st, backend);
                e.finish_check(&self.ops, st)?;
                e.q.take_pool_stats()
            }
            Engine::Blocking(e) => {
                e.pump_all(&self.ops, st, backend);
                e.finish_check(&self.ops)?;
                e.q.take_pool_stats()
            }
            Engine::Naive(e) => {
                e.pump_all(&self.ops, st, backend);
                e.finish_check(&self.ops)?;
                e.q.take_pool_stats()
            }
        };
        // Sharded sessions (`--workers N`, N ≥ 2): fold the worker
        // pool's per-drain tallies into the profiler's host section.
        // Take semantics on the queue side keep repeated drains of one
        // live session from double-counting.
        if let Some(ps) = pool {
            let workers: Vec<(u64, u64)> = ps.workers.iter().map(|w| (w.events, w.nanos)).collect();
            st.prof.absorb_pool(&workers, ps.steals);
        }
        super::count_epoch_ops(st, &self.ops[self.counted..]);
        self.counted = self.ops.len();
        let tv = st.prof.start();
        let res = self.verify_drained(st);
        st.prof.stop(Phase::Verify, tv);
        res
    }

    /// `SchedCfg::verify_deps`: after a drain, prove the dependency
    /// system ordered every exact conflict edge of the stream executed
    /// so far. The oracle re-checks the full stream (its closure is
    /// prefix-stable, so counters are deltaed against the last check)
    /// and a missed edge — a data race the scheduler could have
    /// exploited — is a hard [`SchedError::Stall`]. Pure bookkeeping:
    /// no clock, wait or retirement state is touched, so verified runs
    /// are bit-identical to unverified ones.
    fn verify_drained(&mut self, st: &mut ExecState) -> Result<(), SchedError> {
        let Some(kind) = self.verify else {
            return Ok(());
        };
        if self.ops.len() == self.verified.ops {
            return Ok(());
        }
        let stats = crate::analyze::hazards::check(&self.ops, kind).map_err(|race| {
            st.verify_races += 1;
            SchedError::Stall(format!("verify_deps: {race}"))
        })?;
        st.verify_dep_edges += stats.dep_edges - self.verified.dep_edges;
        st.verify_excess_edges += stats.excess_edges - self.verified.excess_edges;
        st.verify_serialized_pairs += stats.serialized_pairs - self.verified.serialized_pairs;
        self.verified = stats;
        let lints = crate::analyze::lint::lint_stream(&self.ops).len() as u64;
        st.verify_lints += lints.saturating_sub(self.verified_lints);
        self.verified_lints = lints;
        if !self.predicted && crate::analyze::stalls::predict(self.policy, &self.ops).is_some() {
            self.predicted = true;
            st.verify_predicted += 1;
        }
        Ok(())
    }
}

/// Run one batch as the single epoch of an already-prepared state: the
/// shared body of the `run_*` one-shot entry points.
pub(crate) fn one_shot(
    policy: Policy,
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
    st: &mut ExecState,
) -> Result<(), SchedError> {
    let mut session = SchedSession::new(policy, cfg, st);
    session.inject(ops.to_vec(), None, cfg, backend, st)?;
    session.drain(backend, st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::flow::frontier::Splicer;
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    /// A batch with real transfers (3-point stencil on 2 ranks).
    fn stencil_batch(bld: &mut OpBuilder, nprocs: u32) -> Vec<OpNode> {
        let rows = 12u64;
        let mut reg = Registry::new(nprocs);
        let m = reg.alloc(vec![rows], 3, DType::F32);
        let nn = reg.alloc(vec![rows], 3, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        bld.ufunc(
            &reg,
            Kernel::Add,
            &nv.slice(&[(1, rows - 1)]),
            &[&mv.slice(&[(2, rows)]), &mv.slice(&[(0, rows - 2)])],
        );
        bld.finish()
    }

    /// The PR-5 regression: injecting into a *quiescent-but-unfinished*
    /// session — the first epoch's events all pending or drained, every
    /// rank idle or done — must wake the event loop instead of leaving
    /// the new ops stranded (a deadlock report at drain).
    #[test]
    fn inject_into_quiescent_session_wakes_the_loop() {
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
            let mut st = ExecState::new(&cfg);
            let mut bld = OpBuilder::new();
            let mut splicer = Splicer::new();

            let mut b1 = stencil_batch(&mut bld, 2);
            let (lo1, hi1) = splicer.splice(&mut b1);
            let n1 = b1.len();
            let admit1 = vec![0.0; n1];

            let mut s = SchedSession::new(policy, &cfg, &mut st);
            s.inject(b1, Some(&admit1), &cfg, &mut SimBackend, &mut st)
                .unwrap();
            assert_eq!((lo1, hi1), (0, n1));
            // Admission horizon 0.0: transfers are posted but their
            // completion events are still outstanding in the heap.
            let mid = st.max_clock();

            let mut b2 = stencil_batch(&mut bld, 2);
            let n2 = b2.len();
            splicer.splice(&mut b2);
            let admit2 = vec![mid * 0.5; n2];
            s.inject(b2, Some(&admit2), &cfg, &mut SimBackend, &mut st)
                .unwrap();
            s.drain(&mut SimBackend, &mut st)
                .unwrap_or_else(|e| panic!("{policy:?}: injected epoch stranded: {e}"));
            assert_eq!(st.ops_executed, (n1 + n2) as u64, "{policy:?}");

            // And a *fully* quiescent session (drained, all ranks out of
            // work) revives on a later inject instead of deadlocking.
            let mut b3 = stencil_batch(&mut bld, 2);
            let n3 = b3.len();
            splicer.splice(&mut b3);
            let admit3 = vec![st.max_clock(); n3];
            s.inject(b3, Some(&admit3), &cfg, &mut SimBackend, &mut st)
                .unwrap();
            s.drain(&mut SimBackend, &mut st)
                .unwrap_or_else(|e| panic!("{policy:?}: revived session stranded: {e}"));
            assert_eq!(st.ops_executed, (n1 + n2 + n3) as u64, "{policy:?}");
            assert_eq!(st.run_id, 1, "one session = one scheduler run");
        }
    }

    /// A session-injected stream produces the same per-op admission
    /// gating as the pre-session wave path: ops never execute before
    /// their admission time.
    #[test]
    fn injected_ops_respect_admission_gates() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let mut bld = OpBuilder::new();
        let mut splicer = Splicer::new();
        let mut b1 = stencil_batch(&mut bld, 2);
        splicer.splice(&mut b1);
        let gate = 1.5;
        let admit = vec![gate; b1.len()];
        let mut s = SchedSession::new(Policy::LatencyHiding, &cfg, &mut st);
        s.inject(b1, Some(&admit), &cfg, &mut SimBackend, &mut st)
            .unwrap();
        s.drain(&mut SimBackend, &mut st).unwrap();
        for (r, t) in &st.retire {
            let _ = r;
            assert!(*t >= gate, "op retired at {t} before its admission {gate}");
        }
        assert!(st.wait_at_admission > 0.0, "gating from t=0 stalls ranks");
    }
}
