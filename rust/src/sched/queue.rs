//! The shared discrete-event queue of the three policy engines, in two
//! interchangeable shapes (DESIGN.md §13):
//!
//! * **Global** (`--workers 1`, the default) — one `BinaryHeap` over
//!   all pending events, exactly the seed engines' loop. This is the
//!   frozen reference path: every existing ablation and baseline runs
//!   on it unchanged.
//! * **Sharded** (`--workers N`, N ≥ 2) — one bounded inbox per rank
//!   actor plus a *frontier index* of null messages, pumped by a
//!   deterministic work-stealing pool of N cooperative host workers.
//!
//! ## Null-message synchronization, degenerate form
//!
//! Conservative parallel DES (Chandy–Misra–Bryant) lets an actor
//! advance to `min` over its neighbors' promised timestamp lower
//! bounds, delivered as null messages. Our engines have *zero
//! lookahead* — any event handler may schedule a new event at the very
//! time it runs (a `RecvDone` can immediately ready a compute on
//! another rank) — so the safe bound for every actor degenerates to
//! the global minimum `(t, seq)` key. The frontier index materializes
//! exactly that: each entry is a null message `(t, seq) -> actor`
//! announcing one actor's current head, and the heap over them *is*
//! the min-reduction. Pops therefore commit in the identical global
//! order the single heap would produce, which is what makes
//! `--workers N` bit-identical to the serial path by construction
//! rather than by tolerance.
//!
//! Null messages are published lazily: a push announces itself only
//! when it becomes its actor's head, and a pop re-announces the next
//! head. Superseded announcements are not retracted — they are
//! discarded on contact (`settle`), the classic lazy-deletion trick,
//! bounding the index at ≤ 2 entries per event ever pushed.
//!
//! **Invariant:** every non-empty inbox has at least one frontier
//! entry whose `(t, seq)` equals its current head's. Pushes that
//! create a new head publish one; pops republish the successor;
//! `(t, seq)` keys are globally unique (the `seq` draw), so a stale
//! entry can never *falsely* match. An entry keyed below the global
//! minimum must reference an already-popped event (anything still
//! queued below the minimum would contradict minimality), so `settle`
//! discards it and the surviving top is the true minimum.
//!
//! ## The worker pool
//!
//! Actors are dealt round-robin to `N` workers; every pop is charged
//! to the owning worker's event-count credit. When an owner runs
//! [`STEAL_SLACK`] events ahead of the least-loaded worker, that
//! worker steals the actor (cf. the nonzero-latency steal model of
//! arXiv 1805.01768 — the slack amortizes the handoff). Decisions
//! read **only event counts**, never wall clocks, so the schedule —
//! and `steal_count` itself — is reproducible across machines. The
//! per-worker wall timers exist only under `--profile` and are purely
//! observational ([`PoolStats`] feeds the `host` JSON section, which
//! the perf-compare gate never reads).

use std::collections::BinaryHeap;
use std::time::Instant;

use crate::types::VTime;

/// Min-heap event for the DES engines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct TEvent<E> {
    pub t: VTime,
    pub seq: u64,
    pub ev: E,
}

impl<E: PartialEq> Eq for TEvent<E> {}

impl<E: PartialEq> Ord for TEvent<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E: PartialEq> PartialOrd for TEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Event-count lead at which the least-loaded worker steals an actor
/// from its owner. Small enough to react within an epoch, large enough
/// that a steal amortizes its bookkeeping (arXiv 1805.01768 models the
/// latency term this slack stands in for).
const STEAL_SLACK: u64 = 64;

/// One worker's tally since the last [`EventQueue::take_pool_stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub(crate) struct WorkerStat {
    /// Events this worker committed (deterministic).
    pub events: u64,
    /// Wall nanoseconds attributed to those events — pop through the
    /// next pop, so handler time is included. Zero unless profiled.
    pub nanos: u64,
}

/// A drained snapshot of the worker pool, folded into the profiler's
/// `host` section at session drain ([`crate::profile`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub(crate) struct PoolStats {
    pub workers: Vec<WorkerStat>,
    /// Actor reassignments taken by an under-loaded worker.
    pub steals: u64,
}

/// The deterministic cooperative worker pool: pure event-count
/// accounting plus optional wall timers.
struct Pool {
    /// actor -> owning worker; mutated by steals.
    assign: Vec<usize>,
    events: Vec<u64>,
    nanos: Vec<u64>,
    steals: u64,
    timed: bool,
    /// Last pop's (worker, instant): the next pop closes the interval.
    last: Option<(usize, Instant)>,
}

impl Pool {
    fn new(nactors: usize, workers: usize, timed: bool) -> Self {
        Pool {
            assign: (0..nactors).map(|a| a % workers).collect(),
            events: vec![0; workers],
            nanos: vec![0; workers],
            steals: 0,
            timed,
            last: None,
        }
    }

    /// Charge one committed event against `actor`'s worker, stealing
    /// the actor first if its owner has run too far ahead. Lowest
    /// index wins ties, so the choice is a pure function of the event
    /// counts — wall time never participates.
    fn account(&mut self, actor: usize) {
        let owner = self.assign[actor];
        let (thief, low) = lowest_loaded(&self.events);
        let w = if thief != owner && self.events[owner] >= low + STEAL_SLACK {
            self.assign[actor] = thief;
            self.steals += 1;
            thief
        } else {
            owner
        };
        self.events[w] += 1;
        if self.timed {
            let now = Instant::now();
            if let Some((prev, t0)) = self.last.take() {
                self.nanos[prev] += now.duration_since(t0).as_nanos() as u64;
            }
            self.last = Some((w, now));
        }
    }

    fn take(&mut self) -> PoolStats {
        if let Some((prev, t0)) = self.last.take() {
            self.nanos[prev] += t0.elapsed().as_nanos() as u64;
        }
        let workers = self
            .events
            .iter()
            .zip(&self.nanos)
            .map(|(&events, &nanos)| WorkerStat { events, nanos })
            .collect();
        let steals = self.steals;
        self.events.iter_mut().for_each(|e| *e = 0);
        self.nanos.iter_mut().for_each(|n| *n = 0);
        self.steals = 0;
        PoolStats { workers, steals }
    }
}

/// Lowest-loaded worker: (index, events), lowest index breaking ties.
fn lowest_loaded(events: &[u64]) -> (usize, u64) {
    let mut w = 0;
    let mut lo = events[0];
    for (i, &e) in events.iter().enumerate().skip(1) {
        if e < lo {
            lo = e;
            w = i;
        }
    }
    (w, lo)
}

/// Per-actor shards: one inbox heap per rank plus the frontier index
/// of null messages over their heads.
struct Shards<E: Copy + PartialEq> {
    inbox: Vec<BinaryHeap<TEvent<E>>>,
    frontier: BinaryHeap<TEvent<usize>>,
    pool: Pool,
}

impl<E: Copy + PartialEq> Shards<E> {
    /// Discard stale null messages until the top one exactly matches
    /// its actor's current head. Returns false when drained.
    fn settle(&mut self) -> bool {
        while let Some(top) = self.frontier.peek() {
            match self.inbox[top.ev].peek() {
                Some(h) if h.t == top.t && h.seq == top.seq => return true,
                _ => {
                    self.frontier.pop();
                }
            }
        }
        false
    }
}

enum Inner<E: Copy + PartialEq> {
    Global { heap: BinaryHeap<TEvent<E>> },
    Sharded(Shards<E>),
}

/// The engines' event queue. `workers <= 1` builds the Global shape —
/// byte-for-byte the seed heap — anything larger builds the sharded
/// actor shape. Both pop in identical `(t, seq)` order (module docs).
pub(crate) struct EventQueue<E: Copy + PartialEq> {
    seq: u64,
    inner: Inner<E>,
}

impl<E: Copy + PartialEq> EventQueue<E> {
    pub(crate) fn new(nactors: usize, workers: usize, timed: bool) -> Self {
        let inner = if workers <= 1 {
            Inner::Global {
                heap: BinaryHeap::new(),
            }
        } else {
            Inner::Sharded(Shards {
                inbox: (0..nactors.max(1)).map(|_| BinaryHeap::new()).collect(),
                frontier: BinaryHeap::new(),
                pool: Pool::new(nactors.max(1), workers, timed),
            })
        };
        EventQueue { seq: 0, inner }
    }

    /// Schedule `ev` for `actor` (its rank index) at virtual time `t`.
    pub(crate) fn push(&mut self, t: VTime, actor: usize, ev: E) {
        let e = TEvent {
            t,
            seq: self.seq,
            ev,
        };
        self.seq += 1;
        match &mut self.inner {
            Inner::Global { heap } => heap.push(e),
            Inner::Sharded(s) => {
                let inbox = &mut s.inbox[actor];
                // Fresh seq > every queued seq, so this is a new head
                // iff it is strictly earlier in virtual time.
                let announces = inbox.peek().is_none_or(|h| e.t < h.t);
                inbox.push(e);
                if announces {
                    s.frontier.push(TEvent {
                        t: e.t,
                        seq: e.seq,
                        ev: actor,
                    });
                }
            }
        }
    }

    /// Earliest pending event time, if any.
    pub(crate) fn peek_t(&mut self) -> Option<VTime> {
        match &mut self.inner {
            Inner::Global { heap } => heap.peek().map(|e| e.t),
            Inner::Sharded(s) => {
                if s.settle() {
                    s.frontier.peek().map(|e| e.t)
                } else {
                    None
                }
            }
        }
    }

    /// Commit the globally earliest event (min `(t, seq)`).
    pub(crate) fn pop(&mut self) -> Option<TEvent<E>> {
        match &mut self.inner {
            Inner::Global { heap } => heap.pop(),
            Inner::Sharded(s) => {
                if !s.settle() {
                    return None;
                }
                let a = s.frontier.pop().expect("settled frontier").ev;
                let e = s.inbox[a].pop().expect("matched inbox head");
                // Republish the successor head's null message.
                if let Some(h) = s.inbox[a].peek() {
                    s.frontier.push(TEvent {
                        t: h.t,
                        seq: h.seq,
                        ev: a,
                    });
                }
                s.pool.account(a);
                Some(e)
            }
        }
    }

    /// Drain the worker-pool tallies (None in Global shape). Take
    /// semantics: a second call without new pops reads zeros, so
    /// per-drain folds never double-count.
    pub(crate) fn take_pool_stats(&mut self) -> Option<PoolStats> {
        match &mut self.inner {
            Inner::Global { .. } => None,
            Inner::Sharded(s) => Some(s.pool.take()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tevent_orders_min_first() {
        let mut h = std::collections::BinaryHeap::new();
        h.push(TEvent {
            t: 2.0,
            seq: 0,
            ev: (),
        });
        h.push(TEvent {
            t: 1.0,
            seq: 1,
            ev: (),
        });
        h.push(TEvent {
            t: 1.0,
            seq: 0,
            ev: (),
        });
        assert_eq!(h.pop().unwrap().seq, 0);
        assert_eq!(h.pop().unwrap().t, 1.0);
        assert_eq!(h.pop().unwrap().t, 2.0);
    }

    /// The load-bearing property: under random interleavings of pushes
    /// and pops — with heavy virtual-time ties, the engines' common
    /// case — the sharded queue commits the exact event sequence the
    /// global heap does.
    #[test]
    fn sharded_pop_order_matches_global_heap() {
        let mut rng = Rng::new(0x5A4D);
        for trial in 0..40u64 {
            let actors = 1 + rng.below(12) as usize;
            for workers in [2usize, 3, 8] {
                let mut global = EventQueue::new(actors, 1, false);
                let mut sharded = EventQueue::new(actors, workers, false);
                let mut rng2 = Rng::new(0xE0 + trial);
                let mut pending = 0u32;
                for step in 0..400u32 {
                    if pending > 0 && rng2.chance(0.4) {
                        let a = global.pop();
                        let b = sharded.pop();
                        assert_eq!(a, b, "divergent pop at step {step} (trial {trial})");
                        pending -= 1;
                    } else {
                        // Quantized times force (t, seq) tie-breaks.
                        let t = rng2.below(8) as f64 * 0.5;
                        let actor = rng2.below(actors as u64) as usize;
                        global.push(t, actor, step);
                        sharded.push(t, actor, step);
                        pending += 1;
                    }
                }
                while let Some(a) = global.pop() {
                    assert_eq!(Some(a), sharded.pop(), "divergent drain (trial {trial})");
                }
                assert_eq!(sharded.pop(), None, "sharded drained no further");
            }
        }
    }

    /// A single hot actor runs its owner far ahead of the idle worker,
    /// which must deterministically steal it; tallies are take-once.
    #[test]
    fn skewed_load_steals_deterministically() {
        let n = 4 * STEAL_SLACK;
        let run = || {
            let mut q = EventQueue::new(2, 2, false);
            for i in 0..n {
                q.push(i as f64, 0, i);
            }
            while q.pop().is_some() {}
            q.take_pool_stats().expect("sharded pool")
        };
        let stats = run();
        assert_eq!(stats.workers.len(), 2);
        let total: u64 = stats.workers.iter().map(|w| w.events).sum();
        assert_eq!(total, n, "every event attributed exactly once");
        assert!(stats.steals >= 1, "idle worker must steal the hot actor");
        assert!(
            stats.workers[1].events > 0,
            "stolen actor pumps on the thief"
        );
        // Determinism: counts are a pure function of the pop sequence.
        assert_eq!(stats, run());
        // Take semantics: nothing left to drain.
        let mut q = EventQueue::<u64>::new(2, 2, false);
        q.push(0.0, 0, 7);
        q.pop();
        q.take_pool_stats();
        let again = q.take_pool_stats().expect("sharded pool");
        assert_eq!(again.workers.iter().map(|w| w.events).sum::<u64>(), 0);
        assert_eq!(again.steals, 0);
    }

    /// The serial shape reports no pool — the profiler's host section
    /// must not grow worker rows on the reference path.
    #[test]
    fn global_shape_has_no_pool() {
        let mut q = EventQueue::<u32>::new(4, 1, true);
        q.push(1.0, 0, 9);
        assert_eq!(q.peek_t(), Some(1.0));
        assert_eq!(q.pop().map(|e| e.ev), Some(9));
        assert!(q.take_pool_stats().is_none());
    }
}
