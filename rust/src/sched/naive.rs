//! The naive evaluator of the paper's Fig. 6.
//!
//! Evaluates ready operations in *becoming-ready* order with **blocking**
//! communication. Receives are ready the moment they are recorded (their
//! staging buffer has no prior accesses), so a rank happily blocks on a
//! receive whose matching send sits behind other work — when every rank
//! does that simultaneously the program deadlocks "in the first
//! iteration" (Fig. 6). The engine detects the cycle and returns
//! [`SchedError::Deadlock`] instead of hanging, which the test-suite and
//! `examples/quickstart.rs` demonstrate against the latency-hiding
//! scheduler that completes the same batch.
//!
//! Runs as one epoch of a persistent [`ExecState`] like the other
//! policies. A deadlocked epoch leaves the state with pending work; the
//! lazy context poisons itself on the error, so the torn state is never
//! resumed.

use std::collections::{BinaryHeap, VecDeque};

use super::{compute_costs, ExecState, SchedCfg, SchedError, TEvent, TransferTable};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::types::{Rank, Tag, VTime};
use crate::ufunc::{OpNode, OpPayload};
use crate::util::fxhash::FxHashMap;

/// One-shot convenience: run `ops` as the single epoch of a fresh
/// [`ExecState`] and report it.
pub fn run_naive(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    state.n_epochs = 1;
    state.run_id = 1;
    run_naive_epoch(ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

pub(crate) fn run_naive_epoch(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
    st: &mut ExecState,
) -> Result<(), SchedError> {
    let n = cfg.nprocs as usize;
    let xfers = TransferTable::build(ops)?;
    let costs = compute_costs(ops, cfg);
    st.begin_epoch(ops);
    st.deps.insert_all(ops);

    // Flow degrades the naive evaluator to single-epoch waves (see
    // `crate::flow::engine`): recording still rides the recorder clock
    // (`st.admit` set), so skip the serial charge exactly like the
    // other policies.
    if st.admit.is_empty() {
        st.charge_overhead(super::batch_overhead(ops, cfg.spec.lh_op_overhead, &cfg.spec));
    }
    // FIFO of ready ops per rank, in becoming-ready order — the naive
    // evaluator draws no distinction between communication and compute.
    let mut fifo: Vec<VecDeque<usize>> = vec![VecDeque::new(); n];
    let mut parked: FxHashMap<Tag, (Rank, VTime)> = FxHashMap::default();
    let mut heap: BinaryHeap<TEvent<Rank>> = BinaryHeap::new();
    let mut queued = vec![false; n];
    let mut seq = 0u64;

    let mut executed = 0u64;

    macro_rules! enqueue {
        ($rank:expr, $t:expr) => {{
            let r: Rank = $rank;
            if !queued[r.idx()] && !fifo[r.idx()].is_empty() {
                st.clock[r.idx()] = st.clock[r.idx()].max($t);
                heap.push(TEvent {
                    t: st.clock[r.idx()],
                    seq,
                    ev: r,
                });
                seq += 1;
                queued[r.idx()] = true;
            }
        }};
    }

    let initial = st.deps.take_ready();
    for id in initial {
        fifo[ops[id.idx()].rank.idx()].push_back(id.idx());
    }
    for r in 0..n {
        enqueue!(Rank(r as u32), st.clock[r]);
    }

    while let Some(TEvent { ev: rank, .. }) = heap.pop() {
        let r = rank.idx();
        queued[r] = false;
        let Some(&i) = fifo[r].front() else {
            continue;
        };
        let op = &ops[i];
        let mut done_ids = Vec::new();
        match &op.payload {
            OpPayload::Compute(task) => {
                st.gate_admission(rank, op.id);
                backend.exec_compute(rank, task);
                st.busy[r] += costs[i];
                st.clock[r] += costs[i];
                st.note_retire(op, st.clock[r], backend);
                fifo[r].pop_front();
                executed += 1;
                done_ids.push(op.id);
            }
            OpPayload::Send {
                peer, tag, bytes, ..
            } => {
                let t0 = st.gate_admission(rank, op.id);
                let res = st.net.post_send(t0, rank, *peer, *tag, *bytes);
                // Capture the payload at injection time (see lh.rs).
                let info = &xfers.info[tag];
                backend.exec_transfer(info.from, info.to, *tag, &info.src);
                let done = res.send_done.unwrap();
                st.wait[r] += done - t0;
                st.clock[r] = done;
                st.note_retire(op, done, backend);
                fifo[r].pop_front();
                executed += 1;
                done_ids.push(op.id);
                if let Some(rd) = res.recv_done {
                    if let Some((peer_rank, parked_at)) = parked.remove(tag) {
                        let pr = peer_rank.idx();
                        let resume = rd.max(parked_at);
                        st.wait[pr] += resume - parked_at;
                        st.clock[pr] = resume;
                        st.note_retire(&ops[xfers.info[tag].recv_op.idx()], resume, backend);
                        fifo[pr].pop_front(); // the blocked recv
                        executed += 1;
                        done_ids.push(ops[xfers.info[tag].recv_op.idx()].id);
                        enqueue!(peer_rank, st.clock[pr]);
                    }
                }
            }
            OpPayload::Recv { tag, .. } => {
                let t0 = st.gate_admission(rank, op.id);
                if st.net.send_posted(*tag) {
                    let res = st.net.post_recv(t0, rank, *tag);
                    let rd = res.recv_done.unwrap();
                    st.wait[r] += rd - t0;
                    st.clock[r] = rd;
                    st.note_retire(op, rd, backend);
                    fifo[r].pop_front();
                    executed += 1;
                    done_ids.push(op.id);
                } else if !parked.contains_key(tag) {
                    // Blocking recv with no matching send posted: park.
                    st.net.post_recv(t0, rank, *tag);
                    parked.insert(*tag, (rank, t0));
                    continue;
                } else {
                    continue;
                }
            }
        }
        for id in done_ids {
            st.deps.complete(id);
            for nr in st.deps.take_ready() {
                let owner = ops[nr.idx()].rank;
                fifo[owner.idx()].push_back(nr.idx());
                enqueue!(owner, st.clock[r]);
            }
        }
        enqueue!(rank, st.clock[r]);
    }

    if executed as usize != ops.len() {
        // Progress stopped. A genuine deadlock leaves at least one rank
        // parked on a receive whose matching send was never initiated —
        // including sends the aggregation pass coalesced, whose
        // constituents can span a blocked receive on another rank (the
        // packed send only becomes ready once *all* constituents are).
        // Anything else is an internal scheduling bug: report it as a
        // stall instead of mislabelling it.
        if parked.is_empty() {
            return Err(SchedError::Stall(format!(
                "naive evaluator stopped at {executed}/{} with no blocked receive",
                ops.len()
            )));
        }
        return Err(SchedError::Deadlock {
            executed,
            total: ops.len() as u64,
            blocked_recvs: parked.len() as u64,
        });
    }

    super::count_epoch_ops(st, ops);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::sched::{run_latency_hiding, Policy};
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    /// Two ping-ponged stencil iterations over the same bases: the
    /// streams of the paper's Fig. 6. Naive deadlocks in iteration 1;
    /// latency-hiding completes.
    fn two_iteration_stencil(nprocs: u32) -> Vec<OpNode> {
        let rows = 12u64;
        let br = 3u64;
        let mut reg = Registry::new(nprocs);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let mut bld = OpBuilder::new();
        for _ in 0..2 {
            // N[1:-1] = M[2:] + M[:-2]
            bld.ufunc(
                &reg,
                Kernel::Add,
                &nv.slice(&[(1, rows - 1)]),
                &[&mv.slice(&[(2, rows)]), &mv.slice(&[(0, rows - 2)])],
            );
            // M[1:-1] = N[2:] + N[:-2]
            bld.ufunc(
                &reg,
                Kernel::Add,
                &mv.slice(&[(1, rows - 1)]),
                &[&nv.slice(&[(2, rows)]), &nv.slice(&[(0, rows - 2)])],
            );
        }
        bld.finish()
    }

    #[test]
    fn naive_deadlocks_where_lh_completes() {
        let ops = two_iteration_stencil(4);
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        let lh = run_latency_hiding(&ops, &cfg, &mut SimBackend);
        assert!(lh.is_ok(), "latency-hiding must complete");
        let nv = run_naive(&ops, &cfg, &mut SimBackend);
        match nv {
            Err(SchedError::Deadlock {
                executed,
                total,
                blocked_recvs,
            }) => {
                assert!(executed < total);
                assert!(blocked_recvs > 0, "a deadlock names its blocked receives");
            }
            Ok(_) => {
                // Depending on interleaving the naive order *may* squeak
                // through on small configs; the canonical deadlock demo
                // in rust/tests asserts the 2-rank paper configuration.
                // Treat unexpectedly-completing larger configs as a test
                // failure only if the 2-rank case also completes.
                let ops2 = two_iteration_stencil(2);
                let cfg2 = SchedCfg::new(MachineSpec::tiny(), 2);
                assert!(
                    matches!(
                        run_naive(&ops2, &cfg2, &mut SimBackend),
                        Err(SchedError::Deadlock { .. })
                    ),
                    "naive evaluator should deadlock on the Fig. 6 stream"
                );
            }
            Err(e) => panic!("unexpected error {e}"),
        }
        let _ = Policy::Naive;
    }

    #[test]
    fn naive_completes_comm_free_batch() {
        let mut reg = Registry::new(2);
        let x = reg.alloc(vec![8], 4, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Scale(3.0), &xv, &[&xv]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let rep = run_naive(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.ops_executed, 2);
    }
}
