//! The naive evaluator of the paper's Fig. 6.
//!
//! Evaluates ready operations in *becoming-ready* order with **blocking**
//! communication. Receives are ready the moment they are recorded (their
//! staging buffer has no prior accesses), so a rank happily blocks on a
//! receive whose matching send sits behind other work — when every rank
//! does that simultaneously the program deadlocks "in the first
//! iteration" (Fig. 6). The engine detects the cycle and returns
//! [`SchedError::Deadlock`] instead of hanging, which the test-suite and
//! `examples/quickstart.rs` demonstrate against the latency-hiding
//! scheduler that completes the same batch.
//!
//! Since PR 5 the evaluator is a **resumable engine** ([`NaiveSession`],
//! driven through [`crate::sched::SchedSession`]) like the other
//! policies: ready FIFOs, parked receives and the runnable-rank heap
//! persist between injects. The incremental flush engine still feeds it
//! conservatively — merged waves are admitted only after a dry-run shows
//! the becoming-ready order completes them ([`crate::flow::engine`]'s
//! bounded-lookahead merge) — because splicing epochs into its blocking
//! ready-order can manufacture deadlocks the per-batch stream never
//! exposes. A deadlocked session leaves the state with pending work; the
//! lazy context poisons itself on the error, so the torn state is never
//! resumed.

use std::collections::VecDeque;

use super::{compute_costs, EventQueue, ExecState, SchedCfg, SchedError, TEvent, TransferTable};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::trace::{OpKind, WaitCause};
use crate::types::{Rank, Tag, VTime};
use crate::ufunc::{OpNode, OpPayload};
use crate::util::fxhash::FxHashMap;

/// The naive evaluator's persistent session state.
pub(crate) struct NaiveSession {
    xfers: TransferTable,
    costs: Vec<VTime>,
    /// FIFO of ready ops per rank, in becoming-ready order — the naive
    /// evaluator draws no distinction between communication and compute.
    fifo: Vec<VecDeque<usize>>,
    parked: FxHashMap<Tag, (Rank, VTime)>,
    /// Runnable ranks by clock: the seed global heap at `--workers 1`,
    /// per-rank actor shards beyond ([`crate::sched::queue`]).
    pub(crate) q: EventQueue<Rank>,
    queued: Vec<bool>,
    pub(crate) executed: u64,
}

impl NaiveSession {
    pub(crate) fn new(cfg: &SchedCfg) -> Self {
        let n = cfg.nprocs as usize;
        NaiveSession {
            xfers: TransferTable::empty(),
            costs: Vec::new(),
            fifo: vec![VecDeque::new(); n],
            parked: FxHashMap::default(),
            q: EventQueue::new(n, cfg.workers, cfg.profile.enabled),
            queued: vec![false; n],
            executed: 0,
        }
    }

    /// Splice the tail `ops[lo..]` into the session's tables.
    pub(crate) fn extend(
        &mut self,
        ops: &[OpNode],
        lo: usize,
        cfg: &SchedCfg,
    ) -> Result<(), SchedError> {
        let new = &ops[lo..];
        self.xfers.extend(new)?;
        self.costs.extend(compute_costs(new, cfg));
        Ok(())
    }

    fn enqueue(&mut self, st: &mut ExecState, rank: Rank, t: VTime) {
        let r = rank.idx();
        if !self.queued[r] && !self.fifo[r].is_empty() {
            st.clock[r] = st.clock[r].max(t);
            self.q.push(st.clock[r], r, rank);
            self.queued[r] = true;
        }
    }

    /// Activate the tail: dependencies, recording charge (Batch epochs
    /// only — gated injects ride the recorder clock), ready
    /// distribution, and wake every rank that has runnable work.
    pub(crate) fn activate(
        &mut self,
        ops: &[OpNode],
        lo: usize,
        cfg: &SchedCfg,
        _backend: &mut dyn Backend,
        st: &mut ExecState,
    ) {
        let new = &ops[lo..];
        st.deps.insert_all(new);
        if st.admit.is_empty() {
            st.charge_overhead(super::batch_overhead(new, cfg.spec.lh_op_overhead, &cfg.spec));
        }
        let initial = st.deps.take_ready();
        for id in initial {
            self.fifo[ops[id.idx()].rank.idx()].push_back(id.idx());
        }
        for r in 0..self.fifo.len() {
            let t = st.clock[r];
            self.enqueue(st, Rank(r as u32), t);
        }
    }

    /// One rank's turn: execute its FIFO head (or park on it).
    fn turn(&mut self, ops: &[OpNode], st: &mut ExecState, backend: &mut dyn Backend, rank: Rank) {
        let r = rank.idx();
        let Some(&i) = self.fifo[r].front() else {
            return;
        };
        let op = &ops[i];
        let mut done_ids = Vec::new();
        match &op.payload {
            OpPayload::Compute(task) => {
                let t0 = st.gate_admission(rank, op.id);
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op.id, rank, OpKind::Compute, ep, t0);
                }
                backend.exec_compute(rank, task);
                st.busy[r] += self.costs[i];
                st.clock[r] += self.costs[i];
                st.note_retire(op, st.clock[r], backend);
                self.fifo[r].pop_front();
                self.executed += 1;
                done_ids.push(op.id);
            }
            OpPayload::Send {
                peer, tag, bytes, ..
            } => {
                let t0 = st.gate_admission(rank, op.id);
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op.id, rank, OpKind::Send, ep, t0);
                }
                let res = st.note_msg_post(*tag, rank, *peer, *bytes, t0);
                // Capture the payload at injection time (see lh.rs).
                let recv_op = {
                    let info = &self.xfers.info[tag];
                    backend.exec_transfer(info.from, info.to, *tag, &info.src);
                    info.recv_op
                };
                let done = res.send_done.unwrap();
                st.charge_wait(r, t0, done, WaitCause::Transfer { peer: *peer });
                st.clock[r] = done;
                st.note_retire(op, done, backend);
                self.fifo[r].pop_front();
                self.executed += 1;
                done_ids.push(op.id);
                if let Some(rd) = res.recv_done {
                    st.trace.msg_deliver(*tag, rank, *peer, *bytes, rd);
                    if let Some((peer_rank, parked_at)) = self.parked.remove(tag) {
                        let pr = peer_rank.idx();
                        let resume = rd.max(parked_at);
                        st.charge_wait(pr, parked_at, resume, WaitCause::Transfer { peer: rank });
                        st.clock[pr] = resume;
                        st.note_retire(&ops[recv_op.idx()], resume, backend);
                        self.fifo[pr].pop_front(); // the blocked recv
                        self.executed += 1;
                        done_ids.push(ops[recv_op.idx()].id);
                        let t = st.clock[pr];
                        self.enqueue(st, peer_rank, t);
                    }
                }
            }
            OpPayload::Recv { peer, tag, bytes } => {
                let t0 = st.gate_admission(rank, op.id);
                if st.net.send_posted(*tag) {
                    let res = st.net.post_recv(t0, rank, *tag);
                    let rd = res.recv_done.unwrap();
                    if st.trace.on() {
                        let ep = st.cur_epoch();
                        st.trace.op_start(op.id, rank, OpKind::Recv, ep, t0);
                        st.trace.msg_deliver(*tag, *peer, rank, *bytes, rd);
                    }
                    st.charge_wait(r, t0, rd, WaitCause::Transfer { peer: *peer });
                    st.clock[r] = rd;
                    st.note_retire(op, rd, backend);
                    self.fifo[r].pop_front();
                    self.executed += 1;
                    done_ids.push(op.id);
                } else if !self.parked.contains_key(tag) {
                    // Blocking recv with no matching send posted: park.
                    if st.trace.on() {
                        let ep = st.cur_epoch();
                        st.trace.op_start(op.id, rank, OpKind::Recv, ep, t0);
                    }
                    st.net.post_recv(t0, rank, *tag);
                    self.parked.insert(*tag, (rank, t0));
                    return;
                } else {
                    return;
                }
            }
        }
        for id in done_ids {
            st.deps.complete(id);
            for nr in st.deps.take_ready() {
                let owner = ops[nr.idx()].rank;
                self.fifo[owner.idx()].push_back(nr.idx());
                let t = st.clock[r];
                self.enqueue(st, owner, t);
            }
        }
        let t = st.clock[r];
        self.enqueue(st, rank, t);
    }

    /// Advance through every turn at or before `until`.
    pub(crate) fn pump_until(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        until: VTime,
    ) {
        while self.q.peek_t().is_some_and(|t| t <= until) {
            let TEvent { ev: rank, .. } = self.q.pop().unwrap();
            self.queued[rank.idx()] = false;
            self.turn(ops, st, backend, rank);
        }
    }

    /// Process the earliest pending turn; `None` on a quiescent loop.
    pub(crate) fn pump_next(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
    ) -> Option<VTime> {
        let TEvent { t, ev: rank, .. } = self.q.pop()?;
        self.queued[rank.idx()] = false;
        self.turn(ops, st, backend, rank);
        Some(t)
    }

    /// Run the loop to quiescence.
    pub(crate) fn pump_all(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
    ) {
        while let Some(TEvent { ev: rank, .. }) = self.q.pop() {
            self.queued[rank.idx()] = false;
            self.turn(ops, st, backend, rank);
        }
    }

    /// Progress stopped: a genuine deadlock leaves at least one rank
    /// parked on a receive whose matching send was never initiated —
    /// including sends the aggregation pass coalesced, whose
    /// constituents can span a blocked receive on another rank (the
    /// packed send only becomes ready once *all* constituents are).
    /// Anything else is an internal scheduling bug: report it as a
    /// stall instead of mislabelling it.
    pub(crate) fn finish_check(&self, ops: &[OpNode]) -> Result<(), SchedError> {
        if self.executed as usize != ops.len() {
            if self.parked.is_empty() {
                return Err(SchedError::Stall(format!(
                    "naive evaluator stopped at {}/{} with no blocked receive",
                    self.executed,
                    ops.len()
                )));
            }
            // Name the wait chain: which rank is parked on which tag,
            // and where the chain bites its own tail. Shares the
            // renderer with the static predictor, so the runtime error
            // and `distnumpy analyze` describe the same cycle.
            let mut parked: Vec<(Rank, Tag)> =
                self.parked.iter().map(|(&t, &(r, _))| (r, t)).collect();
            parked.sort_unstable();
            return Err(SchedError::Deadlock {
                executed: self.executed,
                total: ops.len() as u64,
                blocked_recvs: self.parked.len() as u64,
                cycle: crate::analyze::stalls::witness_cycle(ops, &parked),
            });
        }
        Ok(())
    }
}

/// One-shot convenience: run `ops` as the single epoch of a fresh
/// [`ExecState`] and report it.
pub fn run_naive(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    state.n_epochs = 1;
    super::session::one_shot(super::Policy::Naive, ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::sched::{run_latency_hiding, Policy};
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    /// Two ping-ponged stencil iterations over the same bases: the
    /// streams of the paper's Fig. 6. Naive deadlocks in iteration 1;
    /// latency-hiding completes.
    fn two_iteration_stencil(nprocs: u32) -> Vec<OpNode> {
        let rows = 12u64;
        let br = 3u64;
        let mut reg = Registry::new(nprocs);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let mut bld = OpBuilder::new();
        for _ in 0..2 {
            // N[1:-1] = M[2:] + M[:-2]
            bld.ufunc(
                &reg,
                Kernel::Add,
                &nv.slice(&[(1, rows - 1)]),
                &[&mv.slice(&[(2, rows)]), &mv.slice(&[(0, rows - 2)])],
            );
            // M[1:-1] = N[2:] + N[:-2]
            bld.ufunc(
                &reg,
                Kernel::Add,
                &mv.slice(&[(1, rows - 1)]),
                &[&nv.slice(&[(2, rows)]), &nv.slice(&[(0, rows - 2)])],
            );
        }
        bld.finish()
    }

    #[test]
    fn naive_deadlocks_where_lh_completes() {
        let ops = two_iteration_stencil(4);
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        let lh = run_latency_hiding(&ops, &cfg, &mut SimBackend);
        assert!(lh.is_ok(), "latency-hiding must complete");
        let nv = run_naive(&ops, &cfg, &mut SimBackend);
        match nv {
            Err(SchedError::Deadlock {
                executed,
                total,
                blocked_recvs,
                cycle,
            }) => {
                assert!(executed < total);
                assert!(blocked_recvs > 0, "a deadlock names its blocked receives");
                assert!(cycle.contains("rank"), "the error names the wait chain: {cycle}");
            }
            Ok(_) => {
                // Depending on interleaving the naive order *may* squeak
                // through on small configs; the canonical deadlock demo
                // in rust/tests asserts the 2-rank paper configuration.
                // Treat unexpectedly-completing larger configs as a test
                // failure only if the 2-rank case also completes.
                let ops2 = two_iteration_stencil(2);
                let cfg2 = SchedCfg::new(MachineSpec::tiny(), 2);
                assert!(
                    matches!(
                        run_naive(&ops2, &cfg2, &mut SimBackend),
                        Err(SchedError::Deadlock { .. })
                    ),
                    "naive evaluator should deadlock on the Fig. 6 stream"
                );
            }
            Err(e) => panic!("unexpected error {e}"),
        }
        let _ = Policy::Naive;
    }

    #[test]
    fn naive_completes_comm_free_batch() {
        let mut reg = Registry::new(2);
        let x = reg.alloc(vec![8], 4, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Scale(3.0), &xv, &[&xv]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let rep = run_naive(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.ops_executed, 2);
    }
}
