//! Persistent cross-flush execution state — the *epoch* model.
//!
//! Historically every flush simulated on a fresh [`Network`] with all
//! per-rank clocks reset to the batch overhead, and the per-flush
//! reports were summed makespan-by-makespan. That makes every flush a
//! full global barrier: communication initiated in flush *k* can never
//! drain behind flush *k+1*'s computation, and a convergence read per
//! iteration (Jacobi's `sum_absdiff`) forfeits the paper's headline
//! latency-hiding effect exactly where it matters most.
//!
//! [`ExecState`] fixes this by extracting everything that must survive a
//! flush out of the schedulers:
//!
//! * per-rank **virtual clocks** — a flush becomes an *epoch* in one
//!   continuous timeline; ranks resume where they left off;
//! * the **NIC egress/ingress FIFO frontiers** (inside the owned
//!   [`Network`]) — a transfer injected late in epoch *k* still occupies
//!   the wire while epoch *k+1* computes;
//! * accumulated **waiting/busy time** and counters;
//! * the **live dependency system** — operation ids recycle once an
//!   epoch fully drains (see `deps`), so one system serves the whole run.
//!
//! The only remaining global synchronization is an explicit
//! [`ExecState::barrier`], issued by the lazy context when the program
//! actually *forces* a scalar (an immediate `sum`, a `ScalarFuture::wait`
//! or a `gather`): every rank joins the global maximum clock and the
//! joined idle time is accounted as `wait_at_barrier`. Deferring reads
//! through futures therefore directly removes barriers from the
//! timeline — the ablation in `benches/ablation_epochs.rs` measures it.

use crate::deps::DepSystem;
use crate::metrics::RunReport;
use crate::net::Network;
use crate::types::{BaseId, VTime};

use super::SchedCfg;

/// Execution state that persists across flush epochs.
pub struct ExecState {
    /// Per-rank virtual clocks, continuous across epochs.
    pub clock: Vec<VTime>,
    /// Accumulated per-rank waiting time (comm stalls + barriers).
    pub wait: Vec<VTime>,
    /// Accumulated per-rank busy compute time.
    pub busy: Vec<VTime>,
    /// Accumulated recording/dependency overhead (charged every epoch).
    pub overhead: VTime,
    /// The simulated interconnect: NIC frontiers and in-flight transfer
    /// halves survive across epochs.
    pub net: Network,
    /// The live dependency system, reused epoch after epoch.
    pub deps: Box<dyn DepSystem>,
    /// Per-rank most recently touched base-block (§7 cache key) — cache
    /// residency is physical state, so it survives the flush boundary.
    pub last_block: Vec<Option<(BaseId, u64)>>,
    /// Executed flush epochs.
    pub n_epochs: u64,
    /// Wait accumulated at explicit barriers (forced scalar reads).
    pub wait_at_barrier: VTime,
    // -- accumulated counters (per-epoch deltas folded in by the
    // -- schedulers; byte/message totals live in `net`) --
    pub ops_executed: u64,
    pub n_compute: u64,
    pub n_comm: u64,
    pub agg_msgs: u64,
    pub agg_parts: u64,
}

impl ExecState {
    pub fn new(cfg: &SchedCfg) -> Self {
        let n = cfg.nprocs as usize;
        let node_of = cfg.placement.assign(cfg.nprocs, &cfg.spec);
        ExecState {
            clock: vec![0.0; n],
            wait: vec![0.0; n],
            busy: vec![0.0; n],
            overhead: 0.0,
            net: Network::new(&cfg.spec, node_of),
            deps: cfg.deps.build(),
            last_block: vec![None; n],
            n_epochs: 0,
            wait_at_barrier: 0.0,
            ops_executed: 0,
            n_compute: 0,
            n_comm: 0,
            agg_msgs: 0,
            agg_parts: 0,
        }
    }

    /// Latest rank clock — the makespan of the run so far.
    pub fn max_clock(&self) -> VTime {
        self.clock.iter().cloned().fold(0.0, f64::max)
    }

    /// Global barrier: every rank joins the maximum clock. The joined
    /// idle time is charged to per-rank wait *and* to `wait_at_barrier`
    /// so the cost of forcing a scalar is visible in the metrics.
    /// Returns the barrier time.
    pub fn barrier(&mut self) -> VTime {
        let tmax = self.max_clock();
        for r in 0..self.clock.len() {
            let d = tmax - self.clock[r];
            if d > 0.0 {
                self.wait[r] += d;
                self.wait_at_barrier += d;
                self.clock[r] = tmax;
            }
        }
        tmax
    }

    /// Snapshot the continuous timeline as a [`RunReport`]: the makespan
    /// is the *latest clock*, not a sum of per-flush makespans — epochs
    /// overlap wherever the schedules allow it.
    pub fn report(&self) -> RunReport {
        let mut rep = RunReport::new(self.clock.len());
        rep.makespan = self.max_clock();
        rep.wait = self.wait.clone();
        rep.busy = self.busy.clone();
        rep.overhead = self.overhead;
        rep.ops_executed = self.ops_executed;
        rep.n_compute = self.n_compute;
        rep.n_comm = self.n_comm;
        rep.bytes_inter = self.net.bytes_inter;
        rep.bytes_intra = self.net.bytes_intra;
        rep.n_messages = self.net.n_transfers;
        rep.agg_msgs = self.agg_msgs;
        rep.agg_parts = self.agg_parts;
        rep.n_epochs = self.n_epochs;
        rep.wait_at_barrier = self.wait_at_barrier;
        rep
    }

    /// Charge one epoch's recording/bookkeeping overhead to every rank.
    pub(crate) fn charge_overhead(&mut self, per_epoch: VTime) {
        self.overhead += per_epoch;
        for c in self.clock.iter_mut() {
            *c += per_epoch;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;

    #[test]
    fn barrier_joins_clocks_and_accounts_wait() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 3);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 3.0, 2.0];
        let t = st.barrier();
        assert_eq!(t, 3.0);
        assert_eq!(st.clock, vec![3.0, 3.0, 3.0]);
        assert_eq!(st.wait, vec![2.0, 0.0, 1.0]);
        assert!((st.wait_at_barrier - 3.0).abs() < 1e-12);
        // Idempotent: a second barrier at the same frontier is free.
        st.barrier();
        assert!((st.wait_at_barrier - 3.0).abs() < 1e-12);
    }

    #[test]
    fn report_snapshots_continuous_timeline() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![4.0, 5.0];
        st.n_epochs = 3;
        st.ops_executed = 7;
        let rep = st.report();
        assert_eq!(rep.makespan, 5.0);
        assert_eq!(rep.n_epochs, 3);
        assert_eq!(rep.ops_executed, 7);
    }

    #[test]
    fn charge_overhead_advances_every_rank() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 2.0];
        st.charge_overhead(0.5);
        assert_eq!(st.clock, vec![1.5, 2.5]);
        assert_eq!(st.overhead, 0.5);
    }
}
