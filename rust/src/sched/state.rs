//! Persistent cross-flush execution state — the *epoch* model.
//!
//! Historically every flush simulated on a fresh [`Network`] with all
//! per-rank clocks reset to the batch overhead, and the per-flush
//! reports were summed makespan-by-makespan. That makes every flush a
//! full global barrier: communication initiated in flush *k* can never
//! drain behind flush *k+1*'s computation, and a convergence read per
//! iteration (Jacobi's `sum_absdiff`) forfeits the paper's headline
//! latency-hiding effect exactly where it matters most.
//!
//! [`ExecState`] fixes this by extracting everything that must survive a
//! flush out of the schedulers:
//!
//! * per-rank **virtual clocks** — a flush becomes an *epoch* in one
//!   continuous timeline; ranks resume where they left off;
//! * the **NIC egress/ingress FIFO frontiers** (inside the owned
//!   [`Network`]) — a transfer injected late in epoch *k* still occupies
//!   the wire while epoch *k+1* computes;
//! * accumulated **waiting/busy time** and counters;
//! * the **live dependency system** — operation ids recycle once an
//!   epoch fully drains (see `deps`), so one system serves the whole run.
//!
//! Forcing a value synchronizes the timeline one of two ways
//! ([`crate::sync::SyncMode`]): the explicit global [`ExecState::barrier`]
//! (every rank joins the maximum clock; idle accounted as
//! `wait_at_barrier`) or — the default since the `sync/` engine — a
//! *targeted* settle of just the value's dependency cone
//! ([`crate::sync::settle_cone`]; idle accounted as `wait_at_cone`).
//! Either way, deferring reads through futures directly removes
//! synchronization points from the timeline — the ablations in
//! `benches/ablation_epochs.rs` and `benches/ablation_sync.rs` measure
//! it.
//!
//! To support the targeted settle, the state additionally records **when
//! each operation retires** (`note_retire`, reported by all three
//! policies) and runs the reference-counted stage accounting
//! ([`crate::sync::StageTable`]): a staging buffer drops the moment its
//! last reader — operation or pinned future — retires.

use crate::deps::DepSystem;
use crate::exec::Backend;
use crate::flow::AdmissionLog;
use crate::metrics::hist::DistMetrics;
use crate::metrics::ledger::Ledger;
use crate::metrics::RunReport;
use crate::net::{Network, PostResult};
use crate::profile::Profiler;
use crate::sync::StageTable;
use crate::trace::{self, TraceSink, WaitCause};
use crate::types::{BaseId, OpId, Rank, Tag, VTime};
use crate::ufunc::{Loc, OpNode};

use super::SchedCfg;

/// Execution state that persists across flush epochs.
pub struct ExecState {
    /// Per-rank virtual clocks, continuous across epochs.
    pub clock: Vec<VTime>,
    /// Accumulated per-rank waiting time (comm stalls + barriers).
    pub wait: Vec<VTime>,
    /// Accumulated per-rank busy compute time.
    pub busy: Vec<VTime>,
    /// Accumulated recording/dependency overhead (charged every epoch).
    pub overhead: VTime,
    /// The simulated interconnect: NIC frontiers and in-flight transfer
    /// halves survive across epochs.
    pub net: Network,
    /// The live dependency system, reused epoch after epoch.
    pub deps: Box<dyn DepSystem>,
    /// Per-rank most recently touched base-block (§7 cache key) — cache
    /// residency is physical state, so it survives the flush boundary.
    pub last_block: Vec<Option<(BaseId, u64)>>,
    /// Executed flush epochs.
    pub n_epochs: u64,
    /// Wait accumulated at explicit global barriers (forced reads under
    /// [`crate::sync::SyncMode::Barrier`]).
    pub wait_at_barrier: VTime,
    /// Wait accumulated at targeted cone settles (forced reads under
    /// [`crate::sync::SyncMode::Cone`]): joining the value's dependency
    /// cone plus riding its broadcast back out.
    pub wait_at_cone: VTime,
    /// Retirement log of the *current* scheduler run (a Batch epoch or
    /// a merged Flow wave): `(rank, time)` per operation id, `NaN`
    /// until the operation retires. Reset by `begin_epoch`; consumed by
    /// the cone-wait machinery.
    pub retire: Vec<(Rank, VTime)>,
    /// Scheduler dispatches executed so far (a Flow wave spans several
    /// epochs but is one run). Stage provenance and the retirement log
    /// are only valid for the current run — the cone-wait machinery
    /// keys on this, not on `n_epochs`.
    pub run_id: u64,
    /// Per-op admission times of the wave currently executing (indexed
    /// by op id). Empty for Batch epochs: everything is admitted
    /// up front and recording overhead is charged on the rank clocks
    /// instead (`ExecState::charge_overhead`).
    pub admit: Vec<VTime>,
    /// Wait accumulated at admission gates: a rank stalled because the
    /// recorder had not yet admitted the operation (Flow mode only).
    pub wait_at_admission: VTime,
    /// Recording overhead charged on the concurrent recorder clock
    /// (Flow mode) instead of on the rank clocks. Feeds
    /// [`RunReport::overlap_pct`].
    pub overhead_streamed: VTime,
    /// The continuous admission log: one entry per flush epoch across
    /// the whole run, replacing the old per-epoch ready frontiers as
    /// the record of when epochs were admitted and retired
    /// ([`crate::flow::frontier`]).
    pub flow_log: AdmissionLog,
    /// Reference-counted staging-buffer accounting (liveness, completion
    /// times, pins) — see [`crate::sync::stages`].
    pub stages: StageTable,
    /// Event-sourced trace of the run (no-op sink unless
    /// `SchedCfg::trace` enables it) — see [`crate::trace`]. Every wait
    /// charge routes through [`ExecState::charge_wait`] so per-cause
    /// event sums reconcile with the `wait` vector exactly.
    pub trace: TraceSink,
    /// Always-on distribution metrics: per-cause wait histograms, the
    /// wire-message size histogram and the per-epoch wait series
    /// ([`crate::metrics::hist`]). Populated at the same choke points
    /// the trace sink uses, but unconditionally — recording is pure
    /// bookkeeping and never touches the `VTime` arithmetic.
    pub dist: DistMetrics,
    /// Always-on per-epoch run ledger ([`crate::metrics::ledger`]):
    /// one accounting row per flush epoch (makespan-advance, per-cause
    /// wait, messages, ops), fed at the same choke points as `dist` —
    /// `charge_wait`, `gate_admission`, `note_msg_post`, `note_retire`
    /// — so row sums reconcile exactly with the scalar report. Pure
    /// bookkeeping: never touches the `VTime` arithmetic.
    pub ledger: Ledger,
    /// Host-side self-profiler (`SchedCfg::profile`): phase-scoped wall
    /// timers and the DES events-processed counter. Free when disabled.
    pub prof: Profiler,
    // -- accumulated counters (per-epoch deltas folded in by the
    // -- schedulers; byte/message totals live in `net`) --
    pub ops_executed: u64,
    pub n_compute: u64,
    pub n_comm: u64,
    pub agg_msgs: u64,
    pub agg_parts: u64,
    // -- analyzer hooks ([`crate::analyze`]) --
    /// When set, every [`crate::sched::SchedSession::inject`] appends
    /// the post-aggregation, renumbered ops it admitted, keyed by the
    /// session's run id — the capture feed of `distnumpy analyze`
    /// ([`crate::harness::captured_streams`]). `None` (the default)
    /// costs nothing.
    pub capture: Option<CapturedStreams>,
    /// Data races found by [`SchedCfg::verify_deps`] (always 0 on a
    /// completed run — a race is a hard error).
    pub verify_races: u64,
    /// Direct dependency edges the verifier checked.
    pub verify_dep_edges: u64,
    /// Spurious direct edges (no conflict path justifies them).
    pub verify_excess_edges: u64,
    /// Conflict-free op pairs the dependency closure serialized.
    pub verify_serialized_pairs: u64,
    /// Scheduler runs the static stall predictor flagged.
    pub verify_predicted: u64,
    /// Linter diagnostics emitted across verified runs.
    pub verify_lints: u64,
}

/// Captured op streams: one `(run_id, ops)` entry per scheduler run
/// ([`ExecState::capture`]).
pub type CapturedStreams = Vec<(u64, Vec<OpNode>)>;

impl ExecState {
    pub fn new(cfg: &SchedCfg) -> Self {
        let n = cfg.nprocs as usize;
        let node_of = cfg.placement.assign(cfg.nprocs, &cfg.spec);
        ExecState {
            clock: vec![0.0; n],
            wait: vec![0.0; n],
            busy: vec![0.0; n],
            overhead: 0.0,
            net: Network::new(&cfg.spec, node_of),
            deps: cfg.deps.build(),
            last_block: vec![None; n],
            n_epochs: 0,
            wait_at_barrier: 0.0,
            wait_at_cone: 0.0,
            retire: Vec::new(),
            run_id: 0,
            admit: Vec::new(),
            wait_at_admission: 0.0,
            overhead_streamed: 0.0,
            flow_log: AdmissionLog::default(),
            stages: StageTable::new(),
            trace: TraceSink::new(cfg.trace),
            dist: DistMetrics::default(),
            ledger: Ledger::default(),
            prof: Profiler::new(cfg.profile),
            ops_executed: 0,
            n_compute: 0,
            n_comm: 0,
            agg_msgs: 0,
            agg_parts: 0,
            capture: None,
            verify_races: 0,
            verify_dep_edges: 0,
            verify_excess_edges: 0,
            verify_serialized_pairs: 0,
            verify_predicted: 0,
            verify_lints: 0,
        }
    }

    /// Latest rank clock — the makespan of the run so far.
    pub fn max_clock(&self) -> VTime {
        self.clock.iter().cloned().fold(0.0, f64::max)
    }

    /// Epoch tag stamped on trace events: the admission-log index of the
    /// most recently admitted epoch (exact in batch mode; "latest
    /// submitted" under pipelined admission, where execution of earlier
    /// epochs deliberately overlaps later recording).
    #[inline]
    pub fn cur_epoch(&self) -> u64 {
        (self.flow_log.epochs.len().max(1) - 1) as u64
    }

    /// Charge rank `r` as waiting over `[t0, t1)` for `cause`. The
    /// arithmetic is exactly the historical `wait[r] += t1 - t0`, so
    /// results are bit-identical with tracing on or off; when the sink
    /// is enabled a [`crate::trace::TraceEvent::Wait`] records the
    /// interval, which makes per-cause attribution sum to the per-rank
    /// `wait` totals by construction. Does **not** move the clock — the
    /// call sites own that.
    #[inline]
    pub fn charge_wait(&mut self, r: usize, t0: VTime, t1: VTime, cause: WaitCause) {
        self.wait[r] += t1 - t0;
        let ep = self.cur_epoch();
        self.dist.record_wait(cause, ep, t1 - t0);
        self.ledger.record_wait(ep, cause, t1 - t0);
        if self.trace.on() {
            self.trace.wait(Rank(r as u32), cause, ep, t0, t1);
        }
    }

    /// Global barrier: every rank joins the maximum clock. The joined
    /// idle time is charged to per-rank wait *and* to `wait_at_barrier`
    /// so the cost of forcing a scalar is visible in the metrics.
    /// Returns the barrier time.
    pub fn barrier(&mut self) -> VTime {
        let tmax = self.max_clock();
        for r in 0..self.clock.len() {
            let t0 = self.clock[r];
            let d = tmax - t0;
            if d > 0.0 {
                self.charge_wait(r, t0, tmax, WaitCause::Barrier);
                self.wait_at_barrier += d;
                self.clock[r] = tmax;
            }
        }
        tmax
    }

    /// Join one rank to virtual time `t` on behalf of a targeted cone
    /// settle; the idle time is charged to per-rank wait *and* to
    /// `wait_at_cone`. A rank already past `t` is untouched (the value
    /// was waiting in its buffers). Returns the rank's clock after.
    pub fn join_at(&mut self, r: Rank, t: VTime) -> VTime {
        self.join_as(r, t, WaitCause::Cone)
    }

    /// [`ExecState::join_at`] with an explicit trace cause — the sync
    /// engine distinguishes frontier joins ([`WaitCause::Cone`]) from
    /// broadcast-arrival joins ([`WaitCause::Collective`]); both accrue
    /// into `wait_at_cone` (one targeted-settle bucket in the report).
    pub fn join_as(&mut self, r: Rank, t: VTime, cause: WaitCause) -> VTime {
        let t0 = self.clock[r.idx()];
        let d = t - t0;
        if d > 0.0 {
            self.charge_wait(r.idx(), t0, t, cause);
            self.wait_at_cone += d;
            self.clock[r.idx()] = t;
        }
        self.clock[r.idx()]
    }

    /// The admission time of an operation of the current wave — 0.0
    /// outside Flow waves (everything admitted up front).
    #[inline]
    pub fn admit_time(&self, id: OpId) -> VTime {
        self.admit.get(id.idx()).copied().unwrap_or(0.0)
    }

    /// Gate rank `r` on operation `id`'s admission: if the recorder has
    /// not admitted the op yet, the rank's clock advances to the
    /// admission time and the stall is charged to `wait_at_admission` —
    /// the *unhidden* part of the streamed recording overhead, the Flow
    /// analogue of Batch mode's `ExecState::charge_overhead` clock
    /// advance. Deliberately **not** charged to per-rank `wait`: the
    /// paper's waiting-time metric means communication latency not
    /// hidden behind computation, and Batch mode's serialized recording
    /// is not counted there either — keeping the two modes comparable.
    /// Returns the rank's clock after the gate. A no-op for Batch
    /// epochs (`admit` empty).
    #[inline]
    pub fn gate_admission(&mut self, r: Rank, id: OpId) -> VTime {
        let gate = self.admit_time(id);
        let t0 = self.clock[r.idx()];
        let d = gate - t0;
        if d > 0.0 {
            self.wait_at_admission += d;
            let ep = self.cur_epoch();
            self.dist.record_wait(WaitCause::Admission, ep, d);
            self.ledger.record_wait(ep, WaitCause::Admission, d);
            if self.trace.on() {
                self.trace.wait(r, WaitCause::Admission, ep, t0, gate);
            }
            self.clock[r.idx()] = gate;
        }
        self.clock[r.idx()]
    }

    /// Post a wire message: the single choke point in front of
    /// [`Network::post_send`] for every policy and the sync engine.
    /// Records the message size into the distribution metrics
    /// (unconditionally — so the histogram count reconciles with
    /// `n_messages`) and emits the trace event when the sink is on,
    /// then posts the send half.
    #[inline]
    pub fn note_msg_post(
        &mut self,
        tag: Tag,
        from: Rank,
        to: Rank,
        bytes: u64,
        t: VTime,
    ) -> PostResult {
        self.dist.msg_bytes.record(bytes as f64);
        self.ledger.record_msg(self.cur_epoch(), bytes);
        if self.trace.on() {
            self.trace.msg_post(tag, from, to, bytes, t);
        }
        self.net.post_send(t, from, to, tag, bytes)
    }

    /// Start one scheduler run's retirement bookkeeping: reset the
    /// per-op retirement log and register every stage *reader* of the
    /// batch in the stage table (so reclamation can never drop a stage
    /// a later operation of the same run still reads). Called through
    /// [`crate::sched::SchedSession`] on the first inject of a run, on
    /// the batch it will execute (i.e. post-aggregation).
    pub fn begin_epoch(&mut self, ops: &[OpNode]) {
        self.retire.clear();
        self.extend_epoch(ops);
    }

    /// Extend the *current* run's retirement log with newly injected
    /// operations (resumable sessions: a sliding-admission epoch splices
    /// into a live event loop): grow the log to cover the new ids and
    /// register their stage readers, leaving already-injected entries
    /// untouched. Ids must continue the run's contiguous stream.
    pub fn extend_epoch(&mut self, ops: &[OpNode]) {
        let need = ops.iter().map(|o| o.id.idx() + 1).max().unwrap_or(0);
        if self.retire.len() < need {
            self.retire.resize(need, (Rank(0), f64::NAN));
        }
        for op in ops {
            self.retire[op.id.idx()].0 = op.rank;
            for a in &op.accesses {
                if let Loc::Stage(tag) = a.loc {
                    if !a.write {
                        self.stages.register_reader(op.rank, tag);
                    }
                }
            }
        }
    }

    /// Record that `op` retired at virtual time `t` — called by all
    /// three policies the moment an operation completes. Stage-writing
    /// retirements materialize their buffers (capturing the completion
    /// time the cone-wait settles on); stage-reading retirements repay
    /// the reference counts, dropping buffers whose last reader this
    /// was.
    pub fn note_retire(&mut self, op: &OpNode, t: VTime, backend: &mut dyn Backend) {
        self.prof.count_event();
        if let Some(slot) = self.retire.get_mut(op.id.idx()) {
            *slot = (op.rank, t);
        }
        let ep = self.cur_epoch();
        self.ledger.record_retire(ep, t);
        if self.trace.on() {
            let (kind, bytes) = trace::op_kind_bytes(op);
            self.trace
                .op_retire(op.id, op.rank, kind, bytes, ep, t, op.describe());
        }
        for a in &op.accesses {
            let Loc::Stage(tag) = a.loc else { continue };
            if a.write {
                self.stages.materialized(op.rank, tag, t, self.run_id, op.id);
                self.trace.stage_alloc(op.rank, tag, t);
            } else if self.stages.reader_retired(op.rank, tag) {
                backend.drop_stage(op.rank, tag);
                self.trace.stage_free(op.rank, tag, t);
            }
        }
    }

    /// Rank and retirement time of an operation of the current epoch,
    /// if it has retired.
    pub fn retired(&self, id: OpId) -> Option<(Rank, VTime)> {
        match self.retire.get(id.idx()) {
            Some(&(rank, t)) if !t.is_nan() => Some((rank, t)),
            _ => None,
        }
    }

    /// Snapshot the continuous timeline as a [`RunReport`]: the makespan
    /// is the *latest clock*, not a sum of per-flush makespans — epochs
    /// overlap wherever the schedules allow it.
    pub fn report(&self) -> RunReport {
        let mut rep = RunReport::new(self.clock.len());
        rep.makespan = self.max_clock();
        rep.wait = self.wait.clone();
        rep.busy = self.busy.clone();
        rep.overhead = self.overhead;
        rep.ops_executed = self.ops_executed;
        rep.n_compute = self.n_compute;
        rep.n_comm = self.n_comm;
        rep.bytes_inter = self.net.bytes_inter;
        rep.bytes_intra = self.net.bytes_intra;
        rep.n_messages = self.net.n_transfers;
        rep.agg_msgs = self.agg_msgs;
        rep.agg_parts = self.agg_parts;
        rep.n_epochs = self.n_epochs;
        rep.wait_at_barrier = self.wait_at_barrier;
        rep.wait_at_cone = self.wait_at_cone;
        rep.wait_at_admission = self.wait_at_admission;
        rep.overhead_streamed = self.overhead_streamed;
        rep.live_stages = self.stages.live;
        rep.peak_live_stages = self.stages.peak_live;
        rep.max_in_flight = self.flow_log.max_in_flight;
        rep.recorder_clock = self.flow_log.recorder_clock();
        rep.admission_latency = self.flow_log.mean_admission_latency();
        rep.flow_window_final = self
            .flow_log
            .window_trace
            .last()
            .map_or(0, |&(_, w)| w);
        rep.window_decisions = self.flow_log.window_trace.len() as u64;
        rep.races = self.verify_races;
        rep.dep_edges = self.verify_dep_edges;
        rep.excess_edges = self.verify_excess_edges;
        rep.serialized_pairs = self.verify_serialized_pairs;
        rep.predicted_stalls = self.verify_predicted;
        rep.lints = self.verify_lints;
        rep.trace_dropped = self.trace.dropped();
        rep.dist = self.dist.clone();
        rep.admission_hist = self.flow_log.latency_hist.clone();
        rep.ledger = self.ledger.annotated(&self.flow_log);
        if self.prof.on() {
            rep.host = Some(self.prof.clone());
        }
        rep
    }

    /// Charge one epoch's recording/bookkeeping overhead to every rank.
    pub(crate) fn charge_overhead(&mut self, per_epoch: VTime) {
        self.overhead += per_epoch;
        for c in self.clock.iter_mut() {
            *c += per_epoch;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;

    #[test]
    fn barrier_joins_clocks_and_accounts_wait() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 3);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 3.0, 2.0];
        let t = st.barrier();
        assert_eq!(t, 3.0);
        assert_eq!(st.clock, vec![3.0, 3.0, 3.0]);
        assert_eq!(st.wait, vec![2.0, 0.0, 1.0]);
        assert!((st.wait_at_barrier - 3.0).abs() < 1e-12);
        // Idempotent: a second barrier at the same frontier is free.
        st.barrier();
        assert!((st.wait_at_barrier - 3.0).abs() < 1e-12);
    }

    #[test]
    fn report_snapshots_continuous_timeline() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![4.0, 5.0];
        st.n_epochs = 3;
        st.ops_executed = 7;
        let rep = st.report();
        assert_eq!(rep.makespan, 5.0);
        assert_eq!(rep.n_epochs, 3);
        assert_eq!(rep.ops_executed, 7);
    }

    #[test]
    fn join_at_accounts_cone_wait_and_never_rewinds() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 5.0];
        st.join_at(Rank(0), 3.0);
        st.join_at(Rank(1), 3.0);
        assert_eq!(st.clock, vec![3.0, 5.0], "fast rank untouched");
        assert_eq!(st.wait, vec![2.0, 0.0]);
        assert!((st.wait_at_cone - 2.0).abs() < 1e-12);
        assert_eq!(st.wait_at_barrier, 0.0);
    }

    #[test]
    fn gate_admission_charges_only_unadmitted_ops() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 5.0];
        st.admit = vec![3.0, 3.0];
        st.gate_admission(Rank(0), OpId(0));
        st.gate_admission(Rank(1), OpId(1));
        assert_eq!(st.clock, vec![3.0, 5.0], "only the lagging rank stalls");
        assert!((st.wait_at_admission - 2.0).abs() < 1e-12);
        assert_eq!(
            st.wait,
            vec![0.0, 0.0],
            "admission stalls are recording overhead, not comm wait"
        );
        // Batch epochs (empty admit) never gate.
        st.admit.clear();
        st.gate_admission(Rank(0), OpId(99));
        assert_eq!(st.clock[0], 3.0);
        assert!((st.wait_at_admission - 2.0).abs() < 1e-12);
    }

    #[test]
    fn retire_log_and_stage_lifecycle() {
        use crate::exec::SimBackend;
        use crate::types::{OpId, Tag};
        use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpNode, OpPayload, Operand};
        let cfg = SchedCfg::new(MachineSpec::tiny(), 1);
        let mut st = ExecState::new(&cfg);
        st.stages.reclaim = true;
        st.run_id = 1;
        let writer = OpNode {
            id: OpId(0),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::PartialSum,
                inputs: vec![Operand::Staged(Tag(99))],
                dst: Dst::Stage(Tag(7)),
                elems: 1,
            }),
            accesses: vec![Access::write_stage(Tag(7))],
        };
        let reader = OpNode {
            id: OpId(1),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::AccumSum,
                inputs: vec![Operand::Staged(Tag(7))],
                dst: Dst::Stage(Tag(8)),
                elems: 1,
            }),
            accesses: vec![Access::read_stage(Tag(7)), Access::write_stage(Tag(8))],
        };
        st.begin_epoch(&[writer.clone(), reader.clone()]);
        assert!(st.retired(OpId(0)).is_none(), "nothing retired yet");
        let mut be = SimBackend;
        st.note_retire(&writer, 1.5, &mut be);
        assert_eq!(st.retired(OpId(0)), Some((Rank(0), 1.5)));
        let w = st.stages.writer(Rank(0), Tag(7)).unwrap();
        assert_eq!(w.done, 1.5);
        assert_eq!(w.run, 1);
        st.note_retire(&reader, 2.0, &mut be);
        assert!(
            st.stages.writer(Rank(0), Tag(7)).is_none(),
            "last reader retired: the stage reclaimed"
        );
        assert!(
            st.stages.writer(Rank(0), Tag(8)).is_some(),
            "the unread result persists"
        );
        assert_eq!(st.stages.dropped, 1);
    }

    #[test]
    fn dist_metrics_track_the_choke_points() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 3);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 3.0, 2.0];
        st.barrier();
        let barrier_hist = &st.dist.wait_by_cause[WaitCause::Barrier.index()];
        assert_eq!(barrier_hist.n(), 2, "two ranks stalled");
        assert!((barrier_hist.sum() - st.wait_at_barrier).abs() < 1e-12);
        assert!(
            (st.dist.epoch_wait.iter().sum::<f64>() - st.wait.iter().sum::<f64>()).abs() < 1e-12,
            "the epoch series mirrors the per-rank wait totals"
        );

        st.admit = vec![10.0];
        st.gate_admission(Rank(0), OpId(0));
        let adm = &st.dist.wait_by_cause[WaitCause::Admission.index()];
        assert_eq!(adm.n(), 1);
        assert!((adm.sum() - st.wait_at_admission).abs() < 1e-12);
        assert!(
            (st.dist.epoch_wait.iter().sum::<f64>() - st.wait.iter().sum::<f64>()).abs() < 1e-12,
            "admission stalls stay out of the epoch wait series"
        );

        st.net.post_recv(0.0, Rank(1), Tag(5));
        st.note_msg_post(Tag(5), Rank(0), Rank(1), 4096, 0.0);
        assert_eq!(st.dist.msg_bytes.n(), st.net.n_transfers);
        assert_eq!(st.dist.msg_bytes.max(), 4096.0);

        let rep = st.report();
        assert_eq!(rep.dist, st.dist, "report snapshots the distributions");
    }

    #[test]
    fn profiler_counts_events_only_when_enabled() {
        use crate::exec::SimBackend;
        use crate::ufunc::{ComputeTask, Dst, Kernel, OpPayload};
        let op = OpNode {
            id: OpId(0),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::PartialSum,
                inputs: vec![],
                dst: Dst::Stage(Tag(1)),
                elems: 1,
            }),
            accesses: vec![],
        };
        let mut be = SimBackend;
        let cfg = SchedCfg::new(MachineSpec::tiny(), 1);
        let mut off = ExecState::new(&cfg);
        off.note_retire(&op, 1.0, &mut be);
        assert_eq!(off.prof.events(), 0);

        let mut pcfg = SchedCfg::new(MachineSpec::tiny(), 1);
        pcfg.profile.enabled = true;
        let mut on = ExecState::new(&pcfg);
        on.note_retire(&op, 1.0, &mut be);
        assert_eq!(on.prof.events(), 1);
        assert!(on.report().host.is_some());
        assert!(off.report().host.is_none());
    }

    #[test]
    fn charge_overhead_advances_every_rank() {
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        st.clock = vec![1.0, 2.0];
        st.charge_overhead(0.5);
        assert_eq!(st.clock, vec![1.5, 2.5]);
        assert_eq!(st.overhead, 0.5);
    }
}
