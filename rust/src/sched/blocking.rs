//! Blocking-communication baseline (the "without latency-hiding" setup
//! of the paper's evaluation, Section 6).
//!
//! Each rank walks the recorded array operations in order, executing
//! every one with the paper's §5.3 four-step scheme and blocking MPI
//! semantics: first exchange the array elements of the operation (sends
//! return once injected — eager protocol; receives block until arrival),
//! then compute the local fragments. No dependency analysis, no overlap
//! across array operations — communication time lands squarely in the
//! waiting-time metric.
//!
//! Within one array operation (an op *group*) the per-rank order is
//! sends, then receives, then computes; groups execute strictly in
//! recording order. This is exactly DistNumPy-without-latency-hiding:
//! the exchange phase pipelines inside one operation, but nothing ever
//! crosses an operation boundary.
//!
//! Progress property: within a group every send precedes every recv on
//! each rank and matched pairs share a group, so the globally-earliest
//! unexecuted operation can always proceed; the smallest-clock-first
//! loop below therefore never deadlocks.
//!
//! Like the other policies this runs as one epoch of a persistent
//! [`ExecState`] — even the blocking baseline resumes per-rank clocks
//! and NIC frontiers across flushes; what it *never* does is overlap
//! across operation (or epoch) boundaries on the same rank.

use std::collections::BinaryHeap;

use super::{compute_costs, ExecState, SchedCfg, SchedError, TEvent, TransferTable};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::types::{Rank, Tag, VTime};
use crate::ufunc::{OpNode, OpPayload};
use crate::util::fxhash::FxHashMap;

/// One-shot convenience: run `ops` as the single epoch of a fresh
/// [`ExecState`] and report it.
pub fn run_blocking(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    state.n_epochs = 1;
    state.run_id = 1;
    run_blocking_epoch(ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

pub(crate) fn run_blocking_epoch(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
    st: &mut ExecState,
) -> Result<(), SchedError> {
    let n = cfg.nprocs as usize;
    let xfers = TransferTable::build(ops)?;
    let costs = compute_costs(ops, cfg);
    st.begin_epoch(ops);

    // Per-rank program: indices into `ops`, phased per §5.3 — groups in
    // recording order; within a group sends, then recvs, then computes
    // (each sub-phase in recording order).
    let phase = |op: &OpNode| match op.payload {
        OpPayload::Send { .. } => 0u8,
        OpPayload::Recv { .. } => 1,
        OpPayload::Compute(_) => 2,
    };
    let mut program: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, op) in ops.iter().enumerate() {
        program[op.rank.idx()].push(i);
    }
    for prog in program.iter_mut() {
        prog.sort_by_key(|&i| (ops[i].group, phase(&ops[i]), i));
    }
    let mut ptr = vec![0usize; n];
    // No dependency system: only the (cheaper) recording overhead.
    // Flow waves pay it on the concurrent recorder clock instead; the
    // per-op admission gates below are what execution observes. The
    // blocking baseline still never overlaps across operation
    // boundaries on a rank — a wave buys it the streamed recording
    // clock, nothing more.
    if st.admit.is_empty() {
        st.charge_overhead(super::batch_overhead(
            ops,
            cfg.spec.blocking_op_overhead,
            &cfg.spec,
        ));
    }

    // Runnable ranks by clock; receivers parked on an unposted send.
    let mut heap: BinaryHeap<TEvent<Rank>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut parked: FxHashMap<Tag, (Rank, VTime)> = FxHashMap::default();
    for r in 0..n {
        if !program[r].is_empty() {
            heap.push(TEvent {
                t: st.clock[r],
                seq,
                ev: Rank(r as u32),
            });
            seq += 1;
        }
    }

    let mut executed = 0u64;
    while let Some(TEvent { ev: rank, .. }) = heap.pop() {
        let r = rank.idx();
        if ptr[r] >= program[r].len() {
            continue;
        }
        let i = program[r][ptr[r]];
        let op = &ops[i];
        match &op.payload {
            OpPayload::Compute(task) => {
                st.gate_admission(rank, op.id);
                backend.exec_compute(rank, task);
                st.busy[r] += costs[i];
                st.clock[r] += costs[i];
                st.note_retire(op, st.clock[r], backend);
                ptr[r] += 1;
                executed += 1;
            }
            OpPayload::Send {
                peer, tag, bytes, ..
            } => {
                let t0 = st.gate_admission(rank, op.id);
                let res = st.net.post_send(t0, rank, *peer, *tag, *bytes);
                // Data leaves the sender *now* (eager injection): the
                // payload must be captured before the sender's later
                // operations can overwrite the source region. The
                // receiver only reads its stage after recv completion
                // in virtual time, so early delivery is unobservable.
                let info = &xfers.info[tag];
                backend.exec_transfer(info.from, info.to, *tag, &info.src);
                let done = res.send_done.unwrap();
                st.wait[r] += done - t0;
                st.clock[r] = done;
                st.note_retire(op, done, backend);
                ptr[r] += 1;
                executed += 1;
                if let Some(rd) = res.recv_done {
                    // The matching recv was already blocked: wake it.
                    if let Some((peer_rank, parked_at)) = parked.remove(tag) {
                        let pr = peer_rank.idx();
                        let resume = rd.max(parked_at);
                        st.wait[pr] += resume - parked_at;
                        st.clock[pr] = resume;
                        st.note_retire(&ops[xfers.info[tag].recv_op.idx()], resume, backend);
                        ptr[pr] += 1;
                        executed += 1;
                        heap.push(TEvent {
                            t: st.clock[pr],
                            seq,
                            ev: peer_rank,
                        });
                        seq += 1;
                    }
                }
            }
            OpPayload::Recv { tag, .. } => {
                let t0 = st.gate_admission(rank, op.id);
                if st.net.send_posted(*tag) {
                    let res = st.net.post_recv(t0, rank, *tag);
                    let rd = res.recv_done.unwrap();
                    st.wait[r] += rd - t0;
                    st.clock[r] = rd;
                    st.note_retire(op, rd, backend);
                    ptr[r] += 1;
                    executed += 1;
                } else {
                    // Block until the send appears.
                    st.net.post_recv(t0, rank, *tag);
                    parked.insert(*tag, (rank, t0));
                    continue; // don't requeue; the sender wakes us.
                }
            }
        }
        if ptr[r] < program[r].len() {
            heap.push(TEvent {
                t: st.clock[r],
                seq,
                ev: rank,
            });
            seq += 1;
        }
    }

    if executed as usize != ops.len() {
        return Err(SchedError::Deadlock {
            executed,
            total: ops.len() as u64,
            blocked_recvs: parked.len() as u64,
        });
    }

    super::count_epoch_ops(st, ops);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    #[test]
    fn executes_all_ops_in_order() {
        let mut reg = Registry::new(2);
        let m = reg.alloc(vec![6], 3, DType::F32);
        let nn = reg.alloc(vec![6], 3, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let a = mv.slice(&[(2, 6)]);
        let b = mv.slice(&[(0, 4)]);
        let c = nv.slice(&[(1, 5)]);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &c, &[&a, &b]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let rep = run_blocking(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.ops_executed, ops.len() as u64);
        assert!(rep.wait.iter().sum::<f64>() > 0.0, "blocking must wait");
    }

    #[test]
    fn single_rank_never_waits() {
        let mut reg = Registry::new(1);
        let x = reg.alloc(vec![100], 10, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Scale(2.0), &xv, &[&xv]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 1);
        let rep = run_blocking(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.wait[0], 0.0);
        assert_eq!(rep.n_comm, 0);
    }
}
