//! Blocking-communication baseline (the "without latency-hiding" setup
//! of the paper's evaluation, Section 6).
//!
//! Each rank walks the recorded array operations in order, executing
//! every one with the paper's §5.3 four-step scheme and blocking MPI
//! semantics: first exchange the array elements of the operation (sends
//! return once injected — eager protocol; receives block until arrival),
//! then compute the local fragments. No dependency analysis, no overlap
//! across array operations — communication time lands squarely in the
//! waiting-time metric.
//!
//! Within one array operation (an op *group*) the per-rank order is
//! sends, then receives, then computes; groups execute strictly in
//! recording order. This is exactly DistNumPy-without-latency-hiding:
//! the exchange phase pipelines inside one operation, but nothing ever
//! crosses an operation boundary.
//!
//! Progress property: within a group every send precedes every recv on
//! each rank and matched pairs share a group, so the globally-earliest
//! unexecuted operation can always proceed; the smallest-clock-first
//! loop below therefore never deadlocks.
//!
//! Since PR 5 the baseline is a **resumable engine** ([`BlockingSession`],
//! driven through [`crate::sched::SchedSession`]): per-rank programs,
//! program counters, parked receives and the runnable-rank heap persist
//! between injects, so later epochs append to the per-rank programs (the
//! splicer keeps their §5.3 groups strictly after earlier epochs') and a
//! rank that finished its program is re-queued when new work arrives.
//! What the baseline still *never* does is overlap across operation (or
//! epoch) boundaries on the same rank — streaming admission buys it the
//! concurrent recording clock, nothing more.

use super::{compute_costs, EventQueue, ExecState, SchedCfg, SchedError, TEvent, TransferTable};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::trace::{OpKind, WaitCause};
use crate::types::{Rank, Tag, VTime};
use crate::ufunc::{OpNode, OpPayload};
use crate::util::fxhash::FxHashMap;

/// §5.3 phase of an operation within its group: sends, then receives,
/// then computes (each sub-phase in recording order).
fn phase(op: &OpNode) -> u8 {
    match op.payload {
        OpPayload::Send { .. } => 0u8,
        OpPayload::Recv { .. } => 1,
        OpPayload::Compute(_) => 2,
    }
}

/// The blocking baseline's persistent session state.
pub(crate) struct BlockingSession {
    xfers: TransferTable,
    costs: Vec<VTime>,
    /// Per-rank program: indices into the session's op stream, phased
    /// per §5.3 — groups in recording order; within a group sends, then
    /// recvs, then computes.
    program: Vec<Vec<usize>>,
    ptr: Vec<usize>,
    /// Receivers parked on an unposted send.
    parked: FxHashMap<Tag, (Rank, VTime)>,
    /// Parked-receive count per rank — the sharded session's O(1)
    /// replacement for scanning `parked` in [`Self::is_parked`].
    parked_by_rank: Vec<u32>,
    /// Runnable ranks by clock: the seed global heap at `--workers 1`,
    /// per-rank actor shards beyond ([`crate::sched::queue`]).
    pub(crate) q: EventQueue<Rank>,
    queued: Vec<bool>,
    workers: usize,
    pub(crate) executed: u64,
}

impl BlockingSession {
    pub(crate) fn new(cfg: &SchedCfg) -> Self {
        let n = cfg.nprocs as usize;
        BlockingSession {
            xfers: TransferTable::empty(),
            costs: Vec::new(),
            program: vec![Vec::new(); n],
            ptr: vec![0; n],
            parked: FxHashMap::default(),
            parked_by_rank: vec![0; n],
            q: EventQueue::new(n, cfg.workers, cfg.profile.enabled),
            queued: vec![false; n],
            workers: cfg.workers,
            executed: 0,
        }
    }

    fn is_parked(&self, rank: Rank) -> bool {
        // Identical answers, two shapes: the serial reference keeps the
        // seed scan verbatim; sharded sessions read the per-actor
        // counter, so a P-wide activate costs O(P), not O(P × parked).
        if self.workers > 1 {
            self.parked_by_rank[rank.idx()] > 0
        } else {
            self.parked.values().any(|&(pr, _)| pr == rank)
        }
    }

    /// Splice the tail `ops[lo..]` into the per-rank programs. The
    /// tail's groups are strictly after every earlier group (flush
    /// epochs never interleave — the wave merge and the sliding splicer
    /// both offset them), so per-rank sorting of the tail alone keeps
    /// each whole program in (group, phase, index) order.
    pub(crate) fn extend(
        &mut self,
        ops: &[OpNode],
        lo: usize,
        cfg: &SchedCfg,
    ) -> Result<(), SchedError> {
        let new = &ops[lo..];
        self.xfers.extend(new)?;
        self.costs.extend(compute_costs(new, cfg));
        let n = self.program.len();
        let mut chunk: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (k, op) in new.iter().enumerate() {
            chunk[op.rank.idx()].push(lo + k);
        }
        for (r, mut c) in chunk.into_iter().enumerate() {
            c.sort_by_key(|&i| (ops[i].group, phase(&ops[i]), i));
            self.program[r].extend(c);
        }
        Ok(())
    }

    /// Activate the tail: charge recording (Batch epochs only) and
    /// queue every rank that has work but no pending turn — including
    /// ranks that had finished their program before this inject (the
    /// quiescent-session wake-up). Parked ranks are left alone: their
    /// sender wakes them.
    pub(crate) fn activate(
        &mut self,
        ops: &[OpNode],
        lo: usize,
        cfg: &SchedCfg,
        _backend: &mut dyn Backend,
        st: &mut ExecState,
    ) {
        let new = &ops[lo..];
        // No dependency system: only the (cheaper) recording overhead.
        // Gated injects pay it on the concurrent recorder clock instead;
        // the per-op admission gates below are what execution observes.
        if st.admit.is_empty() {
            st.charge_overhead(super::batch_overhead(
                new,
                cfg.spec.blocking_op_overhead,
                &cfg.spec,
            ));
        }
        for r in 0..self.program.len() {
            let rank = Rank(r as u32);
            if self.ptr[r] < self.program[r].len() && !self.queued[r] && !self.is_parked(rank) {
                self.q.push(st.clock[r], r, rank);
                self.queued[r] = true;
            }
        }
    }

    /// One rank's turn: execute its next program entry.
    fn turn(&mut self, ops: &[OpNode], st: &mut ExecState, backend: &mut dyn Backend, rank: Rank) {
        let r = rank.idx();
        if self.ptr[r] >= self.program[r].len() {
            return;
        }
        let i = self.program[r][self.ptr[r]];
        let op = &ops[i];
        match &op.payload {
            OpPayload::Compute(task) => {
                let t0 = st.gate_admission(rank, op.id);
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op.id, rank, OpKind::Compute, ep, t0);
                }
                backend.exec_compute(rank, task);
                st.busy[r] += self.costs[i];
                st.clock[r] += self.costs[i];
                st.note_retire(op, st.clock[r], backend);
                self.ptr[r] += 1;
                self.executed += 1;
            }
            OpPayload::Send {
                peer, tag, bytes, ..
            } => {
                let t0 = st.gate_admission(rank, op.id);
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op.id, rank, OpKind::Send, ep, t0);
                }
                let res = st.note_msg_post(*tag, rank, *peer, *bytes, t0);
                // Data leaves the sender *now* (eager injection): the
                // payload must be captured before the sender's later
                // operations can overwrite the source region. The
                // receiver only reads its stage after recv completion
                // in virtual time, so early delivery is unobservable.
                let recv_op = {
                    let info = &self.xfers.info[tag];
                    backend.exec_transfer(info.from, info.to, *tag, &info.src);
                    info.recv_op
                };
                let done = res.send_done.unwrap();
                st.charge_wait(r, t0, done, WaitCause::Transfer { peer: *peer });
                st.clock[r] = done;
                st.note_retire(op, done, backend);
                self.ptr[r] += 1;
                self.executed += 1;
                if let Some(rd) = res.recv_done {
                    st.trace.msg_deliver(*tag, rank, *peer, *bytes, rd);
                    // The matching recv was already blocked: wake it.
                    if let Some((peer_rank, parked_at)) = self.parked.remove(tag) {
                        let pr = peer_rank.idx();
                        self.parked_by_rank[pr] -= 1;
                        let resume = rd.max(parked_at);
                        st.charge_wait(pr, parked_at, resume, WaitCause::Transfer { peer: rank });
                        st.clock[pr] = resume;
                        st.note_retire(&ops[recv_op.idx()], resume, backend);
                        self.ptr[pr] += 1;
                        self.executed += 1;
                        self.q.push(st.clock[pr], pr, peer_rank);
                        self.queued[pr] = true;
                    }
                }
            }
            OpPayload::Recv { peer, tag, bytes } => {
                let t0 = st.gate_admission(rank, op.id);
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op.id, rank, OpKind::Recv, ep, t0);
                }
                if st.net.send_posted(*tag) {
                    let res = st.net.post_recv(t0, rank, *tag);
                    let rd = res.recv_done.unwrap();
                    st.trace.msg_deliver(*tag, *peer, rank, *bytes, rd);
                    st.charge_wait(r, t0, rd, WaitCause::Transfer { peer: *peer });
                    st.clock[r] = rd;
                    st.note_retire(op, rd, backend);
                    self.ptr[r] += 1;
                    self.executed += 1;
                } else {
                    // Block until the send appears.
                    st.net.post_recv(t0, rank, *tag);
                    if self.parked.insert(*tag, (rank, t0)).is_none() {
                        self.parked_by_rank[r] += 1;
                    }
                    return; // don't requeue; the sender wakes us.
                }
            }
        }
        if self.ptr[r] < self.program[r].len() {
            self.q.push(st.clock[r], r, rank);
            self.queued[r] = true;
        }
    }

    /// Advance through every turn at or before `until`.
    pub(crate) fn pump_until(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        until: VTime,
    ) {
        while self.q.peek_t().is_some_and(|t| t <= until) {
            let TEvent { ev: rank, .. } = self.q.pop().unwrap();
            self.queued[rank.idx()] = false;
            self.turn(ops, st, backend, rank);
        }
    }

    /// Process the earliest pending turn; `None` on a quiescent loop.
    pub(crate) fn pump_next(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
    ) -> Option<VTime> {
        let TEvent { t, ev: rank, .. } = self.q.pop()?;
        self.queued[rank.idx()] = false;
        self.turn(ops, st, backend, rank);
        Some(t)
    }

    /// Run the loop to quiescence.
    pub(crate) fn pump_all(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
    ) {
        while let Some(TEvent { ev: rank, .. }) = self.q.pop() {
            self.queued[rank.idx()] = false;
            self.turn(ops, st, backend, rank);
        }
    }

    /// Verify every injected operation executed.
    pub(crate) fn finish_check(&self, ops: &[OpNode]) -> Result<(), SchedError> {
        if self.executed as usize != ops.len() {
            // Name the wait chain like the naive engine does (a cyclic
            // stream — e.g. a mis-aggregated batch — can wedge the
            // baseline too); empty when nothing was parked.
            let mut parked: Vec<(Rank, Tag)> =
                self.parked.iter().map(|(&t, &(r, _))| (r, t)).collect();
            parked.sort_unstable();
            return Err(SchedError::Deadlock {
                executed: self.executed,
                total: ops.len() as u64,
                blocked_recvs: self.parked.len() as u64,
                cycle: crate::analyze::stalls::witness_cycle(ops, &parked),
            });
        }
        Ok(())
    }
}

/// One-shot convenience: run `ops` as the single epoch of a fresh
/// [`ExecState`] and report it.
pub fn run_blocking(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    state.n_epochs = 1;
    super::session::one_shot(super::Policy::Blocking, ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    #[test]
    fn executes_all_ops_in_order() {
        let mut reg = Registry::new(2);
        let m = reg.alloc(vec![6], 3, DType::F32);
        let nn = reg.alloc(vec![6], 3, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let a = mv.slice(&[(2, 6)]);
        let b = mv.slice(&[(0, 4)]);
        let c = nv.slice(&[(1, 5)]);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &c, &[&a, &b]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let rep = run_blocking(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.ops_executed, ops.len() as u64);
        assert!(rep.wait.iter().sum::<f64>() > 0.0, "blocking must wait");
    }

    #[test]
    fn single_rank_never_waits() {
        let mut reg = Registry::new(1);
        let x = reg.alloc(vec![100], 10, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Scale(2.0), &xv, &[&xv]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 1);
        let rep = run_blocking(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.wait[0], 0.0);
        assert_eq!(rep.n_comm, 0);
    }
}
