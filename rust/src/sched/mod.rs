//! Operation-flush schedulers (paper Sections 5.6–5.7).
//!
//! Three execution policies over the same recorded operation batch:
//!
//! * [`Policy::LatencyHiding`] — the paper's contribution: initiate every
//!   ready communication immediately and non-blockingly, evaluate
//!   computation lazily, test for finished transfers between compute
//!   operations (the flush algorithm of Section 5.7 with its three
//!   invariants).
//! * [`Policy::Blocking`] — the baseline of the evaluation: operations
//!   execute in recorded order with blocking communication; nothing
//!   overlaps.
//! * [`Policy::Naive`] — the Fig. 6 strawman: ready operations execute
//!   in becoming-ready order with blocking communication. Deadlocks on
//!   streams whose matching send sits behind a blocked receive; the
//!   engine detects this and reports it instead of hanging.
//!
//! All policies run on the same discrete-event cluster (virtual clocks
//! per rank, α–β network, NIC FIFOs, memory contention) and the same
//! pluggable [`Backend`], so timing and numerics share one code path.

mod blocking;
mod lh;
mod naive;

pub use blocking::run_blocking;
pub use lh::run_latency_hiding;
pub use naive::run_naive;

use crate::cluster::{MachineSpec, Placement};
use crate::comm::Collective;
use crate::deps::{DagDeps, DepSystem, HeuristicDeps};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::types::{OpId, Rank, Tag, VTime};
use crate::util::fxhash::FxHashMap;
use crate::ufunc::{OpNode, OpPayload, SendSrc};

/// Which dependency system backs the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DepsKind {
    Heuristic,
    Dag,
}

impl DepsKind {
    pub fn build(self) -> Box<dyn DepSystem> {
        match self {
            DepsKind::Heuristic => Box::new(HeuristicDeps::new()),
            DepsKind::Dag => Box::new(DagDeps::new()),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    LatencyHiding,
    Blocking,
    Naive,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "lh" | "latency-hiding" => Some(Policy::LatencyHiding),
            "blocking" => Some(Policy::Blocking),
            "naive" => Some(Policy::Naive),
            _ => None,
        }
    }
}

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedCfg {
    pub spec: MachineSpec,
    pub nprocs: u32,
    pub placement: Placement,
    pub deps: DepsKind,
    /// §7 extension: prefer ready compute operations whose base-block
    /// the rank touched last (cache-locality scheduling). Changes only
    /// the *selection order* of the ready queue; the cache-reuse cost
    /// discount itself applies under every policy.
    pub locality: bool,
    /// Which cross-rank schedule collectives record ([`crate::comm`]):
    /// flat fan-ins (the paper) or binomial-tree / ring schedules.
    pub collective: Collective,
    /// Message-aggregation threshold: maximum constituent transfers per
    /// packed wire message (`comm::aggregate`). `0` or `1` disables.
    pub aggregation: usize,
}

impl SchedCfg {
    pub fn new(spec: MachineSpec, nprocs: u32) -> Self {
        SchedCfg {
            spec,
            nprocs,
            placement: Placement::ByNode,
            deps: DepsKind::Heuristic,
            locality: false,
            collective: Collective::Flat,
            aggregation: 0,
        }
    }
}

#[derive(Debug)]
pub enum SchedError {
    /// Every runnable path is blocked on an unreachable transfer (the
    /// naive evaluator of Fig. 6; also any policy fed a cyclic stream,
    /// e.g. an aggregated message whose constituents span a blocked
    /// receive). `blocked_recvs` counts the receives parked with no
    /// matching send posted when progress stopped.
    Deadlock {
        executed: u64,
        total: u64,
        blocked_recvs: u64,
    },
    /// Internal scheduler invariant violation (a bug, not a program
    /// property): progress stopped with no blocked receive to blame.
    Stall(String),
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::Deadlock {
                executed,
                total,
                blocked_recvs,
            } => write!(
                f,
                "deadlock detected: {executed} of {total} operations executed \
                 ({blocked_recvs} receives blocked on unposted sends)"
            ),
            SchedError::Stall(s) => write!(f, "internal scheduler stall: {s}"),
        }
    }
}

impl std::error::Error for SchedError {}

/// Execute one flushed batch under `policy`. When the configuration
/// enables message aggregation, the batch is rewritten by
/// [`crate::comm::aggregate`] first and the resulting statistics are
/// threaded into the report.
pub fn execute(
    policy: Policy,
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let dispatch = |ops: &[OpNode], backend: &mut dyn Backend| match policy {
        Policy::LatencyHiding => run_latency_hiding(ops, cfg, backend),
        Policy::Blocking => run_blocking(ops, cfg, backend),
        Policy::Naive => run_naive(ops, cfg, backend),
    };
    if cfg.aggregation >= 2 {
        let (packed, stats) = crate::comm::aggregate(ops, cfg.aggregation);
        let mut report = dispatch(&packed, backend)?;
        report.agg_msgs = stats.packed_msgs;
        report.agg_parts = stats.packed_parts;
        Ok(report)
    } else {
        dispatch(ops, backend)
    }
}

/// Virtual cost of one sequential NumPy execution of the same compute
/// payloads — the denominator of every speedup figure. NumPy 1.3
/// allocates a fresh temporary per ufunc (no lazy buffer recycling), so
/// each op additionally pays interpreter + allocation overhead
/// (Section 6.1.1 explains the resulting super-linear speedups).
pub fn numpy_baseline(ops: &[OpNode], spec: &MachineSpec) -> VTime {
    let mut t = 0.0;
    for op in ops {
        if let Some((flops, bytes)) = op.compute_cost() {
            t += spec.compute_time(flops, bytes, 1);
            // Fresh output temporary per ufunc: first-touch cost.
            if let OpPayload::Compute(task) = &op.payload {
                let out_bytes = task.elems as f64 * 4.0;
                t += out_bytes * spec.numpy_alloc_per_byte;
            }
        }
    }
    // Note: the per-ufunc *interpreter* overhead is charged per
    // array-level operation by the lazy Context (fragment counts depend
    // on P; the NumPy original sees one call per array op).
    t
}

// ---------------------------------------------------------------------------
// Shared internals for the three policies
// ---------------------------------------------------------------------------

/// Transfer bookkeeping shared by the schedulers: tag -> endpoints.
pub(crate) struct TransferTable {
    pub info: FxHashMap<Tag, TransferInfo>,
}

#[derive(Clone, Debug)]
pub(crate) struct TransferInfo {
    pub send_op: OpId,
    pub recv_op: OpId,
    pub from: Rank,
    pub to: Rank,
    pub bytes: u64,
    pub src: SendSrc,
}

impl TransferTable {
    pub fn build(ops: &[OpNode]) -> Self {
        let mut half: FxHashMap<Tag, TransferInfo> = FxHashMap::default();
        for op in ops {
            match &op.payload {
                OpPayload::Send {
                    peer,
                    tag,
                    bytes,
                    src,
                } => {
                    let e = half.entry(*tag).or_insert_with(|| TransferInfo {
                        send_op: op.id,
                        recv_op: OpId(u32::MAX),
                        from: op.rank,
                        to: *peer,
                        bytes: *bytes,
                        src: src.clone(),
                    });
                    e.send_op = op.id;
                    e.from = op.rank;
                    e.src = src.clone();
                    e.bytes = *bytes;
                }
                OpPayload::Recv { peer, tag, bytes } => {
                    let e = half.entry(*tag).or_insert_with(|| TransferInfo {
                        send_op: OpId(u32::MAX),
                        recv_op: op.id,
                        from: *peer,
                        to: op.rank,
                        bytes: *bytes,
                        src: SendSrc::Stage(*tag),
                    });
                    e.recv_op = op.id;
                    e.to = op.rank;
                }
                _ => {}
            }
        }
        for (tag, t) in &half {
            assert!(
                t.send_op != OpId(u32::MAX) && t.recv_op != OpId(u32::MAX),
                "unpaired transfer {tag:?}"
            );
        }
        TransferTable { info: half }
    }
}

/// Per-rank recording/bookkeeping overhead of a flush batch: every
/// rank records every fragment op (global knowledge, §5.5) plus the
/// CPython dispatch per array-level operation (group).
pub(crate) fn batch_overhead(ops: &[OpNode], per_op: VTime, spec: &MachineSpec) -> VTime {
    let n_groups = ops.iter().map(|o| o.group as u64 + 1).max().unwrap_or(0);
    ops.len() as f64 * per_op + n_groups as f64 * spec.py_op_overhead
}

/// Precomputed per-op compute costs under the given contention.
pub(crate) fn compute_costs(ops: &[OpNode], cfg: &SchedCfg) -> Vec<VTime> {
    let contention = cfg.placement.contention(cfg.nprocs, &cfg.spec);
    ops.iter()
        .map(|op| match op.compute_cost() {
            Some((flops, bytes)) => {
                cfg.spec
                    .compute_time(flops, bytes, contention[op.rank.idx()])
            }
            None => 0.0,
        })
        .collect()
}

/// Per-op compute costs when the primary operand block is L2-resident.
pub(crate) fn compute_costs_hot(ops: &[OpNode], cfg: &SchedCfg) -> Vec<VTime> {
    let contention = cfg.placement.contention(cfg.nprocs, &cfg.spec);
    ops.iter()
        .map(|op| match op.compute_cost() {
            Some((flops, bytes)) => {
                cfg.spec
                    .compute_time_hot(flops, bytes, contention[op.rank.idx()])
            }
            None => 0.0,
        })
        .collect()
}

/// The base-block an operation's working set is keyed on for cache
/// purposes: its first block access (the output for compute ops).
pub(crate) fn primary_block(op: &OpNode) -> Option<(crate::types::BaseId, u64)> {
    op.accesses.iter().find_map(|a| match a.loc {
        crate::ufunc::Loc::Block { base, block } => Some((base, block)),
        crate::ufunc::Loc::Stage(_) => None,
    })
}

/// Min-heap event for the DES engines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct TEvent<E> {
    pub t: VTime,
    pub seq: u64,
    pub ev: E,
}

impl<E: PartialEq> Eq for TEvent<E> {}

impl<E: PartialEq> Ord for TEvent<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E: PartialEq> PartialOrd for TEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tevent_orders_min_first() {
        let mut h = std::collections::BinaryHeap::new();
        h.push(TEvent {
            t: 2.0,
            seq: 0,
            ev: (),
        });
        h.push(TEvent {
            t: 1.0,
            seq: 1,
            ev: (),
        });
        h.push(TEvent {
            t: 1.0,
            seq: 0,
            ev: (),
        });
        assert_eq!(h.pop().unwrap().seq, 0);
        assert_eq!(h.pop().unwrap().t, 1.0);
        assert_eq!(h.pop().unwrap().t, 2.0);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("lh"), Some(Policy::LatencyHiding));
        assert_eq!(Policy::parse("blocking"), Some(Policy::Blocking));
        assert_eq!(Policy::parse("naive"), Some(Policy::Naive));
        assert_eq!(Policy::parse("x"), None);
    }
}
