//! Operation-flush schedulers (paper Sections 5.6–5.7).
//!
//! Three execution policies over the same recorded operation batch:
//!
//! * [`Policy::LatencyHiding`] — the paper's contribution: initiate every
//!   ready communication immediately and non-blockingly, evaluate
//!   computation lazily, test for finished transfers between compute
//!   operations (the flush algorithm of Section 5.7 with its three
//!   invariants).
//! * [`Policy::Blocking`] — the baseline of the evaluation: operations
//!   execute in recorded order with blocking communication; nothing
//!   overlaps.
//! * [`Policy::Naive`] — the Fig. 6 strawman: ready operations execute
//!   in becoming-ready order with blocking communication. Deadlocks on
//!   streams whose matching send sits behind a blocked receive; the
//!   engine detects this and reports it instead of hanging.
//!
//! All policies run on the same discrete-event cluster (virtual clocks
//! per rank, α–β network, NIC FIFOs, memory contention) and the same
//! pluggable [`Backend`], so timing and numerics share one code path.

mod blocking;
mod lh;
mod naive;
mod queue;
mod session;
mod state;

pub use blocking::run_blocking;
pub use lh::run_latency_hiding;
pub use naive::run_naive;
pub use session::SchedSession;
pub use state::{CapturedStreams, ExecState};
pub use crate::sync::SyncMode;

use crate::cluster::{MachineSpec, Placement};
use crate::comm::Collective;
use crate::deps::{DagDeps, DepSystem, HeuristicDeps};
use crate::exec::Backend;
use crate::flow::FlowCfg;
use crate::metrics::RunReport;
use crate::types::{OpId, Rank, Tag, VTime};
use crate::util::fxhash::FxHashMap;
use crate::ufunc::{Dst, Kernel, OpNode, OpPayload, SendSrc};

/// Default flush threshold of the lazy context (paper: "a user-defined
/// threshold"). Lives here so [`SchedCfg`] can carry the knob end to
/// end (CLI `--flush-threshold`, harness JSON metadata).
pub const DEFAULT_FLUSH_THRESHOLD: usize = 50_000;

/// Which dependency system backs the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DepsKind {
    Heuristic,
    Dag,
}

impl DepsKind {
    /// Parse a CLI name (`heuristic` / `dag`).
    pub fn parse(s: &str) -> Option<DepsKind> {
        match s {
            "heuristic" => Some(DepsKind::Heuristic),
            "dag" => Some(DepsKind::Dag),
            _ => None,
        }
    }

    pub fn build(self) -> Box<dyn DepSystem> {
        match self {
            DepsKind::Heuristic => Box::new(HeuristicDeps::new()),
            DepsKind::Dag => Box::new(DagDeps::new()),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    LatencyHiding,
    Blocking,
    Naive,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "lh" | "latency-hiding" => Some(Policy::LatencyHiding),
            "blocking" => Some(Policy::Blocking),
            "naive" => Some(Policy::Naive),
            _ => None,
        }
    }
}

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedCfg {
    pub spec: MachineSpec,
    pub nprocs: u32,
    pub placement: Placement,
    pub deps: DepsKind,
    /// §7 extension: prefer ready compute operations whose base-block
    /// the rank touched last (cache-locality scheduling). Changes only
    /// the *selection order* of the ready queue; the cache-reuse cost
    /// discount itself applies under every policy.
    pub locality: bool,
    /// Which cross-rank schedule collectives record ([`crate::comm`]):
    /// flat fan-ins (the paper) or binomial-tree / ring schedules.
    pub collective: Collective,
    /// Message-aggregation threshold: maximum constituent transfers per
    /// packed wire message (`comm::aggregate`). `0` or `1` disables.
    pub aggregation: usize,
    /// How forcing a value synchronizes the timeline: the global clock
    /// join of PR 2, or the targeted dependency-cone settle of
    /// [`crate::sync`] (the default).
    pub sync: SyncMode,
    /// How threshold triggers turn into execution: stop-the-world
    /// batches (the reference path) or the incremental flush engine's
    /// streaming admission ([`crate::flow`]; CLI `--flow`).
    pub flow: FlowCfg,
    /// Recorded-operation count that fires flush trigger 2
    /// ([`crate::lazy::Context`]; CLI `--flush-threshold`).
    pub flush_threshold: usize,
    /// Event-sourced tracing ([`crate::trace`]; CLI `--trace`): disabled
    /// by default — the sink on [`ExecState`] is then a no-op.
    pub trace: crate::trace::TraceCfg,
    /// Host-side self-profiling ([`crate::profile`]; CLI `--profile`):
    /// phase-scoped wall timers and DES events/sec. Disabled by default
    /// — no `Instant` is ever taken, and the simulated timeline is
    /// bit-identical either way.
    pub profile: crate::profile::ProfCfg,
    /// Run the [`crate::analyze`] hazard oracle on every drained wave
    /// (CLI `--verify`): recompute the exact conflict edges of the ops
    /// the session executed and hard-error if the active dependency
    /// system missed one. Off by default — the verification replay is
    /// O(ops²/64) per wave.
    pub verify_deps: bool,
    /// Host workers pumping the event loop (CLI `--workers`). `1` (the
    /// default) is the seed serial engine, byte for byte — the
    /// reference for every ablation. `N ≥ 2` switches the engines to
    /// the sharded per-rank actor queue with null-message
    /// synchronization and deterministic work stealing
    /// ([`queue`]; DESIGN.md §13): simulated results stay
    /// bit-identical, only host wall time and the `host` profile
    /// section change.
    pub workers: usize,
}

impl SchedCfg {
    pub fn new(spec: MachineSpec, nprocs: u32) -> Self {
        SchedCfg {
            spec,
            nprocs,
            placement: Placement::ByNode,
            deps: DepsKind::Heuristic,
            locality: false,
            collective: Collective::Flat,
            aggregation: 0,
            sync: SyncMode::Cone,
            flow: FlowCfg::default(),
            flush_threshold: DEFAULT_FLUSH_THRESHOLD,
            trace: crate::trace::TraceCfg::default(),
            profile: crate::profile::ProfCfg::default(),
            verify_deps: false,
            workers: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub enum SchedError {
    /// Every runnable path is blocked on an unreachable transfer (the
    /// naive evaluator of Fig. 6; also any policy fed a cyclic stream,
    /// e.g. an aggregated message whose constituents span a blocked
    /// receive). `blocked_recvs` counts the receives parked with no
    /// matching send posted when progress stopped.
    Deadlock {
        executed: u64,
        total: u64,
        blocked_recvs: u64,
        /// The rendered rank/tag wait chain behind the parked receives
        /// ([`crate::analyze::stalls::witness_cycle`]); empty when no
        /// receive was parked (pure dependency wedge).
        cycle: String,
    },
    /// Internal scheduler invariant violation (a bug, not a program
    /// property): progress stopped with no blocked receive to blame.
    Stall(String),
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::Deadlock {
                executed,
                total,
                blocked_recvs,
                cycle,
            } => {
                write!(
                    f,
                    "deadlock detected: {executed} of {total} operations executed \
                     ({blocked_recvs} receives blocked on unposted sends)"
                )?;
                if !cycle.is_empty() {
                    write!(f, "; cycle: {cycle}")?;
                }
                Ok(())
            }
            SchedError::Stall(s) => write!(f, "internal scheduler stall: {s}"),
        }
    }
}

impl std::error::Error for SchedError {}

/// Execute one flushed batch under `policy` on a *fresh* [`ExecState`]
/// and return the resulting report — the single-epoch entry point used
/// by tests and standalone batch runs. Long-lived contexts use
/// [`execute_epoch`] instead, which resumes the simulation.
pub fn execute(
    policy: Policy,
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    execute_epoch(policy, ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

/// Execute one flushed batch as the next *epoch* of a continuous
/// simulation: per-rank clocks, NIC frontiers, accumulated wait/busy and
/// the dependency system all resume from `state` instead of restarting.
/// When the configuration enables message aggregation, the batch is
/// rewritten by [`crate::comm::aggregate`] first and the statistics are
/// folded into the state's counters.
pub fn execute_epoch(
    policy: Policy,
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
    state: &mut ExecState,
) -> Result<(), SchedError> {
    let run = |ops: Vec<OpNode>,
               backend: &mut dyn Backend,
               state: &mut ExecState|
     -> Result<(), SchedError> {
        // Batch epochs keep the continuous admission log continuous:
        // recording times are NaN (the overhead lands on the rank
        // clocks instead), retirement is attributed after the drain.
        let log_idx = state.flow_log.submitted(f64::NAN, f64::NAN, ops.len());
        state
            .trace
            .admit(log_idx as u64, f64::NAN, f64::NAN, ops.len() as u64);
        // One epoch = one session run: inject everything, drain. The
        // same [`SchedSession`] API the flow engine streams through —
        // there is no separate batch code path.
        let mut session = SchedSession::new(policy, cfg, state);
        session.inject(ops, None, cfg, backend, state)?;
        session.drain(backend, state)?;
        state.flow_log.retire_from(log_idx, &state.retire);
        state
            .trace
            .epoch_retired(log_idx as u64, state.flow_log.epochs[log_idx].retired);
        Ok(())
    };
    state.n_epochs += 1;
    if cfg.aggregation >= 2 {
        let (packed, stats) = crate::comm::aggregate(ops, cfg.aggregation);
        run(packed.into_owned(), backend, state)?;
        state.agg_msgs += stats.packed_msgs;
        state.agg_parts += stats.packed_parts;
        Ok(())
    } else {
        run(ops.to_vec(), backend, state)
    }
}

/// Execute a merged Flow *wave* — one scheduler dispatch spanning
/// several flush epochs, each operation gated on its epoch's admission
/// time ([`ExecState::gate_admission`]). The caller (the incremental
/// flush engine, [`crate::flow::FlowEngine`]) has already counted the
/// epochs, priced the recording on the recorder clock and filled the
/// admission log; recording overhead is therefore *not* charged on the
/// rank clocks here (the session's engines skip `charge_overhead`
/// whenever `state.admit` is non-empty).
pub(crate) fn execute_wave(
    policy: Policy,
    ops: Vec<OpNode>,
    admit: &[VTime],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
    state: &mut ExecState,
) -> Result<(), SchedError> {
    debug_assert_eq!(ops.len(), admit.len(), "one admission time per op");
    let mut session = SchedSession::new(policy, cfg, state);
    let res = match session.inject(ops, Some(admit), cfg, backend, state) {
        Ok(()) => session.drain(backend, state),
        Err(e) => Err(e),
    };
    state.admit = Vec::new();
    res
}

/// Virtual cost of one sequential NumPy execution of the same compute
/// payloads — the denominator of every speedup figure. NumPy 1.3
/// allocates a fresh temporary per ufunc (no lazy buffer recycling), so
/// each op additionally pays interpreter + allocation overhead
/// (Section 6.1.1 explains the resulting super-linear speedups).
pub fn numpy_baseline(ops: &[OpNode], spec: &MachineSpec) -> VTime {
    let mut t = 0.0;
    for op in ops {
        // Runtime-internal staging copies (the gather snapshots of
        // `lazy::Context::gather_deferred`) have no NumPy counterpart —
        // the sequential array is already dense — so they must not
        // inflate the speedup denominator.
        if let OpPayload::Compute(task) = &op.payload {
            if task.kernel == Kernel::Copy && matches!(task.dst, Dst::Stage(_)) {
                continue;
            }
        }
        if let Some((flops, bytes)) = op.compute_cost() {
            t += spec.compute_time(flops, bytes, 1);
            // Fresh output temporary per ufunc: first-touch cost.
            if let OpPayload::Compute(task) = &op.payload {
                let out_bytes = task.elems as f64 * 4.0;
                t += out_bytes * spec.numpy_alloc_per_byte;
            }
        }
    }
    // Note: the per-ufunc *interpreter* overhead is charged per
    // array-level operation by the lazy Context (fragment counts depend
    // on P; the NumPy original sees one call per array op).
    t
}

// ---------------------------------------------------------------------------
// Shared internals for the three policies
// ---------------------------------------------------------------------------

/// Transfer bookkeeping shared by the schedulers: tag -> endpoints.
pub(crate) struct TransferTable {
    pub info: FxHashMap<Tag, TransferInfo>,
}

#[derive(Clone, Debug)]
pub(crate) struct TransferInfo {
    pub send_op: OpId,
    pub recv_op: OpId,
    pub from: Rank,
    pub to: Rank,
    pub bytes: u64,
    pub src: SendSrc,
}

impl TransferTable {
    /// An empty table — resumable sessions start with no transfers and
    /// splice pairs in per inject ([`TransferTable::extend`]).
    pub(crate) fn empty() -> Self {
        TransferTable {
            info: FxHashMap::default(),
        }
    }

    /// Splice one injected batch's transfer pairs into the table. The
    /// batch must pair internally (send/recv pairs never span flush
    /// epochs — each array operation records both halves); tags are
    /// run-unique, so entries never collide with earlier injects.
    pub(crate) fn extend(&mut self, ops: &[OpNode]) -> Result<(), SchedError> {
        let add = TransferTable::build(ops)?;
        self.info.extend(add.info);
        Ok(())
    }

    /// Pair every send with its receive by tag. A half-paired tag means
    /// the recorded (or aggregation-rewritten) stream is malformed —
    /// reported as [`SchedError::Stall`] so a bad batch fails the flush
    /// loudly instead of aborting the process.
    pub fn build(ops: &[OpNode]) -> Result<Self, SchedError> {
        let mut half: FxHashMap<Tag, TransferInfo> = FxHashMap::default();
        for op in ops {
            match &op.payload {
                OpPayload::Send {
                    peer,
                    tag,
                    bytes,
                    src,
                } => {
                    let e = half.entry(*tag).or_insert_with(|| TransferInfo {
                        send_op: op.id,
                        recv_op: OpId(u32::MAX),
                        from: op.rank,
                        to: *peer,
                        bytes: *bytes,
                        src: src.clone(),
                    });
                    e.send_op = op.id;
                    e.from = op.rank;
                    e.src = src.clone();
                    e.bytes = *bytes;
                }
                OpPayload::Recv { peer, tag, bytes } => {
                    let e = half.entry(*tag).or_insert_with(|| TransferInfo {
                        send_op: OpId(u32::MAX),
                        recv_op: op.id,
                        from: *peer,
                        to: op.rank,
                        bytes: *bytes,
                        src: SendSrc::Stage(*tag),
                    });
                    e.recv_op = op.id;
                    e.to = op.rank;
                }
                _ => {}
            }
        }
        for (tag, t) in &half {
            if t.send_op == OpId(u32::MAX) || t.recv_op == OpId(u32::MAX) {
                let side = if t.send_op == OpId(u32::MAX) {
                    "send"
                } else {
                    "recv"
                };
                return Err(SchedError::Stall(format!(
                    "unpaired transfer {tag:?}: missing {side} half"
                )));
            }
        }
        Ok(TransferTable { info: half })
    }
}

/// Fold one executed epoch's operation counters into the state.
pub(crate) fn count_epoch_ops(state: &mut ExecState, ops: &[OpNode]) {
    let n_compute = ops.iter().filter(|o| !o.is_comm()).count() as u64;
    state.ops_executed += ops.len() as u64;
    state.n_compute += n_compute;
    state.n_comm += ops.len() as u64 - n_compute;
}

/// Per-rank recording/bookkeeping overhead of a flush batch: every
/// rank records every fragment op (global knowledge, §5.5) plus the
/// CPython dispatch per array-level operation (group).
pub(crate) fn batch_overhead(ops: &[OpNode], per_op: VTime, spec: &MachineSpec) -> VTime {
    let n_groups = ops.iter().map(|o| o.group as u64 + 1).max().unwrap_or(0);
    ops.len() as f64 * per_op + n_groups as f64 * spec.py_op_overhead
}

/// Precomputed per-op compute costs under the given contention.
pub(crate) fn compute_costs(ops: &[OpNode], cfg: &SchedCfg) -> Vec<VTime> {
    let contention = cfg.placement.contention(cfg.nprocs, &cfg.spec);
    ops.iter()
        .map(|op| match op.compute_cost() {
            Some((flops, bytes)) => {
                cfg.spec
                    .compute_time(flops, bytes, contention[op.rank.idx()])
            }
            None => 0.0,
        })
        .collect()
}

/// Per-op compute costs when the primary operand block is L2-resident.
pub(crate) fn compute_costs_hot(ops: &[OpNode], cfg: &SchedCfg) -> Vec<VTime> {
    let contention = cfg.placement.contention(cfg.nprocs, &cfg.spec);
    ops.iter()
        .map(|op| match op.compute_cost() {
            Some((flops, bytes)) => {
                cfg.spec
                    .compute_time_hot(flops, bytes, contention[op.rank.idx()])
            }
            None => 0.0,
        })
        .collect()
}

/// The base-block an operation's working set is keyed on for cache
/// purposes: its first block access (the output for compute ops).
pub(crate) fn primary_block(op: &OpNode) -> Option<(crate::types::BaseId, u64)> {
    op.accesses.iter().find_map(|a| match a.loc {
        crate::ufunc::Loc::Block { base, block } => Some((base, block)),
        crate::ufunc::Loc::Stage(_) => None,
    })
}

// The engines' shared event queue (global heap or per-rank actor
// shards — [`queue`] module docs) and its min-heap event key.
pub(crate) use queue::{EventQueue, TEvent};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("lh"), Some(Policy::LatencyHiding));
        assert_eq!(Policy::parse("blocking"), Some(Policy::Blocking));
        assert_eq!(Policy::parse("naive"), Some(Policy::Naive));
        assert_eq!(Policy::parse("x"), None);
    }

    /// A send whose matching recv is missing (a malformed or
    /// mis-aggregated stream).
    fn half_paired_batch() -> Vec<OpNode> {
        use crate::ufunc::Access;
        vec![OpNode {
            id: OpId(0),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Send {
                peer: Rank(1),
                tag: Tag(7),
                bytes: 16,
                src: SendSrc::Stage(Tag(7)),
            },
            accesses: vec![Access::read_stage(Tag(7))],
        }]
    }

    #[test]
    fn unpaired_transfer_is_a_stall_not_a_panic() {
        let ops = half_paired_batch();
        match TransferTable::build(&ops) {
            Err(SchedError::Stall(msg)) => assert!(msg.contains("unpaired"), "{msg}"),
            other => panic!("expected Stall, got {other:?}"),
        }
    }

    #[test]
    fn all_policies_propagate_unpaired_transfer_stall() {
        let ops = half_paired_batch();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            match execute(policy, &ops, &cfg, &mut crate::exec::SimBackend) {
                Err(SchedError::Stall(_)) => {}
                other => panic!("{policy:?}: expected Stall, got {other:?}"),
            }
        }
    }

    #[test]
    fn execute_epoch_resumes_clocks_and_frontiers() {
        use crate::array::Registry;
        use crate::types::DType;
        use crate::ufunc::{Kernel, OpBuilder};
        // Two identical aligned batches: resuming must accumulate the
        // timeline instead of restarting it.
        let batch = || {
            let mut reg = Registry::new(2);
            let x = reg.alloc(vec![64], 8, DType::F32);
            let xv = reg.full_view(x);
            let mut bld = OpBuilder::new();
            bld.ufunc(&reg, Kernel::Scale(2.0), &xv, &[&xv]);
            bld.finish()
        };
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut st = ExecState::new(&cfg);
        let ops = batch();
        execute_epoch(
            Policy::LatencyHiding,
            &ops,
            &cfg,
            &mut crate::exec::SimBackend,
            &mut st,
        )
        .unwrap();
        let t1 = st.max_clock();
        assert!(t1 > 0.0);
        assert_eq!(st.n_epochs, 1);
        let ops2 = batch();
        execute_epoch(
            Policy::LatencyHiding,
            &ops2,
            &cfg,
            &mut crate::exec::SimBackend,
            &mut st,
        )
        .unwrap();
        assert_eq!(st.n_epochs, 2);
        assert!(st.max_clock() > t1, "second epoch extends the timeline");
        assert_eq!(st.ops_executed, (ops.len() + ops2.len()) as u64);
        // One continuous report, not a sum of per-flush makespans.
        let rep = st.report();
        assert_eq!(rep.n_epochs, 2);
        assert!((rep.makespan - st.max_clock()).abs() < 1e-12);
    }
}
