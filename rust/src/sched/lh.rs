//! The latency-hiding flush scheduler (paper Section 5.7).
//!
//! Event-driven implementation of the flush algorithm:
//!
//! 1. initiate every communication operation in the ready queue
//!    (non-blocking isend/irecv — zero rank time);
//! 2. completed transfers retire between compute operations
//!    (`MPI_Testsome` — modelled as completion events);
//! 3. execute one ready compute operation at a time;
//! 4. repeat; block only when no compute is ready and transfers are
//!    outstanding (invariants 1–3 of Section 5.7; deadlock-free per
//!    Section 5.7.1 because no blocking call is ever issued before all
//!    ready communication is initiated).
//!
//! Waiting time — the paper's headline metric — accrues exactly while a
//! rank is idle with operations still pending.
//!
//! The scheduler runs as one *epoch* of a persistent [`ExecState`]: rank
//! clocks, NIC frontiers, cache keys and the dependency system resume
//! from wherever the previous flush left them, so a flush is no longer a
//! global barrier and communication posted near an epoch's end keeps
//! occupying the wire into the next one.

use std::collections::{BinaryHeap, VecDeque};

use super::{compute_costs, ExecState, SchedCfg, SchedError, TEvent, TransferTable};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::types::{OpId, Rank, VTime};
use crate::ufunc::{OpNode, OpPayload};

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    ComputeDone { rank: Rank, op: OpId },
    SendDone { rank: Rank, op: OpId },
    RecvDone { rank: Rank, op: OpId },
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Idle,
    Busy,
    Done,
}

struct Lh<'a> {
    ops: &'a [OpNode],
    backend: &'a mut dyn Backend,
    /// Persistent state: clocks, wait/busy, network, deps, cache keys.
    st: &'a mut ExecState,
    xfers: TransferTable,
    costs: Vec<VTime>,
    costs_hot: Vec<VTime>,
    locality: bool,

    // -- epoch-local scheduling state --
    state: Vec<State>,
    idle_since: Vec<Option<VTime>>,
    ready_comm: Vec<VecDeque<OpId>>,
    ready_comp: Vec<VecDeque<OpId>>,
    remaining: Vec<u64>,

    heap: BinaryHeap<TEvent<Ev>>,
    seq: u64,
    completed: u64,
}

impl<'a> Lh<'a> {
    fn push_ev(&mut self, t: VTime, ev: Ev) {
        self.heap.push(TEvent {
            t,
            seq: self.seq,
            ev,
        });
        self.seq += 1;
    }

    /// Distribute newly-ready ops into per-rank queues; step idle ranks.
    fn distribute(&mut self, ready: Vec<OpId>, t: VTime) {
        let mut affected = Vec::new();
        for id in ready {
            let op = &self.ops[id.idx()];
            let r = op.rank.idx();
            if op.is_comm() {
                self.ready_comm[r].push_back(id);
            } else {
                self.ready_comp[r].push_back(id);
            }
            if !affected.contains(&op.rank) {
                affected.push(op.rank);
            }
        }
        for r in affected {
            if self.state[r.idx()] == State::Idle {
                self.step(r, t);
            }
        }
    }

    /// Mark `op` complete in the dependency system and release dependents.
    fn complete_op(&mut self, op: OpId, t: VTime) {
        self.st.note_retire(&self.ops[op.idx()], t, &mut *self.backend);
        self.st.deps.complete(op);
        self.remaining[self.ops[op.idx()].rank.idx()] -= 1;
        self.completed += 1;
        let ready = self.st.deps.take_ready();
        self.distribute(ready, t);
    }

    /// Post one communication op at the rank's current time — no
    /// earlier than its admission (a Flow wave's later epochs post
    /// their comm the moment the recorder admits them; the post itself
    /// costs the rank nothing, so the clock is not advanced).
    fn post_comm(&mut self, op_id: OpId) {
        let op = &self.ops[op_id.idx()];
        let now = self.st.clock[op.rank.idx()].max(self.st.admit_time(op_id));
        match &op.payload {
            OpPayload::Send {
                peer, tag, bytes, ..
            } => {
                let res = self.st.net.post_send(now, op.rank, *peer, *tag, *bytes);
                // Capture the payload at injection time: once the send
                // completes, the dependency system allows the sender's
                // later ops to overwrite the source region — the data
                // must leave first. The receiver reads its stage only
                // after RecvDone in virtual time, so early delivery is
                // unobservable.
                let info = self.xfers.info[tag].clone();
                self.backend
                    .exec_transfer(info.from, info.to, *tag, &info.src);
                self.push_ev(
                    res.send_done.unwrap(),
                    Ev::SendDone {
                        rank: op.rank,
                        op: op_id,
                    },
                );
                if let Some(rd) = res.recv_done {
                    self.push_ev(
                        rd,
                        Ev::RecvDone {
                            rank: info.to,
                            op: info.recv_op,
                        },
                    );
                }
            }
            OpPayload::Recv { tag, .. } => {
                let res = self.st.net.post_recv(now, op.rank, *tag);
                if let Some(rd) = res.recv_done {
                    self.push_ev(
                        rd,
                        Ev::RecvDone {
                            rank: op.rank,
                            op: op_id,
                        },
                    );
                }
            }
            OpPayload::Compute(_) => unreachable!("compute in comm queue"),
        }
    }

    /// Choose the next compute op for rank `r`: FIFO by default; under
    /// the §7 locality extension, prefer (within a bounded scan window)
    /// an op whose primary block the rank touched last — "sort the
    /// operations in the ready queue after the last time the associated
    /// data block has been accessed".
    fn pick_compute(&mut self, r: usize) -> Option<OpId> {
        if !self.locality || self.st.last_block[r].is_none() {
            return self.ready_comp[r].pop_front();
        }
        const WINDOW: usize = 16;
        let want = self.st.last_block[r];
        let hit = self.ready_comp[r]
            .iter()
            .take(WINDOW)
            .position(|id| super::primary_block(&self.ops[id.idx()]) == want);
        match hit {
            Some(i) => self.ready_comp[r].remove(i),
            None => self.ready_comp[r].pop_front(),
        }
    }

    /// Advance a rank: flush its comm queue, start compute or idle.
    fn step(&mut self, rank: Rank, t: VTime) {
        let r = rank.idx();
        if self.state[r] == State::Done {
            return;
        }
        let now = self.st.clock[r].max(t);
        if let Some(t0) = self.idle_since[r].take() {
            self.st.wait[r] += now - t0;
        }
        self.st.clock[r] = now;

        // Invariant 2: all ready communication is initiated before any
        // compute starts (under a Flow wave, no earlier than each op's
        // admission — handled inside `post_comm`).
        while let Some(c) = self.ready_comm[r].pop_front() {
            self.post_comm(c);
        }

        if self.state[r] == State::Busy {
            return;
        }
        if let Some(op) = self.pick_compute(r) {
            self.state[r] = State::Busy;
            let now = self.st.gate_admission(rank, op);
            let blk = super::primary_block(&self.ops[op.idx()]);
            let hot = blk.is_some() && blk == self.st.last_block[r];
            self.st.last_block[r] = blk.or(self.st.last_block[r]);
            let cost = if hot {
                self.costs_hot[op.idx()]
            } else {
                self.costs[op.idx()]
            };
            let done = now + cost;
            self.push_ev(done, Ev::ComputeDone { rank, op });
        } else if self.remaining[r] > 0 {
            self.state[r] = State::Idle;
            self.idle_since[r] = Some(now);
        } else {
            self.state[r] = State::Done;
        }
    }
}

/// One-shot convenience: run `ops` as the single epoch of a fresh
/// [`ExecState`] and report it (the pre-epoch API, kept for batch tests
/// and benches).
pub fn run_latency_hiding(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    state.n_epochs = 1;
    state.run_id = 1;
    run_latency_hiding_epoch(ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

/// Resume the persistent simulation with one more flushed batch.
pub(crate) fn run_latency_hiding_epoch(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
    st: &mut ExecState,
) -> Result<(), SchedError> {
    let n = cfg.nprocs as usize;
    let xfers = TransferTable::build(ops)?;
    st.begin_epoch(ops);
    st.deps.insert_all(ops);
    let initial = st.deps.take_ready();

    // Every process records + inserts every operation (global knowledge,
    // Section 5.5): the dependency-system overhead is charged to all
    // ranks up front, on top of wherever their clocks already are.
    // Flow waves (`st.admit` non-empty) pay recording on the concurrent
    // recorder clock instead — execution observes it only through the
    // per-op admission gates (see `crate::flow::overlap`).
    if st.admit.is_empty() {
        st.charge_overhead(super::batch_overhead(ops, cfg.spec.lh_op_overhead, &cfg.spec));
    }

    let mut remaining = vec![0u64; n];
    for op in ops {
        remaining[op.rank.idx()] += 1;
    }

    let mut lh = Lh {
        ops,
        backend,
        st,
        xfers,
        costs: compute_costs(ops, cfg),
        costs_hot: super::compute_costs_hot(ops, cfg),
        locality: cfg.locality,
        state: vec![State::Idle; n],
        idle_since: vec![None; n],
        ready_comm: vec![VecDeque::new(); n],
        ready_comp: vec![VecDeque::new(); n],
        remaining,
        heap: BinaryHeap::new(),
        seq: 0,
        completed: 0,
    };

    lh.distribute(initial, 0.0);
    for r in 0..n {
        // Ranks with nothing to do yet park as Idle (or Done).
        if lh.state[r] == State::Idle && lh.idle_since[r].is_none() {
            lh.step(Rank(r as u32), 0.0);
        }
    }

    while let Some(TEvent { t, ev, .. }) = lh.heap.pop() {
        match ev {
            Ev::ComputeDone { rank, op } => {
                let r = rank.idx();
                // Busy time = the cost actually charged when the op was
                // started (clock advanced to the start time back then).
                let started = lh.st.clock[r];
                lh.st.busy[r] += t - started;
                lh.st.clock[r] = t;
                lh.state[r] = State::Idle;
                if let OpPayload::Compute(task) = &lh.ops[op.idx()].payload {
                    lh.backend.exec_compute(rank, task);
                }
                lh.complete_op(op, t);
                lh.step(rank, t);
            }
            Ev::SendDone { rank, op } => {
                lh.complete_op(op, t);
                if lh.state[rank.idx()] == State::Idle {
                    lh.step(rank, t);
                }
            }
            Ev::RecvDone { rank, op } => {
                lh.complete_op(op, t);
                if lh.state[rank.idx()] == State::Idle {
                    lh.step(rank, t);
                }
            }
        }
    }

    if lh.completed as usize != ops.len() {
        return Err(SchedError::Deadlock {
            executed: lh.completed,
            total: ops.len() as u64,
            blocked_recvs: lh.st.net.unmatched_recvs() as u64,
        });
    }

    super::count_epoch_ops(lh.st, ops);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    fn stencil3_batch(nprocs: u32, rows: u64, br: u64) -> Vec<OpNode> {
        let mut reg = Registry::new(nprocs);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let a = mv.slice(&[(2, rows)]);
        let b = mv.slice(&[(0, rows - 2)]);
        let c = nv.slice(&[(1, rows - 1)]);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &c, &[&a, &b]);
        bld.finish()
    }

    #[test]
    fn completes_paper_stencil() {
        let ops = stencil3_batch(2, 6, 3);
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut be = SimBackend;
        let rep = run_latency_hiding(&ops, &cfg, &mut be).unwrap();
        assert_eq!(rep.ops_executed, ops.len() as u64);
        assert!(rep.makespan > 0.0);
    }

    #[test]
    fn aligned_batch_has_no_wait() {
        // Aligned add: no communication at all -> zero wait.
        let mut reg = Registry::new(4);
        let x = reg.alloc(vec![64], 4, DType::F32);
        let y = reg.alloc(vec![64], 4, DType::F32);
        let xv = reg.full_view(x);
        let yv = reg.full_view(y);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &yv, &[&xv, &yv]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        let rep = run_latency_hiding(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.n_comm, 0);
        assert!(rep.wait.iter().all(|&w| w == 0.0), "wait={:?}", rep.wait);
    }

    #[test]
    fn makespan_scales_down_with_ranks() {
        // Embarrassingly parallel batch: more ranks, shorter makespan.
        let mk = |p: u32| {
            let mut reg = Registry::new(p);
            let x = reg.alloc(vec![1 << 14], 64, DType::F32);
            let y = reg.alloc(vec![1 << 14], 64, DType::F32);
            let xv = reg.full_view(x);
            let yv = reg.full_view(y);
            let mut bld = OpBuilder::new();
            bld.ufunc(&reg, Kernel::Mul, &yv, &[&xv, &yv]);
            let ops = bld.finish();
            let mut spec = MachineSpec::tiny();
            spec.nodes = 16;
            let cfg = SchedCfg::new(spec, p);
            run_latency_hiding(&ops, &cfg, &mut SimBackend)
                .unwrap()
                .makespan
        };
        let t1 = mk(1);
        let t4 = mk(4);
        let t16 = mk(16);
        assert!(t4 < t1 * 0.4, "t1={t1} t4={t4}");
        assert!(t16 < t4 * 0.5, "t4={t4} t16={t16}");
    }

    #[test]
    fn wait_drops_vs_blocking_on_stencil() {
        // The paper's core claim, in miniature: non-aligned stencil
        // traffic waits less under latency-hiding than blocking.
        let ops = stencil3_batch(4, 4096, 64);
        let mut spec = MachineSpec::tiny();
        spec.net_alpha = 100e-6; // make comm expensive
        let cfg = SchedCfg::new(spec, 4);
        let lh = run_latency_hiding(&ops, &cfg, &mut SimBackend).unwrap();
        let bl = super::super::run_blocking(&ops, &cfg, &mut SimBackend).unwrap();
        let lw: f64 = lh.wait.iter().sum();
        let bw: f64 = bl.wait.iter().sum();
        assert!(
            lw < bw,
            "latency-hiding should wait less: lh={lw} blocking={bw}"
        );
    }

    #[test]
    fn pipelined_epochs_beat_barriered_epochs() {
        // The epoch model's core claim: running batch after batch on one
        // persistent state with no barrier in between yields a shorter
        // makespan than barriering after every batch — halo transfers
        // drain behind the next batch's compute.
        let mut spec = MachineSpec::tiny();
        spec.net_alpha = 100e-6;
        let cfg = SchedCfg::new(spec, 4);
        let run = |barrier_every_epoch: bool| -> f64 {
            let mut st = ExecState::new(&cfg);
            for _ in 0..4 {
                let ops = stencil3_batch(4, 4096, 64);
                run_latency_hiding_epoch(&ops, &cfg, &mut SimBackend, &mut st).unwrap();
                if barrier_every_epoch {
                    st.barrier();
                }
            }
            st.max_clock()
        };
        let barriered = run(true);
        let pipelined = run(false);
        assert!(
            pipelined <= barriered,
            "pipelined {pipelined} must not exceed barriered {barriered}"
        );
    }
}
