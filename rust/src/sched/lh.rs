//! The latency-hiding flush scheduler (paper Section 5.7).
//!
//! Event-driven implementation of the flush algorithm:
//!
//! 1. initiate every communication operation in the ready queue
//!    (non-blocking isend/irecv — zero rank time);
//! 2. completed transfers retire between compute operations
//!    (`MPI_Testsome` — modelled as completion events);
//! 3. execute one ready compute operation at a time;
//! 4. repeat; block only when no compute is ready and transfers are
//!    outstanding (invariants 1–3 of Section 5.7; deadlock-free per
//!    Section 5.7.1 because no blocking call is ever issued before all
//!    ready communication is initiated).
//!
//! Waiting time — the paper's headline metric — accrues exactly while a
//! rank is idle with operations still pending.
//!
//! Since PR 5 the scheduler is a **resumable engine** ([`LhSession`],
//! driven through [`crate::sched::SchedSession`]): the epoch-local
//! state that used to live on the stack of a run-to-completion function
//! — per-rank `State`/`idle_since`, ready queues, the event heap, the
//! transfer table, per-op costs — is a struct that survives between
//! calls, so newly admitted epochs can be spliced into a *running*
//! event loop (`extend`/`activate`) and the loop can be advanced
//! incrementally (`pump_until`) or to quiescence (`pump_all`). A Batch
//! epoch is simply one inject followed by one drain, which reproduces
//! the old run-to-completion behaviour exactly; the sliding-admission
//! mode of [`crate::flow`] keeps one session alive across many
//! injects, so a rank idling on an epoch tail picks up the next
//! epoch's ready fragments the moment the recorder admits them.

use std::collections::VecDeque;

use super::{compute_costs, EventQueue, ExecState, SchedCfg, SchedError, TEvent, TransferTable};
use crate::exec::Backend;
use crate::metrics::RunReport;
use crate::trace::{OpKind, WaitCause};
use crate::types::{OpId, Rank, VTime};
use crate::ufunc::{OpNode, OpPayload};

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    ComputeDone { rank: Rank, op: OpId },
    SendDone { rank: Rank, op: OpId },
    RecvDone { rank: Rank, op: OpId },
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Idle,
    Busy,
    Done,
}

/// The latency-hiding scheduler's persistent session state. Owns no
/// operations — the shared op stream lives in
/// [`crate::sched::SchedSession`] and is passed into every method — but
/// everything else the event loop needs survives here across injects.
pub(crate) struct LhSession {
    xfers: TransferTable,
    costs: Vec<VTime>,
    costs_hot: Vec<VTime>,
    locality: bool,

    state: Vec<State>,
    idle_since: Vec<Option<VTime>>,
    ready_comm: Vec<VecDeque<OpId>>,
    ready_comp: Vec<VecDeque<OpId>>,
    remaining: Vec<u64>,

    /// The event loop's queue: the seed global heap at `--workers 1`,
    /// per-rank actor shards beyond ([`crate::sched::queue`]).
    pub(crate) q: EventQueue<Ev>,
    /// `cfg.workers`, cached: selects the sharded session's O(ready)
    /// wake-marking in [`LhSession::distribute`].
    workers: usize,
    /// Scratch wake bits for the sharded distribute (always false
    /// between calls).
    touched: Vec<bool>,
    pub(crate) completed: u64,
    /// Trace attribution for the *next* idle-wait charge: what the event
    /// loop is currently delivering when it wakes an idle rank — a local
    /// dependency (compute completion / fresh inject) or a transfer
    /// completion from a peer. Only read when the sink is enabled.
    wake: WaitCause,
}

impl LhSession {
    pub(crate) fn new(cfg: &SchedCfg) -> Self {
        let n = cfg.nprocs as usize;
        LhSession {
            xfers: TransferTable::empty(),
            costs: Vec::new(),
            costs_hot: Vec::new(),
            locality: cfg.locality,
            state: vec![State::Idle; n],
            idle_since: vec![None; n],
            ready_comm: vec![VecDeque::new(); n],
            ready_comp: vec![VecDeque::new(); n],
            remaining: vec![0; n],
            q: EventQueue::new(n, cfg.workers, cfg.profile.enabled),
            workers: cfg.workers,
            touched: vec![false; n],
            completed: 0,
            wake: WaitCause::Dependency,
        }
    }

    /// Splice the tail `ops[lo..]` into the session's tables (transfer
    /// pairs, per-op costs). A malformed tail errors before any
    /// execution state is touched.
    pub(crate) fn extend(
        &mut self,
        ops: &[OpNode],
        lo: usize,
        cfg: &SchedCfg,
    ) -> Result<(), SchedError> {
        let new = &ops[lo..];
        self.xfers.extend(new)?;
        self.costs.extend(compute_costs(new, cfg));
        self.costs_hot.extend(super::compute_costs_hot(new, cfg));
        Ok(())
    }

    /// Activate the tail: insert it into the dependency system, charge
    /// recording (Batch epochs only — gated injects pay on the recorder
    /// clock), revive finished ranks and wake the event loop. Ranks are
    /// woken at their *own* clocks; any admission gap is charged by
    /// [`ExecState::gate_admission`] exactly as in a merged wave.
    pub(crate) fn activate(
        &mut self,
        ops: &[OpNode],
        lo: usize,
        cfg: &SchedCfg,
        backend: &mut dyn Backend,
        st: &mut ExecState,
    ) {
        let new = &ops[lo..];
        self.wake = WaitCause::Dependency; // idle ranks wake on the inject
        st.deps.insert_all(new);
        let initial = st.deps.take_ready();
        // Every process records + inserts every operation (global
        // knowledge, Section 5.5): the dependency-system overhead is
        // charged to all ranks up front, on top of wherever their
        // clocks already are. Gated injects (`st.admit` non-empty) pay
        // recording on the concurrent recorder clock instead —
        // execution observes it only through the per-op admission
        // gates (see `crate::flow::overlap`).
        if st.admit.is_empty() {
            st.charge_overhead(super::batch_overhead(new, cfg.spec.lh_op_overhead, &cfg.spec));
        }
        for op in new {
            self.remaining[op.rank.idx()] += 1;
        }
        for r in 0..self.state.len() {
            // A rank that ran out of work between injects parked as
            // Done; new operations revive it (the sliding regression:
            // injecting into a quiescent session must wake the loop).
            if self.state[r] == State::Done && self.remaining[r] > 0 {
                self.state[r] = State::Idle;
            }
        }
        self.distribute(ops, st, backend, initial, 0.0);
        for r in 0..self.state.len() {
            // Ranks with nothing to do yet park as Idle (or Done).
            if self.state[r] == State::Idle && self.idle_since[r].is_none() {
                self.step(ops, st, backend, Rank(r as u32), 0.0);
            }
        }
    }

    fn push_ev(&mut self, t: VTime, ev: Ev) {
        let actor = match ev {
            Ev::ComputeDone { rank, .. }
            | Ev::SendDone { rank, .. }
            | Ev::RecvDone { rank, .. } => rank.idx(),
        };
        self.q.push(t, actor, ev);
    }

    /// Distribute newly-ready ops into per-rank queues; step idle ranks.
    fn distribute(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        ready: Vec<OpId>,
        t: VTime,
    ) {
        let sharded = self.workers > 1;
        let mut affected = Vec::new();
        for id in ready {
            let rank = ops[id.idx()].rank;
            let r = rank.idx();
            if ops[id.idx()].is_comm() {
                self.ready_comm[r].push_back(id);
            } else {
                self.ready_comp[r].push_back(id);
            }
            // First-touch wake order, two equivalent shapes: the serial
            // reference keeps the seed membership scan verbatim; sharded
            // sessions mark the actor's wake bit, so a P-wide inject
            // costs O(ready) instead of O(ready × P) (DESIGN.md §13).
            let fresh = if sharded {
                !std::mem::replace(&mut self.touched[r], true)
            } else {
                !affected.contains(&rank)
            };
            if fresh {
                affected.push(rank);
            }
        }
        if sharded {
            for rank in &affected {
                self.touched[rank.idx()] = false;
            }
        }
        for r in affected {
            if self.state[r.idx()] == State::Idle {
                self.step(ops, st, backend, r, t);
            }
        }
    }

    /// Mark `op` complete in the dependency system and release dependents.
    fn complete_op(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        op: OpId,
        t: VTime,
    ) {
        st.note_retire(&ops[op.idx()], t, backend);
        st.deps.complete(op);
        let r = ops[op.idx()].rank.idx();
        self.remaining[r] -= 1;
        self.completed += 1;
        let ready = st.deps.take_ready();
        self.distribute(ops, st, backend, ready, t);
    }

    /// Post one communication op at the rank's current time — no
    /// earlier than its admission (a gated inject's epochs post their
    /// comm the moment the recorder admits them; the post itself costs
    /// the rank nothing, so the clock is not advanced).
    fn post_comm(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        op_id: OpId,
    ) {
        let op = &ops[op_id.idx()];
        let now = st.clock[op.rank.idx()].max(st.admit_time(op_id));
        match &op.payload {
            OpPayload::Send {
                peer, tag, bytes, ..
            } => {
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op_id, op.rank, OpKind::Send, ep, now);
                }
                let res = st.note_msg_post(*tag, op.rank, *peer, *bytes, now);
                // Capture the payload at injection time: once the send
                // completes, the dependency system allows the sender's
                // later ops to overwrite the source region — the data
                // must leave first. The receiver reads its stage only
                // after RecvDone in virtual time, so early delivery is
                // unobservable.
                let info = self.xfers.info[tag].clone();
                backend.exec_transfer(info.from, info.to, *tag, &info.src);
                self.push_ev(
                    res.send_done.unwrap(),
                    Ev::SendDone {
                        rank: op.rank,
                        op: op_id,
                    },
                );
                if let Some(rd) = res.recv_done {
                    st.trace.msg_deliver(*tag, info.from, info.to, *bytes, rd);
                    self.push_ev(
                        rd,
                        Ev::RecvDone {
                            rank: info.to,
                            op: info.recv_op,
                        },
                    );
                }
            }
            OpPayload::Recv { peer, tag, bytes } => {
                if st.trace.on() {
                    let ep = st.cur_epoch();
                    st.trace.op_start(op_id, op.rank, OpKind::Recv, ep, now);
                }
                let res = st.net.post_recv(now, op.rank, *tag);
                if let Some(rd) = res.recv_done {
                    st.trace.msg_deliver(*tag, *peer, op.rank, *bytes, rd);
                    self.push_ev(
                        rd,
                        Ev::RecvDone {
                            rank: op.rank,
                            op: op_id,
                        },
                    );
                }
            }
            OpPayload::Compute(_) => unreachable!("compute in comm queue"),
        }
    }

    /// Choose the next compute op for rank `r`: FIFO by default; under
    /// the §7 locality extension, prefer (within a bounded scan window)
    /// an op whose primary block the rank touched last — "sort the
    /// operations in the ready queue after the last time the associated
    /// data block has been accessed".
    fn pick_compute(&mut self, ops: &[OpNode], st: &ExecState, r: usize) -> Option<OpId> {
        if !self.locality || st.last_block[r].is_none() {
            return self.ready_comp[r].pop_front();
        }
        const WINDOW: usize = 16;
        let want = st.last_block[r];
        let hit = self.ready_comp[r]
            .iter()
            .take(WINDOW)
            .position(|id| super::primary_block(&ops[id.idx()]) == want);
        match hit {
            Some(i) => self.ready_comp[r].remove(i),
            None => self.ready_comp[r].pop_front(),
        }
    }

    /// Advance a rank: flush its comm queue, start compute or idle.
    fn step(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        rank: Rank,
        t: VTime,
    ) {
        let r = rank.idx();
        if self.state[r] == State::Done {
            return;
        }
        let now = st.clock[r].max(t);
        if let Some(t0) = self.idle_since[r].take() {
            st.charge_wait(r, t0, now, self.wake);
        }
        st.clock[r] = now;

        // Invariant 2: all ready communication is initiated before any
        // compute starts (under an admission-gated inject, no earlier
        // than each op's admission — handled inside `post_comm`).
        while let Some(c) = self.ready_comm[r].pop_front() {
            self.post_comm(ops, st, backend, c);
        }

        if self.state[r] == State::Busy {
            return;
        }
        if let Some(op) = self.pick_compute(ops, st, r) {
            self.state[r] = State::Busy;
            let now = st.gate_admission(rank, op);
            if st.trace.on() {
                let ep = st.cur_epoch();
                st.trace.op_start(op, rank, OpKind::Compute, ep, now);
            }
            let blk = super::primary_block(&ops[op.idx()]);
            let hot = blk.is_some() && blk == st.last_block[r];
            st.last_block[r] = blk.or(st.last_block[r]);
            let cost = if hot {
                self.costs_hot[op.idx()]
            } else {
                self.costs[op.idx()]
            };
            let done = now + cost;
            self.push_ev(done, Ev::ComputeDone { rank, op });
        } else if self.remaining[r] > 0 {
            self.state[r] = State::Idle;
            self.idle_since[r] = Some(now);
        } else {
            self.state[r] = State::Done;
        }
    }

    /// Process one popped event — the body of the event loop.
    fn handle(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        t: VTime,
        ev: Ev,
    ) {
        if st.trace.on() {
            // Attribute any idle wait the delivery ends: a transfer
            // completion unblocks on the wire, a compute completion
            // unblocks a local dependency.
            self.wake = match ev {
                Ev::ComputeDone { .. } => WaitCause::Dependency,
                Ev::SendDone { op, .. } | Ev::RecvDone { op, .. } => {
                    match ops[op.idx()].payload {
                        OpPayload::Send { peer, .. } | OpPayload::Recv { peer, .. } => {
                            WaitCause::Transfer { peer }
                        }
                        OpPayload::Compute(_) => WaitCause::Dependency,
                    }
                }
            };
        }
        match ev {
            Ev::ComputeDone { rank, op } => {
                let r = rank.idx();
                // Busy time = the cost actually charged when the op was
                // started (clock advanced to the start time back then).
                let started = st.clock[r];
                st.busy[r] += t - started;
                st.clock[r] = t;
                self.state[r] = State::Idle;
                if let OpPayload::Compute(task) = &ops[op.idx()].payload {
                    backend.exec_compute(rank, task);
                }
                self.complete_op(ops, st, backend, op, t);
                self.step(ops, st, backend, rank, t);
            }
            Ev::SendDone { rank, op } | Ev::RecvDone { rank, op } => {
                self.complete_op(ops, st, backend, op, t);
                if self.state[rank.idx()] == State::Idle {
                    self.step(ops, st, backend, rank, t);
                }
            }
        }
    }

    /// Advance the event loop through every event at or before `until`
    /// — the prefix of the timeline that a later inject (whose ops
    /// cannot start before `until`) can no longer affect.
    pub(crate) fn pump_until(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
        until: VTime,
    ) {
        while self.q.peek_t().is_some_and(|t| t <= until) {
            let TEvent { t, ev, .. } = self.q.pop().unwrap();
            self.handle(ops, st, backend, t, ev);
        }
    }

    /// Process the earliest pending event; returns its time, or `None`
    /// on a quiescent loop.
    pub(crate) fn pump_next(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
    ) -> Option<VTime> {
        let TEvent { t, ev, .. } = self.q.pop()?;
        self.handle(ops, st, backend, t, ev);
        Some(t)
    }

    /// Run the loop to quiescence.
    pub(crate) fn pump_all(
        &mut self,
        ops: &[OpNode],
        st: &mut ExecState,
        backend: &mut dyn Backend,
    ) {
        while let Some(TEvent { t, ev, .. }) = self.q.pop() {
            self.handle(ops, st, backend, t, ev);
        }
    }

    /// Verify every injected operation retired (quiescence ≠ success).
    pub(crate) fn finish_check(&self, ops: &[OpNode], st: &ExecState) -> Result<(), SchedError> {
        if self.completed as usize != ops.len() {
            return Err(SchedError::Deadlock {
                executed: self.completed,
                total: ops.len() as u64,
                blocked_recvs: st.net.unmatched_recvs() as u64,
                // The LH engine parks no receives — a wedge here is a
                // dependency cycle, not a blocked-transfer chain.
                cycle: String::new(),
            });
        }
        Ok(())
    }
}

/// One-shot convenience: run `ops` as the single epoch of a fresh
/// [`ExecState`] and report it (the pre-epoch API, kept for batch tests
/// and benches).
pub fn run_latency_hiding(
    ops: &[OpNode],
    cfg: &SchedCfg,
    backend: &mut dyn Backend,
) -> Result<RunReport, SchedError> {
    let mut state = ExecState::new(cfg);
    state.n_epochs = 1;
    super::session::one_shot(super::Policy::LatencyHiding, ops, cfg, backend, &mut state)?;
    Ok(state.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::sched::{execute_epoch, Policy};
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    fn stencil3_batch(nprocs: u32, rows: u64, br: u64) -> Vec<OpNode> {
        let mut reg = Registry::new(nprocs);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let a = mv.slice(&[(2, rows)]);
        let b = mv.slice(&[(0, rows - 2)]);
        let c = nv.slice(&[(1, rows - 1)]);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &c, &[&a, &b]);
        bld.finish()
    }

    #[test]
    fn completes_paper_stencil() {
        let ops = stencil3_batch(2, 6, 3);
        let cfg = SchedCfg::new(MachineSpec::tiny(), 2);
        let mut be = SimBackend;
        let rep = run_latency_hiding(&ops, &cfg, &mut be).unwrap();
        assert_eq!(rep.ops_executed, ops.len() as u64);
        assert!(rep.makespan > 0.0);
    }

    #[test]
    fn aligned_batch_has_no_wait() {
        // Aligned add: no communication at all -> zero wait.
        let mut reg = Registry::new(4);
        let x = reg.alloc(vec![64], 4, DType::F32);
        let y = reg.alloc(vec![64], 4, DType::F32);
        let xv = reg.full_view(x);
        let yv = reg.full_view(y);
        let mut bld = OpBuilder::new();
        bld.ufunc(&reg, Kernel::Add, &yv, &[&xv, &yv]);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        let rep = run_latency_hiding(&ops, &cfg, &mut SimBackend).unwrap();
        assert_eq!(rep.n_comm, 0);
        assert!(rep.wait.iter().all(|&w| w == 0.0), "wait={:?}", rep.wait);
    }

    #[test]
    fn makespan_scales_down_with_ranks() {
        // Embarrassingly parallel batch: more ranks, shorter makespan.
        let mk = |p: u32| {
            let mut reg = Registry::new(p);
            let x = reg.alloc(vec![1 << 14], 64, DType::F32);
            let y = reg.alloc(vec![1 << 14], 64, DType::F32);
            let xv = reg.full_view(x);
            let yv = reg.full_view(y);
            let mut bld = OpBuilder::new();
            bld.ufunc(&reg, Kernel::Mul, &yv, &[&xv, &yv]);
            let ops = bld.finish();
            let mut spec = MachineSpec::tiny();
            spec.nodes = 16;
            let cfg = SchedCfg::new(spec, p);
            run_latency_hiding(&ops, &cfg, &mut SimBackend)
                .unwrap()
                .makespan
        };
        let t1 = mk(1);
        let t4 = mk(4);
        let t16 = mk(16);
        assert!(t4 < t1 * 0.4, "t1={t1} t4={t4}");
        assert!(t16 < t4 * 0.5, "t4={t4} t16={t16}");
    }

    #[test]
    fn wait_drops_vs_blocking_on_stencil() {
        // The paper's core claim, in miniature: non-aligned stencil
        // traffic waits less under latency-hiding than blocking.
        let ops = stencil3_batch(4, 4096, 64);
        let mut spec = MachineSpec::tiny();
        spec.net_alpha = 100e-6; // make comm expensive
        let cfg = SchedCfg::new(spec, 4);
        let lh = run_latency_hiding(&ops, &cfg, &mut SimBackend).unwrap();
        let bl = super::super::run_blocking(&ops, &cfg, &mut SimBackend).unwrap();
        let lw: f64 = lh.wait.iter().sum();
        let bw: f64 = bl.wait.iter().sum();
        assert!(
            lw < bw,
            "latency-hiding should wait less: lh={lw} blocking={bw}"
        );
    }

    #[test]
    fn pipelined_epochs_beat_barriered_epochs() {
        // The epoch model's core claim: running batch after batch on one
        // persistent state with no barrier in between yields a shorter
        // makespan than barriering after every batch — halo transfers
        // drain behind the next batch's compute.
        let mut spec = MachineSpec::tiny();
        spec.net_alpha = 100e-6;
        let cfg = SchedCfg::new(spec, 4);
        let run = |barrier_every_epoch: bool| -> f64 {
            let mut st = ExecState::new(&cfg);
            for _ in 0..4 {
                let ops = stencil3_batch(4, 4096, 64);
                execute_epoch(
                    Policy::LatencyHiding,
                    &ops,
                    &cfg,
                    &mut SimBackend,
                    &mut st,
                )
                .unwrap();
                if barrier_every_epoch {
                    st.barrier();
                }
            }
            st.max_clock()
        };
        let barriered = run(true);
        let pipelined = run(false);
        assert!(
            pipelined <= barriered,
            "pipelined {pipelined} must not exceed barriered {barriered}"
        );
    }
}
