//! SUMMA distributed matrix multiply (van de Geijn & Watts, paper
//! ref [26]) — DistNumPy's native matmul, used by the N-body and kNN
//! benchmarks (Section 6.1.1).
//!
//! Row-slab layout variant: A (n×k), B (k×m) and C (n×m) are all
//! distributed by rows with the same block size. Each SUMMA step
//! broadcasts one row-panel of B (one base-block) from its owner to
//! every rank; every rank then updates each of its local C blocks with
//! `C_blk += A_blk[:, panel] @ B_panel`. The broadcast transfers overlap
//! the panel updates of previous steps under the latency-hiding
//! scheduler — which is why the paper sees SUMMA "performing very
//! similar" with and without latency-hiding (compute dominates), a
//! shape `benches/figures.rs` reproduces for Fig. 13.

use crate::array::Registry;
use crate::comm::Collective;
use crate::layout::ViewSpec;
use crate::types::{BaseId, Rank};
use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpBuilder, Operand, Region};

/// Record `C = C + A @ B` into the builder.
///
/// Each SUMMA step broadcasts one row-panel of B; `collective` picks the
/// broadcast schedule — the flat owner-to-all fan-out (the owner injects
/// P-1 messages back-to-back) or the binomial tree of
/// [`crate::comm::broadcast_tree`] (⌈log₂P⌉ injections, forwarding hops
/// overlap the panel updates of previous steps).
///
/// Requirements (asserted): all three bases 2-D, same `block_rows`,
/// `a.shape = [n, k]`, `b.shape = [k, m]`, `c.shape = [n, m]`.
pub fn record_matmul(
    bld: &mut OpBuilder,
    reg: &Registry,
    a: BaseId,
    b: BaseId,
    c: BaseId,
    collective: Collective,
) {
    let (la, lb, lc) = (
        reg.layout(a).clone(),
        reg.layout(b).clone(),
        reg.layout(c).clone(),
    );
    assert_eq!(la.shape.len(), 2);
    assert_eq!(lb.shape.len(), 2);
    assert_eq!(lc.shape.len(), 2);
    let (n, k) = (la.shape[0], la.shape[1]);
    let m = lb.shape[1];
    assert_eq!(lb.shape[0], k, "inner dims must agree");
    assert_eq!(lc.shape, vec![n, m]);
    assert_eq!(la.block_rows, lc.block_rows, "A and C row-aligned");

    let bv = ViewSpec::full(&lb);

    // One SUMMA step per base-block of B (panel height = block size).
    // Each step is one §5.3 group: broadcast the panel, then update.
    for (panel_region, panel_intra, panel_owner) in
        OpBuilder::default().svb_regions(reg, &bv)
    {
        bld.begin_group();
        let panel_rows = panel_region.nrows;
        let s0 = panel_region.block * lb.block_rows; // global first row of panel
        // Broadcast the panel to every rank that owns C blocks.
        let tags = match collective {
            Collective::Flat => {
                bld.broadcast(reg, panel_region.clone(), panel_intra, reg.nprocs)
            }
            Collective::Tree => {
                // Tree rounds open their own §5.3 groups; the updates
                // below get a fresh group of their own.
                let t = crate::comm::broadcast_tree(
                    bld,
                    reg,
                    panel_region.clone(),
                    panel_intra,
                    reg.nprocs,
                );
                bld.begin_group();
                t
            }
        };

        for rank in 0..reg.nprocs {
            let rank = Rank(rank);
            // Panel operand on this rank: local on the owner, staged else.
            let (panel_op, panel_access) = if rank == panel_owner {
                (
                    Operand::Local(panel_region.clone()),
                    Access::read_block(b, panel_region.block, panel_intra),
                )
            } else {
                let tag = tags[rank.idx()].expect("broadcast tag");
                (Operand::Staged(tag), Access::read_stage(tag))
            };

            // Update every local C block.
            for cblk in lc.blocks_of(rank) {
                let c_rows = lc.block_nrows(cblk);
                let c_region = Region {
                    base: c,
                    block: cblk,
                    row0: 0,
                    nrows: c_rows,
                    col0: 0,
                    ncols: m,
                    row_stride: m,
                };
                let c_intra = (0, c_rows * m);
                // A panel slice: the same rows, columns [s0, s0+panel_rows).
                let a_region = Region {
                    base: a,
                    block: cblk,
                    row0: 0,
                    nrows: c_rows,
                    col0: s0,
                    ncols: panel_rows,
                    row_stride: k,
                };
                let a_intra = (s0, (c_rows - 1) * k + s0 + panel_rows);
                let task = ComputeTask {
                    kernel: Kernel::MatmulAcc {
                        n: c_rows,
                        k: panel_rows,
                        m,
                    },
                    inputs: vec![
                        Operand::Local(c_region.clone()),
                        Operand::Local(a_region),
                        panel_op.clone(),
                    ],
                    dst: Dst::Block(c_region),
                    elems: c_rows * m,
                };
                let accesses = vec![
                    Access::read_block(c, cblk, c_intra),
                    Access::write_block(c, cblk, c_intra),
                    Access::read_block(a, cblk, a_intra),
                    panel_access,
                ];
                bld.compute(rank, task, accesses);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::{ClusterStore, Registry};
    use crate::cluster::MachineSpec;
    use crate::exec::{NativeBackend, SimBackend};
    use crate::sched::{execute, Policy, SchedCfg};
    use crate::types::DType;
    use crate::util::rng::Rng;

    fn dense_matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; n * m];
        for i in 0..n {
            for kk in 0..k {
                let aik = a[i * k + kk];
                for j in 0..m {
                    c[i * m + j] += aik * b[kk * m + j];
                }
            }
        }
        c
    }

    fn run_summa_with(
        p: u32,
        n: u64,
        br: u64,
        policy: Policy,
        collective: Collective,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut reg = Registry::new(p);
        let a = reg.alloc(vec![n, n], br, DType::F32);
        let b = reg.alloc(vec![n, n], br, DType::F32);
        let c = reg.alloc(vec![n, n], br, DType::F32);
        let mut store = ClusterStore::new(p);
        store.alloc_base(reg.layout(a));
        store.alloc_base(reg.layout(b));
        store.alloc_base(reg.layout(c));
        let mut rng = Rng::new(7);
        let da = rng.fill_f32((n * n) as usize, -1.0, 1.0);
        let db = rng.fill_f32((n * n) as usize, -1.0, 1.0);
        store.scatter(reg.layout(a), &da);
        store.scatter(reg.layout(b), &db);
        let mut bld = OpBuilder::new();
        record_matmul(&mut bld, &reg, a, b, c, collective);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), p);
        let mut be = NativeBackend::new(store);
        execute(policy, &ops, &cfg, &mut be).unwrap();
        let got = be.store.gather(reg.layout(c));
        let want = dense_matmul(&da, &db, n as usize, n as usize, n as usize);
        (got, want)
    }

    fn run_summa(p: u32, n: u64, br: u64, policy: Policy) -> (Vec<f32>, Vec<f32>) {
        run_summa_with(p, n, br, policy, Collective::Flat)
    }

    #[test]
    fn summa_matches_dense_latency_hiding() {
        let (got, want) = run_summa(3, 12, 2, Policy::LatencyHiding);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn summa_matches_dense_blocking() {
        let (got, want) = run_summa(2, 8, 2, Policy::Blocking);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn summa_tree_broadcast_matches_dense() {
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let (got, want) = run_summa_with(4, 12, 2, policy, Collective::Tree);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{policy:?}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn summa_tree_conserves_message_totals() {
        // Per panel the flat fan-out makes the owner inject P-1
        // messages; the tree caps any rank at ceil(log2 P).
        let sends_per_rank = |collective: Collective| -> Vec<usize> {
            let p = 8u32;
            let mut reg = Registry::new(p);
            let a = reg.alloc(vec![16, 16], 2, DType::F32);
            let b = reg.alloc(vec![16, 16], 2, DType::F32);
            let c = reg.alloc(vec![16, 16], 2, DType::F32);
            let mut bld = OpBuilder::new();
            record_matmul(&mut bld, &reg, a, b, c, collective);
            let ops = bld.finish();
            let mut counts = vec![0usize; p as usize];
            for op in &ops {
                if matches!(op.payload, crate::ufunc::OpPayload::Send { .. }) {
                    counts[op.rank.idx()] += 1;
                }
            }
            counts
        };
        // Both schedules move each panel with P-1 messages in total; the
        // difference is *when* and *from where* they are injected (the
        // per-panel spread is asserted in comm::tests). With one panel
        // per rank, per-rank totals even out to P-1 under both.
        let flat = sends_per_rank(Collective::Flat);
        let tree = sends_per_rank(Collective::Tree);
        assert_eq!(flat.iter().sum::<usize>(), tree.iter().sum::<usize>());
        assert_eq!(*flat.iter().max().unwrap(), 7);
        assert_eq!(*tree.iter().max().unwrap(), 7);
    }

    #[test]
    fn summa_comm_volume_scales_with_ranks() {
        // P-1 transfers per panel: volume grows with P.
        let vol = |p: u32| {
            let mut reg = Registry::new(p);
            let a = reg.alloc(vec![16, 16], 4, DType::F32);
            let b = reg.alloc(vec![16, 16], 4, DType::F32);
            let c = reg.alloc(vec![16, 16], 4, DType::F32);
            let mut bld = OpBuilder::new();
            record_matmul(&mut bld, &reg, a, b, c, Collective::Flat);
            let ops = bld.finish();
            let cfg = SchedCfg::new(MachineSpec::tiny(), p);
            execute(Policy::LatencyHiding, &ops, &cfg, &mut SimBackend)
                .unwrap()
                .bytes_inter
        };
        assert!(vol(4) > vol(2));
    }
}
