//! Message aggregation: per-(src, dst) coalescing of block transfers.
//!
//! A flush batch routinely records several small transfers between the
//! same pair of ranks in the same epoch — a stencil fragment pulling two
//! shifted regions from one neighbour, SUMMA panels, halo exchanges of
//! consecutive array operations. Each one pays the full per-message cost
//! (α latency plus the receiver-side message overhead). This pass packs
//! them into one wire message ([`SendSrc::Packed`]), amortizing those
//! per-message terms, while staying a *pure op-stream rewrite*: the
//! packed send/recv are ordinary dependency-tracked [`OpNode`]s, so
//! every policy schedules them through the unmodified machinery.
//!
//! ## Hoisting and validity
//!
//! The packed message is emitted at the position of its **first**
//! constituent (the anchor); later constituents are hoisted up to it. A
//! candidate may join a buffer only if nothing between the *start of
//! the anchor's §5.3 group* and the candidate writes anything the
//! candidate reads — otherwise the hoisted send would capture pre-write
//! data. (Group start, not anchor position: the blocking baseline
//! executes the packed pair in the anchor group's exchange phase, i.e.
//! before every compute of that group, so writes anywhere in the anchor
//! group count as hazards too.) Under this rule the rewrite is
//! semantics-preserving for the dependency-tracked policies *and* for
//! blocking.
//!
//! The naive evaluator of Fig. 6 is a different story: a coalesced send
//! becomes ready only once *all* constituents are, so its matching
//! (blocking) receive can park a rank behind work that feeds the packed
//! send — a cycle the scheduler must detect and report rather than hang
//! (see `sched::naive` and the regression test in `rust/tests/props.rs`).

use std::borrow::Cow;

use crate::types::{OpId, Rank, Tag};
use crate::ufunc::{Access, Loc, OpNode, OpPayload, SendSrc};
use crate::util::fxhash::FxHashMap;

/// What the pass did — threaded into [`crate::metrics::RunReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AggStats {
    /// Packed wire messages emitted.
    pub packed_msgs: u64,
    /// Constituent transfers absorbed into packed messages.
    pub packed_parts: u64,
}

/// An open per-(src, dst) coalescing buffer.
struct Buffer {
    /// Index of the first constituent send (packed ops land here).
    anchor: usize,
    /// Indices of the constituent sends.
    parts: Vec<usize>,
    /// Block write accesses recorded since the anchor (hazard list).
    hazards: Vec<Access>,
}

/// Hazard lists longer than this seal the buffer — bounds the validity
/// scan on long flush batches. Sized for the figure-generation runs: a
/// full-scale ufunc group records a few hundred fragment writes, and a
/// buffer usually spans a handful of groups.
const HAZARD_CAP: usize = 4096;

/// Coalesce same-(src, dst) block transfers into packed messages of at
/// most `max_parts` constituents. `max_parts < 2` disables the pass.
/// Returns the rewritten stream (ids renumbered) and what was packed;
/// the input is borrowed unchanged when nothing coalesces.
pub fn aggregate(ops: &[OpNode], max_parts: usize) -> (Cow<'_, [OpNode]>, AggStats) {
    if max_parts < 2 {
        return (Cow::Borrowed(ops), AggStats::default());
    }

    // Tag -> recv index (to drop constituent recvs alongside sends).
    let mut recv_of: FxHashMap<Tag, usize> = FxHashMap::default();
    for (i, op) in ops.iter().enumerate() {
        if let OpPayload::Recv { tag, .. } = &op.payload {
            recv_of.insert(*tag, i);
        }
    }

    let mut open: Vec<((Rank, Rank), Buffer)> = Vec::new();
    let mut sealed: Vec<Buffer> = Vec::new();
    // Block writes seen so far in the current §5.3 group — the seed for
    // a buffer opened later in the same group (see the validity rule).
    let mut group_writes: Vec<Access> = Vec::new();
    let mut cur_group = ops.first().map(|o| o.group).unwrap_or(0);

    for (i, op) in ops.iter().enumerate() {
        if op.group != cur_group {
            cur_group = op.group;
            group_writes.clear();
        }
        // Only plain block transfers coalesce; stage-sourced forwards
        // (tree hops, reduction partials) keep their own message.
        let candidate_peer = match &op.payload {
            OpPayload::Send {
                peer,
                src: SendSrc::Region(_),
                ..
            } => Some(*peer),
            _ => None,
        };
        if let Some(peer) = candidate_peer {
            let key = (op.rank, peer);
            match open.iter().position(|(k, _)| *k == key) {
                Some(pos) => {
                    let full = open[pos].1.parts.len() >= max_parts;
                    let hazard = op.accesses.iter().any(|a| {
                        !a.write && open[pos].1.hazards.iter().any(|h| h.conflicts(a))
                    });
                    if full || hazard {
                        let (_, buf) = open.remove(pos);
                        sealed.push(buf);
                        open.push((
                            key,
                            Buffer {
                                anchor: i,
                                parts: vec![i],
                                hazards: group_writes.clone(),
                            },
                        ));
                    } else {
                        open[pos].1.parts.push(i);
                    }
                }
                None => open.push((
                    key,
                    Buffer {
                        anchor: i,
                        parts: vec![i],
                        hazards: group_writes.clone(),
                    },
                )),
            }
            continue;
        }

        // Track block writes for the validity rule — both in every open
        // buffer and in the current group's seed list. (Stage writes can
        // never conflict with a candidate's block reads — skip them to
        // keep hazard lists short.)
        let mut wrote = false;
        for a in &op.accesses {
            if a.write && matches!(a.loc, Loc::Block { .. }) {
                group_writes.push(*a);
                for (_, buf) in open.iter_mut() {
                    buf.hazards.push(*a);
                }
                wrote = true;
            }
        }
        if wrote {
            let mut j = 0;
            while j < open.len() {
                if open[j].1.hazards.len() > HAZARD_CAP {
                    let (_, buf) = open.remove(j);
                    sealed.push(buf);
                } else {
                    j += 1;
                }
            }
        }
    }
    sealed.extend(open.into_iter().map(|(_, b)| b));

    // Decide what to pack.
    let mut stats = AggStats::default();
    let mut drop = vec![false; ops.len()];
    let mut packed_at: FxHashMap<usize, Vec<usize>> = FxHashMap::default();
    for buf in sealed {
        if buf.parts.len() < 2 {
            continue;
        }
        for &p in &buf.parts {
            let tag = match &ops[p].payload {
                OpPayload::Send { tag, .. } => *tag,
                _ => unreachable!("buffered op is a send"),
            };
            drop[p] = true;
            drop[recv_of[&tag]] = true;
        }
        stats.packed_msgs += 1;
        stats.packed_parts += buf.parts.len() as u64;
        packed_at.insert(buf.anchor, buf.parts);
    }
    if stats.packed_msgs == 0 {
        return (Cow::Borrowed(ops), stats);
    }

    // Envelope tags must not collide with any tag in the batch.
    let mut next_tag = 1 + ops
        .iter()
        .flat_map(|op| {
            let payload_tag = match &op.payload {
                OpPayload::Send { tag, .. } | OpPayload::Recv { tag, .. } => Some(tag.0),
                OpPayload::Compute(_) => None,
            };
            payload_tag.into_iter().chain(op.accesses.iter().filter_map(|a| {
                match a.loc {
                    Loc::Stage(t) => Some(t.0),
                    Loc::Block { .. } => None,
                }
            }))
        })
        .max()
        .unwrap_or(0);

    let mut out: Vec<OpNode> = Vec::with_capacity(ops.len());
    for (i, op) in ops.iter().enumerate() {
        if let Some(parts) = packed_at.get(&i) {
            let (from, group) = (op.rank, op.group);
            let to = match &op.payload {
                OpPayload::Send { peer, .. } => *peer,
                _ => unreachable!(),
            };
            let mut packed = Vec::with_capacity(parts.len());
            let mut send_accesses = Vec::new();
            let mut recv_accesses = Vec::with_capacity(parts.len());
            let mut bytes = 0u64;
            for &p in parts {
                match &ops[p].payload {
                    OpPayload::Send {
                        tag, bytes: b, src, ..
                    } => {
                        packed.push((*tag, src.clone()));
                        bytes += b;
                        recv_accesses.push(Access::write_stage(*tag));
                    }
                    _ => unreachable!(),
                }
                send_accesses.extend(ops[p].accesses.iter().copied());
            }
            let envelope = Tag(next_tag);
            next_tag += 1;
            out.push(OpNode {
                id: OpId(0), // renumbered below
                rank: from,
                group,
                payload: OpPayload::Send {
                    peer: to,
                    tag: envelope,
                    bytes,
                    src: SendSrc::Packed(packed),
                },
                accesses: send_accesses,
            });
            out.push(OpNode {
                id: OpId(0),
                rank: to,
                group,
                payload: OpPayload::Recv {
                    peer: from,
                    tag: envelope,
                    bytes,
                },
                accesses: recv_accesses,
            });
            continue;
        }
        if drop[i] {
            continue;
        }
        out.push(op.clone());
    }
    for (i, op) in out.iter_mut().enumerate() {
        op.id = OpId(i as u32);
    }
    (Cow::Owned(out), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::cluster::MachineSpec;
    use crate::exec::SimBackend;
    use crate::sched::{execute, Policy, SchedCfg};
    use crate::types::DType;
    use crate::ufunc::{Kernel, OpBuilder};

    /// A 3-point stencil whose fragments pull two shifted regions from
    /// the same neighbour: the canonical coalescing opportunity.
    fn stencil_ops(p: u32, rows: u64, br: u64) -> Vec<OpNode> {
        let mut reg = Registry::new(p);
        let m = reg.alloc(vec![rows], br, DType::F32);
        let nn = reg.alloc(vec![rows], br, DType::F32);
        let mv = reg.full_view(m);
        let nv = reg.full_view(nn);
        let mut bld = OpBuilder::new();
        bld.ufunc(
            &reg,
            Kernel::Add,
            &nv.slice(&[(1, rows - 1)]),
            &[&mv.slice(&[(2, rows)]), &mv.slice(&[(0, rows - 2)])],
        );
        bld.finish()
    }

    fn count_transfers(ops: &[OpNode]) -> (usize, usize) {
        let s = ops
            .iter()
            .filter(|o| matches!(o.payload, OpPayload::Send { .. }))
            .count();
        let r = ops
            .iter()
            .filter(|o| matches!(o.payload, OpPayload::Recv { .. }))
            .count();
        (s, r)
    }

    #[test]
    fn threshold_below_two_is_identity() {
        let ops = stencil_ops(2, 12, 2);
        let (out, stats) = aggregate(&ops, 1);
        assert_eq!(out.len(), ops.len());
        assert_eq!(stats, AggStats::default());
    }

    #[test]
    fn packs_same_pair_transfers_and_renumbers() {
        let ops = stencil_ops(2, 12, 2);
        let (before_s, before_r) = count_transfers(&ops);
        let (out, stats) = aggregate(&ops, 8);
        let (after_s, after_r) = count_transfers(&out);
        assert!(stats.packed_msgs >= 1, "stencil must offer coalescing");
        assert!(stats.packed_parts > stats.packed_msgs);
        let saved = (stats.packed_parts - stats.packed_msgs) as usize;
        assert_eq!(after_s, before_s - saved);
        assert_eq!(after_r, before_r - saved);
        for (i, op) in out.iter().enumerate() {
            assert_eq!(op.id.idx(), i, "ids must match indices");
        }
        // Envelope tags are fresh.
        let mut seen = std::collections::HashSet::new();
        for op in out.iter() {
            if let OpPayload::Recv { tag, .. } = &op.payload {
                assert!(seen.insert(*tag), "duplicate wire tag {tag:?}");
            }
        }
    }

    #[test]
    fn respects_max_parts() {
        let ops = stencil_ops(2, 48, 2);
        let (_, unbounded) = aggregate(&ops, usize::MAX);
        let (out, stats) = aggregate(&ops, 2);
        assert!(unbounded.packed_parts >= 2, "workload offers coalescing");
        // The bounded run can never absorb more constituents than the
        // unbounded one, and splitting the same constituents into
        // 2-part envelopes takes at least as many messages.
        assert!(stats.packed_parts <= unbounded.packed_parts);
        assert!(stats.packed_msgs >= unbounded.packed_msgs);
        for op in out.iter() {
            if let OpPayload::Send {
                src: SendSrc::Packed(parts),
                ..
            } = &op.payload
            {
                assert!(parts.len() <= 2);
            }
        }
    }

    #[test]
    fn write_hazard_prevents_stale_capture() {
        // Two same-pair sends with an intervening write to the second
        // send's source must NOT merge.
        use crate::ufunc::{ComputeTask, Dst, Operand, Region};
        let b = crate::types::BaseId(0);
        let region = |lo: u64| Region {
            base: b,
            block: 0,
            row0: lo,
            nrows: 1,
            col0: 0,
            ncols: 4,
            row_stride: 4,
        };
        let send = |id: u32, tag: u64, lo: u64| OpNode {
            id: OpId(id),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Send {
                peer: Rank(1),
                tag: Tag(tag),
                bytes: 16,
                src: SendSrc::Region(region(lo)),
            },
            accesses: vec![Access::read_block(b, 0, (lo * 4, lo * 4 + 4))],
        };
        let recv = |id: u32, tag: u64| OpNode {
            id: OpId(id),
            rank: Rank(1),
            group: 0,
            payload: OpPayload::Recv {
                peer: Rank(0),
                tag: Tag(tag),
                bytes: 16,
            },
            accesses: vec![Access::write_stage(Tag(tag))],
        };
        let writer = OpNode {
            id: OpId(2),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::Scale(2.0),
                inputs: vec![Operand::Local(region(1))],
                dst: Dst::Block(region(1)),
                elems: 4,
            }),
            accesses: vec![Access::write_block(b, 0, (4, 8))],
        };
        let ops = vec![send(0, 0, 0), recv(1, 0), writer, send(3, 1, 1), recv(4, 1)];
        let (out, stats) = aggregate(&ops, 8);
        assert_eq!(stats.packed_msgs, 0, "hazard must block the merge");
        assert_eq!(out.len(), ops.len());

        // Without the writer the two sends do merge.
        let ops2 = vec![send(0, 0, 0), recv(1, 0), send(2, 1, 1), recv(3, 1)];
        let (out2, stats2) = aggregate(&ops2, 8);
        assert_eq!(stats2.packed_msgs, 1);
        assert_eq!(stats2.packed_parts, 2);
        assert_eq!(out2.len(), 2, "2 sends + 2 recvs become 1 + 1");
    }

    #[test]
    fn aggregated_stream_schedules_and_counts_match() {
        let ops = stencil_ops(4, 64, 4);
        let (packed, stats) = aggregate(&ops, 8);
        assert!(stats.packed_msgs > 0);
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        for policy in [Policy::LatencyHiding, Policy::Blocking] {
            let rep = execute(policy, &packed, &cfg, &mut SimBackend)
                .unwrap_or_else(|e| panic!("{policy:?}: {e}"));
            assert_eq!(rep.ops_executed, packed.len() as u64, "{policy:?}");
            let plain = execute(policy, &ops, &cfg, &mut SimBackend).unwrap();
            assert!(
                rep.n_messages < plain.n_messages,
                "{policy:?}: packing must cut wire messages ({} vs {})",
                rep.n_messages,
                plain.n_messages
            );
            assert_eq!(
                rep.bytes_inter + rep.bytes_intra,
                plain.bytes_inter + plain.bytes_intra,
                "{policy:?}: volume is conserved"
            );
        }
    }
}
