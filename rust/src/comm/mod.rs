//! Collective communication engine.
//!
//! The paper's flush triggers — reductions and gathers (Section 5.6) —
//! drain through flat O(P) fan-ins to the root rank, which serializes on
//! the root's NIC ingress and dominates makespan at P = 128. This module
//! provides *structured* collective schedules:
//!
//! * [`reduce_scalar_tree`] — binomial-tree combine of per-rank
//!   reduction partials (depth ⌈log₂P⌉ instead of P-1 root messages);
//! * [`broadcast_tree`] — binomial-tree broadcast of a base-block region
//!   (the owner injects ⌈log₂P⌉ messages instead of P-1);
//! * [`allgather_ring`] — ring allgather of a whole array-base (every
//!   link carries 1/P of the volume; no root hot spot);
//! * [`gather_flat`] — the flat fan-in baseline the ablation compares
//!   against;
//! * [`aggregate`] — message aggregation: same-`(src, dst)` block
//!   transfers that are ready in the same flush epoch are packed into
//!   one wire message, amortizing the per-message latency α and the
//!   receiver-side message cost.
//!
//! Everything is emitted as ordinary dependency-tracked send / recv /
//! combine [`crate::ufunc::OpNode`]s, so all three policies (latency-hiding, blocking,
//! naive) schedule collectives through the existing dependency systems
//! and the α–β [`crate::net::Network`] with no special cases. Tree hops
//! forward received data out of staging buffers ([`SendSrc::Stage`]);
//! every round is its own §5.3 group so the blocking baseline's
//! send-recv-compute phasing stays deadlock-free.
//!
//! **Determinism:** each tree node combines `[own partial, received
//! partial]` in that fixed order, and the tree shape depends only on the
//! participating ranks — so a data backend produces bit-identical
//! reduction results under every policy (asserted by
//! `rust/tests/props.rs`).

mod aggregate;

pub use aggregate::{aggregate, AggStats};

use crate::array::Registry;
use crate::types::{BaseId, Rank, Tag};
use crate::ufunc::{
    Access, ComputeTask, Dst, Kernel, OpBuilder, OpPayload, Operand, Region, SendSrc,
};

/// Bytes on the wire per staged reduction scalar (matches the flat
/// gather of `OpBuilder::reduce`; also the payload of the value
/// broadcast a cone-wait rides, see [`crate::sync`]).
pub const SCALAR_BYTES: u64 = 8;

/// Payload size above which a cone-settle value broadcast switches
/// from the latency-optimal binomial tree to the bandwidth-optimal
/// pipelined ring ([`bcast_shape_for`]). Tree moves the full payload
/// ⌈log₂P⌉ sequential times; a ring pipelined into
/// [`RING_BCAST_SEGMENTS`] segments approaches one payload time once
/// `bytes·β` dominates `α` — the crossover sits around the point where
/// per-hop serialization stops being latency-bound.
pub const RING_BCAST_MIN_BYTES: u64 = 1 << 16;

/// Segments a pipelined ring broadcast cuts its payload into. Each
/// segment chases the previous one around the ring, so the pipeline
/// fill costs `(P-2)` segment hops and the drain `SEGMENTS` — total
/// `≈ (P + S - 2)·(α + bytes/S·β)` versus the tree's
/// `⌈log₂P⌉·(α + bytes·β)`.
pub const RING_BCAST_SEGMENTS: u64 = 8;

/// Shape of the value broadcast a forced read rides back out of its
/// cone settle ([`crate::sync::settle_cone`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BcastShape {
    /// Root injects P-1 messages directly (the paper's scheme).
    Flat,
    /// Binomial tree: ⌈log₂P⌉ rounds, latency-optimal for scalars.
    Tree,
    /// Pipelined ring: bandwidth-optimal for dense payloads (deferred
    /// array gathers, [`crate::sync::ArrayFuture`]).
    Ring,
}

/// Choose the broadcast shape for a `bytes`-sized forced value at
/// P = `p`, given the configured collective schedule. Scalar-sized
/// notifications keep the configured shape (flat fan-out or binomial
/// tree); dense payloads — a forced [`crate::sync::ArrayFuture`] under
/// the flat gather, where every rank consumes the array (§5.5) — ride
/// the pipelined ring once the volume crosses
/// [`RING_BCAST_MIN_BYTES`].
pub fn bcast_shape_for(collective: Collective, p: u32, bytes: u64) -> BcastShape {
    if p >= 4 && bytes >= RING_BCAST_MIN_BYTES {
        return BcastShape::Ring;
    }
    match collective {
        Collective::Flat => BcastShape::Flat,
        Collective::Tree => BcastShape::Tree,
    }
}

/// The binomial-tree broadcast schedule in *virtual-id* space (vid 0 is
/// the root): rounds of `(from_vid, to_vid)` hops, doubling the covered
/// set each round. Shared by [`broadcast_tree`] (which emits the hops as
/// dependency-tracked operation nodes) and by the cone-wait value
/// broadcast in [`crate::sync::settle_cone`] (which times the same hops
/// directly against the persistent network).
pub fn bcast_rounds(p: u32) -> Vec<Vec<(u32, u32)>> {
    let mut rounds = Vec::new();
    let mut k = 1u32;
    while k < p {
        let mut hops = Vec::new();
        for vid in 0..k {
            let dst = vid + k;
            if dst >= p {
                break;
            }
            hops.push((vid, dst));
        }
        rounds.push(hops);
        k *= 2;
    }
    rounds
}

/// Which schedule the cross-rank phase of a collective uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Direct fan-in/fan-out to or from the root (the paper's scheme).
    Flat,
    /// Binomial trees for reduce/broadcast, a ring for allgather.
    Tree,
}

impl Collective {
    pub fn parse(s: &str) -> Option<Collective> {
        match s {
            "flat" => Some(Collective::Flat),
            "tree" => Some(Collective::Tree),
            _ => None,
        }
    }
}

/// Combine per-rank staged scalars into one scalar on `root` along a
/// binomial tree. `parts` holds each participating rank's current
/// partial tag (at most one entry per rank). Returns the tag of the
/// final result, staged on `root`.
///
/// Round k pairs participants 2k apart: the higher one sends its
/// running partial, the lower one combines `[own, received]` (fixed
/// order — determinism). Each round is one §5.3 group.
pub fn reduce_scalar_tree(bld: &mut OpBuilder, parts: &[(Rank, Tag)], root: Rank) -> Tag {
    assert!(!parts.is_empty(), "reduce over no partials");
    let mut cur: Vec<(Rank, Tag)> = parts.to_vec();
    cur.sort_by_key(|(r, _)| *r);
    if let Some(i) = cur.iter().position(|(r, _)| *r == root) {
        cur.rotate_left(i);
    }
    let n = cur.len();
    let mut k = 1;
    while k < n {
        bld.begin_group();
        let mut i = 0;
        while i + k < n {
            let (s_rank, s_tag) = cur[i + k];
            let (r_rank, r_tag) = cur[i];
            let wire = bld.fresh_tag();
            bld.push(
                s_rank,
                OpPayload::Send {
                    peer: r_rank,
                    tag: wire,
                    bytes: SCALAR_BYTES,
                    src: SendSrc::Stage(s_tag),
                },
                vec![Access::read_stage(s_tag)],
            );
            bld.push(
                r_rank,
                OpPayload::Recv {
                    peer: s_rank,
                    tag: wire,
                    bytes: SCALAR_BYTES,
                },
                vec![Access::write_stage(wire)],
            );
            let combined = bld.fresh_tag();
            bld.push(
                r_rank,
                OpPayload::Compute(ComputeTask {
                    kernel: Kernel::AccumSum,
                    inputs: vec![Operand::Staged(r_tag), Operand::Staged(wire)],
                    dst: Dst::Stage(combined),
                    elems: 2,
                }),
                vec![
                    Access::read_stage(r_tag),
                    Access::read_stage(wire),
                    Access::write_stage(combined),
                ],
            );
            cur[i].1 = combined;
            i += 2 * k;
        }
        k *= 2;
    }
    let (owner, tag) = cur[0];
    if owner == root {
        return tag;
    }
    // The root owned no partial (the reduced view touches none of its
    // blocks): one final hop delivers the result.
    bld.begin_group();
    let wire = bld.fresh_tag();
    bld.push(
        owner,
        OpPayload::Send {
            peer: root,
            tag: wire,
            bytes: SCALAR_BYTES,
            src: SendSrc::Stage(tag),
        },
        vec![Access::read_stage(tag)],
    );
    bld.push(
        root,
        OpPayload::Recv {
            peer: owner,
            tag: wire,
            bytes: SCALAR_BYTES,
        },
        vec![Access::write_stage(wire)],
    );
    wire
}

/// Broadcast `region` from its owning rank to every other rank along a
/// binomial tree; returns the staging tag per rank (index = rank, `None`
/// for the owner). Drop-in replacement for the flat
/// `OpBuilder::broadcast` fan-out: the owner injects ⌈log₂P⌉ messages
/// instead of P-1, and later hops forward out of their staging buffers.
pub fn broadcast_tree(
    bld: &mut OpBuilder,
    reg: &Registry,
    region: Region,
    intra: (u64, u64),
    nprocs: u32,
) -> Vec<Option<Tag>> {
    let owner = reg.layout(region.base).owner(region.block);
    let p = nprocs;
    let mut tags: Vec<Option<Tag>> = vec![None; p as usize];
    let bytes = region.elems() * 4;
    let rank_of = |vid: u32| Rank((owner.0 + vid) % p);
    for round in bcast_rounds(p) {
        bld.begin_group();
        for (vid, dst_vid) in round {
            let from = rank_of(vid);
            let to = rank_of(dst_vid);
            let wire = bld.fresh_tag();
            let (src, access) = if vid == 0 {
                (
                    SendSrc::Region(region.clone()),
                    Access::read_block(region.base, region.block, intra),
                )
            } else {
                let t = tags[from.idx()].expect("forwarder holds the region");
                (SendSrc::Stage(t), Access::read_stage(t))
            };
            bld.push(
                from,
                OpPayload::Send {
                    peer: to,
                    tag: wire,
                    bytes,
                    src,
                },
                vec![access],
            );
            bld.push(
                to,
                OpPayload::Recv {
                    peer: from,
                    tag: wire,
                    bytes,
                },
                vec![Access::write_stage(wire)],
            );
            tags[to.idx()] = Some(wire);
        }
    }
    tags
}

/// Full-block region of base-block `block` (helper for whole-base
/// collectives and the gather snapshots of
/// [`crate::lazy::Context::gather_deferred`]).
pub(crate) fn block_region(reg: &Registry, base: BaseId, block: u64) -> (Region, (u64, u64)) {
    let layout = reg.layout(base);
    let nrows = layout.block_nrows(block);
    let re = layout.row_elems();
    (
        Region {
            base,
            block,
            row0: 0,
            nrows,
            col0: 0,
            ncols: re,
            row_stride: re,
        },
        (0, nrows * re),
    )
}

/// Ring allgather of every base-block of `base`: after execution every
/// rank holds a staged copy of each block it does not own. Returns
/// `tags[rank][block]` (`None` where the block is local to that rank).
///
/// Each block circulates rank-to-rank around the ring, one hop per §5.3
/// group (P-1 rounds); hop s forwards what hop s-1 received, so every
/// link carries the same volume and no rank's NIC becomes a hot spot —
/// unlike the flat fan-in of [`gather_flat`].
pub fn allgather_ring(bld: &mut OpBuilder, reg: &Registry, base: BaseId) -> Vec<Vec<Option<Tag>>> {
    let layout = reg.layout(base);
    let p = layout.nprocs;
    let nb = layout.nblocks();
    let mut tags: Vec<Vec<Option<Tag>>> = vec![vec![None; nb as usize]; p as usize];
    if p == 1 {
        return tags;
    }
    for s in 0..p - 1 {
        bld.begin_group();
        for b in 0..nb {
            let owner = layout.owner(b);
            let from = Rank((owner.0 + s) % p);
            let to = Rank((owner.0 + s + 1) % p);
            let (region, intra) = block_region(reg, base, b);
            let bytes = region.elems() * 4;
            let wire = bld.fresh_tag();
            let (src, access) = if s == 0 {
                (
                    SendSrc::Region(region),
                    Access::read_block(base, b, intra),
                )
            } else {
                let t = tags[from.idx()][b as usize].expect("ring hop holds the block");
                (SendSrc::Stage(t), Access::read_stage(t))
            };
            bld.push(
                from,
                OpPayload::Send {
                    peer: to,
                    tag: wire,
                    bytes,
                    src,
                },
                vec![access],
            );
            bld.push(
                to,
                OpPayload::Recv {
                    peer: from,
                    tag: wire,
                    bytes,
                },
                vec![Access::write_stage(wire)],
            );
            tags[to.idx()][b as usize] = Some(wire);
        }
    }
    tags
}

/// Flat fan-in of every remote base-block of `base` to `root` — the
/// baseline schedule [`allgather_ring`] replaces. Returns the staging
/// tag per block on the root (`None` for root-local blocks).
pub fn gather_flat(
    bld: &mut OpBuilder,
    reg: &Registry,
    base: BaseId,
    root: Rank,
) -> Vec<Option<Tag>> {
    let layout = reg.layout(base);
    let nb = layout.nblocks();
    bld.begin_group();
    let mut tags = vec![None; nb as usize];
    for b in 0..nb {
        let owner = layout.owner(b);
        if owner == root {
            continue;
        }
        let (region, intra) = block_region(reg, base, b);
        tags[b as usize] = Some(bld.transfer(owner, root, region, intra));
    }
    tags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::{ClusterStore, Registry};
    use crate::cluster::MachineSpec;
    use crate::exec::{NativeBackend, SimBackend};
    use crate::sched::{execute, Policy, SchedCfg};
    use crate::types::DType;
    use crate::util::rng::Rng;

    fn count_sends(ops: &[crate::ufunc::OpNode]) -> usize {
        ops.iter()
            .filter(|o| matches!(o.payload, OpPayload::Send { .. }))
            .count()
    }

    #[test]
    fn tree_reduce_message_count_and_depth() {
        for p in [2u32, 3, 5, 8, 16] {
            let mut bld = OpBuilder::new();
            let parts: Vec<(Rank, Tag)> =
                (0..p).map(|r| (Rank(r), bld.fresh_tag())).collect();
            let n0 = bld.n_recorded();
            let _ = reduce_scalar_tree(&mut bld, &parts, Rank(0));
            let ops = bld.finish();
            assert_eq!(ops.len() - n0, 3 * (p as usize - 1), "P={p}");
            assert_eq!(count_sends(&ops), p as usize - 1, "P={p}: P-1 messages");
            // Depth: the root receives exactly ceil(log2 P) messages.
            let root_recvs = ops
                .iter()
                .filter(|o| {
                    o.rank == Rank(0) && matches!(o.payload, OpPayload::Recv { .. })
                })
                .count();
            assert_eq!(root_recvs, (p as f64).log2().ceil() as usize, "P={p}");
        }
    }

    #[test]
    fn tree_reduce_forwards_when_root_has_no_partial() {
        let mut bld = OpBuilder::new();
        let parts = vec![(Rank(2), bld.fresh_tag()), (Rank(3), bld.fresh_tag())];
        let tag = reduce_scalar_tree(&mut bld, &parts, Rank(0));
        let ops = bld.finish();
        // One combine round + one forwarding hop to the root.
        assert_eq!(count_sends(&ops), 2);
        let last = ops.last().unwrap();
        assert_eq!(last.rank, Rank(0));
        assert!(matches!(last.payload, OpPayload::Recv { tag: t, .. } if t == tag));
    }

    #[test]
    fn broadcast_tree_spreads_owner_egress() {
        let mut reg = Registry::new(8);
        let x = reg.alloc(vec![32], 4, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        let regions = bld.svb_regions(&reg, &xv);
        let (r0, intra, owner) = regions[3].clone();
        let tags = broadcast_tree(&mut bld, &reg, r0, intra, 8);
        assert!(tags[owner.idx()].is_none());
        assert_eq!(tags.iter().flatten().count(), 7, "everyone else tagged");
        let ops = bld.finish();
        assert_eq!(count_sends(&ops), 7, "P-1 messages in total");
        let owner_sends = ops
            .iter()
            .filter(|o| o.rank == owner && matches!(o.payload, OpPayload::Send { .. }))
            .count();
        assert_eq!(owner_sends, 3, "owner injects only log2(8) messages");
    }

    #[test]
    fn tree_reduce_schedules_under_all_policies() {
        let mut reg = Registry::new(4);
        let x = reg.alloc(vec![16], 2, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        let _ = bld.reduce(&reg, Kernel::PartialSum, &[&xv], Collective::Tree);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            let rep = execute(policy, &ops, &cfg, &mut SimBackend)
                .unwrap_or_else(|e| panic!("{policy:?}: {e}"));
            assert_eq!(rep.ops_executed, ops.len() as u64, "{policy:?}");
        }
    }

    #[test]
    fn tree_broadcast_schedules_under_all_policies() {
        let mut reg = Registry::new(4);
        let x = reg.alloc(vec![16], 2, DType::F32);
        let xv = reg.full_view(x);
        let mut bld = OpBuilder::new();
        let regions = bld.svb_regions(&reg, &xv);
        let (r0, intra, _) = regions[0].clone();
        let _ = broadcast_tree(&mut bld, &reg, r0, intra, 4);
        let ops = bld.finish();
        let cfg = SchedCfg::new(MachineSpec::tiny(), 4);
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            let rep = execute(policy, &ops, &cfg, &mut SimBackend)
                .unwrap_or_else(|e| panic!("{policy:?}: {e}"));
            assert_eq!(rep.ops_executed, ops.len() as u64, "{policy:?}");
        }
    }

    #[test]
    fn ring_allgather_delivers_every_block() {
        let p = 3u32;
        let rows = 14u64;
        let br = 2u64;
        let mut reg = Registry::new(p);
        let a = reg.alloc(vec![rows], br, DType::F32);
        let mut store = ClusterStore::new(p);
        store.alloc_base(reg.layout(a));
        let mut rng = Rng::new(11);
        let data = rng.fill_f32(rows as usize, -1.0, 1.0);
        store.scatter(reg.layout(a), &data);
        let mut bld = OpBuilder::new();
        let tags = allgather_ring(&mut bld, &reg, a);
        let ops = bld.finish();
        let layout = reg.layout(a).clone();
        assert_eq!(
            count_sends(&ops),
            (layout.nblocks() * (p as u64 - 1)) as usize,
            "each block travels P-1 hops"
        );
        let mut be = NativeBackend::new(store);
        let cfg = SchedCfg::new(MachineSpec::tiny(), p);
        execute(Policy::LatencyHiding, &ops, &cfg, &mut be).unwrap();
        for r in 0..p {
            for b in 0..layout.nblocks() {
                let (lo, hi) = layout.block_rows_range(b);
                let want = &data[lo as usize..hi as usize];
                match tags[r as usize][b as usize] {
                    None => assert_eq!(layout.owner(b), Rank(r), "local blocks untagged"),
                    Some(t) => {
                        assert_eq!(
                            be.store.ranks[r as usize].stage(t),
                            want,
                            "rank {r} block {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gather_flat_targets_root_only() {
        let mut reg = Registry::new(4);
        let a = reg.alloc(vec![16], 2, DType::F32);
        let mut bld = OpBuilder::new();
        let tags = gather_flat(&mut bld, &reg, a, Rank(0));
        let ops = bld.finish();
        // 8 blocks, 2 owned by the root -> 6 transfers, all into rank 0.
        assert_eq!(count_sends(&ops), 6);
        assert_eq!(tags.iter().flatten().count(), 6);
        for op in &ops {
            if let OpPayload::Recv { .. } = op.payload {
                assert_eq!(op.rank, Rank(0));
            }
        }
    }

    #[test]
    fn bcast_rounds_cover_everyone_once() {
        for p in [1u32, 2, 3, 5, 8, 13] {
            let rounds = bcast_rounds(p);
            let mut have = vec![false; p as usize];
            have[0] = true;
            for round in &rounds {
                for &(from, to) in round {
                    assert!(have[from as usize], "P={p}: forwarder {from} has the value");
                    assert!(!have[to as usize], "P={p}: {to} delivered twice");
                    have[to as usize] = true;
                }
            }
            assert!(have.iter().all(|&h| h), "P={p}: everyone covered");
            let hops: usize = rounds.iter().map(|r| r.len()).sum();
            assert_eq!(hops, p as usize - 1, "P={p}: P-1 messages");
            assert_eq!(rounds.len(), (p as f64).log2().ceil() as usize, "P={p}: log2 depth");
        }
    }

    #[test]
    fn bcast_shape_chooser_is_volume_aware() {
        for collective in [Collective::Flat, Collective::Tree] {
            assert_eq!(
                bcast_shape_for(collective, 16, RING_BCAST_MIN_BYTES),
                BcastShape::Ring,
                "dense payloads ride the ring"
            );
            assert_ne!(
                bcast_shape_for(collective, 2, 1 << 30),
                BcastShape::Ring,
                "a 2-rank ring is pointless"
            );
        }
        assert_eq!(bcast_shape_for(Collective::Flat, 16, SCALAR_BYTES), BcastShape::Flat);
        assert_eq!(bcast_shape_for(Collective::Tree, 16, SCALAR_BYTES), BcastShape::Tree);
    }

    #[test]
    fn collective_parse() {
        assert_eq!(Collective::parse("flat"), Some(Collective::Flat));
        assert_eq!(Collective::parse("tree"), Some(Collective::Tree));
        assert_eq!(Collective::parse("ring"), None);
    }
}
