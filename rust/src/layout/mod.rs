//! Block-cyclic data layout (paper Section 5.2).
//!
//! DistNumPy distributes an array-base in fixed-size **base-blocks**,
//! assigned round-robin (block-cyclic) to MPI ranks. A view of the base
//! decomposes into **view-blocks**, and each view-block into
//! **sub-view-blocks** — the largest pieces that live on a single rank.
//! All operations are ultimately expressed on sub-view-blocks.
//!
//! This implementation distributes along dimension 0 (row slabs), the
//! layout DistNumPy uses for its benchmark suite; the remaining
//! dimensions stay intact inside each block. A base-block is therefore a
//! contiguous slab of `block_rows` rows, and the flattened element
//! interval of any rectangular sub-view inside its block is cheap to
//! compute — which the dependency heuristic (deps::heuristic) relies on.

mod frag;
pub use frag::{fragments, Frag, FragOperand};

use crate::types::{BaseId, DType, Rank};

/// Distribution metadata of one array-base.
#[derive(Clone, Debug)]
pub struct Layout {
    pub base: BaseId,
    /// Global shape; `shape[0]` is the distributed dimension.
    pub shape: Vec<u64>,
    /// Rows per base-block (the paper's block size).
    pub block_rows: u64,
    /// Number of ranks the base is distributed over.
    pub nprocs: u32,
    pub dtype: DType,
}

impl Layout {
    pub fn new(
        base: BaseId,
        shape: Vec<u64>,
        block_rows: u64,
        nprocs: u32,
        dtype: DType,
    ) -> Self {
        assert!(!shape.is_empty() && shape.iter().all(|&d| d > 0));
        assert!(block_rows > 0 && nprocs > 0);
        Layout {
            base,
            shape,
            block_rows,
            nprocs,
            dtype,
        }
    }

    /// Number of rows (extent of the distributed dimension).
    #[inline]
    pub fn rows(&self) -> u64 {
        self.shape[0]
    }

    /// Elements per row (product of the non-distributed dimensions).
    #[inline]
    pub fn row_elems(&self) -> u64 {
        self.shape[1..].iter().product::<u64>().max(1)
    }

    /// Total number of base-blocks.
    #[inline]
    pub fn nblocks(&self) -> u64 {
        self.rows().div_ceil(self.block_rows)
    }

    /// Owning rank of a base-block: round-robin (block-cyclic).
    #[inline]
    pub fn owner(&self, block: u64) -> Rank {
        Rank((block % self.nprocs as u64) as u32)
    }

    /// Base-block index containing a global row.
    #[inline]
    pub fn block_of_row(&self, row: u64) -> u64 {
        row / self.block_rows
    }

    /// Global row range `[lo, hi)` covered by a base-block.
    #[inline]
    pub fn block_rows_range(&self, block: u64) -> (u64, u64) {
        let lo = block * self.block_rows;
        (lo, (lo + self.block_rows).min(self.rows()))
    }

    /// Rows actually present in a block (the last block may be short).
    #[inline]
    pub fn block_nrows(&self, block: u64) -> u64 {
        let (lo, hi) = self.block_rows_range(block);
        hi - lo
    }

    /// Bytes of one full base-block.
    #[inline]
    pub fn block_bytes(&self, block: u64) -> u64 {
        self.block_nrows(block) * self.row_elems() * self.dtype.size()
    }

    /// Blocks owned by `rank`, in block order.
    pub fn blocks_of(&self, rank: Rank) -> impl Iterator<Item = u64> + '_ {
        (0..self.nblocks()).filter(move |b| self.owner(*b) == rank)
    }

    /// Is this layout "aligned" with another (identical block structure)?
    /// Aligned arrays admit the simple double-buffering schedule
    /// (paper Section 5.4); non-aligned ones need intra-view-block
    /// latency-hiding — the paper's contribution.
    pub fn aligned_with(&self, other: &Layout) -> bool {
        self.shape == other.shape
            && self.block_rows == other.block_rows
            && self.nprocs == other.nprocs
    }
}

/// A rectangular view of an array-base (paper Section 5.1: array-view).
///
/// Views are unit-stride rectangles: `offset[d] .. offset[d] + shape[d]`
/// in every dimension. The hierarchy is flat — a view always refers
/// directly to a base, never to another view.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ViewSpec {
    pub base: BaseId,
    pub offset: Vec<u64>,
    pub shape: Vec<u64>,
}

impl ViewSpec {
    /// Full view of a layout.
    pub fn full(l: &Layout) -> ViewSpec {
        ViewSpec {
            base: l.base,
            offset: vec![0; l.shape.len()],
            shape: l.shape.clone(),
        }
    }

    /// Number of elements in the view.
    pub fn elems(&self) -> u64 {
        self.shape.iter().product::<u64>().max(1)
    }

    /// Sub-slice relative to this view: `ranges[d] = (lo, hi)` with
    /// `hi <= shape[d]`. Returns a view still anchored at the base
    /// (2-level hierarchy preserved).
    pub fn slice(&self, ranges: &[(u64, u64)]) -> ViewSpec {
        assert_eq!(ranges.len(), self.shape.len(), "rank mismatch");
        let mut offset = Vec::with_capacity(ranges.len());
        let mut shape = Vec::with_capacity(ranges.len());
        for (d, &(lo, hi)) in ranges.iter().enumerate() {
            assert!(
                lo <= hi && hi <= self.shape[d],
                "slice out of bounds: dim {d} ({lo},{hi}) of {}",
                self.shape[d]
            );
            offset.push(self.offset[d] + lo);
            shape.push(hi - lo);
        }
        ViewSpec {
            base: self.base,
            offset,
            shape,
        }
    }

    /// Flattened column offset bounds of the view rectangle within one
    /// row of the base: (min, max) over the non-distributed dims.
    /// Used for the conservative interval of the dependency system.
    pub fn col_bounds(&self, layout: &Layout) -> (u64, u64) {
        let mut stride = 1u64;
        let mut strides = vec![1u64; layout.shape.len()];
        for d in (1..layout.shape.len()).rev() {
            strides[d] = stride;
            stride *= layout.shape[d];
        }
        let mut lo = 0u64;
        let mut hi = 0u64;
        for d in 1..layout.shape.len() {
            lo += self.offset[d] * strides[d];
            hi += (self.offset[d] + self.shape[d] - 1) * strides[d];
        }
        (lo, hi)
    }
}

/// One sub-view-block: the part of a view that lies in a single
/// base-block (and hence on a single rank).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubViewBlock {
    /// Base-block index within the base.
    pub block: u64,
    /// Owning rank of that block.
    pub owner: Rank,
    /// Row range relative to the *view* `[lo, hi)`.
    pub view_rows: (u64, u64),
    /// Row range in *global base* coordinates `[lo, hi)`.
    pub global_rows: (u64, u64),
}

/// Decompose a view into sub-view-blocks along the distributed dim.
pub fn sub_view_blocks(layout: &Layout, view: &ViewSpec) -> Vec<SubViewBlock> {
    assert_eq!(view.base, layout.base);
    let mut out = Vec::new();
    if view.shape.iter().any(|&d| d == 0) {
        return out;
    }
    let g0 = view.offset[0];
    let g1 = g0 + view.shape[0];
    let mut g = g0;
    while g < g1 {
        let b = layout.block_of_row(g);
        let (_, bhi) = layout.block_rows_range(b);
        let seg_hi = g1.min(bhi);
        out.push(SubViewBlock {
            block: b,
            owner: layout.owner(b),
            view_rows: (g - g0, seg_hi - g0),
            global_rows: (g, seg_hi),
        });
        g = seg_hi;
    }
    out
}

/// True when every sub-view-block of the view coincides exactly with a
/// base-block — the paper's *aligned array* case.
pub fn view_is_aligned(layout: &Layout, view: &ViewSpec) -> bool {
    if view.offset.iter().skip(1).any(|&o| o != 0) {
        return false;
    }
    if view.shape[1..] != layout.shape[1..] {
        return false;
    }
    view.offset[0] % layout.block_rows == 0
        && (view.offset[0] + view.shape[0] == layout.rows()
            || (view.offset[0] + view.shape[0]) % layout.block_rows == 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_1d(rows: u64, br: u64, p: u32) -> Layout {
        Layout::new(BaseId(0), vec![rows], br, p, DType::F32)
    }

    #[test]
    fn paper_example_two_ranks_block3() {
        // Fig. 4: arrays of 6 elements, block size 3, two nodes.
        let l = layout_1d(6, 3, 2);
        assert_eq!(l.nblocks(), 2);
        assert_eq!(l.owner(0), Rank(0));
        assert_eq!(l.owner(1), Rank(1));
        // View A = M[2:] spans both blocks.
        let m = ViewSpec::full(&l);
        let a = m.slice(&[(2, 6)]);
        let svbs = sub_view_blocks(&l, &a);
        assert_eq!(svbs.len(), 2);
        assert_eq!(svbs[0].block, 0);
        assert_eq!(svbs[0].global_rows, (2, 3));
        assert_eq!(svbs[0].view_rows, (0, 1));
        assert_eq!(svbs[1].block, 1);
        assert_eq!(svbs[1].global_rows, (3, 6));
        assert_eq!(svbs[1].owner, Rank(1));
    }

    #[test]
    fn block_cyclic_round_robin() {
        let l = layout_1d(100, 10, 3);
        assert_eq!(l.nblocks(), 10);
        let owners: Vec<u32> = (0..10).map(|b| l.owner(b).0).collect();
        assert_eq!(owners, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn last_block_short() {
        let l = layout_1d(25, 10, 2);
        assert_eq!(l.nblocks(), 3);
        assert_eq!(l.block_nrows(2), 5);
        assert_eq!(l.block_bytes(2), 5 * 4);
    }

    #[test]
    fn blocks_of_rank() {
        let l = layout_1d(100, 10, 4);
        let r1: Vec<u64> = l.blocks_of(Rank(1)).collect();
        assert_eq!(r1, vec![1, 5, 9]);
    }

    #[test]
    fn svb_covers_view_exactly() {
        let l = layout_1d(97, 8, 3);
        let v = ViewSpec::full(&l).slice(&[(5, 90)]);
        let svbs = sub_view_blocks(&l, &v);
        // Coverage: contiguous, disjoint, spans [0, 85) in view coords.
        assert_eq!(svbs.first().unwrap().view_rows.0, 0);
        assert_eq!(svbs.last().unwrap().view_rows.1, 85);
        for w in svbs.windows(2) {
            assert_eq!(w[0].view_rows.1, w[1].view_rows.0);
            assert_eq!(w[0].global_rows.1, w[1].global_rows.0);
        }
        // Each segment inside one block.
        for s in &svbs {
            assert_eq!(l.block_of_row(s.global_rows.0), s.block);
            assert_eq!(l.block_of_row(s.global_rows.1 - 1), s.block);
            assert_eq!(l.owner(s.block), s.owner);
        }
    }

    #[test]
    fn view_2d_col_bounds() {
        let l = Layout::new(BaseId(1), vec![8, 10], 2, 2, DType::F32);
        let v = ViewSpec::full(&l).slice(&[(1, 7), (2, 9)]);
        let (lo, hi) = v.col_bounds(&l);
        assert_eq!(lo, 2);
        assert_eq!(hi, 8);
    }

    #[test]
    fn aligned_detection() {
        let l = Layout::new(BaseId(0), vec![12, 4], 3, 2, DType::F32);
        let full = ViewSpec::full(&l);
        assert!(view_is_aligned(&l, &full));
        assert!(view_is_aligned(&l, &full.slice(&[(3, 9), (0, 4)])));
        assert!(!view_is_aligned(&l, &full.slice(&[(1, 7), (0, 4)])));
        assert!(!view_is_aligned(&l, &full.slice(&[(3, 9), (1, 4)])));
    }

    #[test]
    fn slice_of_slice_stays_base_anchored() {
        let l = layout_1d(50, 5, 2);
        let v = ViewSpec::full(&l).slice(&[(10, 40)]);
        let w = v.slice(&[(5, 10)]);
        assert_eq!(w.offset, vec![15]);
        assert_eq!(w.shape, vec![5]);
        assert_eq!(w.base, l.base);
    }

    #[test]
    #[should_panic]
    fn slice_out_of_bounds_panics() {
        let l = layout_1d(10, 5, 2);
        let _ = ViewSpec::full(&l).slice(&[(0, 11)]);
    }

    #[test]
    fn empty_view_no_blocks() {
        let l = layout_1d(10, 5, 2);
        let v = ViewSpec::full(&l).slice(&[(3, 3)]);
        assert!(sub_view_blocks(&l, &v).is_empty());
    }
}
