//! Fragment overlay: the common refinement of several views' sub-view-block
//! decompositions.
//!
//! An elementwise ufunc over k same-shaped views must be split so that each
//! piece touches exactly one sub-view-block of *every* operand — then each
//! piece has a single computing rank (the output piece's owner) and at most
//! k-1 single-source transfers. The paper reaches the same granularity by
//! splitting view-block operations into sub-view-block operations
//! (Section 5.3/5.7); for non-aligned operands the fragment grid is the
//! intersection of all operands' block boundaries.

use super::{Layout, ViewSpec};
use crate::types::Rank;

/// One operand of a fragment: the region of that operand's view the
/// fragment covers, resolved to a base-block.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FragOperand {
    pub base: crate::types::BaseId,
    /// Base-block index.
    pub block: u64,
    /// Owning rank.
    pub owner: Rank,
    /// Global row range within the base `[lo, hi)`.
    pub global_rows: (u64, u64),
    /// Flattened element interval within the base-block `[lo, hi)` —
    /// conservative bounding interval used by the dependency system.
    pub intra_block: (u64, u64),
}

/// One fragment of an elementwise operation.
#[derive(Clone, Debug)]
pub struct Frag {
    /// Row range relative to the views `[lo, hi)` (all views share shape).
    pub view_rows: (u64, u64),
    /// Per-operand resolution, same order as the input slices.
    pub operands: Vec<FragOperand>,
}

impl Frag {
    /// Rows in the fragment.
    pub fn nrows(&self) -> u64 {
        self.view_rows.1 - self.view_rows.0
    }
}

fn resolve(layout: &Layout, view: &ViewSpec, vlo: u64, vhi: u64) -> FragOperand {
    let glo = view.offset[0] + vlo;
    let ghi = view.offset[0] + vhi;
    let block = layout.block_of_row(glo);
    debug_assert_eq!(
        layout.block_of_row(ghi - 1),
        block,
        "fragment crosses a block boundary of an operand"
    );
    let (blk_lo, _) = layout.block_rows_range(block);
    let row_elems = layout.row_elems();
    let (col_lo, col_hi) = view.col_bounds(layout);
    let intra_lo = (glo - blk_lo) * row_elems + col_lo;
    let intra_hi = (ghi - 1 - blk_lo) * row_elems + col_hi + 1;
    FragOperand {
        base: layout.base,
        block,
        owner: layout.owner(block),
        global_rows: (glo, ghi),
        intra_block: (intra_lo, intra_hi),
    }
}

/// Compute the fragment overlay of `views` (all with identical shape).
/// `layouts[i]` is the layout of `views[i]`'s base. Fragments are returned
/// in ascending view-row order and exactly tile `[0, shape[0])`.
pub fn fragments(layouts: &[&Layout], views: &[&ViewSpec]) -> Vec<Frag> {
    assert_eq!(layouts.len(), views.len());
    assert!(!views.is_empty());
    let shape = &views[0].shape;
    for v in views {
        assert_eq!(&v.shape, shape, "elementwise operands must share shape");
    }
    let rows = shape[0];
    if shape.iter().any(|&d| d == 0) {
        return Vec::new();
    }

    // Cut points in view-relative row coordinates: 0, rows, and every
    // operand block boundary that falls strictly inside.
    let mut cuts: Vec<u64> = vec![0, rows];
    for (l, v) in layouts.iter().zip(views.iter()) {
        let g0 = v.offset[0];
        let g1 = g0 + rows;
        // First block boundary strictly greater than g0.
        let mut b = (g0 / l.block_rows + 1) * l.block_rows;
        while b < g1 {
            cuts.push(b - g0);
            b += l.block_rows;
        }
    }
    cuts.sort_unstable();
    cuts.dedup();

    let mut frags = Vec::with_capacity(cuts.len() - 1);
    for w in cuts.windows(2) {
        let (vlo, vhi) = (w[0], w[1]);
        let operands = layouts
            .iter()
            .zip(views.iter())
            .map(|(l, v)| resolve(l, v, vlo, vhi))
            .collect();
        frags.push(Frag {
            view_rows: (vlo, vhi),
            operands,
        });
    }
    frags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BaseId, DType};

    fn layout(id: u32, rows: u64, br: u64, p: u32) -> Layout {
        Layout::new(BaseId(id), vec![rows], br, p, DType::F32)
    }

    /// The paper's Fig. 3/4 three-point stencil: M (6 elems, block 3,
    /// 2 ranks), N likewise; A = M[2:6], B = M[0:4], C = N[1:5].
    #[test]
    fn paper_3pt_stencil_fragments() {
        let lm = layout(0, 6, 3, 2);
        let ln = layout(1, 6, 3, 2);
        let m = ViewSpec::full(&lm);
        let n = ViewSpec::full(&ln);
        let a = m.slice(&[(2, 6)]);
        let b = m.slice(&[(0, 4)]);
        let c = n.slice(&[(1, 5)]);
        let frags = fragments(&[&ln, &lm, &lm], &[&c, &a, &b]);
        // Cut points (view-relative, len 4): from C: rows 3-1=2; from A:
        // 3-2=1; from B: 3. => 0,1,2,3,4 -> 4 fragments.
        assert_eq!(frags.len(), 4);
        let owners: Vec<Vec<u32>> = frags
            .iter()
            .map(|f| f.operands.iter().map(|o| o.owner.0).collect())
            .collect();
        // frag 0 (view row 0): C[1] on p0, A=M[2] on p0, B=M[0] on p0.
        assert_eq!(owners[0], vec![0, 0, 0]);
        // frag 1 (view row 1): C[2] p0, A=M[3] p1, B=M[1] p0.
        assert_eq!(owners[1], vec![0, 1, 0]);
        // frag 2 (view row 2): C[3] p1, A=M[4] p1, B=M[2] p0.
        assert_eq!(owners[2], vec![1, 1, 0]);
        // frag 3 (view row 3): C[4] p1, A=M[5] p1, B=M[3] p1.
        assert_eq!(owners[3], vec![1, 1, 1]);
    }

    #[test]
    fn aligned_views_one_fragment_per_block() {
        let l0 = layout(0, 30, 10, 3);
        let l1 = layout(1, 30, 10, 3);
        let v0 = ViewSpec::full(&l0);
        let v1 = ViewSpec::full(&l1);
        let frags = fragments(&[&l0, &l1], &[&v0, &v1]);
        assert_eq!(frags.len(), 3);
        for f in &frags {
            // Aligned: both operands in the same-numbered block, same rank.
            assert_eq!(f.operands[0].block, f.operands[1].block);
            assert_eq!(f.operands[0].owner, f.operands[1].owner);
            assert_eq!(f.nrows(), 10);
        }
    }

    #[test]
    fn fragments_tile_view_exactly() {
        let l0 = layout(0, 101, 7, 4);
        let l1 = layout(1, 120, 11, 4);
        let v0 = ViewSpec::full(&l0).slice(&[(3, 98)]);
        let v1 = ViewSpec::full(&l1).slice(&[(20, 115)]);
        let frags = fragments(&[&l0, &l1], &[&v0, &v1]);
        assert_eq!(frags[0].view_rows.0, 0);
        assert_eq!(frags.last().unwrap().view_rows.1, 95);
        for w in frags.windows(2) {
            assert_eq!(w[0].view_rows.1, w[1].view_rows.0);
        }
        // No fragment crosses a block boundary in either operand.
        for f in &frags {
            for (op, l) in f.operands.iter().zip([&l0, &l1]) {
                assert_eq!(l.block_of_row(op.global_rows.0), op.block);
                assert_eq!(l.block_of_row(op.global_rows.1 - 1), op.block);
            }
        }
    }

    #[test]
    fn intra_block_intervals_within_block() {
        let l = layout(0, 64, 8, 2);
        let v = ViewSpec::full(&l).slice(&[(5, 60)]);
        for f in fragments(&[&l], &[&v]) {
            let op = &f.operands[0];
            let blk_elems = l.block_nrows(op.block) * l.row_elems();
            assert!(op.intra_block.0 < op.intra_block.1);
            assert!(op.intra_block.1 <= blk_elems);
        }
    }

    #[test]
    fn intervals_2d_conservative() {
        let l = Layout::new(BaseId(0), vec![16, 10], 4, 2, DType::F32);
        let v = ViewSpec::full(&l).slice(&[(2, 14), (3, 8)]);
        let frags = fragments(&[&l], &[&v]);
        for f in &frags {
            let op = &f.operands[0];
            // Interval covers at least the rectangle's element count.
            let rect = f.nrows() * 5;
            assert!(op.intra_block.1 - op.intra_block.0 >= rect);
        }
    }

    #[test]
    fn empty_view_no_fragments() {
        let l = layout(0, 10, 5, 2);
        let v = ViewSpec::full(&l).slice(&[(2, 2)]);
        assert!(fragments(&[&l], &[&v]).is_empty());
    }
}
