//! Differential run analysis: `distnumpy diff <base.json> <new.json>`.
//!
//! The perf gate ([`crate::metrics::compare`]) says *that* a run
//! regressed; this module says *where* and *why*. Two run reports are
//! aligned epoch-by-epoch on their ledgers
//! ([`crate::metrics::ledger`]) — epoch indices are admission-log
//! positions, comparable across runs of the same program because the
//! splice renumbering is deterministic — and the makespan delta is
//! attributed into:
//!
//! * **per-epoch deltas** — each row's makespan-advance and per-cause
//!   wait movement, ranked by magnitude. Because each side's rows
//!   partition its makespan exactly (`Σ advance + residual ==
//!   makespan`), the deltas partition the makespan *delta* exactly:
//!   the reported `coverage` is 1.0 up to float rounding whenever both
//!   ledgers are intact, and materially below 1.0 only when a report
//!   was truncated or hand-edited — which is itself a finding.
//! * **a cause-shift table** — total wait per [`WaitCause`] on each
//!   side, plus the p50/p90/p99 of the per-cause histograms when the
//!   reports carry a `dist` section (n=0 quantiles are null).
//! * **scalar deltas** — every shared numeric metric ranked by
//!   relative movement, reusing the comparator's walk. This is also
//!   the fallback when either report predates the ledger (old
//!   `BENCH_*.json` artifacts): the diff degrades to a ranked scalar
//!   explanation instead of failing.
//!
//! With `--trace` timelines ([`crate::trace::export::perfetto`]) the
//! diff goes op-by-op: slices are re-aligned by *(rank, kind, sequence
//! index)* — never by op id, which batch mode recycles per epoch — and
//! the top divergent ops are named with their source provenance
//! (`args.desc`, from [`crate::ufunc::OpNode::describe`]). Both
//! timelines are also re-walked with [`critical::critical_path`] so the
//! report shows how the critical-path composition drifted.
//!
//! Exit discipline (the CLI): a large delta is a *successful* analysis
//! — only malformed or unalignable inputs are errors.

use crate::metrics::compare;
use crate::metrics::ledger::{Ledger, LedgerRow};
use crate::trace::critical::{self, CriticalPath};
use crate::trace::{OpKind, TraceCfg, TraceSink, WaitCause};
use crate::types::{OpId, Rank, VTime};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Microseconds per virtual second (the trace-event time unit).
const US: f64 = 1e6;

/// One epoch's movement between the two runs.
#[derive(Clone, Debug)]
pub struct EpochDelta {
    /// Admission-log index (the alignment key).
    pub epoch: usize,
    /// Makespan-advance movement: this epoch's share of the makespan
    /// delta (s). Signed; the nonzero deltas plus the residual delta
    /// sum to the makespan delta exactly.
    pub d_advance: VTime,
    /// Per-cause wait movement, indexed by [`WaitCause::index`].
    pub d_wait: [VTime; WaitCause::N],
    pub d_msgs: i64,
    pub d_bytes: i64,
    pub d_ops: i64,
}

impl EpochDelta {
    pub fn d_wait_total(&self) -> VTime {
        self.d_wait.iter().sum()
    }

    fn is_nonzero(&self) -> bool {
        self.d_advance != 0.0
            || self.d_wait.iter().any(|&w| w != 0.0)
            || self.d_msgs != 0
            || self.d_bytes != 0
            || self.d_ops != 0
    }

    /// Ranking magnitude: the larger of the advance and wait movement.
    fn weight(&self) -> f64 {
        self.d_advance.abs().max(self.d_wait_total().abs())
    }
}

/// Total wait per cause on each side, with histogram quantiles when the
/// reports carry them.
#[derive(Clone, Debug)]
pub struct CauseShift {
    pub cause: &'static str,
    pub base: VTime,
    pub new: VTime,
    /// (base, new) per quantile, ordered p50/p90/p99; `None` when the
    /// side's report has no histogram for the cause (or n=0 → null).
    pub quantiles: [(Option<f64>, Option<f64>); 3],
}

impl CauseShift {
    pub fn delta(&self) -> VTime {
        self.new - self.base
    }
}

/// One aligned op pair whose duration diverged.
#[derive(Clone, Debug)]
pub struct OpDelta {
    pub rank: u32,
    pub kind: OpKind,
    /// Sequence index within the (rank, kind) stream — the alignment
    /// key (op ids recycle across batch epochs and cannot be compared).
    pub seq: usize,
    pub base_dur: VTime,
    pub new_dur: VTime,
    /// Source provenance (`args.desc`), preferring the new side's.
    pub desc: String,
}

impl OpDelta {
    pub fn delta(&self) -> VTime {
        self.new_dur - self.base_dur
    }
}

/// Op-level alignment of two `--trace` timelines.
#[derive(Clone, Debug)]
pub struct TraceDiff {
    /// Op slices paired by (rank, kind, sequence index).
    pub matched: usize,
    /// Base-side op slices with no partner (stream got shorter).
    pub unmatched_base: usize,
    /// New-side op slices with no partner (stream got longer).
    pub unmatched_new: usize,
    /// Most-divergent pairs, largest |duration delta| first.
    pub top_ops: Vec<OpDelta>,
    pub base_cp: CriticalPath,
    pub new_cp: CriticalPath,
}

/// The full differential report.
#[derive(Clone, Debug)]
pub struct DiffReport {
    /// `NaN` when a side's report carries no numeric `makespan`.
    pub base_makespan: VTime,
    pub new_makespan: VTime,
    /// Whether both sides carried a ledger (epoch attribution ran).
    pub aligned: bool,
    /// Diverging epochs, ranked by movement magnitude. Empty on a
    /// self-diff.
    pub epochs: Vec<EpochDelta>,
    /// `Σ epochs.d_advance` — the makespan delta attributed to named
    /// epochs.
    pub attributed: VTime,
    /// Residual movement (trailing joins / final overhead).
    pub d_residual: VTime,
    /// One row per [`WaitCause`].
    pub causes: Vec<CauseShift>,
    /// Shared numeric metrics ranked by |relative change| (movement
    /// only), capped — the whole story when `aligned` is false.
    pub scalars: Vec<compare::Row>,
    /// Present when `--trace` timelines were supplied.
    pub trace: Option<TraceDiff>,
}

impl DiffReport {
    pub fn d_makespan(&self) -> VTime {
        self.new_makespan - self.base_makespan
    }

    /// Share of the makespan delta the epoch attribution explains
    /// (named epochs + residual). 1.0 up to float rounding when both
    /// ledgers are intact; 1.0 by convention on a zero-delta self-diff;
    /// 0.0 when unaligned.
    pub fn coverage(&self) -> f64 {
        if !self.aligned {
            return 0.0;
        }
        let d = self.d_makespan();
        if !d.is_finite() || d.abs() < 1e-12 {
            return 1.0;
        }
        (self.attributed + self.d_residual) / d
    }

    /// Total wait (all causes) on each side, from the cause table.
    pub fn wait_totals(&self) -> (VTime, VTime) {
        let b = self.causes.iter().map(|c| c.base).sum();
        let n = self.causes.iter().map(|c| c.new).sum();
        (b, n)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("base_makespan", self.base_makespan.into());
        o.push("new_makespan", self.new_makespan.into());
        o.push("d_makespan", self.d_makespan().into());
        o.push("aligned", self.aligned.into());
        o.push("coverage", self.coverage().into());
        o.push("attributed", self.attributed.into());
        o.push("d_residual", self.d_residual.into());
        let (bw, nw) = self.wait_totals();
        o.push("base_wait", bw.into());
        o.push("new_wait", nw.into());
        let mut eps = Vec::new();
        for e in self.epochs.iter().take(50) {
            let mut j = Json::obj();
            j.push("epoch", e.epoch.into());
            j.push("d_advance", e.d_advance.into());
            j.push("d_wait_total", e.d_wait_total().into());
            let mut w = Json::obj();
            for (i, label) in WaitCause::LABELS.iter().enumerate() {
                if e.d_wait[i] != 0.0 {
                    w.push(label, e.d_wait[i].into());
                }
            }
            j.push("d_wait", w);
            j.push("d_msgs", Json::Int(e.d_msgs));
            j.push("d_bytes", Json::Int(e.d_bytes));
            j.push("d_ops", Json::Int(e.d_ops));
            eps.push(j);
        }
        o.push("epochs", Json::Arr(eps));
        o.push("epochs_diverging", self.epochs.len().into());
        let mut causes = Vec::new();
        for c in &self.causes {
            let mut j = Json::obj();
            j.push("cause", c.cause.into());
            j.push("base", c.base.into());
            j.push("new", c.new.into());
            j.push("delta", c.delta().into());
            for (qi, q) in ["p50", "p90", "p99"].iter().enumerate() {
                let (b, n) = c.quantiles[qi];
                let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
                let mut p = Json::obj();
                p.push("base", opt(b));
                p.push("new", opt(n));
                j.push(q, p);
            }
            causes.push(j);
        }
        o.push("causes", Json::Arr(causes));
        let mut sc = Vec::new();
        for r in &self.scalars {
            let mut j = Json::obj();
            j.push("metric", r.path.as_str().into());
            j.push("base", r.base.into());
            j.push("new", r.new.into());
            j.push("rel", r.rel.into());
            sc.push(j);
        }
        o.push("scalars", Json::Arr(sc));
        if let Some(t) = &self.trace {
            let mut j = Json::obj();
            j.push("matched", t.matched.into());
            j.push("unmatched_base", t.unmatched_base.into());
            j.push("unmatched_new", t.unmatched_new.into());
            let mut tops = Vec::new();
            for op in &t.top_ops {
                let mut e = Json::obj();
                e.push("rank", (op.rank as u64).into());
                e.push("kind", op.kind.label().into());
                e.push("seq", op.seq.into());
                e.push("base_dur", op.base_dur.into());
                e.push("new_dur", op.new_dur.into());
                e.push("delta", op.delta().into());
                e.push("desc", op.desc.as_str().into());
                tops.push(e);
            }
            j.push("top_ops", Json::Arr(tops));
            j.push("base_critical_path", t.base_cp.to_json());
            j.push("new_critical_path", t.new_cp.to_json());
            o.push("trace", j);
        }
        o
    }

    /// Human-readable report, regressions-first like the gate's.
    pub fn render_text(&self) -> String {
        let mut s = String::from("differential run analysis\n");
        let d = self.d_makespan();
        if self.base_makespan.is_finite() && self.new_makespan.is_finite() {
            let pct = if self.base_makespan.abs() > 1e-12 {
                100.0 * d / self.base_makespan
            } else {
                0.0
            };
            s.push_str(&format!(
                "  makespan {:.6} -> {:.6}  ({:+.6}, {:+.1}%)\n",
                self.base_makespan, self.new_makespan, d, pct
            ));
        }
        let (bw, nw) = self.wait_totals();
        s.push_str(&format!(
            "  wait     {:.6} -> {:.6}  ({:+.6})\n",
            bw,
            nw,
            nw - bw
        ));
        if self.aligned {
            s.push_str(&format!(
                "epoch attribution ({} diverging epoch(s), coverage {:.1}% of the \
                 makespan delta):\n",
                self.epochs.len(),
                100.0 * self.coverage()
            ));
            for e in self.epochs.iter().take(10) {
                let mut detail = String::new();
                for (i, label) in WaitCause::LABELS.iter().enumerate() {
                    if e.d_wait[i] != 0.0 {
                        detail.push_str(&format!("  {label} {:+.6}", e.d_wait[i]));
                    }
                }
                s.push_str(&format!(
                    "  epoch {:>5}  advance {:+.6}  wait {:+.6}{}\n",
                    e.epoch,
                    e.d_advance,
                    e.d_wait_total(),
                    detail
                ));
            }
            if self.epochs.len() > 10 {
                s.push_str(&format!("  ... {} more\n", self.epochs.len() - 10));
            }
            if self.d_residual != 0.0 {
                s.push_str(&format!("  residual     {:+.6}\n", self.d_residual));
            }
        } else {
            s.push_str(
                "no per-epoch ledger on both sides — scalar attribution only\n",
            );
        }
        let moved: Vec<&CauseShift> =
            self.causes.iter().filter(|c| c.delta() != 0.0).collect();
        if !moved.is_empty() {
            s.push_str("cause shift:\n");
            let q = |v: Option<f64>| match v {
                Some(x) => format!("{x:.2e}"),
                None => "null".into(),
            };
            for c in moved {
                s.push_str(&format!(
                    "  {:<11} {:>12.6} -> {:<12.6} ({:+.6})  p50 {}->{}  p90 {}->{}  p99 {}->{}\n",
                    c.cause,
                    c.base,
                    c.new,
                    c.delta(),
                    q(c.quantiles[0].0),
                    q(c.quantiles[0].1),
                    q(c.quantiles[1].0),
                    q(c.quantiles[1].1),
                    q(c.quantiles[2].0),
                    q(c.quantiles[2].1),
                ));
            }
        }
        if !self.scalars.is_empty() {
            s.push_str(&format!(
                "scalar deltas (top {} by |relative change|):\n",
                self.scalars.len()
            ));
            for r in &self.scalars {
                s.push_str(&format!(
                    "  {:<40} {:>13.6e} -> {:<13.6e} ({:+.1}%)\n",
                    r.path,
                    r.base,
                    r.new,
                    r.rel * 100.0
                ));
            }
        }
        if let Some(t) = &self.trace {
            s.push_str(&format!(
                "trace alignment: {} op pair(s), {} base / {} new unmatched\n",
                t.matched, t.unmatched_base, t.unmatched_new
            ));
            if !t.top_ops.is_empty() {
                s.push_str("top divergent ops:\n");
                for op in &t.top_ops {
                    s.push_str(&format!(
                        "  p{} {:<7} [{}]  {:.6} -> {:.6}  ({:+.6})  {}\n",
                        op.rank,
                        op.kind.label(),
                        op.seq,
                        op.base_dur,
                        op.new_dur,
                        op.delta(),
                        op.desc
                    ));
                }
            }
            let pct = |x: VTime, cp: &CriticalPath| {
                if cp.makespan > 0.0 {
                    100.0 * x / cp.makespan
                } else {
                    0.0
                }
            };
            s.push_str(&format!(
                "critical path drift (base -> new, % of makespan):\n  \
                 compute {:.1} -> {:.1}   comm {:.1} -> {:.1}   \
                 wait {:.1} -> {:.1}   overhead {:.1} -> {:.1}\n",
                pct(t.base_cp.compute, &t.base_cp),
                pct(t.new_cp.compute, &t.new_cp),
                pct(t.base_cp.comm, &t.base_cp),
                pct(t.new_cp.comm, &t.new_cp),
                pct(t.base_cp.wait, &t.base_cp),
                pct(t.new_cp.wait, &t.new_cp),
                pct(t.base_cp.overhead, &t.base_cp),
                pct(t.new_cp.overhead, &t.new_cp),
            ));
        }
        s
    }
}

/// A `dist.wait.<label>.<quantile>` lookup; `None` when the report has
/// no histogram for the cause or the quantile rendered null (n=0).
fn quantile(report: &Json, label: &str, q: &str) -> Option<f64> {
    report
        .get("dist")?
        .get("wait")?
        .get(label)?
        .get(q)
        .and_then(Json::as_f64)
}

/// A `dist.wait.<label>.sum` lookup, for the unaligned cause table.
fn dist_sum(report: &Json, label: &str) -> VTime {
    report
        .get("dist")
        .and_then(|d| d.get("wait"))
        .and_then(|w| w.get(label))
        .and_then(|h| h.get("sum"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0)
}

/// Align two parsed run reports and attribute their delta. `Err` only
/// on malformed inputs (a broken `ledger` section) or unalignable ones
/// (no ledgers *and* no shared numeric metrics).
pub fn diff_runs(base: &Json, new: &Json) -> Result<DiffReport, String> {
    let makespan =
        |j: &Json| j.get("makespan").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let ledger_of = |j: &Json, side: &str| match Ledger::parse_section(j) {
        None => Ok(None),
        Some(Ok(v)) => Ok(Some(v)),
        Some(Err(e)) => Err(format!("{side} report: {e}")),
    };
    let bl = ledger_of(base, "base")?;
    let nl = ledger_of(new, "new")?;

    // Scalar walk (shared with the gate): gated rows + informational
    // movement, re-ranked here by |relative change|.
    let cmp = compare::compare(base, new, compare::DEFAULT_THRESHOLD);
    let had_shared = !cmp.rows.is_empty() || !cmp.ungated.is_empty();
    let mut scalars: Vec<compare::Row> = cmp
        .rows
        .into_iter()
        .chain(cmp.ungated)
        .filter(|r| r.base != r.new)
        .collect();
    scalars.sort_by(|a, b| {
        b.rel
            .abs()
            .total_cmp(&a.rel.abs())
            .then_with(|| a.path.cmp(&b.path))
    });
    scalars.truncate(20);

    let aligned = bl.is_some() && nl.is_some();
    if !aligned && !had_shared {
        return Err(
            "cannot align: no ledger sections and no shared numeric metrics \
             between the reports"
                .into(),
        );
    }

    let mut epochs = Vec::new();
    let mut attributed = 0.0;
    let mut d_residual = 0.0;
    let mut base_cause = [0.0; WaitCause::N];
    let mut new_cause = [0.0; WaitCause::N];
    if let (Some((brows, bres)), Some((nrows, nres))) = (&bl, &nl) {
        d_residual = nres - bres;
        let pad = LedgerRow::default();
        for i in 0..brows.len().max(nrows.len()) {
            let b = brows.get(i).unwrap_or(&pad);
            let n = nrows.get(i).unwrap_or(&pad);
            let mut d_wait = [0.0; WaitCause::N];
            for c in 0..WaitCause::N {
                d_wait[c] = n.wait[c] - b.wait[c];
                base_cause[c] += b.wait[c];
                new_cause[c] += n.wait[c];
            }
            let e = EpochDelta {
                epoch: i,
                d_advance: n.advance - b.advance,
                d_wait,
                d_msgs: n.msgs as i64 - b.msgs as i64,
                d_bytes: n.bytes as i64 - b.bytes as i64,
                d_ops: n.ops as i64 - b.ops as i64,
            };
            attributed += e.d_advance;
            if e.is_nonzero() {
                epochs.push(e);
            }
        }
        epochs.sort_by(|a, b| {
            b.weight()
                .total_cmp(&a.weight())
                .then_with(|| a.epoch.cmp(&b.epoch))
        });
    } else {
        // No ledger alignment: fill the cause table from the histogram
        // sums when the reports carry a `dist` section.
        for (c, label) in WaitCause::LABELS.iter().enumerate() {
            base_cause[c] = dist_sum(base, label);
            new_cause[c] = dist_sum(new, label);
        }
    }

    let causes = WaitCause::LABELS
        .iter()
        .enumerate()
        .map(|(c, label)| CauseShift {
            cause: label,
            base: base_cause[c],
            new: new_cause[c],
            quantiles: [
                (quantile(base, label, "p50"), quantile(new, label, "p50")),
                (quantile(base, label, "p90"), quantile(new, label, "p90")),
                (quantile(base, label, "p99"), quantile(new, label, "p99")),
            ],
        })
        .collect();

    Ok(DiffReport {
        base_makespan: makespan(base),
        new_makespan: makespan(new),
        aligned,
        epochs,
        attributed,
        d_residual,
        causes,
        scalars,
        trace: None,
    })
}

/// One op slice pulled from a Perfetto timeline.
struct OpSlice {
    rank: u32,
    kind: OpKind,
    epoch: u64,
    bytes: u64,
    t0: VTime,
    t1: VTime,
    desc: String,
}

/// A parsed `--trace` timeline: op slices (alignment substrate) plus a
/// reconstructed event sink for the critical-path walk.
struct ParsedTrace {
    ops: Vec<OpSlice>,
    sink: TraceSink,
    nprocs: usize,
    makespan: VTime,
}

fn kind_ix(k: OpKind) -> u8 {
    match k {
        OpKind::Compute => 0,
        OpKind::Send => 1,
        OpKind::Recv => 2,
    }
}

fn parse_wait_cause(name: &str) -> Option<WaitCause> {
    let rest = name.strip_prefix("wait:")?;
    if let Some(peer) = rest
        .strip_prefix("transfer(p")
        .and_then(|s| s.strip_suffix(')'))
    {
        return peer.parse::<u32>().ok().map(|p| WaitCause::Transfer {
            peer: Rank(p),
        });
    }
    match rest {
        "collective" => Some(WaitCause::Collective),
        "barrier" => Some(WaitCause::Barrier),
        "cone" => Some(WaitCause::Cone),
        "admission" => Some(WaitCause::Admission),
        "dependency" => Some(WaitCause::Dependency),
        _ => None,
    }
}

fn parse_trace(doc: &Json) -> Result<ParsedTrace, String> {
    let evs = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("not a Chrome-trace JSON (no 'traceEvents' array)")?;
    let mut ops: Vec<OpSlice> = Vec::new();
    // (rank, cause, epoch, t0, t1) wait intervals for the sink.
    let mut waits: Vec<(u32, WaitCause, u64, VTime, VTime)> = Vec::new();
    let mut hi: VTime = 0.0;
    let mut max_rank: i64 = -1;
    for ev in evs {
        if ev.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("");
        let pid = ev.get("pid").and_then(Json::as_f64).unwrap_or(-1.0);
        let (Some(ts), Some(dur)) = (
            ev.get("ts").and_then(Json::as_f64),
            ev.get("dur").and_then(Json::as_f64),
        ) else {
            continue;
        };
        if pid < 0.0 || !ts.is_finite() || !dur.is_finite() {
            continue;
        }
        let (t0, t1) = (ts / US, (ts + dur) / US);
        let rank = pid as u32;
        let arg = |key: &str| ev.get("args").and_then(|a| a.get(key)).cloned();
        match cat {
            "compute" | "send" | "recv" => {
                let kind = match cat {
                    "compute" => OpKind::Compute,
                    "send" => OpKind::Send,
                    _ => OpKind::Recv,
                };
                ops.push(OpSlice {
                    rank,
                    kind,
                    epoch: arg("epoch").and_then(|e| e.as_f64()).unwrap_or(0.0) as u64,
                    bytes: arg("bytes").and_then(|b| b.as_f64()).unwrap_or(0.0) as u64,
                    t0,
                    t1,
                    desc: arg("desc")
                        .and_then(|d| d.as_str().map(str::to_string))
                        .unwrap_or_default(),
                });
            }
            "wait" => {
                let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
                let Some(cause) = parse_wait_cause(name) else {
                    continue;
                };
                let epoch =
                    arg("epoch").and_then(|e| e.as_f64()).unwrap_or(0.0) as u64;
                waits.push((rank, cause, epoch, t0, t1));
            }
            _ => continue,
        }
        max_rank = max_rank.max(rank as i64);
        hi = hi.max(t1);
    }
    // Sequence order within each (rank, kind) stream is start-time
    // order — deterministic for the simulator's per-rank clocks.
    ops.sort_by(|a, b| {
        (a.rank, kind_ix(a.kind))
            .cmp(&(b.rank, kind_ix(b.kind)))
            .then_with(|| a.t0.total_cmp(&b.t0))
            .then_with(|| a.t1.total_cmp(&b.t1))
    });
    let mut sink = TraceSink::new(TraceCfg {
        enabled: true,
        capacity: (2 * ops.len() + waits.len()).max(1),
    });
    for (i, o) in ops.iter().enumerate() {
        // Fresh dense ids: the walk pairs start/retire per id, and the
        // original ids are not unique across batch epochs.
        let id = OpId(i as u32);
        sink.op_start(id, Rank(o.rank), o.kind, o.epoch, o.t0);
        sink.op_retire(id, Rank(o.rank), o.kind, o.bytes, o.epoch, o.t1, o.desc.clone());
    }
    for (rank, cause, epoch, t0, t1) in waits {
        sink.wait(Rank(rank), cause, epoch, t0, t1);
    }
    Ok(ParsedTrace {
        ops,
        sink,
        nprocs: (max_rank + 1).max(1) as usize,
        makespan: hi,
    })
}

/// Align two Perfetto timelines op-by-op and re-walk both critical
/// paths. `Err` only when a document is not a trace.
pub fn diff_traces(base: &Json, new: &Json) -> Result<TraceDiff, String> {
    let b = parse_trace(base).map_err(|e| format!("base trace: {e}"))?;
    let n = parse_trace(new).map_err(|e| format!("new trace: {e}"))?;

    // Group op indices per (rank, kind); `ops` is already stream-sorted
    // so positions within a group are the sequence indices.
    let group = |ops: &[OpSlice]| {
        let mut g: BTreeMap<(u32, u8), Vec<usize>> = BTreeMap::new();
        for (i, o) in ops.iter().enumerate() {
            g.entry((o.rank, kind_ix(o.kind))).or_default().push(i);
        }
        g
    };
    let bg = group(&b.ops);
    let ng = group(&n.ops);

    let mut deltas: Vec<OpDelta> = Vec::new();
    let mut matched = 0;
    let mut unmatched_base = 0;
    let mut unmatched_new = 0;
    let keys: std::collections::BTreeSet<(u32, u8)> =
        bg.keys().chain(ng.keys()).copied().collect();
    for key in keys {
        let empty = Vec::new();
        let bi = bg.get(&key).unwrap_or(&empty);
        let ni = ng.get(&key).unwrap_or(&empty);
        let paired = bi.len().min(ni.len());
        matched += paired;
        unmatched_base += bi.len() - paired;
        unmatched_new += ni.len() - paired;
        for seq in 0..paired {
            let bo = &b.ops[bi[seq]];
            let no = &n.ops[ni[seq]];
            let d = OpDelta {
                rank: key.0,
                kind: bo.kind,
                seq,
                base_dur: bo.t1 - bo.t0,
                new_dur: no.t1 - no.t0,
                desc: if no.desc.is_empty() {
                    bo.desc.clone()
                } else {
                    no.desc.clone()
                },
            };
            if d.delta() != 0.0 {
                deltas.push(d);
            }
        }
    }
    deltas.sort_by(|a, b| {
        b.delta()
            .abs()
            .total_cmp(&a.delta().abs())
            .then_with(|| (a.rank, kind_ix(a.kind), a.seq).cmp(&(b.rank, kind_ix(b.kind), b.seq)))
    });
    deltas.truncate(10);

    Ok(TraceDiff {
        matched,
        unmatched_base,
        unmatched_new,
        top_ops: deltas,
        base_cp: critical::critical_path(&b.sink, b.nprocs, b.makespan),
        new_cp: critical::critical_path(&n.sink, n.nprocs, n.makespan),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_json(makespan: f64, ledger: &Ledger) -> Json {
        let mut o = Json::obj();
        o.push("makespan", makespan.into());
        o.push("ledger", ledger.to_json(makespan));
        o
    }

    #[test]
    fn self_diff_attributes_exactly_zero() {
        let mut l = Ledger::default();
        l.record_retire(0, 1.0);
        l.record_wait(0, WaitCause::Barrier, 0.25);
        l.record_retire(1, 2.5);
        l.record_msg(1, 4096);
        let j = run_json(2.5, &l);
        let d = diff_runs(&j, &j).unwrap();
        assert!(d.aligned);
        assert!(d.epochs.is_empty(), "no diverging epochs on a self-diff");
        assert_eq!(d.attributed, 0.0);
        assert_eq!(d.d_residual, 0.0);
        assert_eq!(d.d_makespan(), 0.0);
        assert_eq!(d.coverage(), 1.0);
        assert!(d.causes.iter().all(|c| c.delta() == 0.0));
        assert!(d.scalars.is_empty(), "no scalar moved");
        let text = d.render_text();
        assert!(text.contains("coverage 100.0%"), "{text}");
        let js = d.to_json().render();
        assert!(js.contains("\"coverage\":1"), "{js}");
    }

    #[test]
    fn attributes_delta_to_named_epochs_and_causes() {
        let mut base = Ledger::default();
        base.record_retire(0, 1.0);
        base.record_retire(1, 2.0);
        let bj = run_json(2.0, &base);
        let mut new = Ledger::default();
        new.record_retire(0, 1.0);
        new.record_retire(1, 3.0);
        new.record_wait(1, WaitCause::Admission, 0.8);
        let nj = run_json(3.2, &new);

        let d = diff_runs(&bj, &nj).unwrap();
        assert!(d.aligned);
        assert!((d.d_makespan() - 1.2).abs() < 1e-12);
        assert!((d.attributed - 1.0).abs() < 1e-12, "epoch 1 grew by 1.0");
        assert!((d.d_residual - 0.2).abs() < 1e-12);
        // Exact partition: named epochs + residual cover the delta.
        assert!((d.coverage() - 1.0).abs() < 1e-9, "coverage {}", d.coverage());
        assert_eq!(d.epochs.len(), 1, "only epoch 1 diverged");
        assert_eq!(d.epochs[0].epoch, 1);
        assert!((d.epochs[0].d_advance - 1.0).abs() < 1e-12);
        let adm = WaitCause::Admission.index();
        assert!((d.epochs[0].d_wait[adm] - 0.8).abs() < 1e-12);
        let shift = d
            .causes
            .iter()
            .find(|c| c.cause == "admission")
            .unwrap();
        assert!((shift.delta() - 0.8).abs() < 1e-12, "wait moved into admission");
        let text = d.render_text();
        assert!(text.contains("epoch     1"), "{text}");
        assert!(text.contains("admission"), "{text}");
        let js = d.to_json().render();
        assert!(js.contains("\"aligned\":true"), "{js}");
        assert!(js.contains("\"epochs_diverging\":1"), "{js}");
    }

    #[test]
    fn scalar_fallback_without_ledgers() {
        let base = Json::parse(r#"{"makespan":10.0,"wait_pct":20.0}"#).unwrap();
        let new = Json::parse(r#"{"makespan":12.0,"wait_pct":30.0}"#).unwrap();
        let d = diff_runs(&base, &new).unwrap();
        assert!(!d.aligned);
        assert_eq!(d.coverage(), 0.0, "no epoch attribution without ledgers");
        assert!(!d.scalars.is_empty());
        // wait_pct moved 50% vs makespan's 20%: ranked first.
        assert_eq!(d.scalars[0].path, "wait_pct");
        let text = d.render_text();
        assert!(text.contains("scalar attribution only"), "{text}");
        assert!(text.contains("wait_pct"), "{text}");
    }

    #[test]
    fn unalignable_inputs_error() {
        let a = Json::parse(r#"{"note":"hello"}"#).unwrap();
        let b = Json::parse(r#"{"other":true}"#).unwrap();
        let err = diff_runs(&a, &b).unwrap_err();
        assert!(err.contains("cannot align"), "{err}");
    }

    #[test]
    fn malformed_ledger_errors() {
        let bad = Json::parse(r#"{"makespan":1.0,"ledger":{"epochs":5}}"#).unwrap();
        let ok = Json::parse(r#"{"makespan":1.0}"#).unwrap();
        let err = diff_runs(&bad, &ok).unwrap_err();
        assert!(err.contains("base report"), "{err}");
        let err = diff_runs(&ok, &bad).unwrap_err();
        assert!(err.contains("new report"), "{err}");
    }

    #[test]
    fn one_sided_ledger_degrades_to_scalars() {
        let mut l = Ledger::default();
        l.record_retire(0, 1.0);
        let with = run_json(1.0, &l);
        let without = Json::parse(r#"{"makespan":2.0}"#).unwrap();
        let d = diff_runs(&with, &without).unwrap();
        assert!(!d.aligned);
        assert!(d.epochs.is_empty());
        assert!(d.scalars.iter().any(|r| r.path == "makespan"));
    }

    fn trace_doc(slices: &[(u32, &str, &str, f64, f64, &str)]) -> Json {
        // (pid, cat, name, ts_us, dur_us, desc)
        let evs = slices
            .iter()
            .map(|&(pid, cat, name, ts, dur, desc)| {
                let mut o = Json::obj();
                o.push("name", name.into());
                o.push("cat", cat.into());
                o.push("ph", "X".into());
                o.push("pid", (pid as u64).into());
                o.push("tid", 0u64.into());
                o.push("ts", ts.into());
                o.push("dur", dur.into());
                let mut args = Json::obj();
                if !desc.is_empty() {
                    args.push("desc", desc.into());
                }
                args.push("epoch", 0u64.into());
                o.push("args", args);
                o
            })
            .collect();
        let mut root = Json::obj();
        root.push("traceEvents", Json::Arr(evs));
        root
    }

    #[test]
    fn trace_diff_aligns_by_rank_kind_seq_and_names_ops() {
        let base = trace_doc(&[
            (0, "compute", "compute #7", 0.0, 1e6, "jacobi: stencil"),
            (0, "compute", "compute #9", 1e6, 1e6, "jacobi: reduce"),
        ]);
        // Same program, second compute 3× slower, plus an extra slice
        // on a second rank (stream got longer there).
        let new = trace_doc(&[
            (0, "compute", "compute #3", 0.0, 1e6, "jacobi: stencil"),
            (0, "compute", "compute #5", 1e6, 3e6, "jacobi: reduce"),
            (1, "compute", "compute #6", 0.0, 1e6, "jacobi: stencil"),
        ]);
        let t = diff_traces(&base, &new).unwrap();
        assert_eq!(t.matched, 2, "ids differ but (rank,kind,seq) aligns");
        assert_eq!(t.unmatched_base, 0);
        assert_eq!(t.unmatched_new, 1);
        assert_eq!(t.top_ops.len(), 1, "only the reduce diverged");
        let top = &t.top_ops[0];
        assert_eq!((top.rank, top.seq), (0, 1));
        assert!((top.delta() - 2.0).abs() < 1e-9);
        assert_eq!(top.desc, "jacobi: reduce", "provenance carried");
        assert!((t.base_cp.makespan - 2.0).abs() < 1e-9);
        assert!((t.new_cp.makespan - 4.0).abs() < 1e-9);
        assert!(t.new_cp.compute > t.base_cp.compute);
    }

    #[test]
    fn trace_diff_parses_wait_slices_into_the_walk() {
        let base = trace_doc(&[(0, "compute", "compute #1", 0.0, 1e6, "")]);
        let new = trace_doc(&[
            (0, "compute", "compute #1", 0.0, 1e6, ""),
            (0, "wait", "wait:transfer(p1)", 1e6, 1e6, ""),
            (1, "compute", "compute #2", 0.0, 1.5e6, ""),
        ]);
        let t = diff_traces(&base, &new).unwrap();
        // The new timeline ends in a transfer wait: the walk jumps to
        // the peer and classifies unhidden communication.
        assert!(t.new_cp.comm > 0.0, "{:?}", t.new_cp);
        assert_eq!(t.base_cp.comm, 0.0);
    }

    #[test]
    fn non_trace_document_errors() {
        let not = Json::parse(r#"{"makespan":1.0}"#).unwrap();
        let err = diff_traces(&not, &not).unwrap_err();
        assert!(err.contains("traceEvents"), "{err}");
    }
}
