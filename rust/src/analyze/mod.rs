//! Static analysis over recorded op graphs — the pass between
//! recording (L2) and admission (L3).
//!
//! Three instruments, one module ([ISSUE 7]):
//!
//! * [`hazards`] — the **hazard oracle**: recompute the exact conflict
//!   edges from every op's `Access` list and prove the active
//!   dependency system orders all of them (a missed edge is a data
//!   race, a hard error); count spurious order as lost overlap.
//! * [`stalls`] — the **static stall predictor**: an abstract replay
//!   of the naive evaluator's becoming-ready order that predicts its
//!   `Deadlock`/`blocked_recvs` outcomes (and names the wait cycle)
//!   at schedule time.
//! * [`lint`] — the **schedule linter**: advisory diagnostics for
//!   overlap left on the table (barrier-in-loop, hoistable sends,
//!   stage leaks, window-starved epochs).
//!
//! Plus one *differential* instrument over finished runs rather than
//! recorded streams: [`diff`] — the regression explainer behind
//! `distnumpy diff`, which aligns two run reports epoch-by-epoch on
//! their ledgers ([`crate::metrics::ledger`]) and attributes a
//! makespan/wait delta to named epochs, causes, and (with `--trace`
//! timelines) individual ops. The failing perf gate names it.
//!
//! Wired three ways: the `distnumpy analyze` CLI subcommand sweeps the
//! shipped apps (streams captured via `ExecState::capture` +
//! `harness::captured_streams`), `SchedCfg::verify_deps` re-runs the
//! oracle on every drained wave inside the scheduler session, and the
//! oracle/lint counters surface in the run JSON (`RunReport::{races,
//! excess_edges, predicted_stalls, lints}`).

pub mod diff;
pub mod hazards;
pub mod lint;
pub mod stalls;

pub use diff::{DiffReport, TraceDiff};
pub use hazards::{HazardStats, Race};
pub use lint::{Diag, Severity};
pub use stalls::StallPrediction;

use crate::apps::{AppId, AppParams};
use crate::cluster::MachineSpec;
use crate::sched::{DepsKind, Policy, SchedCfg};
use crate::util::json::Json;

/// The three policies the analyzer predicts stalls for.
pub const POLICIES: [Policy; 3] = [Policy::LatencyHiding, Policy::Blocking, Policy::Naive];

/// Short policy name for tables and JSON keys.
pub fn policy_name(p: Policy) -> &'static str {
    match p {
        Policy::LatencyHiding => "lh",
        Policy::Blocking => "blocking",
        Policy::Naive => "naive",
    }
}

/// Everything the analyzer learned about one app's recorded streams.
pub struct AppAnalysis {
    /// The analyzed app.
    pub app: AppId,
    /// Rank count the streams were recorded for.
    pub procs: u32,
    /// Scheduler runs captured (one per drained epoch/wave).
    pub streams: usize,
    /// Total ops across the streams.
    pub ops: usize,
    /// (stream × dep system) checks that found a missed edge.
    pub races: u64,
    /// First race found, for the report.
    pub race: Option<Race>,
    /// Per-dep-system precision stats, summed over streams.
    pub stats: Vec<(DepsKind, HazardStats)>,
    /// Per-policy count of streams predicted to stall.
    pub stalls: Vec<(Policy, u64)>,
    /// Example predicted wait cycle (naive), if any.
    pub cycle: Option<String>,
    /// Linter diagnostics across all streams + the admission log.
    pub lints: Vec<Diag>,
}

/// Record `app` once under latency-hiding (which completes every
/// shipped stream), capturing the exact post-aggregation op streams
/// the scheduler consumed, then run all three instruments over them.
pub fn analyze_app(app: AppId, p: u32, params: &AppParams, kinds: &[DepsKind]) -> AppAnalysis {
    let cfg = SchedCfg::new(MachineSpec::paper(), p);
    let (streams, epochs) = crate::harness::captured_streams(app, params, cfg);
    let mut out = AppAnalysis {
        app,
        procs: p,
        streams: streams.len(),
        ops: 0,
        races: 0,
        race: None,
        stats: kinds.iter().map(|&k| (k, HazardStats::default())).collect(),
        stalls: POLICIES.iter().map(|&pl| (pl, 0)).collect(),
        cycle: None,
        lints: Vec::new(),
    };
    for (_, ops) in &streams {
        out.ops += ops.len();
        for (k, acc) in out.stats.iter_mut() {
            match hazards::check(ops, *k) {
                Ok(s) => acc.absorb(&s),
                Err(r) => {
                    out.races += 1;
                    if out.race.is_none() {
                        out.race = Some(r);
                    }
                }
            }
        }
        for (pl, count) in out.stalls.iter_mut() {
            if let Some(pred) = stalls::predict(*pl, ops) {
                *count += 1;
                if *pl == Policy::Naive && out.cycle.is_none() && !pred.cycle.is_empty() {
                    out.cycle = Some(pred.cycle);
                }
            }
        }
        out.lints.extend(lint::lint_stream(ops));
    }
    out.lints.extend(lint::lint_reductions(&streams));
    out.lints.extend(lint::lint_epochs(&epochs));
    out
}

impl AppAnalysis {
    /// Predicted stalls for one policy.
    pub fn stalls_for(&self, policy: Policy) -> u64 {
        self.stalls
            .iter()
            .find(|(p, _)| *p == policy)
            .map_or(0, |&(_, c)| c)
    }

    /// Zero races *and* zero predicted latency-hiding stalls — the
    /// property `distnumpy analyze` (and the CI smoke job) asserts for
    /// every shipped app. Naive-policy predictions are reported but do
    /// not fail the check: the naive evaluator legitimately deadlocks
    /// on becoming-ready rings (Fig. 6), which is the predictor doing
    /// its job.
    pub fn clean(&self) -> bool {
        self.races == 0 && self.stalls_for(Policy::LatencyHiding) == 0
    }

    /// JSON row for `--json` output.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("app", self.app.name().into());
        o.push("procs", (self.procs as u64).into());
        o.push("streams", self.streams.into());
        o.push("ops", self.ops.into());
        o.push("races", self.races.into());
        if let Some(r) = &self.race {
            o.push("race", r.to_string().as_str().into());
        }
        let hz = self
            .stats
            .iter()
            .map(|(k, s)| {
                let mut h = Json::obj();
                h.push("deps", format!("{k:?}").to_lowercase().as_str().into());
                h.push("exact_edges", s.exact_edges.into());
                h.push("dep_edges", s.dep_edges.into());
                h.push("excess_edges", s.excess_edges.into());
                h.push("excess_edge_pct", s.excess_edge_pct().into());
                h.push("serialized_pairs", s.serialized_pairs.into());
                h
            })
            .collect();
        o.push("hazards", Json::Arr(hz));
        let mut st = Json::obj();
        for (pl, c) in &self.stalls {
            st.push(policy_name(*pl), (*c).into());
        }
        o.push("predicted_stalls", st);
        if let Some(c) = &self.cycle {
            o.push("cycle", c.as_str().into());
        }
        o.push(
            "lints",
            Json::Arr(self.lints.iter().map(Diag::to_json).collect()),
        );
        o
    }

    /// Human-readable block for the CLI table.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{} (P={}): {} streams, {} ops\n",
            self.app.name(),
            self.procs,
            self.streams,
            self.ops
        );
        for (k, st) in &self.stats {
            s.push_str(&format!(
                "  {:<10} {} dep edges vs {} exact, excess {} ({:.2}%), \
                 serialized pairs {} — {}\n",
                format!("{k:?}").to_lowercase(),
                st.dep_edges,
                st.exact_edges,
                st.excess_edges,
                st.excess_edge_pct(),
                st.serialized_pairs,
                if self.races == 0 { "sound" } else { "RACE" },
            ));
        }
        if let Some(r) = &self.race {
            s.push_str(&format!("  !! {r}\n"));
        }
        s.push_str(&format!(
            "  predicted stalls: lh {}, blocking {}, naive {}\n",
            self.stalls_for(Policy::LatencyHiding),
            self.stalls_for(Policy::Blocking),
            self.stalls_for(Policy::Naive),
        ));
        if let Some(c) = &self.cycle {
            s.push_str(&format!("    naive cycle: {c}\n"));
        }
        for d in &self.lints {
            s.push_str(&format!("  {}\n", d.pretty()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analyze_app_is_clean_on_a_shipped_stencil() {
        let a = analyze_app(
            AppId::JacobiStencil,
            4,
            &AppParams { scale: 0.1, iters: 2 },
            &[DepsKind::Heuristic, DepsKind::Dag],
        );
        assert!(a.streams > 0, "capture must surface the drained streams");
        assert!(a.ops > 0);
        assert!(a.clean(), "shipped app must analyze clean: {}", a.render());
        for (k, st) in &a.stats {
            assert!(st.exact_edges > 0, "{k:?}: stencil has real conflicts");
            assert_eq!(st.excess_edges, 0, "{k:?} adds no spurious edges");
        }
        let json = a.to_json().render();
        assert!(json.contains("\"races\": 0") || json.contains("\"races\":0"), "{json}");
        assert!(json.contains("excess_edge_pct"), "{json}");
        assert!(json.contains("predicted_stalls"), "{json}");
    }

    #[test]
    fn policy_names_are_stable() {
        assert_eq!(policy_name(Policy::LatencyHiding), "lh");
        assert_eq!(policy_name(Policy::Blocking), "blocking");
        assert_eq!(policy_name(Policy::Naive), "naive");
    }
}
