//! Static stall prediction: decide at schedule time whether a recorded
//! stream can deadlock a policy, instead of discovering it mid-run.
//!
//! The naive evaluator (the paper's Fig. 6 strawman) executes each
//! rank's ops in becoming-ready order and *blocks* on every receive;
//! a receive whose matching send sits behind another blocked receive
//! forms a wait cycle. That order is fully determined by the recorded
//! graph, so an abstract, timing-free replay over the exact conflict
//! preds ([`super::hazards::exact_direct_preds`]) predicts the
//! runtime's `Deadlock { blocked_recvs }` outcome exactly — including
//! cycles threaded *through aggregated messages*, because prediction
//! runs on the post-aggregation stream the scheduler actually sees.
//! The latency-hiding and blocking policies initiate every ready
//! communication before blocking, so for them only unpaired transfers
//! (a `TransferTable::build` stall) are statically predictable.
//!
//! [`witness_cycle`] renders the actual rank/tag wait chain; the naive
//! session reuses it at runtime so `SchedError::Deadlock` names the
//! cycle instead of only counting its blocked receives.

use std::collections::VecDeque;

use crate::sched::Policy;
use crate::types::{Rank, Tag};
use crate::ufunc::{OpNode, OpPayload};
use crate::util::fxhash::FxHashMap;

/// A predicted stall: how far the policy would get, which receives
/// park, and the wait cycle (or unpaired tag) that explains it.
#[derive(Clone, Debug)]
pub struct StallPrediction {
    /// Ops the abstract replay managed to execute.
    pub executed: u64,
    /// Ops in the stream.
    pub total: u64,
    /// Parked receives at the fixpoint: (rank, awaited tag).
    pub blocked: Vec<(Rank, Tag)>,
    /// The rendered wait chain ([`witness_cycle`]), or the unpaired
    /// transfer note.
    pub cycle: String,
}

/// Predict whether `policy` stalls on `ops`. `None` means the stream
/// is statically clean for that policy.
pub fn predict(policy: Policy, ops: &[OpNode]) -> Option<StallPrediction> {
    match policy {
        Policy::Naive => predict_naive(ops),
        Policy::LatencyHiding | Policy::Blocking => unpaired_prediction(ops),
    }
}

/// Every policy stalls loudly on a half-recorded transfer; report the
/// first unpaired tag without running anything.
fn unpaired_prediction(ops: &[OpNode]) -> Option<StallPrediction> {
    let mut sends: FxHashMap<Tag, u32> = FxHashMap::default();
    let mut recvs: FxHashMap<Tag, u32> = FxHashMap::default();
    for op in ops {
        match &op.payload {
            OpPayload::Send { tag, .. } => *sends.entry(*tag).or_insert(0) += 1,
            OpPayload::Recv { tag, .. } => *recvs.entry(*tag).or_insert(0) += 1,
            OpPayload::Compute(_) => {}
        }
    }
    let mut odd: Vec<Tag> = sends
        .iter()
        .filter(|&(t, &c)| recvs.get(t).copied().unwrap_or(0) != c)
        .map(|(&t, _)| t)
        .collect();
    odd.extend(
        recvs
            .keys()
            .filter(|t| !sends.contains_key(t))
            .copied(),
    );
    odd.sort_unstable();
    odd.dedup();
    let first = *odd.first()?;
    Some(StallPrediction {
        executed: 0,
        total: ops.len() as u64,
        blocked: Vec::new(),
        cycle: format!("unpaired transfer {first:?}: send/recv halves do not match"),
    })
}

/// Abstract replay of the naive evaluator: per-rank FIFOs fed in
/// becoming-ready (dependency) order, heads executing unless they are
/// receives whose matching send has not run. The fixpoint either
/// drains the stream (no stall) or leaves parked receives — the
/// predicted deadlock.
pub fn predict_naive(ops: &[OpNode]) -> Option<StallPrediction> {
    if ops.is_empty() {
        return None;
    }
    let preds = super::hazards::exact_direct_preds(ops);
    let n = ops.len();
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut indeg: Vec<u32> = vec![0; n];
    for (j, pj) in preds.iter().enumerate() {
        indeg[j] = pj.len() as u32;
        for &i in pj {
            succs[i as usize].push(j as u32);
        }
    }
    let mut send_of: FxHashMap<Tag, usize> = FxHashMap::default();
    for (j, op) in ops.iter().enumerate() {
        if let OpPayload::Send { tag, .. } = &op.payload {
            send_of.insert(*tag, j);
        }
    }
    let nranks = ops.iter().map(|o| o.rank.0 as usize + 1).max().unwrap_or(1);
    let mut fifo: Vec<VecDeque<usize>> = vec![VecDeque::new(); nranks];
    let mut queued = vec![false; n];
    let mut done = vec![false; n];
    let mut executed = 0u64;
    loop {
        let mut progressed = false;
        for j in 0..n {
            if !queued[j] && indeg[j] == 0 {
                queued[j] = true;
                fifo[ops[j].rank.0 as usize].push_back(j);
                progressed = true;
            }
        }
        for q in fifo.iter_mut() {
            while let Some(&j) = q.front() {
                let runnable = match &ops[j].payload {
                    OpPayload::Recv { tag, .. } => {
                        send_of.get(tag).is_some_and(|&s| done[s])
                    }
                    _ => true,
                };
                if !runnable {
                    break;
                }
                q.pop_front();
                done[j] = true;
                executed += 1;
                progressed = true;
                for &s in &succs[j] {
                    indeg[s as usize] -= 1;
                }
            }
        }
        if !progressed {
            break;
        }
    }
    if executed == n as u64 {
        return None;
    }
    let mut blocked: Vec<(Rank, Tag)> = Vec::new();
    for q in &fifo {
        if let Some(&j) = q.front() {
            if let OpPayload::Recv { tag, .. } = &ops[j].payload {
                blocked.push((ops[j].rank, *tag));
            }
        }
    }
    blocked.sort_unstable();
    let cycle = witness_cycle(ops, &blocked);
    Some(StallPrediction {
        executed,
        total: n as u64,
        blocked,
        cycle,
    })
}

/// Render the wait chain behind a set of parked receives: starting
/// from the lowest parked rank, chase each awaited tag to its sender's
/// rank and that rank's own parked receive, until the chain revisits a
/// rank (a cycle) or leaves the parked set. Pure over the recorded
/// stream, so the naive session calls it at deadlock time with its
/// live parked map and the static predictor with its fixpoint residue.
pub fn witness_cycle(ops: &[OpNode], parked: &[(Rank, Tag)]) -> String {
    if parked.is_empty() {
        return String::new();
    }
    let mut sender: FxHashMap<Tag, Rank> = FxHashMap::default();
    for op in ops {
        if let OpPayload::Send { tag, .. } = &op.payload {
            sender.insert(*tag, op.rank);
        }
    }
    let mut entries = parked.to_vec();
    entries.sort_unstable();
    let mut parked_on: FxHashMap<Rank, Tag> = FxHashMap::default();
    for &(r, t) in &entries {
        parked_on.entry(r).or_insert(t);
    }
    let (mut r, mut t) = entries[0];
    let mut seen: Vec<Rank> = Vec::new();
    let mut parts: Vec<String> = Vec::new();
    loop {
        if seen.contains(&r) {
            parts.push(format!("back to rank {} — cycle", r.0));
            break;
        }
        seen.push(r);
        match sender.get(&t) {
            None => {
                parts.push(format!(
                    "rank {} blocked on recv {t:?} with no matching send",
                    r.0
                ));
                break;
            }
            Some(&s) => {
                parts.push(format!("rank {} waits on recv {t:?} from rank {}", r.0, s.0));
                match parked_on.get(&s) {
                    Some(&nt) => {
                        r = s;
                        t = nt;
                    }
                    None => {
                        parts.push(format!("rank {} never reaches the matching send", s.0));
                        break;
                    }
                }
            }
        }
    }
    parts.join("; ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::OpId;
    use crate::ufunc::{Access, SendSrc};

    fn send(id: u32, rank: u32, peer: u32, tag: Tag) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(rank),
            group: 0,
            payload: OpPayload::Send {
                peer: Rank(peer),
                tag,
                bytes: 8,
                src: SendSrc::Stage(Tag(1_000 + id as u64)),
            },
            accesses: vec![Access::read_stage(Tag(1_000 + id as u64))],
        }
    }

    fn recv(id: u32, rank: u32, peer: u32, tag: Tag) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(rank),
            group: 0,
            payload: OpPayload::Recv {
                peer: Rank(peer),
                tag,
                bytes: 8,
            },
            accesses: vec![Access::write_stage(tag)],
        }
    }

    #[test]
    fn ordered_pair_completes() {
        let ops = vec![send(0, 0, 1, Tag(0)), recv(1, 1, 0, Tag(0))];
        assert!(predict_naive(&ops).is_none());
        assert!(predict(Policy::LatencyHiding, &ops).is_none());
    }

    #[test]
    fn ping_pong_head_recvs_deadlock_naive_only() {
        // Each rank's receive is recorded before its send: the naive
        // FIFO heads park on each other. lh/blocking post the sends
        // first and complete.
        let ops = vec![
            recv(0, 0, 1, Tag(1)),
            send(1, 0, 1, Tag(0)),
            recv(2, 1, 0, Tag(0)),
            send(3, 1, 0, Tag(1)),
        ];
        let p = predict_naive(&ops).expect("naive must be predicted to park");
        assert_eq!(p.executed, 0);
        assert_eq!(p.total, 4);
        assert_eq!(p.blocked, vec![(Rank(0), Tag(1)), (Rank(1), Tag(0))]);
        assert!(p.cycle.contains("cycle"), "{}", p.cycle);
        assert!(p.cycle.contains("rank 0"), "{}", p.cycle);
        assert!(p.cycle.contains("rank 1"), "{}", p.cycle);
        assert!(
            predict(Policy::LatencyHiding, &ops).is_none(),
            "paired stream is clean for latency-hiding"
        );
        assert!(predict(Policy::Blocking, &ops).is_none());
    }

    #[test]
    fn self_wait_cycle_is_named() {
        let ops = vec![recv(0, 0, 0, Tag(0)), send(1, 0, 0, Tag(0))];
        let p = predict_naive(&ops).expect("recv ahead of its own send parks");
        assert_eq!(p.blocked, vec![(Rank(0), Tag(0))]);
        assert!(p.cycle.contains("back to rank 0"), "{}", p.cycle);
    }

    #[test]
    fn unpaired_recv_is_predicted_for_every_policy() {
        let ops = vec![recv(0, 0, 1, Tag(5))];
        for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
            let p = predict(policy, &ops).expect("half a transfer must be flagged");
            assert!(
                p.cycle.contains("no matching send") || p.cycle.contains("unpaired"),
                "{policy:?}: {}",
                p.cycle
            );
        }
    }

    #[test]
    fn witness_names_the_missing_send() {
        let ops = vec![recv(0, 0, 1, Tag(9))];
        let w = witness_cycle(&ops, &[(Rank(0), Tag(9))]);
        assert!(w.contains("no matching send"), "{w}");
    }
}
