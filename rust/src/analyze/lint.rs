//! The schedule linter: advisory diagnostics over recorded streams and
//! the admission log. Nothing here is an error — the rules flag
//! schedules that are *legal but leave overlap on the table*, the
//! paper's actual currency: forced reductions that a deferred future
//! would pipeline, sends recorded far below their last data
//! dependency, staged writes nothing reads, and epochs the admission
//! window gated the recorder on.

use crate::flow::EpochEntry;
use crate::types::{OpId, Tag};
use crate::ufunc::{Loc, OpNode, OpPayload};
use crate::util::fxhash::FxHashMap;
use crate::util::json::Json;

/// Diagnostic severity. `Warn` marks likely lost overlap; `Info` marks
/// patterns that are often intentional (pinned futures, small runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Worth knowing; frequently benign.
    Info,
    /// Likely costs overlap.
    Warn,
}

impl Severity {
    /// Lower-case renderer shared by the JSON and pretty outputs.
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
        }
    }
}

/// One linter diagnostic.
#[derive(Clone, Debug)]
pub struct Diag {
    /// Stable rule name (kebab-case).
    pub rule: &'static str,
    /// How seriously to take it.
    pub severity: Severity,
    /// Example op the rule anchors on, when one exists.
    pub op: Option<OpId>,
    /// Epoch / recorded-run the rule anchors on, when one exists.
    pub epoch: Option<u64>,
    /// Human-readable explanation with counts.
    pub note: String,
}

impl Diag {
    /// JSON object for `distnumpy analyze --json`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("rule", self.rule.into());
        o.push("severity", self.severity.name().into());
        o.push("op", self.op.map_or(Json::Null, |id| (id.0 as u64).into()));
        o.push("epoch", self.epoch.map_or(Json::Null, Json::from));
        o.push("note", self.note.as_str().into());
        o
    }

    /// One-line human renderer.
    pub fn pretty(&self) -> String {
        let mut s = format!("[{}] {}: {}", self.severity.name(), self.rule, self.note);
        if let Some(id) = self.op {
            s.push_str(&format!(" (op {})", id.0));
        }
        if let Some(e) = self.epoch {
            s.push_str(&format!(" (epoch {e})"));
        }
        s
    }
}

/// Sends may post the moment their last predecessor retires; one
/// recorded further than this below that predecessor is "hoistable".
const HOIST_GAP: u32 = 64;

/// Per-stream rules: hoistable sends and stage leaks.
pub fn lint_stream(ops: &[OpNode]) -> Vec<Diag> {
    let mut diags = Vec::new();
    hoistable_sends(ops, &mut diags);
    stage_leaks(ops, &mut diags);
    diags
}

fn hoistable_sends(ops: &[OpNode], diags: &mut Vec<Diag>) {
    let preds = super::hazards::exact_direct_preds(ops);
    let mut count = 0u64;
    let mut worst = 0u32;
    let mut example = None;
    for (j, op) in ops.iter().enumerate() {
        if !matches!(op.payload, OpPayload::Send { .. }) {
            continue;
        }
        let gap = j as u32 - preds[j].last().copied().unwrap_or(0);
        if gap > HOIST_GAP {
            count += 1;
            if gap >= worst {
                worst = gap;
                example = Some(op.id);
            }
        }
    }
    if count > 0 {
        diags.push(Diag {
            rule: "hoistable-send",
            severity: Severity::Warn,
            op: example,
            epoch: None,
            note: format!(
                "{count} sends recorded more than {HOIST_GAP} ops below their \
                 last data dependency (worst gap {worst}); posting them at \
                 readiness would widen overlap"
            ),
        });
    }
}

fn stage_leaks(ops: &[OpNode], diags: &mut Vec<Diag>) {
    let mut writers: Vec<(Tag, OpId)> = Vec::new();
    let mut read: FxHashMap<Tag, ()> = FxHashMap::default();
    for op in ops {
        for a in &op.accesses {
            if let Loc::Stage(t) = a.loc {
                if a.write {
                    writers.push((t, op.id));
                } else {
                    read.insert(t, ());
                }
            }
        }
    }
    writers.retain(|(t, _)| !read.contains_key(t));
    writers.sort_unstable();
    writers.dedup();
    if let Some(&(t, id)) = writers.first() {
        diags.push(Diag {
            rule: "stage-leak",
            severity: Severity::Info,
            op: Some(id),
            epoch: None,
            note: format!(
                "{} staged writes never read within the stream (first: {t:?}); \
                 expected only for stages pinned by deferred futures",
                writers.len()
            ),
        });
    }
}

/// Cross-stream rule: reductions forced epoch after epoch. Three or
/// more distinct (run, group) spots containing a reduction kernel mean
/// the program forces a read every loop iteration — the barrier the
/// deferred-future API (`sum_deferred`) exists to remove.
pub fn lint_reductions(streams: &[(u64, Vec<OpNode>)]) -> Vec<Diag> {
    let mut spots = 0u64;
    let mut example = None;
    for (run, ops) in streams {
        let mut groups: Vec<u32> = ops
            .iter()
            .filter(|o| {
                matches!(&o.payload, OpPayload::Compute(t) if t.kernel.is_reduction())
            })
            .map(|o| o.group)
            .collect();
        groups.sort_unstable();
        groups.dedup();
        spots += groups.len() as u64;
        if example.is_none() && !groups.is_empty() {
            example = Some(*run);
        }
    }
    if spots >= 3 {
        vec![Diag {
            rule: "barrier-in-loop",
            severity: Severity::Info,
            op: None,
            epoch: example,
            note: format!(
                "reductions forced in {spots} recorded epochs; deferred \
                 futures (sum_deferred) would pipeline the convergence checks"
            ),
        }]
    } else {
        Vec::new()
    }
}

/// Admission-log rule: epochs whose recording start was gated on the
/// admission window (`record_start[k] > record_done[k-1]`). Batch-mode
/// entries carry NaN record times and are skipped.
pub fn lint_epochs(entries: &[EpochEntry]) -> Vec<Diag> {
    let mut count = 0u64;
    let mut total = 0.0f64;
    for w in entries.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        if a.record_done.is_nan() || b.record_start.is_nan() {
            continue;
        }
        let gap = b.record_start - a.record_done;
        if gap > 0.0 {
            count += 1;
            total += gap;
        }
    }
    if count > 0 {
        vec![Diag {
            rule: "window-starved",
            severity: Severity::Info,
            op: None,
            epoch: None,
            note: format!(
                "{count} epochs gated the recorder on the admission window \
                 for {total:.3e}s total; a larger --flow window records ahead"
            ),
        }]
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BaseId, OpId, Rank};
    use crate::ufunc::{Access, ComputeTask, Dst, Kernel, SendSrc};

    fn compute(id: u32, kernel: Kernel, group: u32, accesses: Vec<Access>) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(0),
            group,
            payload: OpPayload::Compute(ComputeTask {
                kernel,
                inputs: vec![],
                dst: Dst::Stage(Tag(90_000 + id as u64)),
                elems: 1,
            }),
            accesses,
        }
    }

    #[test]
    fn stage_leak_detected_and_rendered() {
        let t = Tag(3);
        let ops = vec![compute(0, Kernel::Copy, 0, vec![Access::write_stage(t)])];
        let diags = lint_stream(&ops);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "stage-leak");
        assert_eq!(diags[0].op, Some(OpId(0)));
        let json = diags[0].to_json().render();
        assert!(json.contains("\"rule\""), "{json}");
        assert!(json.contains("stage-leak"), "{json}");
        assert!(diags[0].pretty().contains("[info] stage-leak"));
    }

    #[test]
    fn read_stage_is_not_a_leak() {
        let t = Tag(3);
        let ops = vec![
            compute(0, Kernel::Copy, 0, vec![Access::write_stage(t)]),
            compute(1, Kernel::Copy, 0, vec![Access::read_stage(t)]),
        ];
        assert!(lint_stream(&ops).is_empty());
    }

    #[test]
    fn distant_send_is_hoistable() {
        let b = BaseId(0);
        let mut ops = vec![compute(0, Kernel::Copy, 0, vec![Access::write_block(b, 0, (0, 4))])];
        // 70 unrelated ops of padding between the producer and its send.
        for i in 1..=70u32 {
            ops.push(compute(i, Kernel::Add, 0, vec![Access::write_block(b, i as u64 + 1, (0, 4))]));
        }
        ops.push(OpNode {
            id: OpId(71),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Send {
                peer: Rank(1),
                tag: Tag(0),
                bytes: 16,
                src: SendSrc::Region(crate::ufunc::Region {
                    base: b,
                    block: 0,
                    row0: 0,
                    nrows: 1,
                    col0: 0,
                    ncols: 4,
                    row_stride: 4,
                }),
            },
            accesses: vec![Access::read_block(b, 0, (0, 4))],
        });
        let diags = lint_stream(&ops);
        let hoist: Vec<_> = diags.iter().filter(|d| d.rule == "hoistable-send").collect();
        assert_eq!(hoist.len(), 1);
        assert_eq!(hoist[0].op, Some(OpId(71)));
        assert_eq!(hoist[0].severity, Severity::Warn);
    }

    #[test]
    fn repeated_forced_reductions_flagged() {
        let streams: Vec<(u64, Vec<OpNode>)> = (0..3)
            .map(|run| {
                (
                    run,
                    vec![compute(0, Kernel::PartialSum, 0, vec![])],
                )
            })
            .collect();
        let diags = lint_reductions(&streams);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "barrier-in-loop");
        assert!(lint_reductions(&streams[..2]).is_empty(), "2 spots stay quiet");
    }

    #[test]
    fn window_starved_epochs_read_from_the_log() {
        let e = |start: f64, done: f64| EpochEntry {
            record_start: start,
            record_done: done,
            retired: f64::NAN,
            n_ops: 4,
            in_flight_at_admit: 1,
            latency: f64::NAN,
        };
        // Epoch 1 starts 0.5s after epoch 0 finished recording.
        let entries = [e(0.0, 1.0), e(1.5, 2.0)];
        let diags = lint_epochs(&entries);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "window-starved");
        // Batch entries (NaN record times) never fire.
        let batch = [e(f64::NAN, f64::NAN), e(f64::NAN, f64::NAN)];
        assert!(lint_epochs(&batch).is_empty());
        // Back-to-back recording does not fire.
        let tight = [e(0.0, 1.0), e(1.0, 2.0)];
        assert!(lint_epochs(&tight).is_empty());
    }
}
