//! The hazard oracle: recompute the *exact* conflict-edge set of a
//! recorded op stream from first principles ([`Access::conflicts`]) and
//! verify that a dependency system's recorded edges imply every one of
//! them — the soundness property the paper's §5.7.2 heuristic claims
//! ("an optimization, not a relaxation") but the runtime never checked.
//!
//! Soundness here is a *closure* property, not an edge-set property:
//! the heuristic deliberately records fewer direct edges than the full
//! DAG (a superseding writer stands in for the accessors before it),
//! relying on transitivity through the superseding op. The oracle
//! therefore compares happens-before closures: every exact conflict
//! edge (i, j) must have i inside the dep system's closure of j. A
//! missed edge is a **data race** — the scheduler is free to reorder a
//! write past a conflicting access — and is a hard error carrying full
//! op provenance. The opposite direction is *precision*: dependency
//! order not implied by any conflict path serializes ops that could
//! have overlapped, counted as [`HazardStats::excess_edges`] (direct)
//! and [`HazardStats::serialized_pairs`] (transitive).

use std::fmt;

use crate::sched::DepsKind;
use crate::types::OpId;
use crate::ufunc::OpNode;

/// Soundness/precision summary of one stream × one dependency system.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HazardStats {
    /// Operations in the analyzed stream.
    pub ops: usize,
    /// Direct conflict edges the access lists imply (the ground truth).
    pub exact_edges: u64,
    /// Direct edges the dependency system recorded.
    pub dep_edges: u64,
    /// Recorded direct edges not implied by any conflict path —
    /// pure lost overlap.
    pub excess_edges: u64,
    /// Ordered-but-conflict-free op pairs in the dep closure: the
    /// transitive measure of serialization the system added.
    pub serialized_pairs: u64,
}

impl HazardStats {
    /// Share of recorded direct edges that no conflict justifies (%).
    pub fn excess_edge_pct(&self) -> f64 {
        if self.dep_edges == 0 {
            0.0
        } else {
            self.excess_edges as f64 / self.dep_edges as f64 * 100.0
        }
    }

    /// Fold another stream's stats into this one (CLI per-app totals).
    pub fn absorb(&mut self, o: &HazardStats) {
        self.ops += o.ops;
        self.exact_edges += o.exact_edges;
        self.dep_edges += o.dep_edges;
        self.excess_edges += o.excess_edges;
        self.serialized_pairs += o.serialized_pairs;
    }
}

/// A missed conflict edge: the dependency system admits a schedule that
/// reorders two conflicting accesses. Carries the provenance of both
/// ops (id, rank, epoch group, kernel or transfer tag) and the
/// conflicting access pair.
#[derive(Clone, Debug)]
pub struct Race {
    /// The earlier op of the unordered conflicting pair.
    pub pred: OpId,
    /// The later op, whose closure is missing `pred`.
    pub succ: OpId,
    /// Human-readable provenance of both ends and the access conflict.
    pub what: String,
}

impl fmt::Display for Race {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "data race (missed dependency edge): {}", self.what)
    }
}

/// The exact direct conflict predecessors of every op, recomputed from
/// the access lists alone: `preds[j]` holds every earlier position `i`
/// with a conflicting access pair (per-location scan lists, so the
/// cost is proportional to actual conflicts, not `n²`). Positions and
/// op ids coincide — see [`check_preds`].
pub fn exact_direct_preds(ops: &[OpNode]) -> Vec<Vec<u32>> {
    use crate::ufunc::{Access, Loc};
    use crate::util::fxhash::FxHashMap;
    let mut by_loc: FxHashMap<Loc, Vec<(u32, Access)>> = FxHashMap::default();
    let mut preds: Vec<Vec<u32>> = Vec::with_capacity(ops.len());
    for (j, op) in ops.iter().enumerate() {
        let mut pj: Vec<u32> = Vec::new();
        for a in &op.accesses {
            if let Some(list) = by_loc.get(&a.loc) {
                for &(i, b) in list {
                    if a.conflicts(&b) {
                        pj.push(i);
                    }
                }
            }
        }
        pj.sort_unstable();
        pj.dedup();
        preds.push(pj);
        for a in &op.accesses {
            by_loc.entry(a.loc).or_default().push((j as u32, *a));
        }
    }
    preds
}

/// The direct predecessors a dependency system records for the stream,
/// replayed on a fresh instance (insert-only, no completions: exactly
/// the state the scheduler consults when it first admits the ops, and
/// no id recycling can fire).
pub fn dep_direct_preds(ops: &[OpNode], kind: DepsKind) -> Vec<Vec<u32>> {
    let mut sys = kind.build();
    sys.insert_all(ops);
    ops.iter()
        .enumerate()
        .map(|(j, op)| {
            let mut v: Vec<u32> = sys
                .direct_preds(op.id)
                .into_iter()
                .map(|p| p.0)
                .filter(|&i| (i as usize) < j)
                .collect();
            v.sort_unstable();
            v.dedup();
            v
        })
        .collect()
}

/// Run the oracle against the dependency system `kind` on a fresh
/// replay of `ops`. `Err` is a data race; `Ok` carries the precision
/// stats.
pub fn check(ops: &[OpNode], kind: DepsKind) -> Result<HazardStats, Race> {
    check_preds(ops, &dep_direct_preds(ops, kind))
}

/// The core oracle, parameterized on the dep system's direct-pred
/// lists so tests can mutate them (drop an edge) and prove the race
/// detector actually fires. Requires position-contiguous op ids
/// (`ops[j].id.idx() == j`), which every recorded or session-spliced
/// stream satisfies.
pub fn check_preds(ops: &[OpNode], dep_preds: &[Vec<u32>]) -> Result<HazardStats, Race> {
    let n = ops.len();
    assert_eq!(dep_preds.len(), n, "one pred list per op");
    for (j, op) in ops.iter().enumerate() {
        assert_eq!(
            op.id.idx(),
            j,
            "hazard oracle requires position-contiguous op ids"
        );
    }
    let exact = exact_direct_preds(ops);
    let exact_cl = closure(n, &exact);
    let dep_cl = closure(n, dep_preds);
    let mut stats = HazardStats {
        ops: n,
        ..HazardStats::default()
    };
    for (j, (ej, dj)) in exact.iter().zip(dep_preds).enumerate() {
        stats.exact_edges += ej.len() as u64;
        stats.dep_edges += dj.len() as u64;
        for &i in ej {
            if !dep_cl.get(j, i as usize) {
                return Err(race(ops, i as usize, j));
            }
        }
        for &i in dj {
            if !exact_cl.get(j, i as usize) {
                stats.excess_edges += 1;
            }
        }
        stats.serialized_pairs += dep_cl.excess_over(&exact_cl, j);
    }
    Ok(stats)
}

fn race(ops: &[OpNode], i: usize, j: usize) -> Race {
    let conflict = ops[j]
        .accesses
        .iter()
        .find_map(|a| {
            ops[i]
                .accesses
                .iter()
                .copied()
                .find(|b| a.conflicts(b))
                .map(|b| format!("{a:?} vs {b:?}"))
        })
        .unwrap_or_else(|| "conflicting accesses".into());
    Race {
        pred: ops[i].id,
        succ: ops[j].id,
        what: format!(
            "{} may reorder against {}; conflict [{conflict}] has no dependency path",
            ops[j].describe(),
            ops[i].describe(),
        ),
    }
}

/// Dense happens-before closure as an n×n bit matrix. Edges always
/// point from lower to higher positions, so one pass in position order
/// suffices: row(j) = ∪ row(i) ∪ {i} over direct preds i.
struct BitMat {
    words: usize,
    bits: Vec<u64>,
}

impl BitMat {
    fn get(&self, j: usize, i: usize) -> bool {
        self.bits[j * self.words + i / 64] >> (i % 64) & 1 == 1
    }

    /// Bits set in row `j` here but not in `other`'s row `j`.
    fn excess_over(&self, other: &BitMat, j: usize) -> u64 {
        let off = j * self.words;
        self.bits[off..off + self.words]
            .iter()
            .zip(&other.bits[off..off + self.words])
            .map(|(&d, &e)| u64::from((d & !e).count_ones()))
            .sum()
    }
}

fn closure(n: usize, preds: &[Vec<u32>]) -> BitMat {
    let words = n.div_ceil(64).max(1);
    let mut m = BitMat {
        words,
        bits: vec![0u64; words * n],
    };
    for (j, pj) in preds.iter().enumerate() {
        for &i in pj {
            let i = i as usize;
            debug_assert!(i < j, "dependency edges must point backwards");
            let (lo, hi) = m.bits.split_at_mut(j * words);
            let src = &lo[i * words..i * words + words];
            for (d, s) in hi[..words].iter_mut().zip(src) {
                *d |= *s;
            }
            hi[i / 64] |= 1 << (i % 64);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BaseId, Rank, Tag};
    use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpNode, OpPayload};

    fn op(id: u32, rank: u32, accesses: Vec<Access>) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(rank),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::Copy,
                inputs: vec![],
                dst: Dst::Stage(Tag(90_000 + id as u64)),
                elems: 1,
            }),
            accesses,
        }
    }

    fn b() -> BaseId {
        BaseId(0)
    }

    #[test]
    fn raw_war_waw_edges_all_detected() {
        let ops = vec![
            op(0, 0, vec![Access::write_block(b(), 0, (0, 8))]),
            op(1, 0, vec![Access::read_block(b(), 0, (0, 8))]),
            op(2, 0, vec![Access::write_block(b(), 0, (4, 12))]),
        ];
        let exact = exact_direct_preds(&ops);
        assert_eq!(exact, vec![vec![], vec![0], vec![0, 1]]);
        for kind in [DepsKind::Heuristic, DepsKind::Dag] {
            let stats = check(&ops, kind).expect("both systems are sound");
            assert_eq!(stats.exact_edges, 3);
            assert_eq!(stats.excess_edges, 0, "{kind:?}");
            assert_eq!(stats.serialized_pairs, 0, "{kind:?}");
        }
    }

    #[test]
    fn read_read_pairs_carry_no_edge() {
        let ops = vec![
            op(0, 0, vec![Access::read_block(b(), 0, (0, 8))]),
            op(1, 1, vec![Access::read_block(b(), 0, (0, 8))]),
        ];
        assert_eq!(exact_direct_preds(&ops), vec![vec![], vec![]]);
        let stats = check(&ops, DepsKind::Dag).unwrap();
        assert_eq!(stats.exact_edges, 0);
    }

    #[test]
    fn disjoint_intervals_carry_no_edge() {
        let ops = vec![
            op(0, 0, vec![Access::write_block(b(), 0, (0, 4))]),
            op(1, 0, vec![Access::write_block(b(), 0, (4, 8))]),
        ];
        assert_eq!(exact_direct_preds(&ops), vec![vec![], vec![]]);
    }

    #[test]
    fn stage_conflicts_are_tracked_like_blocks() {
        let t = Tag(7);
        let ops = vec![
            op(0, 0, vec![Access::write_stage(t)]),
            op(1, 0, vec![Access::read_stage(t), Access::write_block(b(), 0, (0, 4))]),
            op(2, 0, vec![Access::read_block(b(), 0, (0, 4))]),
        ];
        assert_eq!(exact_direct_preds(&ops), vec![vec![], vec![0], vec![1]]);
        for kind in [DepsKind::Heuristic, DepsKind::Dag] {
            check(&ops, kind).expect("sound on staged streams");
        }
    }

    #[test]
    fn dropping_a_dep_edge_is_caught_as_a_race() {
        let ops = vec![
            op(0, 0, vec![Access::write_block(b(), 0, (0, 8))]),
            op(1, 1, vec![Access::read_block(b(), 0, (0, 8))]),
        ];
        // The mutated dep graph "forgets" the RAW edge 0 -> 1.
        let err = check_preds(&ops, &[vec![], vec![]]).unwrap_err();
        assert_eq!(err.pred, OpId(0));
        assert_eq!(err.succ, OpId(1));
        let msg = err.to_string();
        assert!(msg.contains("data race"), "{msg}");
        assert!(msg.contains("op 1"), "provenance names the ops: {msg}");
    }

    #[test]
    fn transitively_covered_edges_are_not_races() {
        // 0 -w-> 1 -w-> 2: the exact edge 0 -> 2 is implied by the dep
        // chain even when the system never records it directly.
        let ops = vec![
            op(0, 0, vec![Access::write_block(b(), 0, (0, 8))]),
            op(1, 0, vec![Access::write_block(b(), 0, (0, 8))]),
            op(2, 0, vec![Access::write_block(b(), 0, (0, 8))]),
        ];
        let stats = check_preds(&ops, &[vec![], vec![0], vec![1]]).expect("chain covers 0->2");
        assert_eq!(stats.exact_edges, 3);
        assert_eq!(stats.dep_edges, 2);
        assert_eq!(stats.excess_edges, 0);
    }

    #[test]
    fn spurious_edges_are_counted_not_raced() {
        let ops = vec![
            op(0, 0, vec![Access::write_block(b(), 0, (0, 4))]),
            op(1, 0, vec![Access::write_block(b(), 0, (8, 12))]),
        ];
        // No conflict, yet the dep system serialized them.
        let stats = check_preds(&ops, &[vec![], vec![0]]).expect("extra order is not a race");
        assert_eq!(stats.excess_edges, 1);
        assert_eq!(stats.serialized_pairs, 1);
        assert!(stats.excess_edge_pct() > 99.0);
    }
}
