//! Core identifier and element types shared across the runtime.

/// MPI-style process rank in the (simulated) cluster.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct Rank(pub u32);

impl Rank {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for Rank {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "p{}", self.0)
    }
}

/// Identifier of an array-base (the paper's two-level hierarchy bottom).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct BaseId(pub u32);

/// Identifier of a recorded operation (operation-node in the paper).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct OpId(pub u32);

impl OpId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Message / staging-buffer tag. Unique per transfer within a flush batch.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct Tag(pub u64);

/// Element dtype of distributed arrays. The benchmarks are f32 (matching
/// the AOT artifacts); f64 is supported by the layout/dependency machinery.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DType {
    F32,
    F64,
}

impl DType {
    #[inline]
    pub fn size(self) -> u64 {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }
}

/// Virtual time in seconds (discrete-event clock).
pub type VTime = f64;
