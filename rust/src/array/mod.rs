//! Array-bases, array-views and per-rank block storage
//! (paper Section 5.1, Fig. 1).
//!
//! The [`Registry`] owns the metadata of every array-base (its [`Layout`])
//! and hands out [`ViewSpec`]s. Real element data — when a run executes
//! with actual numerics rather than in pure simulation — lives in a
//! [`BlockStore`] per rank: one dense buffer per owned base-block, plus
//! staging buffers for received fragments (keyed by message [`Tag`]).

use crate::util::fxhash::FxHashMap;

use crate::layout::{Layout, ViewSpec};
use crate::types::{BaseId, DType, Rank, Tag};
use crate::ufunc::Region;

/// Metadata registry of all distributed array-bases in a context.
#[derive(Clone, Debug)]
pub struct Registry {
    layouts: Vec<Layout>,
    pub nprocs: u32,
}

impl Registry {
    pub fn new(nprocs: u32) -> Self {
        assert!(nprocs > 0);
        Registry {
            layouts: Vec::new(),
            nprocs,
        }
    }

    /// Allocate a new distributed array-base.
    pub fn alloc(&mut self, shape: Vec<u64>, block_rows: u64, dtype: DType) -> BaseId {
        let id = BaseId(self.layouts.len() as u32);
        self.layouts
            .push(Layout::new(id, shape, block_rows, self.nprocs, dtype));
        id
    }

    pub fn layout(&self, id: BaseId) -> &Layout {
        &self.layouts[id.0 as usize]
    }

    pub fn full_view(&self, id: BaseId) -> ViewSpec {
        ViewSpec::full(self.layout(id))
    }

    pub fn n_bases(&self) -> usize {
        self.layouts.len()
    }
}

/// Per-rank physical storage: owned base-blocks + staging buffers.
#[derive(Default, Debug)]
pub struct BlockStore {
    blocks: FxHashMap<(BaseId, u64), Vec<f32>>,
    stages: FxHashMap<Tag, Vec<f32>>,
}

impl BlockStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate (zeroed) every block of `base` owned by `rank`.
    pub fn alloc_base(&mut self, layout: &Layout, rank: Rank) {
        for b in layout.blocks_of(rank) {
            let n = (layout.block_nrows(b) * layout.row_elems()) as usize;
            self.blocks.insert((layout.base, b), vec![0.0; n]);
        }
    }

    pub fn block(&self, base: BaseId, block: u64) -> &[f32] {
        &self.blocks[&(base, block)]
    }

    pub fn block_mut(&mut self, base: BaseId, block: u64) -> &mut Vec<f32> {
        self.blocks.get_mut(&(base, block)).expect("block not local")
    }

    pub fn has_block(&self, base: BaseId, block: u64) -> bool {
        self.blocks.contains_key(&(base, block))
    }

    /// Extract a region into a contiguous buffer (row-major).
    pub fn extract(&self, r: &Region) -> Vec<f32> {
        let blk = self.block(r.base, r.block);
        let mut out = Vec::with_capacity(r.elems() as usize);
        for row in r.row0..r.row0 + r.nrows {
            let start = (row * r.row_stride + r.col0) as usize;
            out.extend_from_slice(&blk[start..start + r.ncols as usize]);
        }
        out
    }

    /// Write a contiguous buffer back into a region.
    pub fn write_region(&mut self, r: &Region, data: &[f32]) {
        assert_eq!(data.len() as u64, r.elems());
        let blk = self.block_mut(r.base, r.block);
        for (i, row) in (r.row0..r.row0 + r.nrows).enumerate() {
            let start = (row * r.row_stride + r.col0) as usize;
            blk[start..start + r.ncols as usize]
                .copy_from_slice(&data[i * r.ncols as usize..(i + 1) * r.ncols as usize]);
        }
    }

    pub fn put_stage(&mut self, tag: Tag, data: Vec<f32>) {
        self.stages.insert(tag, data);
    }

    pub fn stage(&self, tag: Tag) -> &[f32] {
        &self.stages[&tag]
    }

    pub fn has_stage(&self, tag: Tag) -> bool {
        self.stages.contains_key(&tag)
    }

    pub fn take_stage(&mut self, tag: Tag) -> Option<Vec<f32>> {
        self.stages.remove(&tag)
    }

    /// Staging buffers retained after a flush (results of reductions).
    pub fn clear_stages(&mut self) {
        self.stages.clear();
    }

    pub fn owned_blocks(&self) -> impl Iterator<Item = (&(BaseId, u64), &Vec<f32>)> {
        self.blocks.iter()
    }
}

/// Whole-cluster storage: one [`BlockStore`] per rank, plus helpers to
/// scatter/gather full arrays for test oracles and examples.
#[derive(Default, Debug)]
pub struct ClusterStore {
    pub ranks: Vec<BlockStore>,
}

impl ClusterStore {
    pub fn new(nprocs: u32) -> Self {
        ClusterStore {
            ranks: (0..nprocs).map(|_| BlockStore::new()).collect(),
        }
    }

    pub fn alloc_base(&mut self, layout: &Layout) {
        for (r, store) in self.ranks.iter_mut().enumerate() {
            store.alloc_base(layout, Rank(r as u32));
        }
    }

    /// Scatter a dense row-major global array into the owning blocks.
    pub fn scatter(&mut self, layout: &Layout, data: &[f32]) {
        let re = layout.row_elems();
        assert_eq!(data.len() as u64, layout.rows() * re);
        for b in 0..layout.nblocks() {
            let owner = layout.owner(b);
            let (lo, hi) = layout.block_rows_range(b);
            let slice = &data[(lo * re) as usize..(hi * re) as usize];
            self.ranks[owner.idx()]
                .block_mut(layout.base, b)
                .copy_from_slice(slice);
        }
    }

    /// Gather the full array into a dense row-major buffer.
    pub fn gather(&self, layout: &Layout) -> Vec<f32> {
        let re = layout.row_elems();
        let mut out = vec![0.0f32; (layout.rows() * re) as usize];
        for b in 0..layout.nblocks() {
            let owner = layout.owner(b);
            let (lo, hi) = layout.block_rows_range(b);
            out[(lo * re) as usize..(hi * re) as usize]
                .copy_from_slice(self.ranks[owner.idx()].block(layout.base, b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_roundtrip() {
        let mut reg = Registry::new(3);
        let a = reg.alloc(vec![10, 4], 3, DType::F32);
        let layout = reg.layout(a).clone();
        let mut cs = ClusterStore::new(3);
        cs.alloc_base(&layout);
        let data: Vec<f32> = (0..40).map(|i| i as f32).collect();
        cs.scatter(&layout, &data);
        assert_eq!(cs.gather(&layout), data);
    }

    #[test]
    fn extract_region_2d() {
        let mut reg = Registry::new(1);
        let a = reg.alloc(vec![4, 5], 4, DType::F32);
        let layout = reg.layout(a).clone();
        let mut st = BlockStore::new();
        st.alloc_base(&layout, Rank(0));
        let data: Vec<f32> = (0..20).map(|i| i as f32).collect();
        st.block_mut(a, 0).copy_from_slice(&data);
        // rows 1..3, cols 2..4
        let r = Region {
            base: a,
            block: 0,
            row0: 1,
            nrows: 2,
            col0: 2,
            ncols: 2,
            row_stride: 5,
        };
        assert_eq!(st.extract(&r), vec![7.0, 8.0, 12.0, 13.0]);
    }

    #[test]
    fn write_region_roundtrip() {
        let mut reg = Registry::new(1);
        let a = reg.alloc(vec![6], 6, DType::F32);
        let layout = reg.layout(a).clone();
        let mut st = BlockStore::new();
        st.alloc_base(&layout, Rank(0));
        let r = Region {
            base: a,
            block: 0,
            row0: 2,
            nrows: 3,
            col0: 0,
            ncols: 1,
            row_stride: 1,
        };
        st.write_region(&r, &[7.0, 8.0, 9.0]);
        assert_eq!(st.block(a, 0), &[0.0, 0.0, 7.0, 8.0, 9.0, 0.0]);
        assert_eq!(st.extract(&r), vec![7.0, 8.0, 9.0]);
    }

    #[test]
    fn stages() {
        let mut st = BlockStore::new();
        st.put_stage(Tag(3), vec![1.0, 2.0]);
        assert!(st.has_stage(Tag(3)));
        assert_eq!(st.stage(Tag(3)), &[1.0, 2.0]);
        assert_eq!(st.take_stage(Tag(3)), Some(vec![1.0, 2.0]));
        assert!(!st.has_stage(Tag(3)));
    }

    #[test]
    fn scatter_gather_multirank_cyclic() {
        let mut reg = Registry::new(2);
        let a = reg.alloc(vec![7], 2, DType::F32);
        let layout = reg.layout(a).clone();
        let mut cs = ClusterStore::new(2);
        cs.alloc_base(&layout);
        let data: Vec<f32> = (0..7).map(|i| i as f32 * 1.5).collect();
        cs.scatter(&layout, &data);
        // blocks: [0,1]->p0, [2,3]->p1, [4,5]->p0, [6]->p1
        assert_eq!(cs.ranks[0].block(a, 0), &[0.0, 1.5]);
        assert_eq!(cs.ranks[1].block(a, 1), &[3.0, 4.5]);
        assert_eq!(cs.ranks[1].block(a, 3), &[9.0]);
        assert_eq!(cs.gather(&layout), data);
    }
}
