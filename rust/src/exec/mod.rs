//! Execution backends: what actually happens to data when the scheduler
//! runs an operation.
//!
//! * [`SimBackend`] — nothing; pure timing simulation (the strong-scaling
//!   sweeps run hundreds of virtual ranks on one host core this way).
//! * [`NativeBackend`] — real numerics in Rust over a [`ClusterStore`];
//!   the correctness oracle for the distributed execution.
//! * `PjrtBackend` ([`crate::runtime`]) — real numerics through the AOT
//!   HLO artifacts produced by the JAX/Pallas layer, dispatched per
//!   kernel when the block shape matches the artifact contract, falling
//!   back to native kernels otherwise.

pub mod kernels;

use crate::array::ClusterStore;
use crate::layout::Layout;
use crate::types::{Rank, Tag};
use crate::ufunc::{ComputeTask, Dst, Operand, SendSrc};

/// Backend interface invoked by the schedulers in dependency order.
pub trait Backend {
    /// Execute one compute task on `rank`.
    fn exec_compute(&mut self, rank: Rank, task: &ComputeTask);

    /// Move `src` (on `from`) into `to`'s staging area under `tag`.
    /// Packed sources unpack into one staging buffer per constituent.
    fn exec_transfer(&mut self, from: Rank, to: Rank, tag: Tag, src: &SendSrc);

    /// Read a staged scalar (reduction results) after a flush.
    fn staged_scalar(&self, rank: Rank, tag: Tag) -> Option<f64> {
        let _ = (rank, tag);
        None
    }

    /// Read a whole staged buffer (gather snapshots) after a flush.
    fn staged_data(&self, rank: Rank, tag: Tag) -> Option<Vec<f32>> {
        let _ = (rank, tag);
        None
    }

    /// Does this backend hold real array data? Data backends return
    /// `true`; the default `false` marks timing-only simulation, where
    /// scalar reads legitimately have no staged value and read as 0.0.
    /// The lazy context uses this to tell "simulation" apart from "a
    /// staged value that should exist but doesn't" (an error).
    fn materializes_data(&self) -> bool {
        false
    }

    /// Allocate physical blocks for a new array-base (data backends).
    fn alloc_base(&mut self, layout: &Layout) {
        let _ = layout;
    }

    /// Scatter a dense row-major array into the owning blocks.
    fn scatter(&mut self, layout: &Layout, data: &[f32]) {
        let _ = (layout, data);
    }

    /// Gather a whole base into a dense buffer, if data is materialized.
    fn gather(&self, layout: &Layout) -> Option<Vec<f32>> {
        let _ = layout;
        None
    }

    /// Drop one staging buffer — reference-counted reclamation
    /// ([`crate::sync::StageTable`]): called the moment a stage's last
    /// reader (operation or pinned future) retires. Data backends free
    /// the bytes; the default is a no-op.
    fn drop_stage(&mut self, rank: Rank, tag: Tag) {
        let _ = (rank, tag);
    }

    /// Drop every staging buffer. Tags are run-unique, so stages are
    /// never overwritten — but pending [`crate::lazy::ScalarFuture`]s
    /// *read* stages across flush epochs, so this must NOT be called
    /// mid-run (the lazy context no longer calls it per flush). It
    /// exists for end-of-run cleanup and tests.
    fn clear_stages(&mut self) {}

    /// Downcasting hook: retrieve backend-specific state (e.g. the PJRT
    /// dispatch counters) from a boxed backend.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Timing-only backend.
#[derive(Default)]
pub struct SimBackend;

impl Backend for SimBackend {
    fn exec_compute(&mut self, _rank: Rank, _task: &ComputeTask) {}
    fn exec_transfer(&mut self, _from: Rank, _to: Rank, _tag: Tag, _src: &SendSrc) {}
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Real numerics in Rust.
pub struct NativeBackend {
    pub store: ClusterStore,
}

impl NativeBackend {
    pub fn new(store: ClusterStore) -> Self {
        NativeBackend { store }
    }

    /// Gather a task's input buffers on `rank`.
    pub(crate) fn gather_inputs(store: &ClusterStore, rank: Rank, task: &ComputeTask) -> Vec<Vec<f32>> {
        task.inputs
            .iter()
            .map(|op| match op {
                Operand::Local(r) => store.ranks[rank.idx()].extract(r),
                Operand::Staged(tag) => store.ranks[rank.idx()].stage(*tag).to_vec(),
            })
            .collect()
    }

    pub(crate) fn write_dst(store: &mut ClusterStore, rank: Rank, dst: &Dst, out: Vec<f32>) {
        match dst {
            Dst::Block(r) => store.ranks[rank.idx()].write_region(r, &out),
            Dst::Stage(tag) => store.ranks[rank.idx()].put_stage(*tag, out),
        }
    }
}

impl Backend for NativeBackend {
    fn exec_compute(&mut self, rank: Rank, task: &ComputeTask) {
        let inputs = Self::gather_inputs(&self.store, rank, task);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = kernels::run(task.kernel, &refs, task.elems as usize);
        Self::write_dst(&mut self.store, rank, &task.dst, out);
    }

    fn exec_transfer(&mut self, from: Rank, to: Rank, tag: Tag, src: &SendSrc) {
        match src {
            SendSrc::Region(r) => {
                let data = self.store.ranks[from.idx()].extract(r);
                self.store.ranks[to.idx()].put_stage(tag, data);
            }
            SendSrc::Stage(t) => {
                let data = self.store.ranks[from.idx()].stage(*t).to_vec();
                self.store.ranks[to.idx()].put_stage(tag, data);
            }
            SendSrc::Packed(parts) => {
                for (ptag, part) in parts {
                    let data = match part {
                        SendSrc::Region(r) => self.store.ranks[from.idx()].extract(r),
                        SendSrc::Stage(t) => self.store.ranks[from.idx()].stage(*t).to_vec(),
                        SendSrc::Packed(_) => unreachable!("nested packed message"),
                    };
                    self.store.ranks[to.idx()].put_stage(*ptag, data);
                }
            }
        }
    }

    fn staged_scalar(&self, rank: Rank, tag: Tag) -> Option<f64> {
        if self.store.ranks[rank.idx()].has_stage(tag) {
            Some(self.store.ranks[rank.idx()].stage(tag)[0] as f64)
        } else {
            None
        }
    }

    fn staged_data(&self, rank: Rank, tag: Tag) -> Option<Vec<f32>> {
        if self.store.ranks[rank.idx()].has_stage(tag) {
            Some(self.store.ranks[rank.idx()].stage(tag).to_vec())
        } else {
            None
        }
    }

    fn materializes_data(&self) -> bool {
        true
    }

    fn alloc_base(&mut self, layout: &Layout) {
        self.store.alloc_base(layout);
    }

    fn scatter(&mut self, layout: &Layout, data: &[f32]) {
        self.store.scatter(layout, data);
    }

    fn gather(&self, layout: &Layout) -> Option<Vec<f32>> {
        Some(self.store.gather(layout))
    }

    fn drop_stage(&mut self, rank: Rank, tag: Tag) {
        self.store.ranks[rank.idx()].take_stage(tag);
    }

    fn clear_stages(&mut self) {
        for r in self.store.ranks.iter_mut() {
            r.clear_stages();
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::Registry;
    use crate::types::{BaseId, DType};
    use crate::ufunc::{Kernel, Region};

    fn store1(vals: &[f32]) -> (Registry, ClusterStore, BaseId) {
        let mut reg = Registry::new(1);
        let a = reg.alloc(vec![vals.len() as u64], vals.len() as u64, DType::F32);
        let mut cs = ClusterStore::new(1);
        cs.alloc_base(reg.layout(a));
        cs.scatter(reg.layout(a), vals);
        (reg, cs, a)
    }

    #[test]
    fn native_add_roundtrip() {
        let (reg, cs, a) = store1(&[1.0, 2.0, 3.0, 4.0]);
        let mut be = NativeBackend::new(cs);
        let r = Region {
            base: a,
            block: 0,
            row0: 0,
            nrows: 4,
            col0: 0,
            ncols: 1,
            row_stride: 1,
        };
        let task = ComputeTask {
            kernel: Kernel::Add,
            inputs: vec![Operand::Local(r.clone()), Operand::Local(r.clone())],
            dst: Dst::Block(r),
            elems: 4,
        };
        be.exec_compute(Rank(0), &task);
        assert_eq!(be.store.gather(reg.layout(a)), vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn transfer_stages_data() {
        let mut reg = Registry::new(2);
        let a = reg.alloc(vec![4], 2, DType::F32);
        let mut cs = ClusterStore::new(2);
        cs.alloc_base(reg.layout(a));
        cs.scatter(reg.layout(a), &[1.0, 2.0, 3.0, 4.0]);
        let mut be = NativeBackend::new(cs);
        // Block 1 (rows 2..4) lives on rank 1; ship it to rank 0.
        let r = Region {
            base: a,
            block: 1,
            row0: 0,
            nrows: 2,
            col0: 0,
            ncols: 1,
            row_stride: 1,
        };
        be.exec_transfer(Rank(1), Rank(0), Tag(5), &SendSrc::Region(r));
        assert_eq!(be.store.ranks[0].stage(Tag(5)), &[3.0, 4.0]);
    }

    #[test]
    fn packed_transfer_unpacks_per_part() {
        let mut reg = Registry::new(2);
        let a = reg.alloc(vec![4], 2, DType::F32);
        let mut cs = ClusterStore::new(2);
        cs.alloc_base(reg.layout(a));
        cs.scatter(reg.layout(a), &[1.0, 2.0, 3.0, 4.0]);
        let mut be = NativeBackend::new(cs);
        be.store.ranks[1].put_stage(Tag(9), vec![42.0]);
        let r = Region {
            base: a,
            block: 1,
            row0: 0,
            nrows: 2,
            col0: 0,
            ncols: 1,
            row_stride: 1,
        };
        let packed = SendSrc::Packed(vec![
            (Tag(5), SendSrc::Region(r)),
            (Tag(6), SendSrc::Stage(Tag(9))),
        ]);
        be.exec_transfer(Rank(1), Rank(0), Tag(100), &packed);
        assert_eq!(be.store.ranks[0].stage(Tag(5)), &[3.0, 4.0]);
        assert_eq!(be.store.ranks[0].stage(Tag(6)), &[42.0]);
        assert!(!be.store.ranks[0].has_stage(Tag(100)), "no envelope stage");
    }

    #[test]
    fn staged_scalar_reads() {
        let cs = ClusterStore::new(1);
        let mut be = NativeBackend::new(cs);
        be.store.ranks[0].put_stage(Tag(9), vec![42.5]);
        assert_eq!(be.staged_scalar(Rank(0), Tag(9)), Some(42.5));
        assert_eq!(be.staged_scalar(Rank(0), Tag(10)), None);
    }
}
