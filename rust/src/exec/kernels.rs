//! Native Rust implementations of the block kernels.
//!
//! Semantics mirror the pure-jnp oracles in
//! `python/compile/kernels/ref.py` exactly (same formulas, f32
//! arithmetic), so a run through the native backend, the PJRT backend
//! and the JAX reference all agree — the end-to-end correctness chain.

use crate::ufunc::Kernel;

/// Abramowitz & Stegun 7.1.26 erf approximation (|ε| ≤ 1.5e-7),
/// computed in f64 and cast down — adequate against jax's erf at the
/// e2e tolerance of 1e-4.
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

fn cnd(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Black-Scholes constants baked into the AOT artifact
/// (python/compile/model.py::g_black_scholes).
pub const BS_R: f64 = 0.02;
pub const BS_V: f64 = 0.3;

/// Execute `kernel` over `inputs`, producing `elems` output elements
/// (reductions produce a single element regardless).
pub fn run(kernel: Kernel, inputs: &[&[f32]], elems: usize) -> Vec<f32> {
    match kernel {
        Kernel::Copy => inputs[0].to_vec(),
        Kernel::Add => zip2(inputs, elems, |a, b| a + b),
        Kernel::Sub => zip2(inputs, elems, |a, b| a - b),
        Kernel::Mul => zip2(inputs, elems, |a, b| a * b),
        Kernel::Div => zip2(inputs, elems, |a, b| a / b),
        Kernel::Axpy(alpha) => zip2(inputs, elems, move |a, b| a + alpha * b),
        Kernel::Scale(alpha) => inputs[0].iter().map(|&a| alpha * a).collect(),
        Kernel::AbsDiff => zip2(inputs, elems, |a, b| (a - b).abs()),
        Kernel::Stencil5 => {
            let (c, u, d, l, r) = (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
            (0..elems)
                .map(|i| 0.2 * (c[i] + u[i] + d[i] + l[i] + r[i]))
                .collect()
        }
        Kernel::BlackScholes => {
            let (s, x, t) = (inputs[0], inputs[1], inputs[2]);
            (0..elems)
                .map(|i| {
                    let (s, x, t) = (s[i] as f64, x[i] as f64, t[i] as f64);
                    let sqrt_t = t.sqrt();
                    let d1 = ((s / x).ln() + (BS_R + BS_V * BS_V / 2.0) * t) / (BS_V * sqrt_t);
                    let d2 = d1 - BS_V * sqrt_t;
                    (s * cnd(d1) - x * (-BS_R * t).exp() * cnd(d2)) as f32
                })
                .collect()
        }
        Kernel::Fractal(max_iter) => {
            let (cre, cim) = (inputs[0], inputs[1]);
            (0..elems)
                .map(|i| {
                    let (cre, cim) = (cre[i], cim[i]);
                    let (mut zre, mut zim) = (0.0f32, 0.0f32);
                    let mut count = 0.0f32;
                    for _ in 0..max_iter {
                        let zre2 = zre * zre;
                        let zim2 = zim * zim;
                        if zre2 + zim2 <= 4.0 {
                            count += 1.0;
                            let new_zim = 2.0 * zre * zim + cim;
                            zre = zre2 - zim2 + cre;
                            zim = new_zim;
                        }
                    }
                    count
                })
                .collect()
        }
        Kernel::MatmulAcc { n, k, m } => {
            let (c, a, b) = (inputs[0], inputs[1], inputs[2]);
            let (n, k, m) = (n as usize, k as usize, m as usize);
            debug_assert_eq!(c.len(), n * m);
            debug_assert_eq!(a.len(), n * k);
            debug_assert_eq!(b.len(), k * m);
            let mut out = c.to_vec();
            for i in 0..n {
                for kk in 0..k {
                    let aik = a[i * k + kk];
                    let brow = &b[kk * m..(kk + 1) * m];
                    let orow = &mut out[i * m..(i + 1) * m];
                    for j in 0..m {
                        orow[j] += aik * brow[j];
                    }
                }
            }
            out
        }
        Kernel::PartialSum => {
            // f64 accumulator to match jnp.sum's pairwise accuracy class.
            vec![inputs[0].iter().map(|&x| x as f64).sum::<f64>() as f32]
        }
        Kernel::PartialAbsDiffSum => {
            let (a, b) = (inputs[0], inputs[1]);
            vec![
                a.iter()
                    .zip(b)
                    .map(|(&x, &y)| (x - y).abs() as f64)
                    .sum::<f64>() as f32,
            ]
        }
        Kernel::AccumSum => {
            vec![inputs.iter().map(|s| s[0] as f64).sum::<f64>() as f32]
        }
    }
}

fn zip2(inputs: &[&[f32]], elems: usize, f: impl Fn(f32, f32) -> f32) -> Vec<f32> {
    let (a, b) = (inputs[0], inputs[1]);
    debug_assert!(a.len() >= elems && b.len() >= elems);
    (0..elems).map(|i| f(a[i], b[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_kernels() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [10.0f32, 20.0, 30.0];
        assert_eq!(run(Kernel::Add, &[&a, &b], 3), vec![11.0, 22.0, 33.0]);
        assert_eq!(run(Kernel::Sub, &[&b, &a], 3), vec![9.0, 18.0, 27.0]);
        assert_eq!(run(Kernel::Mul, &[&a, &b], 3), vec![10.0, 40.0, 90.0]);
        assert_eq!(
            run(Kernel::Axpy(0.5), &[&a, &b], 3),
            vec![6.0, 12.0, 18.0]
        );
        assert_eq!(run(Kernel::Scale(2.0), &[&a], 3), vec![2.0, 4.0, 6.0]);
        assert_eq!(run(Kernel::AbsDiff, &[&a, &b], 3), vec![9.0, 18.0, 27.0]);
        assert_eq!(run(Kernel::Copy, &[&a], 3), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn stencil5_is_average() {
        let one = [1.0f32; 4];
        let out = run(Kernel::Stencil5, &[&one, &one, &one, &one, &one], 4);
        for v in out {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn erf_accuracy() {
        // Reference values (scipy.special.erf).
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn black_scholes_deep_itm() {
        // S >> X: call -> S - X*exp(-rT).
        let s = [1000.0f32];
        let x = [10.0f32];
        let t = [1.0f32];
        let out = run(Kernel::BlackScholes, &[&s, &x, &t], 1);
        let want = 1000.0 - 10.0 * (-BS_R as f32).exp();
        assert!((out[0] - want).abs() < 1e-2, "{} vs {want}", out[0]);
    }

    #[test]
    fn fractal_interior_and_escape() {
        let cre = [0.0f32, 10.0];
        let cim = [0.0f32, 0.0];
        let out = run(Kernel::Fractal(32), &[&cre, &cim], 2);
        assert_eq!(out[0], 32.0, "origin never escapes");
        assert_eq!(out[1], 1.0, "far point escapes after first check");
    }

    #[test]
    fn matmul_acc_small() {
        // C += A@B: A=[[1,2],[3,4]], B=I, C=ones.
        let c = [1.0f32; 4];
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 0.0, 0.0, 1.0];
        let out = run(Kernel::MatmulAcc { n: 2, k: 2, m: 2 }, &[&c, &a, &b], 4);
        assert_eq!(out, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn reductions() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [2.0f32, 0.0, 5.0];
        assert_eq!(run(Kernel::PartialSum, &[&a], 3), vec![6.0]);
        assert_eq!(run(Kernel::PartialAbsDiffSum, &[&a, &b], 3), vec![5.0]);
        let p1 = [6.0f32];
        let p2 = [5.0f32];
        assert_eq!(run(Kernel::AccumSum, &[&p1, &p2], 2), vec![11.0]);
    }
}
