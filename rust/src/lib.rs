//! # DistNumPy-rs — runtime-managed communication latency-hiding
//!
//! Reproduction of Kristensen & Vinter, *"Managing Communication
//! Latency-Hiding at Runtime for Parallel Programming Languages and
//! Libraries"*, HPCC 2012 (DOI 10.1109/HPCC.2012.80).
//!
//! The paper's system, DistNumPy, records NumPy array operations lazily,
//! splits them into sub-view-block tasks over block-cyclic distributed
//! arrays, tracks data dependencies with per-base-block dependency lists
//! (instead of a full DAG), and schedules communication aggressively /
//! computation lazily so transfers hide behind local work.
//!
//! This crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (here)**: the lazy-evaluation runtime — [`array`], [`layout`],
//!   [`lazy`], [`deps`], [`sched`], [`ufunc`], [`summa`], the
//!   collective-communication engine [`comm`] (tree/ring collective
//!   schedules and message aggregation, layered between recording and
//!   scheduling), the targeted synchronization engine [`sync`]
//!   (dependency-cone waits, scalar/array futures and reference-counted
//!   stage reclamation, layered between [`lazy`] and [`sched`]), plus
//!   the incremental flush engine [`flow`] (streaming admission:
//!   threshold flushes become non-blocking submits whose execution
//!   overlaps continued recording — and, under sliding admission,
//!   splice straight into the *live* resumable scheduler sessions of
//!   [`sched`] with no wave boundary at all; layered between
//!   [`lazy`]'s triggers and [`sched`]'s session engines), and the
//!   event-sourced tracing layer [`trace`] (per-op timelines, wait
//!   attribution, Perfetto export, critical-path analysis; threaded
//!   through every session engine via the sink on
//!   [`sched::ExecState`]), and the schedule analyzer [`analyze`]
//!   (hazard oracle proving the dependency systems sound against the
//!   exact conflict closure, static naive-stall prediction, overlap
//!   linter; runs standalone via `distnumpy analyze` or on every
//!   drained wave under `SchedCfg::verify_deps`), the always-on
//!   distribution metrics [`metrics::hist`] (log2 wait/message/latency
//!   histograms reconciled against the scalar accounting) with the
//!   perf-regression comparator [`metrics::compare`], and the
//!   host-side self-profiler [`profile`] (phase wall timers and DES
//!   events/sec under `--profile`) — executing over a
//!   discrete-event simulated cluster ([`cluster`], [`net`]) or with
//!   real numerics ([`exec`]).
//! * **L2 (JAX)**: block-level compute graphs, AOT-lowered to HLO text
//!   under `artifacts/` (see `python/compile/model.py`).
//! * **L1 (Pallas)**: the per-block kernels those graphs call
//!   (`python/compile/kernels/`), loaded and executed from Rust via PJRT
//!   in [`runtime`].
//!
//! The paper's 16-node Gigabit-Ethernet cluster is simulated by a
//! calibrated discrete-event engine (see `DESIGN.md` §2 for why this
//! preserves the reported behaviour); the benchmark applications in
//! [`apps`] regenerate every figure of the paper's evaluation through
//! [`harness`].

pub mod analyze;
pub mod apps;
pub mod array;
pub mod cluster;
pub mod comm;
pub mod coordinator;
pub mod deps;
pub mod exec;
pub mod flow;
pub mod harness;
pub mod layout;
pub mod lazy;
pub mod metrics;
pub mod net;
pub mod profile;
pub mod runtime;
pub mod sched;
pub mod summa;
pub mod sync;
pub mod trace;
pub mod types;
pub mod ufunc;
pub mod util;

pub use types::{BaseId, DType, OpId, Rank, Tag};
