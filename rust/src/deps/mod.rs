//! Dependency systems (paper Sections 4 and 5.7).
//!
//! Two interchangeable implementations order the recorded operations:
//!
//! * [`DagDeps`] — the classic full directed-acyclic-graph approach the
//!   paper describes in Section 4 and measures as prohibitively slow:
//!   inserting a node compares it against every live node, O(n) each,
//!   O(n²) for a batch.
//! * [`HeuristicDeps`] — the paper's contribution (Section 5.7.2): no
//!   global graph; instead every base-block keeps a *dependency-list* of
//!   access-nodes, and each operation-node carries a reference counter of
//!   outstanding conflicts. Insertion only scans the (short) lists of the
//!   blocks the operation touches.
//!
//! Both implement [`DepSystem`] with identical conflict semantics (same
//! interval/overlap rule), so they admit exactly the same schedules —
//! a property the test-suite checks — and differ only in cost.

mod dag;
mod heuristic;

pub use dag::DagDeps;
pub use heuristic::HeuristicDeps;

use crate::sync::ConeSource;
use crate::types::OpId;
use crate::ufunc::OpNode;

/// Common interface of the dependency systems. The [`ConeSource`]
/// supertrait lets the `sync/` engine ask either system for the
/// backward dependency cone of a forced value — from the DAG's
/// retained edges, or from the heuristic's location-level predecessor
/// hints (exact on epoch streams, conservative prefix for recycled
/// targets).
pub trait DepSystem: ConeSource {
    /// Insert one recorded operation (in recording order).
    fn insert(&mut self, op: &OpNode);

    /// Drain operations that became ready since the last call
    /// (refcount/in-degree zero), in deterministic order.
    fn take_ready(&mut self) -> Vec<OpId>;

    /// Mark an operation executed; dependents may become ready.
    fn complete(&mut self, op: OpId);

    /// Operations inserted but not yet completed.
    fn pending(&self) -> usize;

    /// The direct predecessors recorded for `op` at insert time — the
    /// edges the system will actually enforce. Consumed by the
    /// [`crate::analyze`] hazard oracle, which verifies their
    /// transitive closure covers every exact conflict edge. Exact for
    /// [`DagDeps`] (retained `preds`); for [`HeuristicDeps`] it is the
    /// predecessor-hint list its insert scan records (complete on
    /// insert-only replays, which is how the oracle calls it). Unknown
    /// or recycled ids return an empty list.
    fn direct_preds(&self, op: OpId) -> Vec<OpId>;

    /// Bulk-insert a whole batch.
    fn insert_all(&mut self, ops: &[OpNode]) {
        for op in ops {
            self.insert(op);
        }
    }
}

/// Construct by name — used by the CLI and the ablation bench.
pub fn by_name(name: &str) -> Box<dyn DepSystem> {
    match name {
        "dag" => Box::new(DagDeps::new()),
        "heuristic" | _ => Box::new(HeuristicDeps::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BaseId, Rank, Tag};
    use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpPayload, Operand, Region};

    /// Helper: build a compute op with the given accesses.
    pub(crate) fn op(id: u32, accesses: Vec<Access>) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::Add,
                inputs: vec![Operand::Local(Region::scalar())],
                dst: Dst::Stage(Tag(u64::MAX)),
                elems: 1,
            }),
            accesses,
        }
    }

    fn rw_chain_ops() -> Vec<OpNode> {
        let b = BaseId(0);
        vec![
            // op0 writes [0,10)
            op(0, vec![Access::write_block(b, 0, (0, 10))]),
            // op1 reads [0,10) -> depends on op0
            op(1, vec![Access::read_block(b, 0, (0, 10))]),
            // op2 reads [5,15) -> depends on op0 (overlap)
            op(2, vec![Access::read_block(b, 0, (5, 15))]),
            // op3 writes [0,5) -> depends on op0 (ww), op1 (rw), NOT op2
            op(3, vec![Access::write_block(b, 0, (0, 5))]),
        ]
    }

    fn check_chain(mut d: impl DepSystem) {
        for o in rw_chain_ops() {
            d.insert(&o);
        }
        assert_eq!(d.take_ready(), vec![OpId(0)]);
        d.complete(OpId(0));
        let r = d.take_ready();
        assert_eq!(r, vec![OpId(1), OpId(2)]);
        d.complete(OpId(2));
        assert!(d.take_ready().is_empty(), "op3 still blocked by op1");
        d.complete(OpId(1));
        assert_eq!(d.take_ready(), vec![OpId(3)]);
        d.complete(OpId(3));
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn chain_heuristic() {
        check_chain(HeuristicDeps::new());
    }

    #[test]
    fn chain_dag() {
        check_chain(DagDeps::new());
    }

    fn check_independent(mut d: impl DepSystem) {
        let b = BaseId(0);
        // Disjoint intervals and different blocks: all ready at once.
        let ops = vec![
            op(0, vec![Access::write_block(b, 0, (0, 10))]),
            op(1, vec![Access::write_block(b, 0, (10, 20))]),
            op(2, vec![Access::write_block(b, 1, (0, 10))]),
            op(3, vec![Access::write_block(BaseId(1), 0, (0, 10))]),
        ];
        for o in &ops {
            d.insert(o);
        }
        assert_eq!(d.take_ready().len(), 4);
    }

    #[test]
    fn independent_heuristic() {
        check_independent(HeuristicDeps::new());
    }

    #[test]
    fn independent_dag() {
        check_independent(DagDeps::new());
    }

    fn check_multi_access(mut d: impl DepSystem) {
        let b = BaseId(0);
        // op1 has two accesses conflicting with op0's single write.
        let ops = vec![
            op(0, vec![Access::write_block(b, 0, (0, 100))]),
            op(
                1,
                vec![
                    Access::read_block(b, 0, (0, 10)),
                    Access::read_block(b, 0, (50, 60)),
                ],
            ),
        ];
        for o in &ops {
            d.insert(o);
        }
        assert_eq!(d.take_ready(), vec![OpId(0)]);
        d.complete(OpId(0));
        assert_eq!(d.take_ready(), vec![OpId(1)]);
    }

    #[test]
    fn multi_access_heuristic() {
        check_multi_access(HeuristicDeps::new());
    }

    #[test]
    fn multi_access_dag() {
        check_multi_access(DagDeps::new());
    }

    #[test]
    fn stage_dependency() {
        let mut d = HeuristicDeps::new();
        let ops = vec![
            op(0, vec![Access::write_stage(Tag(1))]),
            op(1, vec![Access::read_stage(Tag(1))]),
        ];
        for o in &ops {
            d.insert(o);
        }
        assert_eq!(d.take_ready(), vec![OpId(0)]);
        d.complete(OpId(0));
        assert_eq!(d.take_ready(), vec![OpId(1)]);
    }
}
