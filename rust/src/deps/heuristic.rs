//! The paper's dependency heuristic (Section 5.7.2, Figs. 7–8).
//!
//! Instead of a global DAG, every location (base-block or staging buffer)
//! keeps a **dependency-list** of access-nodes in insertion order. An
//! operation-node's **reference counter** is the number of earlier, still
//! live, conflicting access-nodes across all its accesses. Operations
//! whose counter is zero sit in the **ready queue** (O(1) retrieval —
//! invariant 1 of Section 5.7).
//!
//! When an operation completes, its access-nodes are removed from their
//! lists; for every *later* access-node in the same list that conflicts,
//! the owning operation's counter is decremented, and operations reaching
//! zero move to the ready queue — exactly the flow of Fig. 7.
//!
//! Complexity: insertion scans only the lists of the touched locations.
//! In the common case — operations spread evenly over the blocks of the
//! involved arrays — each list stays short, so insertion is O(1) amortized
//! versus O(n) for the full DAG (measured in benches/ablation_deps.rs).
//!
//! The insert scan additionally records **location-level predecessor
//! hints** — the ids of the conflicting access-nodes it walked anyway —
//! so the `sync/` engine's cone queries ([`ConeSource`]) get a
//! transitive-predecessor walk (matching the DAG's exact cone on the
//! epoch drivers) instead of the conservative whole-epoch prefix,
//! without the heuristic ever building a graph. Measured against
//! `DagDeps::cone_of` in benches/ablation_deps.rs.

use super::DepSystem;
use crate::sync::{Cone, ConeSource};
use crate::types::OpId;
use crate::ufunc::{Loc, OpNode};
use crate::util::fxhash::FxHashMap;

/// One access-node in a dependency-list.
#[derive(Clone, Copy, Debug)]
struct AccessNode {
    op: OpId,
    lo: u64,
    hi: u64,
    write: bool,
    alive: bool,
}

impl AccessNode {
    #[inline]
    fn conflicts(&self, other: &AccessNode) -> bool {
        (self.write || other.write) && self.lo < other.hi && other.lo < self.hi
    }
}

/// Dependency-list of one location plus a tombstone counter for
/// amortized compaction.
#[derive(Default, Debug)]
struct DepList {
    nodes: Vec<AccessNode>,
    dead: usize,
}

#[derive(Default)]
pub struct HeuristicDeps {
    /// Dense dependency-lists; `list_ids` interns each touched location
    /// to an index, so the completion path is pure indexing with no
    /// hashing at all (§Perf-4 in EXPERIMENTS.md).
    lists: Vec<DepList>,
    list_ids: FxHashMap<Loc, u32>,
    /// refcount per op (indexed by OpId).
    refcount: Vec<u32>,
    /// Flat arena of (list id, node index) access entries — one
    /// contiguous span per op (`spans`), avoiding a Vec allocation per
    /// operation (§Perf-3 in EXPERIMENTS.md).
    entry_data: Vec<(u32, u32)>,
    /// Per-op `[start, end)` into `entry_data`.
    spans: Vec<(u32, u32)>,
    /// Flat arena of direct-predecessor *hints*: the conflicting
    /// location-level access-nodes each insert scan walked anyway
    /// (ROADMAP "cheaper exact cones"). Costs no extra scan — only the
    /// ids the existing conflict checks already computed — and lets
    /// [`ConeSource::cone_of`] answer with a transitive-predecessor
    /// walk instead of the whole epoch prefix.
    pred_data: Vec<OpId>,
    /// Per-op `[start, end)` into `pred_data`.
    pred_spans: Vec<(u32, u32)>,
    ready: Vec<OpId>,
    pending: usize,
    completed: Vec<bool>,
}

impl HeuristicDeps {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, id: OpId) {
        let need = id.idx() + 1;
        if self.refcount.len() < need {
            self.refcount.resize(need, 0);
            self.spans.resize(need, (0, 0));
            self.pred_spans.resize(need, (0, 0));
            self.completed.resize(need, false);
        }
    }

    /// Compact a list when more than half of it is tombstones, remapping
    /// the stored indices of the surviving ops.
    fn maybe_compact(
        list: &mut DepList,
        entry_data: &mut [(u32, u32)],
        spans: &[(u32, u32)],
        lid: u32,
    ) {
        if list.dead * 2 <= list.nodes.len() || list.nodes.len() < 32 {
            return;
        }
        let mut new_nodes = Vec::with_capacity(list.nodes.len() - list.dead);
        for (i, n) in list.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            let new = new_nodes.len() as u32;
            new_nodes.push(*n);
            // Update the owning op's stored index.
            let (s, e) = spans[n.op.idx()];
            for entry in entry_data[s as usize..e as usize].iter_mut() {
                if entry.0 == lid && entry.1 == i as u32 {
                    entry.1 = new;
                }
            }
        }
        list.nodes = new_nodes;
        list.dead = 0;
    }
}

impl HeuristicDeps {
    /// Drop the drained epoch's residue (tombstoned list nodes, the
    /// entry arena, refcounts) so operation ids can recycle. Sound only
    /// because nothing is pending: every access-node is a tombstone and
    /// no refcount is outstanding. Called lazily from `insert` at the
    /// first insertion of a new flush epoch — the schedulers reuse one
    /// live dependency system across epochs ([`crate::sched::ExecState`])
    /// while each epoch's `OpId`s restart at zero.
    fn recycle(&mut self) {
        for l in self.lists.iter_mut() {
            l.nodes.clear();
            l.dead = 0;
        }
        self.entry_data.clear();
        self.refcount.clear();
        self.spans.clear();
        self.pred_data.clear();
        self.pred_spans.clear();
        self.completed.clear();
    }
}

impl ConeSource for HeuristicDeps {
    /// The heuristic stores no graph — that is its whole point
    /// (Section 5.7.2) — but its insert scan walks exactly the
    /// conflicting access-nodes a graph edge would record, so since the
    /// ROADMAP's "cheaper exact cones" item it keeps those ids as
    /// **predecessor hints** (`pred_data`) and answers cone queries
    /// with a transitive walk over them, like the DAG but without ever
    /// scanning non-conflicting nodes.
    ///
    /// Precision: under the epoch drivers every insert happens before
    /// any completion (insert_all, then execute), so the hints capture
    /// *every* conflicting predecessor and the walk equals
    /// `DagDeps::cone_of`. If insertion ever interleaved with
    /// completion, hints to access-nodes dropped by list compaction
    /// could be missing — which is frontier-safe: a *completed*
    /// predecessor retires before its dependent starts, so it can only
    /// lower, never raise, the cone frontier, and the target itself is
    /// always in the cone. Unknown targets (already recycled) fall back
    /// to the conservative epoch prefix.
    fn cone_of(&self, target: OpId) -> Cone {
        if target.idx() >= self.pred_spans.len() {
            return Cone::Prefix;
        }
        let mut seen = vec![false; self.pred_spans.len()];
        let mut stack = vec![target];
        let mut cone = Vec::new();
        seen[target.idx()] = true;
        while let Some(id) = stack.pop() {
            cone.push(id);
            let (s, e) = self.pred_spans[id.idx()];
            for &p in &self.pred_data[s as usize..e as usize] {
                if !seen[p.idx()] {
                    seen[p.idx()] = true;
                    stack.push(p);
                }
            }
        }
        Cone::Exact(cone)
    }
}

impl DepSystem for HeuristicDeps {
    fn insert(&mut self, op: &OpNode) {
        if self.pending == 0 && !self.completed.is_empty() {
            self.recycle();
        }
        self.ensure(op.id);
        let start = self.entry_data.len() as u32;
        let pred_start = self.pred_data.len() as u32;
        let mut count = 0u32;
        for a in &op.accesses {
            let node = AccessNode {
                op: op.id,
                lo: a.lo,
                hi: a.hi,
                write: a.write,
                alive: true,
            };
            let lid = *self.list_ids.entry(a.loc).or_insert_with(|| {
                self.lists.push(DepList::default());
                (self.lists.len() - 1) as u32
            });
            let list = &mut self.lists[lid as usize];
            for e in &list.nodes {
                if e.op != op.id && e.conflicts(&node) {
                    // Location-level predecessor hint — live *or*
                    // tombstoned: a retired predecessor still bounds
                    // the cone (its rank belongs to it), it just no
                    // longer gates readiness.
                    self.pred_data.push(e.op);
                    if e.alive {
                        count += 1;
                    }
                }
            }
            self.entry_data.push((lid, list.nodes.len() as u32));
            list.nodes.push(node);
        }
        self.spans[op.id.idx()] = (start, self.entry_data.len() as u32);
        self.pred_spans[op.id.idx()] = (pred_start, self.pred_data.len() as u32);
        self.refcount[op.id.idx()] = count;
        self.pending += 1;
        if count == 0 {
            self.ready.push(op.id);
        }
    }

    fn take_ready(&mut self) -> Vec<OpId> {
        std::mem::take(&mut self.ready)
    }

    fn complete(&mut self, op: OpId) {
        assert!(
            !self.completed[op.idx()],
            "operation {op:?} completed twice"
        );
        assert_eq!(
            self.refcount[op.idx()],
            0,
            "completing {op:?} with nonzero refcount"
        );
        self.completed[op.idx()] = true;
        self.pending -= 1;
        let (s, e) = self.spans[op.idx()];
        // One pass per access-node: tombstone it, repay the reference
        // counts of later conflicting access-nodes (sibling accesses of
        // the same op are excluded by the `other.op != op` check, so a
        // separate tombstone pass is unnecessary), then compact if due.
        for k in s..e {
            let (lid, idx) = self.entry_data[k as usize];
            let idx = idx as usize;
            let list = &mut self.lists[lid as usize];
            debug_assert!(list.nodes[idx].alive);
            list.nodes[idx].alive = false;
            list.dead += 1;
            let me = list.nodes[idx];
            for j in idx + 1..list.nodes.len() {
                let other = list.nodes[j];
                if other.alive && other.op != op && me.conflicts(&other) {
                    let rc = &mut self.refcount[other.op.idx()];
                    debug_assert!(*rc > 0, "refcount underflow on {:?}", other.op);
                    *rc -= 1;
                    if *rc == 0 {
                        self.ready.push(other.op);
                    }
                }
            }
            Self::maybe_compact(list, &mut self.entry_data, &self.spans, lid);
        }
    }

    fn pending(&self) -> usize {
        self.pending
    }

    fn direct_preds(&self, op: OpId) -> Vec<OpId> {
        if op.idx() >= self.pred_spans.len() {
            return Vec::new();
        }
        let (s, e) = self.pred_spans[op.idx()];
        let mut preds = self.pred_data[s as usize..e as usize].to_vec();
        // The hint arena holds one entry per conflicting *access-node*
        // pair; dedup to op-level edges for the oracle.
        preds.sort_unstable();
        preds.dedup();
        preds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::BaseId;
    use crate::ufunc::Access;

    fn op(id: u32, accesses: Vec<Access>) -> OpNode {
        super::super::tests::op(id, accesses)
    }

    #[test]
    fn duplicate_conflict_pairs_balance() {
        // op1 reads the same written interval through two accesses: the
        // refcount must reach exactly zero when op0 completes.
        let b = BaseId(0);
        let mut d = HeuristicDeps::new();
        d.insert(&op(0, vec![Access::write_block(b, 0, (0, 100))]));
        d.insert(&op(
            1,
            vec![
                Access::read_block(b, 0, (10, 20)),
                Access::read_block(b, 0, (15, 30)),
            ],
        ));
        assert_eq!(d.refcount[1], 2);
        assert_eq!(d.take_ready(), vec![OpId(0)]);
        d.complete(OpId(0));
        assert_eq!(d.refcount[1], 0);
        assert_eq!(d.take_ready(), vec![OpId(1)]);
    }

    #[test]
    fn compaction_preserves_semantics() {
        let b = BaseId(0);
        let mut d = HeuristicDeps::new();
        // 64 independent writers on disjoint intervals, then complete all:
        // triggers compaction; then a reader of everything must be ready
        // only after the last writer.
        for i in 0..64 {
            d.insert(&op(
                i,
                vec![Access::write_block(b, 0, (i as u64 * 10, i as u64 * 10 + 10))],
            ));
        }
        let ready = d.take_ready();
        assert_eq!(ready.len(), 64);
        for id in &ready[..63] {
            d.complete(*id);
        }
        d.insert(&op(64, vec![Access::read_block(b, 0, (0, 640))]));
        assert!(d.take_ready().is_empty(), "one writer still pending");
        d.complete(OpId(63));
        assert_eq!(d.take_ready(), vec![OpId(64)]);
    }

    #[test]
    fn long_chain_fifo_order() {
        // w -> w -> w on the same interval completes strictly in order.
        let b = BaseId(0);
        let mut d = HeuristicDeps::new();
        for i in 0..10 {
            d.insert(&op(i, vec![Access::write_block(b, 0, (0, 8))]));
        }
        let mut order = Vec::new();
        loop {
            let r = d.take_ready();
            if r.is_empty() {
                break;
            }
            for id in r {
                order.push(id);
                d.complete(id);
            }
        }
        assert_eq!(order, (0..10).map(OpId).collect::<Vec<_>>());
    }

    /// The predecessor hints reproduce the DAG's exact cone on
    /// insert-then-drain streams (the only pattern the epoch drivers
    /// produce), shrinking well below the epoch prefix.
    #[test]
    fn pred_hint_cone_matches_dag_and_undercuts_prefix() {
        use crate::deps::DagDeps;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0DE5);
        for trial in 0..30 {
            let n_ops = 24;
            let ops: Vec<OpNode> = (0..n_ops)
                .map(|i| {
                    let n_acc = rng.range(1, 4);
                    let accesses = (0..n_acc)
                        .map(|_| {
                            let base = BaseId(rng.range(0, 3) as u32);
                            let block = rng.below(3);
                            let lo = rng.below(40);
                            let hi = lo + 1 + rng.below(20);
                            if rng.chance(0.4) {
                                Access::write_block(base, block, (lo, hi))
                            } else {
                                Access::read_block(base, block, (lo, hi))
                            }
                        })
                        .collect();
                    op(i, accesses)
                })
                .collect();
            let mut heu = HeuristicDeps::new();
            let mut dag = DagDeps::new();
            for o in &ops {
                heu.insert(o);
                dag.insert(o);
            }
            for probe in [OpId(n_ops / 2), OpId(n_ops - 1)] {
                let mut h = match heu.cone_of(probe) {
                    Cone::Exact(ids) => ids,
                    other => panic!("trial {trial}: hints must answer exactly, got {other:?}"),
                };
                let mut d = match dag.cone_of(probe) {
                    Cone::Exact(ids) => ids,
                    other => panic!("trial {trial}: dag answers exactly, got {other:?}"),
                };
                h.sort();
                d.sort();
                assert_eq!(h, d, "trial {trial}: cones diverge at {probe:?}");
                assert!(
                    h.len() <= probe.idx() + 1,
                    "trial {trial}: cone must not exceed the prefix"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "completed twice")]
    fn double_complete_panics() {
        let b = BaseId(0);
        let mut d = HeuristicDeps::new();
        d.insert(&op(0, vec![Access::write_block(b, 0, (0, 1))]));
        d.take_ready();
        d.complete(OpId(0));
        d.complete(OpId(0));
    }
}
