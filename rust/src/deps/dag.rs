//! Full-DAG dependency baseline (paper Section 4).
//!
//! Nodes are operations, edges are conflicts between their access-nodes.
//! Insertion compares the new operation against **every** live node —
//! O(n) per insertion, O(n²) per batch — which is precisely the overhead
//! the paper's heuristic eliminates. Kept as (a) the correctness oracle
//! for [`super::HeuristicDeps`] (identical conflict semantics ⇒ identical
//! ready-set evolution) and (b) the baseline of the Section 5.7.2
//! overhead ablation (`benches/ablation_deps.rs`).

use super::DepSystem;
use crate::sync::{Cone, ConeSource};
use crate::types::OpId;
use crate::ufunc::{Access, OpNode};

#[derive(Default)]
pub struct DagDeps {
    /// Access lists of every inserted op (dense by OpId).
    accesses: Vec<Vec<Access>>,
    /// Outgoing edges: completed(op) unlocks these.
    succs: Vec<Vec<OpId>>,
    /// Incoming edges, retained after completion: the backward cone of
    /// a forced value is walked at wait time, when the epoch has
    /// already drained ([`ConeSource`]).
    preds: Vec<Vec<OpId>>,
    indeg: Vec<u32>,
    live: Vec<bool>,
    inserted: Vec<bool>,
    ready: Vec<OpId>,
    pending: usize,
}

impl DagDeps {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, id: OpId) {
        let need = id.idx() + 1;
        if self.accesses.len() < need {
            self.accesses.resize_with(need, Vec::new);
            self.succs.resize_with(need, Vec::new);
            self.preds.resize_with(need, Vec::new);
            self.indeg.resize(need, 0);
            self.live.resize(need, false);
            self.inserted.resize(need, false);
        }
    }

    /// Number of live (inserted, not completed) nodes — for the ablation.
    pub fn live_nodes(&self) -> usize {
        self.live.iter().filter(|l| **l).count()
    }
}

fn conflict(a: &[Access], b: &[Access]) -> bool {
    a.iter().any(|x| b.iter().any(|y| x.conflicts(y)))
}

impl ConeSource for DagDeps {
    /// Exact backward cone: walk the retained predecessor edges from
    /// the target. Edges survive completion (only `recycle` drops
    /// them), so the query works at wait time, after the epoch drained.
    fn cone_of(&self, target: OpId) -> Cone {
        if target.idx() >= self.inserted.len() || !self.inserted[target.idx()] {
            // Unknown op (already recycled): be conservative.
            return Cone::Prefix;
        }
        let mut seen = vec![false; self.preds.len()];
        let mut stack = vec![target];
        let mut cone = Vec::new();
        seen[target.idx()] = true;
        while let Some(id) = stack.pop() {
            cone.push(id);
            for &p in &self.preds[id.idx()] {
                if !seen[p.idx()] {
                    seen[p.idx()] = true;
                    stack.push(p);
                }
            }
        }
        Cone::Exact(cone)
    }
}

impl DepSystem for DagDeps {
    fn insert(&mut self, op: &OpNode) {
        // Epoch recycling (mirrors `HeuristicDeps::recycle`): once an
        // epoch fully drained, drop its nodes so ids can restart at zero
        // and the O(n) insertion scan stays bounded per epoch.
        if self.pending == 0 && !self.inserted.is_empty() {
            self.accesses.clear();
            self.succs.clear();
            self.preds.clear();
            self.indeg.clear();
            self.live.clear();
            self.inserted.clear();
        }
        self.ensure(op.id);
        let mut indeg = 0u32;
        let mut preds = Vec::new();
        // The O(n) scan the paper's Section 4 complains about. Edges to
        // *live* nodes gate readiness; predecessor edges additionally
        // cover completed nodes so the retained graph yields the full
        // backward cone (a value's cone includes retired work).
        for prev in 0..self.accesses.len() {
            if !self.inserted[prev] || prev == op.id.idx() {
                continue;
            }
            if conflict(&self.accesses[prev], &op.accesses) {
                preds.push(OpId(prev as u32));
                if self.live[prev] {
                    self.succs[prev].push(op.id);
                    indeg += 1;
                }
            }
        }
        self.preds[op.id.idx()] = preds;
        self.accesses[op.id.idx()] = op.accesses.clone();
        self.indeg[op.id.idx()] = indeg;
        self.live[op.id.idx()] = true;
        self.inserted[op.id.idx()] = true;
        self.pending += 1;
        if indeg == 0 {
            self.ready.push(op.id);
        }
    }

    fn take_ready(&mut self) -> Vec<OpId> {
        std::mem::take(&mut self.ready)
    }

    fn complete(&mut self, op: OpId) {
        assert!(self.live[op.idx()], "complete of non-live op {op:?}");
        assert_eq!(self.indeg[op.idx()], 0, "completing blocked op {op:?}");
        self.live[op.idx()] = false;
        self.pending -= 1;
        for succ in std::mem::take(&mut self.succs[op.idx()]) {
            let d = &mut self.indeg[succ.idx()];
            *d -= 1;
            if *d == 0 {
                self.ready.push(succ);
            }
        }
    }

    fn pending(&self) -> usize {
        self.pending
    }

    fn direct_preds(&self, op: OpId) -> Vec<OpId> {
        if op.idx() < self.preds.len() && self.inserted[op.idx()] {
            self.preds[op.idx()].clone()
        } else {
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::BaseId;
    use crate::ufunc::Access;
    use crate::util::rng::Rng;

    fn op(id: u32, accesses: Vec<Access>) -> OpNode {
        super::super::tests::op(id, accesses)
    }

    /// Oracle test: heuristic and DAG expose identical ready-set
    /// evolutions on randomized access patterns.
    #[test]
    fn heuristic_matches_dag_on_random_streams() {
        let mut rng = Rng::new(0xD15C0);
        for trial in 0..50 {
            let n_ops = 40;
            let ops: Vec<OpNode> = (0..n_ops)
                .map(|i| {
                    let n_acc = rng.range(1, 4);
                    let accesses = (0..n_acc)
                        .map(|_| {
                            let base = BaseId(rng.range(0, 3) as u32);
                            let block = rng.below(3);
                            let lo = rng.below(40);
                            let hi = lo + 1 + rng.below(20);
                            if rng.chance(0.4) {
                                Access::write_block(base, block, (lo, hi))
                            } else {
                                Access::read_block(base, block, (lo, hi))
                            }
                        })
                        .collect();
                    op(i, accesses)
                })
                .collect();

            let mut h = super::super::HeuristicDeps::new();
            let mut g = DagDeps::new();
            for o in &ops {
                h.insert(o);
                g.insert(o);
            }
            let mut done = 0;
            loop {
                let mut rh = h.take_ready();
                let mut rg = g.take_ready();
                rh.sort();
                rg.sort();
                assert_eq!(rh, rg, "trial {trial}: ready sets diverged");
                if rh.is_empty() {
                    break;
                }
                // Complete in a deterministic shuffled order.
                for id in rh {
                    h.complete(id);
                    g.complete(id);
                    done += 1;
                }
            }
            assert_eq!(done, n_ops, "trial {trial}: not all ops completed");
            assert_eq!(h.pending(), 0);
            assert_eq!(g.pending(), 0);
        }
    }

    #[test]
    fn live_nodes_tracks() {
        let b = BaseId(0);
        let mut g = DagDeps::new();
        g.insert(&op(0, vec![Access::write_block(b, 0, (0, 10))]));
        g.insert(&op(1, vec![Access::write_block(b, 0, (0, 10))]));
        assert_eq!(g.live_nodes(), 2);
        g.take_ready();
        g.complete(OpId(0));
        assert_eq!(g.live_nodes(), 1);
    }
}
