//! Naive k-nearest-neighbours (paper Fig. 14).
//!
//! All query-to-point distances via the Gram-matrix identity
//! ‖q−p‖² = ‖q‖² + ‖p‖² − 2·q·p — the 2·q·pᵀ term is a SUMMA matmul
//! (O(n²)), followed by elementwise assembly and per-query reductions.
//! The paper notes its modest speedup comes from load imbalance when the
//! problem does not divide evenly at 8 and 16 ranks; the same effect
//! falls out of the block layout here.
//!
//! The per-sweep best-distance read is a deferred [`ScalarFuture`]
//! forced one sweep late, so the reduction fan-in drains behind the
//! next sweep's SUMMA panels and forcing it settles only the
//! reduction's cone ([`crate::sync`]).

use crate::lazy::{Context, ScalarFuture};
use crate::summa::record_matmul;
use crate::ufunc::Kernel;

use super::AppParams;

pub fn record(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(1024);
    // Deliberately not a power of two (paper: "the chosen problem is not
    // divided evenly between the processes" at 8/16 ranks).
    let n = n + n / 6;
    let br = (n / 96).max(1);

    let q = ctx.zeros(&[n, n], br); // query Gram tile
    let c = ctx.zeros(&[n, n], br); // corpus Gram tile
    let d = ctx.zeros(&[n, n], br); // distance matrix
    let qq = ctx.zeros(&[n], br);
    let pp = ctx.zeros(&[n], br);

    let mut best: Option<ScalarFuture> = None;
    for _ in 0..p.iters.max(1) {
        // Norms: aligned elementwise.
        ctx.ufunc(Kernel::Mul, &qq, &[&qq, &qq]);
        ctx.ufunc(Kernel::Mul, &pp, &[&pp, &pp]);
        // -2 q pᵀ via SUMMA.
        let collective = ctx.cfg.collective;
        record_matmul(&mut ctx.builder, &ctx.reg, q.base, c.base, d.base, collective);
        // Assemble distances and extract the best per sweep: force the
        // previous sweep's deferred reduction, issue this sweep's.
        ctx.ufunc(Kernel::Scale(-2.0), &d, &[&d]);
        if let Some(fut) = best.take() {
            let _ = ctx.wait_scalar(&fut);
        }
        best = Some(ctx.sum_deferred(&d));
    }
    if let Some(fut) = best.take() {
        let _ = ctx.wait_scalar(&fut);
    }
}
