//! Black-Scholes option pricing (paper Figs. 9 and 12) — embarrassingly
//! parallel; per iteration one fused pricing pass over the portfolio and
//! a price-sum read, exactly the shape of the classic DistNumPy
//! benchmark (price a portfolio for successive maturities, accumulate).

use crate::lazy::Context;
use crate::ufunc::Kernel;

use super::AppParams;

pub fn record(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(4 << 20);
    let br = (n / 512).max(1);
    let s = ctx.zeros(&[n], br);
    let x = ctx.zeros(&[n], br);
    let t = ctx.zeros(&[n], br);
    let prices = ctx.zeros(&[n], br);

    for _ in 0..p.iters {
        // Advance maturities: T += 1/iters (aligned, local).
        ctx.ufunc(Kernel::Axpy(1.0 / p.iters as f32), &t, &[&t, &x]);
        // Price the whole portfolio (fused kernel, L1: black_scholes.py).
        ctx.ufunc(Kernel::BlackScholes, &prices, &[&s, &x, &t]);
        // Portfolio value: scalar read -> flush (trigger 1).
        let _ = ctx.sum(&prices);
    }
}
