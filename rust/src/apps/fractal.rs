//! Mandelbrot set (paper Fig. 11) — the embarrassingly parallel control.
//!
//! The NumPy tutorial original builds the complex plane with meshgrid
//! arithmetic, then iterates; DistNumPy replaces the python-level
//! iteration loop with the fused escape-time kernel (L1:
//! `kernels/fractal.py`). All operands are aligned: no communication.

use crate::lazy::Context;
use crate::ufunc::Kernel;

use super::AppParams;

pub fn record(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(2048);
    let br = (n / 128).max(1);
    let cre = ctx.zeros(&[n, n], br);
    let cim = ctx.zeros(&[n, n], br);
    let out = ctx.zeros(&[n, n], br);

    // Plane setup: a handful of aligned elementwise ops (meshgrid-ish).
    ctx.ufunc(Kernel::Scale(3.0 / n as f32), &cre, &[&cre]);
    ctx.ufunc(Kernel::Scale(2.0 / n as f32), &cim, &[&cim]);
    ctx.ufunc(Kernel::Axpy(-2.0), &cre, &[&cre, &out]);
    ctx.ufunc(Kernel::Axpy(-1.0), &cim, &[&cim, &out]);

    // One fused escape-time pass per "frame".
    let iters_inside = 32 * p.iters.max(1);
    ctx.ufunc(Kernel::Fractal(iters_inside), &out, &[&cre, &cim]);

    // The tutorial renders the result: a read of distributed data.
    let _ = ctx.sum(&out);
}
