//! Lattice-Boltzmann channel flow, D2Q9 and D3Q19 (paper Figs. 15–16).
//!
//! Faithful to the MATLAB-to-NumPy translations the paper benchmarks:
//! collision is a long stream of whole-array elementwise ufuncs (moment
//! sums, equilibrium distribution, BGK relaxation), and streaming is one
//! shifted copy per velocity direction. Shifts with a component along
//! the distributed dimension cross block boundaries ⇒ halo transfers.
//! Updating a site is expensive enough to amortize much of the
//! communication (Section 6.1.1: latency-hiding helps, but modestly —
//! wait 19% → 13% in 2D, 16% → 9% in 3D at 16 ranks).

//! The per-step outlet-density (mass) monitor reads `sum(rho)` — a
//! forced read per step in the original. Here it rides a deferred
//! [`ScalarFuture`] forced one step late: the reduction's fan-in drains
//! behind the next step's collision/streaming compute and the forced
//! read settles only the reduction's dependency cone
//! ([`crate::sync`]), not the whole timeline.

use crate::layout::ViewSpec;
use crate::lazy::{Context, ScalarFuture};
use crate::ufunc::Kernel;

use super::AppParams;

/// D2Q9 velocity set (x = distributed dim here).
const D2Q9: [(i64, i64); 9] = [
    (0, 0),
    (1, 0),
    (0, 1),
    (-1, 0),
    (0, -1),
    (1, 1),
    (-1, 1),
    (-1, -1),
    (1, -1),
];

/// D3Q19 velocity set.
fn d3q19() -> Vec<(i64, i64, i64)> {
    let mut v = vec![(0, 0, 0)];
    for d in 0..3 {
        for s in [-1i64, 1] {
            let mut c = [0i64; 3];
            c[d] = s;
            v.push((c[0], c[1], c[2]));
        }
    }
    for (a, b) in [(0, 1), (0, 2), (1, 2)] {
        for sa in [-1i64, 1] {
            for sb in [-1i64, 1] {
                let mut c = [0i64; 3];
                c[a] = sa;
                c[b] = sb;
                v.push((c[0], c[1], c[2]));
            }
        }
    }
    assert_eq!(v.len(), 19);
    v
}

/// Shifted source view for a displacement along each dim: the
/// destination is the interior; the source is offset by `-c` (pull
/// streaming).
fn shifted(v: &ViewSpec, shape: &[u64], c: &[i64]) -> (ViewSpec, ViewSpec) {
    let mut dst_ranges = Vec::new();
    let mut src_ranges = Vec::new();
    for (d, (&n, &cd)) in shape.iter().zip(c).enumerate() {
        let _ = d;
        match cd {
            0 => {
                dst_ranges.push((1, n - 1));
                src_ranges.push((1, n - 1));
            }
            1 => {
                dst_ranges.push((1, n - 1));
                src_ranges.push((0, n - 2));
            }
            -1 => {
                dst_ranges.push((1, n - 1));
                src_ranges.push((2, n));
            }
            _ => unreachable!(),
        }
    }
    (v.slice(&dst_ranges), v.slice(&src_ranges))
}

/// Record the collision ufunc stream over the population arrays.
fn collide(ctx: &mut Context, f: &[ViewSpec], rho: &ViewSpec, u: &[&ViewSpec], tmp: &ViewSpec) {
    // rho = Σ f_i
    ctx.copy(rho, &f[0]);
    for fi in &f[1..] {
        ctx.add(rho, rho, fi);
    }
    // velocity moments (one accumulation chain per dim).
    for ud in u {
        ctx.ufunc(Kernel::Sub, ud, &[&f[1], &f[2]]);
        ctx.ufunc(Kernel::Div, ud, &[ud, rho]);
    }
    // Per direction: feq assembly + BGK relaxation (4 ufuncs each).
    for fi in f {
        ctx.ufunc(Kernel::Mul, tmp, &[u[0], u[0]]);
        ctx.ufunc(Kernel::Axpy(0.5), tmp, &[tmp, rho]);
        ctx.ufunc(Kernel::Mul, tmp, &[tmp, rho]);
        ctx.ufunc(Kernel::Axpy(-1.0), fi, &[fi, tmp]);
    }
}

pub fn record_2d(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(1024);
    let shape = [n, n];
    let br = (n / 128).max(1);
    let f: Vec<ViewSpec> = (0..9).map(|_| ctx.zeros(&shape, br)).collect();
    let rho = ctx.zeros(&shape, br);
    let ux = ctx.zeros(&shape, br);
    let uy = ctx.zeros(&shape, br);
    let tmp = ctx.zeros(&shape, br);
    // circshift staging buffer: the MATLAB originals stream through a
    // fresh array, so the shifted copy must read pre-stream values (an
    // in-place shift would also serialize the blocks into a chain).
    let fs = ctx.zeros(&shape, br);

    let mut mass: Option<ScalarFuture> = None;
    for _ in 0..p.iters {
        collide(ctx, &f, &rho, &[&ux, &uy], &tmp);
        // Streaming: one shifted copy per non-rest direction. Shifts
        // with c_x ≠ 0 move data across row blocks (communication).
        for (i, &(cx, cy)) in D2Q9.iter().enumerate().skip(1) {
            ctx.copy(&fs, &f[i]);
            let (dst, src) = shifted(&fs, &shape, &[cx, cy]);
            let (fdst, _) = shifted(&f[i], &shape, &[cx, cy]);
            let _ = dst;
            ctx.copy(&fdst, &src);
        }
        // Mass monitor: force the previous step's deferred density
        // read (its fan-in had a whole step to drain), then issue this
        // step's.
        if let Some(fut) = mass.take() {
            let _ = ctx.wait_scalar(&fut);
        }
        mass = Some(ctx.sum_deferred(&rho));
    }
    if let Some(fut) = mass.take() {
        let _ = ctx.wait_scalar(&fut);
    }
    ctx.flush();
}

pub fn record_3d(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(256);
    let shape = [n, n / 2, n / 2];
    let br = (n / 128).max(1);
    let dirs = d3q19();
    let f: Vec<ViewSpec> = (0..19).map(|_| ctx.zeros(&shape, br)).collect();
    let rho = ctx.zeros(&shape, br);
    let ux = ctx.zeros(&shape, br);
    let uy = ctx.zeros(&shape, br);
    let uz = ctx.zeros(&shape, br);
    let tmp = ctx.zeros(&shape, br);

    let fs = ctx.zeros(&shape, br);
    let mut mass: Option<ScalarFuture> = None;
    for _ in 0..p.iters {
        collide(ctx, &f, &rho, &[&ux, &uy, &uz], &tmp);
        for (i, &(cx, cy, cz)) in dirs.iter().enumerate().skip(1) {
            ctx.copy(&fs, &f[i]);
            let (dst, src) = shifted(&fs, &shape, &[cx, cy, cz]);
            let (fdst, _) = shifted(&f[i], &shape, &[cx, cy, cz]);
            let _ = dst;
            ctx.copy(&fdst, &src);
        }
        if let Some(fut) = mass.take() {
            let _ = ctx.wait_scalar(&fut);
        }
        mass = Some(ctx.sum_deferred(&rho));
    }
    if let Some(fut) = mass.take() {
        let _ = ctx.wait_scalar(&fut);
    }
    ctx.flush();
}
