//! Jacobi solver, matrix-row-operation form (paper Fig. 17).
//!
//! The row-ops formulation updates the grid through whole-array shifted
//! copies into temporaries — the natural NumPy style before one thinks
//! in stencils. Per iteration: four shifted copies (the up/down pair
//! crosses block boundaries ⇒ halo communication), three adds, one
//! fused axpy, a copy-back and the convergence read. More memory traffic
//! than the stencil form (Fig. 18), hence the lower absolute speedup the
//! paper reports — but the same communication pattern, hence the same
//! dramatic latency-hiding win (wait 54% → 2% at 16 ranks).
//!
//! The convergence read is where the epochs/futures machinery earns its
//! keep: an *immediate* `sum_absdiff` per iteration erects a global
//! barrier per iteration ([`Convergence::EveryIteration`] — the paper's
//! behaviour and the harness default), while the pipelined variant
//! ([`Convergence::Pipelined`]) issues a *deferred* reduction every `k`
//! iterations and forces it one check-interval later, so the fan-in
//! drains behind subsequent iterations' compute and the timeline
//! barriers ~`iters/k` times instead of `iters` times
//! (`benches/ablation_epochs.rs` measures the difference).

use crate::lazy::{Context, ScalarFuture};
use crate::ufunc::Kernel;

use super::AppParams;

/// How the solver checks convergence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Convergence {
    /// Immediate `sum_absdiff` every iteration: flush + barrier per
    /// iteration (the paper's §5.6 flush-on-read behaviour).
    EveryIteration,
    /// Deferred `sum_absdiff` every `every` iterations, forced one
    /// check-interval later through a [`ScalarFuture`].
    Pipelined { every: u32 },
}

/// What one recorded solver run exposes to callers that want to check
/// numerics: the grid base and every convergence delta actually read
/// (iteration index, value). Reads that failed (poisoned context) are
/// omitted — the error surfaces through `Context::finish`.
pub struct JacobiRun {
    pub grid: crate::types::BaseId,
    pub deltas: Vec<(u32, f64)>,
}

pub fn record(ctx: &mut Context, p: &AppParams) {
    record_with(ctx, p, Convergence::EveryIteration);
}

/// Record the full solver with an explicit convergence-check policy.
pub fn record_with(ctx: &mut Context, p: &AppParams, conv: Convergence) {
    let _ = record_observed(ctx, p, conv, None);
}

/// [`record_with`] exposing the observed deltas and the grid base, with
/// an optional initial grid (`init` must hold `n × n` values, `n =
/// p.dim(4096)`) — the single source of truth for the iteration body,
/// shared by the harness runs and the `ablation_epochs` bit-identity
/// check so the bench exercises exactly the shipped loop.
pub fn record_observed(
    ctx: &mut Context,
    p: &AppParams,
    conv: Convergence,
    init: Option<&[f32]>,
) -> JacobiRun {
    let n = p.dim(4096);
    let br = (n / 256).max(1);
    let g = match init {
        Some(data) => ctx.array(&[n, n], br, data), // seeded grid
        None => ctx.zeros(&[n, n], br),             // full grid, zeros
    };
    let m = n - 2; // interior extent

    // Temporaries are allocated once and recycled (DistNumPy's lazy
    // de-allocation, Section 6.1.1).
    let up = ctx.zeros(&[m, m], br);
    let acc = ctx.zeros(&[m, m], br);
    let work = ctx.zeros(&[m, m], br);

    // Interior views of the grid (offset by one in each direction).
    let v_c = g.slice(&[(1, n - 1), (1, n - 1)]);
    let v_up = g.slice(&[(0, n - 2), (1, n - 1)]);
    let v_dn = g.slice(&[(2, n), (1, n - 1)]);
    let v_lf = g.slice(&[(1, n - 1), (0, n - 2)]);
    let v_rt = g.slice(&[(1, n - 1), (2, n)]);

    let mut deltas = Vec::new();
    let mut pending: Option<(u32, ScalarFuture)> = None;
    for it in 0..p.iters {
        // Row operations: shifted copies into temps, then accumulate.
        // Each shifted copy lands in a temp whose rows are offset by
        // one against the grid's blocks -> every copy carries a halo
        // row across a block boundary (the row-ops formulation moves
        // more data than the fused stencil of Fig. 18).
        ctx.copy(&up, &v_up);
        ctx.copy(&acc, &v_dn);
        ctx.add(&acc, &acc, &up);
        ctx.copy(&up, &v_lf);
        ctx.add(&acc, &acc, &up);
        ctx.copy(&up, &v_rt);
        ctx.add(&acc, &acc, &up);
        // work = cells + 0.2*acc  (the 0.2·Σ update of Fig. 10).
        ctx.ufunc(Kernel::Copy, &work, &[&v_c]);
        ctx.ufunc(Kernel::Axpy(0.2), &work, &[&work, &acc]);
        // delta = sum(|cells - work|): the convergence read.
        match conv {
            Convergence::EveryIteration => {
                if let Ok(d) = ctx.sum_absdiff(&v_c, &work) {
                    deltas.push((it, d));
                }
            }
            Convergence::Pipelined { every } => {
                if (it + 1) % every.max(1) == 0 {
                    // Force the delta issued one interval ago (its
                    // fan-in has had `every` iterations to drain), then
                    // issue this interval's — no barrier in between.
                    if let Some((at, f)) = pending.take() {
                        if let Ok(d) = ctx.wait_scalar(&f) {
                            deltas.push((at, d));
                        }
                    }
                    pending = Some((it, ctx.sum_absdiff_deferred(&v_c, &work)));
                }
            }
        }
        // cells[:] = work (write back into the grid interior).
        ctx.copy(&v_c, &work);
    }
    if let Some((at, f)) = pending.take() {
        if let Ok(d) = ctx.wait_scalar(&f) {
            deltas.push((at, d));
        }
    }
    ctx.flush();
    JacobiRun {
        grid: g.base,
        deltas,
    }
}
