//! Jacobi solver, matrix-row-operation form (paper Fig. 17).
//!
//! The row-ops formulation updates the grid through whole-array shifted
//! copies into temporaries — the natural NumPy style before one thinks
//! in stencils. Per iteration: four shifted copies (the up/down pair
//! crosses block boundaries ⇒ halo communication), three adds, one
//! fused axpy, a copy-back and the convergence read that flushes the
//! batch. More memory traffic than the stencil form (Fig. 18), hence
//! the lower absolute speedup the paper reports — but the same
//! communication pattern, hence the same dramatic latency-hiding win
//! (wait 54% → 2% at 16 ranks).

use crate::lazy::Context;
use crate::ufunc::Kernel;

use super::AppParams;

pub fn record(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(4096);
    let br = (n / 256).max(1);
    let g = ctx.zeros(&[n, n], br); // full grid
    let m = n - 2; // interior extent

    // Temporaries are allocated once and recycled (DistNumPy's lazy
    // de-allocation, Section 6.1.1).
    let up = ctx.zeros(&[m, m], br);
    let acc = ctx.zeros(&[m, m], br);
    let work = ctx.zeros(&[m, m], br);

    // Interior views of the grid (offset by one in each direction).
    let v_c = g.slice(&[(1, n - 1), (1, n - 1)]);
    let v_up = g.slice(&[(0, n - 2), (1, n - 1)]);
    let v_dn = g.slice(&[(2, n), (1, n - 1)]);
    let v_lf = g.slice(&[(1, n - 1), (0, n - 2)]);
    let v_rt = g.slice(&[(1, n - 1), (2, n)]);

    for _ in 0..p.iters {
        // Row operations: shifted copies into temps, then accumulate.
        // Each shifted copy lands in a temp whose rows are offset by
        // one against the grid's blocks -> every copy carries a halo
        // row across a block boundary (the row-ops formulation moves
        // more data than the fused stencil of Fig. 18).
        ctx.copy(&up, &v_up);
        ctx.copy(&acc, &v_dn);
        ctx.add(&acc, &acc, &up);
        ctx.copy(&up, &v_lf);
        ctx.add(&acc, &acc, &up);
        ctx.copy(&up, &v_rt);
        ctx.add(&acc, &acc, &up);
        // work = cells + 0.2*acc  (the 0.2·Σ update of Fig. 10).
        ctx.ufunc(Kernel::Copy, &work, &[&v_c]);
        ctx.ufunc(Kernel::Axpy(0.2), &work, &[&work, &acc]);
        // delta = sum(|cells - work|): the convergence read -> flush.
        let _ = ctx.sum_absdiff(&v_c, &work);
        // cells[:] = work (write back into the grid interior).
        ctx.copy(&v_c, &work);
    }
    ctx.flush();
}
