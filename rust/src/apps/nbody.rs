//! Newtonian N-body, all-pairs (paper Fig. 13).
//!
//! Following Section 6.1.1, the dominating operations are
//! matrix-multiplications executed through the SUMMA algorithm; the
//! per-body state updates are cheap aligned vector ufuncs. O(n²) compute
//! over O(n) data ⇒ scalable even without latency-hiding — the paper's
//! point, which Fig. 13 (and our reproduction) shows as near-identical
//! latency-hiding vs blocking curves (blocking marginally ahead due to
//! runtime overhead).

//! The per-step energy check is a deferred [`ScalarFuture`] forced one
//! step late: its fan-in drains behind the next step's SUMMA products
//! and the forced read settles only the reduction's dependency cone
//! ([`crate::sync`]).

use crate::lazy::{Context, ScalarFuture};
use crate::summa::record_matmul;
use crate::ufunc::Kernel;

use super::AppParams;

pub fn record(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(1024);
    let br = (n / 128).max(1);

    // Interaction matrices (n×n) and body-state vectors (n).
    let r2 = ctx.zeros(&[n, n], br); // pairwise distance products
    let f = ctx.zeros(&[n, n], br); // force contributions
    let w = ctx.zeros(&[n, n], br); // mass outer-product weights
    let pos = ctx.zeros(&[n], br);
    let vel = ctx.zeros(&[n], br);
    let acc = ctx.zeros(&[n], br);

    let mut energy: Option<ScalarFuture> = None;
    for _ in 0..p.iters {
        // Pairwise geometry + force tiles: two SUMMA products, as in the
        // MATLAB translation (distance matrix, then force aggregation).
        let collective = ctx.cfg.collective;
        record_matmul(&mut ctx.builder, &ctx.reg, r2.base, w.base, f.base, collective);
        record_matmul(&mut ctx.builder, &ctx.reg, f.base, r2.base, w.base, collective);
        // Body updates: aligned vector ops.
        ctx.ufunc(Kernel::Axpy(0.5), &acc, &[&acc, &pos]);
        ctx.ufunc(Kernel::Axpy(0.01), &vel, &[&vel, &acc]);
        ctx.ufunc(Kernel::Axpy(0.01), &pos, &[&pos, &vel]);
        // Energy check each step: force the previous step's deferred
        // read, issue this step's.
        if let Some(fut) = energy.take() {
            let _ = ctx.wait_scalar(&fut);
        }
        energy = Some(ctx.sum_deferred(&vel));
    }
    if let Some(fut) = energy.take() {
        let _ = ctx.wait_scalar(&fut);
    }
}
