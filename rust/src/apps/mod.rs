//! The eight benchmark applications of the paper's evaluation
//! (Section 6, Figs. 11–18).
//!
//! Each app records exactly the stream of array operations its
//! NumPy/DistNumPy original issues — same views, same temporaries, same
//! per-iteration convergence reads — through the lazy [`Context`]. Apps
//! are agnostic to the backend: under [`crate::exec::SimBackend`] they
//! drive the strong-scaling figures; under a data backend they compute
//! real numerics (used by the examples and the e2e tests).
//!
//! | App            | Complexity | Communication          | Paper figure |
//! |----------------|-----------|-------------------------|--------------|
//! | fractal        | O(n) heavy| none                    | Fig. 11      |
//! | black_scholes  | O(n) heavy| none                    | Fig. 12      |
//! | nbody          | O(n²)     | SUMMA broadcasts        | Fig. 13      |
//! | knn            | O(n²)     | SUMMA broadcasts        | Fig. 14      |
//! | lbm2d          | O(n)      | streaming halos         | Fig. 15      |
//! | lbm3d          | O(n)      | streaming halos         | Fig. 16      |
//! | jacobi         | O(n) small| row-shift halos         | Fig. 17      |
//! | jacobi_stencil | O(n) small| 5-point stencil halos   | Fig. 18      |

mod black_scholes;
mod fractal;
mod jacobi;
mod jacobi_stencil;
mod knn;
mod lbm;
mod nbody;

pub use jacobi::{
    record_observed as record_jacobi_observed, record_with as record_jacobi_with, Convergence,
    JacobiRun,
};
pub use jacobi_stencil::record_jacobi_stencil_iteration;

use crate::lazy::Context;

/// Which benchmark to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppId {
    Fractal,
    BlackScholes,
    Nbody,
    Knn,
    Lbm2d,
    Lbm3d,
    Jacobi,
    JacobiStencil,
}

impl AppId {
    pub fn all() -> [AppId; 8] {
        [
            AppId::Fractal,
            AppId::BlackScholes,
            AppId::Nbody,
            AppId::Knn,
            AppId::Lbm2d,
            AppId::Lbm3d,
            AppId::Jacobi,
            AppId::JacobiStencil,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            AppId::Fractal => "fractal",
            AppId::BlackScholes => "black_scholes",
            AppId::Nbody => "nbody",
            AppId::Knn => "knn",
            AppId::Lbm2d => "lbm2d",
            AppId::Lbm3d => "lbm3d",
            AppId::Jacobi => "jacobi",
            AppId::JacobiStencil => "jacobi_stencil",
        }
    }

    pub fn parse(s: &str) -> Option<AppId> {
        AppId::all().into_iter().find(|a| a.name() == s)
    }

    /// The paper figure this app reproduces.
    pub fn figure(self) -> u32 {
        match self {
            AppId::Fractal => 11,
            AppId::BlackScholes => 12,
            AppId::Nbody => 13,
            AppId::Knn => 14,
            AppId::Lbm2d => 15,
            AppId::Lbm3d => 16,
            AppId::Jacobi => 17,
            AppId::JacobiStencil => 18,
        }
    }
}

/// Problem sizing. `scale = 1.0` is the figure-generation default —
/// chosen so every P ≤ 128 keeps ≥ 2 blocks per rank (strong scaling,
/// Section 6.1.2) while a full sweep stays tractable on one host core.
#[derive(Clone, Copy, Debug)]
pub struct AppParams {
    pub scale: f64,
    pub iters: u32,
}

impl Default for AppParams {
    fn default() -> Self {
        AppParams {
            scale: 1.0,
            iters: 10,
        }
    }
}

impl AppParams {
    pub fn tiny() -> Self {
        AppParams {
            scale: 0.05,
            iters: 2,
        }
    }

    /// Problem dimension for a given base size (pub so external callers
    /// — e.g. the epochs ablation seeding a grid — can size inputs).
    pub fn dim(&self, base: u64) -> u64 {
        ((base as f64 * self.scale) as u64).max(8)
    }
}

/// Record one full benchmark run into the context.
pub fn record(app: AppId, ctx: &mut Context, p: &AppParams) {
    match app {
        AppId::Fractal => fractal::record(ctx, p),
        AppId::BlackScholes => black_scholes::record(ctx, p),
        AppId::Nbody => nbody::record(ctx, p),
        AppId::Knn => knn::record(ctx, p),
        AppId::Lbm2d => lbm::record_2d(ctx, p),
        AppId::Lbm3d => lbm::record_3d(ctx, p),
        AppId::Jacobi => jacobi::record(ctx, p),
        AppId::JacobiStencil => jacobi_stencil::record(ctx, p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;
    use crate::sched::{Policy, SchedCfg};

    fn run_app(app: AppId, p: u32) -> crate::metrics::RunReport {
        let mut ctx = Context::sim(
            SchedCfg::new(MachineSpec::tiny(), p),
            Policy::LatencyHiding,
        );
        record(app, &mut ctx, &AppParams::tiny());
        ctx.finish().expect("app run completes")
    }

    #[test]
    fn every_app_completes_on_four_ranks() {
        for app in AppId::all() {
            let rep = run_app(app, 4);
            assert!(rep.ops_executed > 0, "{} executed nothing", app.name());
        }
    }

    #[test]
    fn every_app_completes_on_one_rank_without_comm() {
        for app in AppId::all() {
            let rep = run_app(app, 1);
            assert_eq!(
                rep.bytes_inter + rep.bytes_intra,
                0,
                "{} at P=1 must not communicate",
                app.name()
            );
        }
    }

    #[test]
    fn embarrassingly_parallel_apps_have_little_comm() {
        for app in [AppId::Fractal, AppId::BlackScholes] {
            let rep = run_app(app, 4);
            // Only the per-iteration scalar reductions communicate.
            let per_op = rep.bytes_inter as f64 / rep.n_compute.max(1) as f64;
            assert!(
                per_op < 64.0,
                "{}: {} bytes/op is too much for an EP app",
                app.name(),
                per_op
            );
        }
    }

    #[test]
    fn stencil_apps_communicate() {
        for app in [AppId::Jacobi, AppId::JacobiStencil, AppId::Lbm2d] {
            let rep = run_app(app, 4);
            assert!(
                rep.bytes_inter > 0,
                "{} on 4 ranks must exchange halos",
                app.name()
            );
        }
    }

    #[test]
    fn parse_roundtrip() {
        for app in AppId::all() {
            assert_eq!(AppId::parse(app.name()), Some(app));
        }
        assert_eq!(AppId::parse("nope"), None);
    }

    #[test]
    fn figures_are_distinct() {
        let mut f: Vec<u32> = AppId::all().iter().map(|a| a.figure()).collect();
        f.sort();
        f.dedup();
        assert_eq!(f.len(), 8);
    }
}
