//! Jacobi solver, stencil form (paper Figs. 10 and 18) — the headline
//! application: wait time drops 62% → 9% at 16 ranks (87% → 41% at 128)
//! and speedup goes 7.7 → 18.4 with latency-hiding.
//!
//! One fused 5-point stencil operation per iteration consumes the five
//! shifted interior views of the grid (L1 kernel:
//! `kernels/stencil.py::stencil5`); the up/down views are non-aligned
//! with the output ⇒ halo transfers that the latency-hiding scheduler
//! overlaps with the interior fragments' compute.

use crate::layout::ViewSpec;
use crate::lazy::Context;
use crate::ufunc::Kernel;

use super::AppParams;

/// Views of the full grid used by one stencil sweep.
pub struct StencilViews {
    pub center: ViewSpec,
    pub up: ViewSpec,
    pub down: ViewSpec,
    pub left: ViewSpec,
    pub right: ViewSpec,
}

pub fn views_of(g: &ViewSpec, n: u64) -> StencilViews {
    StencilViews {
        center: g.slice(&[(1, n - 1), (1, n - 1)]),
        up: g.slice(&[(0, n - 2), (1, n - 1)]),
        down: g.slice(&[(2, n), (1, n - 1)]),
        left: g.slice(&[(1, n - 1), (0, n - 2)]),
        right: g.slice(&[(1, n - 1), (2, n)]),
    }
}

/// Record one sweep: `work = 0.2*(c+u+d+l+r)`, convergence delta,
/// write-back. Returns the delta (real backends; 0.0 in simulation) —
/// used by the e2e example to iterate to convergence — or the flush
/// error if the schedule failed (the read no longer swallows it).
pub fn record_jacobi_stencil_iteration(
    ctx: &mut Context,
    g: &ViewSpec,
    work: &ViewSpec,
    n: u64,
) -> Result<f64, crate::sched::SchedError> {
    let v = views_of(g, n);
    ctx.ufunc(
        Kernel::Stencil5,
        work,
        &[&v.center, &v.up, &v.down, &v.left, &v.right],
    );
    let delta = ctx.sum_absdiff(&v.center, work);
    ctx.copy(&v.center, work);
    delta
}

pub fn record(ctx: &mut Context, p: &AppParams) {
    let n = p.dim(4096);
    let br = (n / 256).max(1);
    let g = ctx.zeros(&[n, n], br);
    let work = ctx.zeros(&[n - 2, n - 2], br);

    for _ in 0..p.iters {
        let _ = record_jacobi_stencil_iteration(ctx, &g, &work, n);
    }
    ctx.flush();
}
