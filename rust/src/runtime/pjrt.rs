//! The PJRT engine proper (compiled only with the `pjrt` feature).
//!
//! The interchange format is HLO **text** (`artifacts/*.hlo.txt`), not a
//! serialized `HloModuleProto`: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that the crate's xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md). Each
//! artifact is compiled once per process by [`PjrtEngine::load`]; the
//! request path only executes.
//!
//! [`PjrtBackend`] plugs the engine into the scheduler: compute tasks
//! whose kernel and block shape match an artifact contract run through
//! PJRT; everything else falls back to the native Rust kernels (the two
//! paths agree numerically — asserted by `rust/tests/e2e.rs`).

use super::artifacts::{artifact_inputs, ARTIFACT_NAMES};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context as _, Result};

use crate::array::ClusterStore;
use crate::exec::{kernels, Backend, NativeBackend};
use crate::layout::Layout;
use crate::types::{Rank, Tag};
use crate::ufunc::{ComputeTask, Kernel, SendSrc};

/// A compiled artifact plus its input-shape contract.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    /// Expected input shapes (row-major dims per input).
    inputs: Vec<Vec<usize>>,
}

/// Loads and executes the AOT artifacts on the PJRT CPU client.
pub struct PjrtEngine {
    exes: HashMap<&'static str, Compiled>,
}

impl PjrtEngine {
    /// Compile every artifact found in `dir`. Missing files are skipped
    /// (their kernels fall back to native execution).
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = HashMap::new();
        for &name in ARTIFACT_NAMES {
            let path = dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf-8 path"),
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            exes.insert(
                name,
                Compiled {
                    exe,
                    inputs: artifact_inputs(name),
                },
            );
        }
        Ok(PjrtEngine { exes })
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn loaded(&self) -> usize {
        self.exes.len()
    }

    /// Do `inputs` (flat buffers) match the artifact's shape contract?
    pub fn matches(&self, name: &str, input_lens: &[usize]) -> bool {
        match self.exes.get(name) {
            None => false,
            Some(c) => {
                c.inputs.len() == input_lens.len()
                    && c.inputs
                        .iter()
                        .zip(input_lens)
                        .all(|(dims, len)| dims.iter().product::<usize>() == *len)
            }
        }
    }

    /// Execute one artifact on flat f32 buffers; returns the first
    /// (only) tuple element, flattened.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let c = self
            .exes
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, dims) in inputs.iter().zip(&c.inputs) {
            let lit = xla::Literal::vec1(buf);
            let shaped = if dims.len() > 1 {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64)?
            } else {
                lit
            };
            literals.push(shaped);
        }
        let result = c.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // AOT contract: every artifact returns a tuple (gen via
        // return_tuple=True); ours are all 1-tuples.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Scheduler backend dispatching to PJRT where an artifact matches.
pub struct PjrtBackend {
    native: NativeBackend,
    engine: PjrtEngine,
    /// Compute ops executed through PJRT vs the native fallback.
    pub dispatched: u64,
    pub fallback: u64,
}

impl PjrtBackend {
    pub fn new(store: ClusterStore, engine: PjrtEngine) -> Self {
        PjrtBackend {
            native: NativeBackend::new(store),
            engine,
            dispatched: 0,
            fallback: 0,
        }
    }

    pub fn store(&self) -> &ClusterStore {
        &self.native.store
    }

    /// Artifact eligibility: kernel has an artifact, parameters match the
    /// baked constants, shapes match the contract.
    fn artifact_for(&self, task: &ComputeTask, input_lens: &[usize]) -> Option<&'static str> {
        let name = task.kernel.artifact()?;
        // Baked-constant kernels only match their compiled parameters.
        match task.kernel {
            Kernel::Axpy(a) if a != 0.2 => return None,
            Kernel::Fractal(it) if it != 32 => return None,
            _ => {}
        }
        if self.engine.matches(name, input_lens) {
            Some(name)
        } else {
            None
        }
    }
}

impl Backend for PjrtBackend {
    fn exec_compute(&mut self, rank: Rank, task: &ComputeTask) {
        let inputs = NativeBackend::gather_inputs(&self.native.store, rank, task);
        let lens: Vec<usize> = inputs.iter().map(|b| b.len()).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = match self.artifact_for(task, &lens) {
            Some(name) => match self.engine.execute(name, &refs) {
                Ok(out) => {
                    self.dispatched += 1;
                    out
                }
                Err(e) => {
                    // A PJRT failure is a bug, not a fallback case — but
                    // keep the run alive and surface it loudly.
                    eprintln!("PJRT execution of {name} failed: {e:#}");
                    self.fallback += 1;
                    kernels::run(task.kernel, &refs, task.elems as usize)
                }
            },
            None => {
                self.fallback += 1;
                kernels::run(task.kernel, &refs, task.elems as usize)
            }
        };
        NativeBackend::write_dst(&mut self.native.store, rank, &task.dst, out);
    }

    fn exec_transfer(&mut self, from: Rank, to: Rank, tag: Tag, src: &SendSrc) {
        self.native.exec_transfer(from, to, tag, src);
    }

    fn staged_scalar(&self, rank: Rank, tag: Tag) -> Option<f64> {
        self.native.staged_scalar(rank, tag)
    }

    fn staged_data(&self, rank: Rank, tag: Tag) -> Option<Vec<f32>> {
        self.native.staged_data(rank, tag)
    }

    fn materializes_data(&self) -> bool {
        true
    }

    fn alloc_base(&mut self, layout: &Layout) {
        self.native.alloc_base(layout);
    }

    fn scatter(&mut self, layout: &Layout, data: &[f32]) {
        self.native.scatter(layout, data);
    }

    fn gather(&self, layout: &Layout) -> Option<Vec<f32>> {
        self.native.gather(layout)
    }

    fn drop_stage(&mut self, rank: Rank, tag: Tag) {
        self.native.drop_stage(rank, tag);
    }

    fn clear_stages(&mut self) {
        self.native.clear_stages();
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}
