//! PJRT runtime: load the AOT HLO artifacts produced by the JAX/Pallas
//! layer and execute them from the Rust hot path.
//!
//! The artifact *contracts* ([`ARTIFACT_NAMES`], [`artifact_inputs`])
//! are always available — the Python side and the tests cross-check
//! them. The PJRT engine itself ([`PjrtEngine`], [`PjrtBackend`]) needs
//! the `xla` bindings, which the offline build environment does not
//! ship; it is gated behind the `pjrt` cargo feature (see
//! `rust/Cargo.toml`). Without the feature, data-backed runs use
//! [`crate::exec::NativeBackend`] — the same numerics the e2e suite
//! asserts the artifacts against.

mod artifacts;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use artifacts::{artifact_inputs, ARTIFACT_NAMES};
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtBackend, PjrtEngine};

/// Default artifact directory: `$DISTNUMPY_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var_os("DISTNUMPY_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| "artifacts".into())
}
