//! The artifact shape contracts — the Rust mirror of
//! `python/compile/model.py::ARTIFACTS`. Changing either side requires a
//! coordinated change; `rust/tests/e2e.rs` cross-checks against
//! `artifacts/manifest.json`.

/// Block edge of the 2-D AOT artifacts (model.py BS).
pub const BS: usize = 64;
/// Length of the 1-D AOT artifacts (model.py BS1).
pub const BS1: usize = 4096;

/// Artifacts the Rust runtime knows how to drive (subset of the full
/// AOT set: multi-output graphs like `nbody` are exercised from the
/// Python tests only).
pub const ARTIFACT_NAMES: &[&str] = &[
    "add1d",
    "add2d",
    "sub2d",
    "mul2d",
    "axpy1d",
    "stencil3",
    "stencil5",
    "stencil5v",
    "jacobi_row",
    "black_scholes",
    "knn",
    "lbm_d2q9",
    "matmul",
    "fractal",
];

/// Input shapes (row-major dims) per artifact.
pub fn artifact_inputs(name: &str) -> Vec<Vec<usize>> {
    match name {
        "add1d" | "axpy1d" => vec![vec![BS1]; 2],
        "add2d" | "sub2d" | "mul2d" => vec![vec![BS, BS]; 2],
        "stencil3" => vec![vec![BS]; 2],
        "stencil5" => vec![vec![BS + 2, BS + 2]],
        "stencil5v" => vec![vec![BS, BS]; 5],
        "jacobi_row" => vec![vec![BS], vec![BS, BS], vec![BS], vec![BS]],
        "black_scholes" => vec![vec![BS1]; 3],
        "knn" => vec![vec![BS, 4]; 2],
        "lbm_d2q9" => vec![vec![9, BS, BS]],
        "matmul" => vec![vec![BS, BS]; 3],
        "fractal" => vec![vec![BS, BS]; 2],
        _ => vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_artifact_has_shapes() {
        for name in ARTIFACT_NAMES {
            assert!(
                !artifact_inputs(name).is_empty(),
                "missing contract for {name}"
            );
        }
    }

    #[test]
    fn unknown_artifact_is_empty() {
        assert!(artifact_inputs("nope").is_empty());
    }
}
