//! The coordinator CLI: run benchmarks, sweeps and reports from the
//! command line. Argument parsing is hand-rolled (offline environment,
//! no clap) but follows the usual `--flag value` conventions.

pub mod cli;

pub use cli::{main_with_args, Cli};
