//! Command-line interface.
//!
//! ```text
//! distnumpy run    --app jacobi_stencil --procs 16 [--policy lh|blocking|naive]
//!                  [--placement by-node|by-core] [--scale 1.0] [--iters 10]
//!                  [--deps heuristic|dag] [--json]
//! distnumpy analyze [--app jacobi] [--deps heuristic|dag|both] [--procs 16] [--json]
//! distnumpy compare baseline.json new.json [--threshold 0.1] [--json]
//! distnumpy diff   base.json new.json [--trace base_tr.json new_tr.json] [--json]
//! distnumpy sweep  --app jacobi_stencil [--procs 1,2,4,8,16,32,64,128] [--json]
//! distnumpy report wait [--procs 16]
//! distnumpy fig19  [--procs 8,16,32,64,128]
//! distnumpy machine
//! ```

use std::collections::HashMap;

use crate::apps::{AppId, AppParams};
use crate::cluster::{MachineSpec, Placement};
use crate::comm::Collective;
use crate::harness;
use crate::sched::{DepsKind, Policy, SchedCfg, SyncMode};
use crate::util::json::Json;

/// Parsed command line.
pub struct Cli {
    pub cmd: String,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut it = args.iter();
        let cmd = it.next().cloned().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let rest: Vec<&String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = rest[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Cli {
            cmd,
            flags,
            positional,
        })
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn procs_list(&self, default: &[u32]) -> Vec<u32> {
        match self.flag("procs") {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect(),
        }
    }

    fn params(&self) -> AppParams {
        let mut p = AppParams::default();
        if let Some(s) = self.flag("scale") {
            p.scale = s.parse().unwrap_or(1.0);
        }
        if let Some(s) = self.flag("iters") {
            p.iters = s.parse().unwrap_or(10);
        }
        p
    }

    fn app(&self) -> Result<AppId, String> {
        let name = self.flag("app").ok_or("missing --app")?;
        AppId::parse(name).ok_or_else(|| format!("unknown app '{name}'"))
    }
}

const HELP: &str = "\
distnumpy — runtime-managed communication latency-hiding (HPCC'12 repro)

USAGE:
  distnumpy run    --app <name> --procs <P> [--policy lh|blocking|naive]
                   [--placement by-node|by-core] [--scale S] [--iters N]
                   [--locality] [--collective flat|tree] [--agg N]
                   [--sync cone|barrier] [--flush-threshold N]
                   [--flow [W|flow|batch|sliding|auto|MODE:W]]
                       # incremental flush engine: W = quantized window
                       # (default 2), sliding = stream epochs into the
                       # live scheduler session, auto = sliding with an
                       # adaptively-steered window
                   [--trace FILE]
                       # write a Chrome-trace-event / Perfetto timeline
                       # (open at https://ui.perfetto.dev); also folds a
                       # critical-path report + per-epoch series into
                       # --json output (bare --trace writes trace.json)
                   [--deps heuristic|dag] [--verify]
                       # --verify re-checks every drained wave against
                       # the exact-conflict hazard oracle (hard error
                       # on a missed dependency edge)
                   [--profile]
                       # host-side self-profiler: wall time per
                       # scheduler phase (record/admit/inject/pump/
                       # drain/verify) + events/sec, in a \"host\"
                       # section of the JSON report; simulated clocks
                       # are untouched
                   [--workers N]
                       # host workers driving the event engine. 1
                       # (default) = the serial reference engine; N >= 2
                       # shards the event queue into per-rank actors
                       # drained by a deterministic work-stealing pool —
                       # simulated results stay bit-identical. With
                       # --profile, per-worker events/sec + steal_count
                       # join the host section
                   [--json]
  distnumpy analyze [--app <name>] [--deps heuristic|dag|both] [--procs P]
                    [--scale S] [--iters N] [--json]
                       # static analysis over the recorded op streams:
                       # race check vs the exact conflict closure,
                       # naive-deadlock prediction, overlap lints.
                       # Default: all apps, both dep systems. Exits
                       # non-zero on any race or predicted lh stall.
  distnumpy compare <baseline.json> <new.json> [--threshold 0.1] [--json]
                       # perf-regression gate: compares two run/bench
                       # JSON reports metric-by-metric (whitelisted,
                       # direction-aware) and exits non-zero when any
                       # metric regresses beyond the relative threshold
  distnumpy diff <base.json> <new.json> [--trace <base_tr.json> <new_tr.json>] [--json]
                       # regression explainer: aligns two run reports
                       # epoch-by-epoch on their ledgers and attributes
                       # the makespan/wait delta into ranked per-epoch
                       # deltas, a cause-shift table, and scalar deltas;
                       # with --trace timelines also names the top
                       # divergent ops and the critical-path drift.
                       # Exits non-zero only on malformed or
                       # unalignable inputs — a large delta is a
                       # successful analysis
  distnumpy sweep  --app <name> [--procs 1,2,4,...] [--scale S] [--iters N] [--json]
  distnumpy pipeline [--procs 1,2,4,...] [--ks 1,2,4,8,16]
                                             # Jacobi staleness/wait trade-off (JSON)
  distnumpy report wait [--procs P]          # Section 6.1.1 waiting-time table
  distnumpy fig19  [--procs 8,16,...]        # by-node vs by-core (N-body)
  distnumpy machine                          # print the Table 1 machine model
  distnumpy apps                             # list benchmark apps

APPS: fractal black_scholes nbody knn lbm2d lbm3d jacobi jacobi_stencil
";

/// Entry point (also used by tests). Returns the process exit code.
pub fn main_with_args(args: &[String]) -> i32 {
    let cli = match Cli::parse(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match run(&cli) {
        Ok(out) => {
            println!("{out}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            2
        }
    }
}

fn run(cli: &Cli) -> Result<String, String> {
    let spec = MachineSpec::paper();
    match cli.cmd.as_str() {
        "run" => {
            let app = cli.app()?;
            let p: u32 = cli
                .flag("procs")
                .unwrap_or("16")
                .parse()
                .map_err(|_| "bad --procs")?;
            let policy = Policy::parse(cli.flag("policy").unwrap_or("lh"))
                .ok_or("bad --policy")?;
            let placement = Placement::parse(cli.flag("placement").unwrap_or("by-node"))
                .ok_or("bad --placement")?;
            let params = cli.params();
            // Scale studies may push P past the paper's 128-core
            // testbed; grow a local copy of the machine (same per-node
            // calibration) rather than rejecting the run.
            let run_spec = if p > spec.max_ranks() {
                spec.with_capacity(p)
            } else {
                spec.clone()
            };
            let mut cfg = SchedCfg::new(run_spec, p);
            cfg.placement = placement;
            cfg.locality = cli.flag("locality").is_some();
            cfg.collective = Collective::parse(cli.flag("collective").unwrap_or("flat"))
                .ok_or("bad --collective")?;
            if let Some(a) = cli.flag("agg") {
                cfg.aggregation = a.parse().map_err(|_| "bad --agg")?;
            }
            cfg.sync = SyncMode::parse(cli.flag("sync").unwrap_or("cone")).ok_or("bad --sync")?;
            cfg.deps =
                DepsKind::parse(cli.flag("deps").unwrap_or("heuristic")).ok_or("bad --deps")?;
            // `--verify` re-runs the hazard oracle on every drained
            // wave; a missed dependency edge aborts the run.
            cfg.verify_deps = cli.flag("verify").is_some();
            // `--profile` turns on the host-side self-profiler: wall
            // time per scheduler phase + events/sec, in a "host"
            // section of the JSON report. Virtual time is untouched.
            cfg.profile.enabled = cli.flag("profile").is_some();
            // `--workers N` (N ≥ 2) swaps the global event heap for the
            // sharded per-rank actor queue drained by a deterministic
            // work-stealing worker pool. Simulated results are
            // bit-identical; only host-side wall time changes.
            if let Some(w) = cli.flag("workers") {
                cfg.workers = w.parse().map_err(|_| "bad --workers")?;
                if cfg.workers == 0 {
                    return Err("bad --workers (need at least 1)".into());
                }
            }
            if let Some(t) = cli.flag("flush-threshold") {
                cfg.flush_threshold = t.parse().map_err(|_| "bad --flush-threshold")?;
            }
            if let Some(w) = cli.flag("flow") {
                // `--flow` alone parses as "true": default window.
                // Also accepts a mode by name (`--flow batch` pins the
                // reference path, `--flow flow` = quantized waves,
                // `--flow sliding` = splice epochs into the live
                // session, `--flow auto` = sliding + adaptive window,
                // `--flow sliding:W` / `--flow flow:W` pin the window).
                cfg.flow = if w == "true" {
                    crate::flow::FlowCfg::flow(2)
                } else if w == "auto" {
                    crate::flow::FlowCfg::sliding_auto()
                } else if let Some(mode) = crate::flow::FlowMode::parse(w) {
                    crate::flow::FlowCfg {
                        mode,
                        ..crate::flow::FlowCfg::flow(2)
                    }
                } else if let Some((mode, win)) = w.split_once(':') {
                    let mode =
                        crate::flow::FlowMode::parse(mode).ok_or("bad --flow mode")?;
                    let window: usize = win.parse().map_err(|_| "bad --flow window")?;
                    crate::flow::FlowCfg {
                        mode,
                        window: crate::flow::FlowWindow::Fixed(window.max(1)),
                    }
                } else {
                    let window = w.parse().map_err(|_| "bad --flow window")?;
                    crate::flow::FlowCfg::flow(window)
                };
            }
            // `--trace FILE` enables the event sink; bare `--trace`
            // (parsed as "true") defaults to trace.json.
            let trace_path = cli.flag("trace").map(|v| {
                if v == "true" {
                    "trace.json".to_string()
                } else {
                    v.to_string()
                }
            });
            cfg.trace.enabled = trace_path.is_some();
            let flow_cfg = cfg.flow;
            let flush_threshold = cfg.flush_threshold;
            let workers = cfg.workers;
            let (mut report, baseline, sink) =
                harness::run_once_traced(app, policy, &params, cfg);
            let mut trace_extras: Option<(crate::trace::critical::CriticalPath, Json)> = None;
            if let Some(path) = &trace_path {
                let t0 = std::time::Instant::now();
                let timeline = crate::trace::export::perfetto(&sink, p as usize);
                std::fs::write(path, timeline.render())
                    .map_err(|e| format!("cannot write trace '{path}': {e}"))?;
                trace_extras = Some((
                    crate::trace::critical::critical_path(&sink, p as usize, report.makespan),
                    crate::trace::critical::epoch_series(&sink, p as usize),
                ));
                if let Some(h) = report.host.as_mut() {
                    h.add_nanos(
                        crate::profile::Phase::TraceExport,
                        t0.elapsed().as_nanos() as u64,
                    );
                }
            }
            if report.trace_dropped > 0 {
                eprintln!(
                    "warning: trace ring full — {} event(s) dropped; \
                     timeline and critical path are partial",
                    report.trace_dropped
                );
            }
            if cli.flag("json").is_some() {
                let mut o = report.to_json();
                o.push("baseline", baseline.into());
                o.push("speedup", (baseline / report.makespan.max(1e-12)).into());
                // Run metadata: the knobs that shaped the flush stream.
                o.push("flush_threshold", (flush_threshold as u64).into());
                o.push("workers", (workers as u64).into());
                o.push("flow_mode", flow_cfg.mode.name().into());
                match flow_cfg.window {
                    crate::flow::FlowWindow::Fixed(w) => {
                        o.push("flow_window", (w as u64).into());
                    }
                    crate::flow::FlowWindow::Auto { .. } => {
                        // The adaptive window's final value and decision
                        // count ride in the report itself
                        // (flow_window_final / window_decisions).
                        o.push("flow_window", "auto".into());
                    }
                }
                if let Some((cp, series)) = trace_extras {
                    o.push("critical_path", cp.to_json());
                    o.push("epoch_series", series);
                    // `trace_dropped` already rides in the base report.
                    o.push("trace_events", sink.len().into());
                }
                Ok(o.render())
            } else {
                let mut out = format!(
                    "{} on {p} ranks ({policy:?}): makespan {:.4}s  speedup {:.2}  wait {:.1}%  util {:.2}",
                    app.name(),
                    report.makespan,
                    baseline / report.makespan.max(1e-12),
                    report.wait_pct(),
                    report.utilization()
                );
                if let (Some((cp, _)), Some(path)) = (trace_extras, &trace_path) {
                    let pct = |x: f64| 100.0 * x / cp.makespan.max(1e-12);
                    out.push_str(&format!(
                        "\ntrace: {path} ({} events, {} dropped) — open at https://ui.perfetto.dev\
                         \ncritical path: compute {:.1}%  comm {:.1}%  wait {:.1}%  overhead {:.1}%",
                        sink.len(),
                        sink.dropped(),
                        pct(cp.compute),
                        pct(cp.comm),
                        pct(cp.wait),
                        pct(cp.overhead),
                    ));
                }
                Ok(out)
            }
        }
        "analyze" => {
            let apps: Vec<AppId> = match cli.flag("app") {
                Some(name) => {
                    vec![AppId::parse(name).ok_or_else(|| format!("unknown app '{name}'"))?]
                }
                None => AppId::all().to_vec(),
            };
            let kinds: Vec<DepsKind> = match cli.flag("deps") {
                None | Some("both") => vec![DepsKind::Heuristic, DepsKind::Dag],
                Some(s) => vec![DepsKind::parse(s).ok_or("bad --deps (heuristic|dag|both)")?],
            };
            let p: u32 = cli
                .flag("procs")
                .unwrap_or("16")
                .parse()
                .map_err(|_| "bad --procs")?;
            // Analyzer defaults are smaller than `run`'s: the oracle's
            // closure is quadratic in ops per stream, and precision is
            // scale-independent.
            let params = AppParams {
                scale: match cli.flag("scale") {
                    Some(s) => s.parse().map_err(|_| "bad --scale")?,
                    None => 0.25,
                },
                iters: match cli.flag("iters") {
                    Some(s) => s.parse().map_err(|_| "bad --iters")?,
                    None => 2,
                },
            };
            let analyses: Vec<crate::analyze::AppAnalysis> = apps
                .iter()
                .map(|&app| crate::analyze::analyze_app(app, p, &params, &kinds))
                .collect();
            let dirty: Vec<&str> = analyses
                .iter()
                .filter(|a| !a.clean())
                .map(|a| a.app.name())
                .collect();
            let out = if cli.flag("json").is_some() {
                Json::Arr(analyses.iter().map(|a| a.to_json()).collect()).render()
            } else {
                let mut s = String::new();
                for a in &analyses {
                    s.push_str(&a.render());
                }
                s.push_str(&format!(
                    "{} app(s) analyzed: {}\n",
                    analyses.len(),
                    if dirty.is_empty() {
                        "all schedules sound, no latency-hiding stalls predicted".to_string()
                    } else {
                        format!("UNSOUND or stalling: {}", dirty.join(", "))
                    }
                ));
                s
            };
            if dirty.is_empty() {
                Ok(out)
            } else {
                // Surface the full report, then fail the process so CI
                // smoke jobs catch regressions.
                println!("{out}");
                Err(format!("analysis failed for: {}", dirty.join(", ")))
            }
        }
        "compare" => {
            const USAGE: &str =
                "usage: distnumpy compare <baseline.json> <new.json> [--threshold X] [--json]";
            let base_path = cli.positional.first().ok_or(USAGE)?;
            let new_path = cli.positional.get(1).ok_or(USAGE)?;
            let threshold: f64 = match cli.flag("threshold") {
                Some(s) => s.parse().map_err(|_| "bad --threshold")?,
                None => crate::metrics::compare::DEFAULT_THRESHOLD,
            };
            let read = |path: &str| {
                std::fs::read_to_string(path)
                    .map_err(|e| format!("cannot read '{path}': {e}"))
                    .and_then(|s| {
                        Json::parse(&s).map_err(|e| format!("cannot parse '{path}': {e}"))
                    })
            };
            let base = read(base_path)?;
            let new = read(new_path)?;
            let outcome = crate::metrics::compare::compare(&base, &new, threshold);
            let n_bad = outcome.n_regressed();
            let out = if cli.flag("json").is_some() {
                let mut j = outcome.to_json();
                if n_bad > 0 {
                    // Point the gate's consumer at the explainer.
                    j.push(
                        "diff_hint",
                        crate::metrics::compare::diff_hint(base_path, new_path)
                            .as_str()
                            .into(),
                    );
                }
                j.render()
            } else {
                outcome.render_text()
            };
            if outcome.is_vacuous() {
                // The baseline gates metrics but the new report matched
                // none of them: an empty/renamed/truncated artifact
                // must not sail through the gate looking green.
                println!("{out}");
                Err(format!(
                    "vacuous comparison: '{base_path}' gates {} metric(s) \
                     but none were found in '{new_path}'",
                    outcome.baseline_gated
                ))
            } else if n_bad == 0 {
                Ok(out)
            } else {
                // Print the full report, then fail the process so the
                // CI perf gate trips on any regressed metric.
                println!("{out}");
                Err(format!(
                    "{n_bad} metric(s) regressed beyond {:.0}% vs {base_path}\n\
                     attribute it: {}",
                    threshold * 100.0,
                    crate::metrics::compare::diff_hint(base_path, new_path)
                ))
            }
        }
        "diff" => {
            const USAGE: &str = "usage: distnumpy diff <base.json> <new.json> \
                 [--trace <base_trace.json> <new_trace.json>] [--json]";
            let base_path = cli.positional.first().ok_or(USAGE)?;
            let new_path = cli.positional.get(1).ok_or(USAGE)?;
            let read = |path: &str| {
                std::fs::read_to_string(path)
                    .map_err(|e| format!("cannot read '{path}': {e}"))
                    .and_then(|s| {
                        Json::parse(&s).map_err(|e| format!("cannot parse '{path}': {e}"))
                    })
            };
            let base = read(base_path)?;
            let new = read(new_path)?;
            let mut report = crate::analyze::diff::diff_runs(&base, &new)
                .map_err(|e| format!("diff {base_path} {new_path}: {e}"))?;
            if let Some(tb) = cli.flag("trace") {
                // `--trace A B` binds A to the flag and leaves B as the
                // third positional; `--trace A,B` is also accepted.
                // Bare `--trace` parses as "true" and is rejected.
                const TRACE_USAGE: &str = "diff --trace needs two timelines: \
                     --trace <base_trace.json> <new_trace.json>";
                if tb == "true" {
                    return Err(TRACE_USAGE.into());
                }
                let (tb, tn) = match tb.split_once(',') {
                    Some((a, b)) => (a.to_string(), b.to_string()),
                    None => (
                        tb.to_string(),
                        cli.positional.get(2).ok_or(TRACE_USAGE)?.clone(),
                    ),
                };
                let base_tr = read(&tb)?;
                let new_tr = read(&tn)?;
                report.trace = Some(
                    crate::analyze::diff::diff_traces(&base_tr, &new_tr)
                        .map_err(|e| format!("diff --trace {tb} {tn}: {e}"))?,
                );
            }
            if cli.flag("json").is_some() {
                Ok(report.to_json().render())
            } else {
                Ok(report.render_text())
            }
        }
        "sweep" => {
            let app = cli.app()?;
            let ps = cli.procs_list(&harness::PAPER_PS);
            let params = cli.params();
            let fig = harness::figure(app, &ps, &spec, &params);
            if cli.flag("json").is_some() {
                Ok(fig.to_json().render())
            } else {
                Ok(fig.render_table())
            }
        }
        "pipeline" => {
            let ps = cli.procs_list(&[4, 16, 32, 64]);
            let ks: Vec<u32> = match cli.flag("ks") {
                None => vec![1, 2, 4, 8, 16],
                Some(s) => s
                    .split(',')
                    .filter_map(|x| x.trim().parse().ok())
                    .collect(),
            };
            let params = cli.params();
            Ok(harness::pipelined_sweep(&ps, &ks, &spec, &params).render())
        }
        "report" => {
            if cli.positional.first().map(|s| s.as_str()) != Some("wait") {
                return Err("usage: distnumpy report wait".into());
            }
            let p: u32 = cli
                .flag("procs")
                .unwrap_or("16")
                .parse()
                .map_err(|_| "bad --procs")?;
            let params = cli.params();
            let rows = harness::wait_table(p, &spec, &params);
            let mut s = format!(
                "Waiting time at {p} ranks (paper Section 6.1.1)\n  {:16} {:>12} {:>18}\n",
                "app", "blocking", "latency-hiding"
            );
            for (app, blk, lh) in rows {
                s.push_str(&format!(
                    "  {:16} {:>11.1}% {:>17.1}%\n",
                    app.name(),
                    blk,
                    lh
                ));
            }
            Ok(s)
        }
        "fig19" => {
            let ps = cli.procs_list(&[8, 16, 32, 64, 128]);
            let params = cli.params();
            let rows = harness::figure19(&ps, &spec, &params);
            let mut s = String::from(
                "Fig. 19 — N-body by-node vs by-core (speedup)\n    P |  by-node |  by-core\n",
            );
            for (p, bn, bc) in rows {
                s.push_str(&format!(
                    "  {:>3} | {:>8.2} | {:>8.2}\n",
                    p, bn.speedup, bc.speedup
                ));
            }
            Ok(s)
        }
        "machine" => {
            let mut o = Json::obj();
            o.push("nodes", (spec.nodes as u64).into());
            o.push("cores_per_node", (spec.cores_per_node as u64).into());
            o.push("flops_per_core", spec.flops_per_core.into());
            o.push("node_mem_bw", spec.node_mem_bw.into());
            o.push("net_alpha", spec.net_alpha.into());
            o.push("net_beta", spec.net_beta.into());
            Ok(o.render())
        }
        "apps" => Ok(AppId::all()
            .iter()
            .map(|a| format!("{} (Fig. {})", a.name(), a.figure()))
            .collect::<Vec<_>>()
            .join("\n")),
        "help" | "--help" | "-h" => Ok(HELP.to_string()),
        other => Err(format!("unknown command '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_flags() {
        let cli = Cli::parse(&args("run --app jacobi --procs 8 --json")).unwrap();
        assert_eq!(cli.cmd, "run");
        assert_eq!(cli.flag("app"), Some("jacobi"));
        assert_eq!(cli.flag("procs"), Some("8"));
        assert_eq!(cli.flag("json"), Some("true"));
    }

    #[test]
    fn parse_equals_form() {
        let cli = Cli::parse(&args("sweep --app=knn --procs=1,2,4")).unwrap();
        assert_eq!(cli.flag("app"), Some("knn"));
        assert_eq!(cli.procs_list(&[9]), vec![1, 2, 4]);
    }

    #[test]
    fn run_command_executes() {
        let out = run(&Cli::parse(&args(
            "run --app black_scholes --procs 2 --scale 0.05 --iters 1",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("speedup"));
    }

    #[test]
    fn run_with_tree_collective_and_aggregation() {
        let out = run(&Cli::parse(&args(
            "run --app jacobi --procs 8 --scale 0.05 --iters 1 \
             --collective tree --agg 8 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("n_messages"));
        assert!(out.contains("agg_parts"));
        assert!(run(&Cli::parse(&args("run --app jacobi --collective ring")).unwrap()).is_err());
    }

    #[test]
    fn run_with_flow_and_flush_threshold() {
        let out = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 \
             --flow 2 --flush-threshold 64 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("\"flow_mode\":\"flow\""), "{out}");
        assert!(out.contains("\"flow_window\":2"), "{out}");
        assert!(out.contains("\"flush_threshold\":64"), "{out}");
        assert!(out.contains("overlap_pct"), "{out}");
        assert!(out.contains("wait_at_admission"), "{out}");
        // Bare `--flow` means window 2; the default stays batch.
        let bare = run(&Cli::parse(&args(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 --flow --json",
        ))
        .unwrap())
        .unwrap();
        assert!(bare.contains("\"flow_mode\":\"flow\""), "{bare}");
        let batch = run(&Cli::parse(&args(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(batch.contains("\"flow_mode\":\"batch\""), "{batch}");
        // A mode by name: `--flow batch` pins the reference path.
        let pinned = run(&Cli::parse(&args(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 --flow batch --json",
        ))
        .unwrap())
        .unwrap();
        assert!(pinned.contains("\"flow_mode\":\"batch\""), "{pinned}");
        assert!(
            run(&Cli::parse(&args("run --app jacobi --flow nope")).unwrap()).is_err(),
            "a bad window errors"
        );
    }

    #[test]
    fn run_with_sliding_and_auto_flow() {
        let out = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 \
             --flow sliding --flush-threshold 64 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("\"flow_mode\":\"sliding\""), "{out}");
        assert!(out.contains("\"flow_window\":2"), "{out}");
        assert!(out.contains("recorder_clock"), "{out}");
        assert!(out.contains("max_in_flight"), "{out}");
        assert!(out.contains("flow_pending"), "{out}");
        let auto = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 \
             --flow auto --flush-threshold 64 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(auto.contains("\"flow_mode\":\"sliding\""), "{auto}");
        assert!(auto.contains("\"flow_window\":\"auto\""), "{auto}");
        assert!(auto.contains("flow_window_final"), "{auto}");
        assert!(auto.contains("window_decisions"), "{auto}");
        let pinned = run(&Cli::parse(&args(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 \
             --flow sliding:4 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(pinned.contains("\"flow_mode\":\"sliding\""), "{pinned}");
        assert!(pinned.contains("\"flow_window\":4"), "{pinned}");
        assert!(
            run(&Cli::parse(&args("run --app jacobi --flow sliding:x")).unwrap()).is_err(),
            "a bad pinned window errors"
        );
    }

    #[test]
    fn run_with_sync_modes() {
        for sync in ["cone", "barrier"] {
            let cmd =
                format!("run --app jacobi --procs 4 --scale 0.05 --iters 1 --sync {sync} --json");
            let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
            assert!(out.contains("wait_at_cone"), "{sync}: {out}");
        }
        assert!(run(&Cli::parse(&args("run --app jacobi --sync maybe")).unwrap()).is_err());
    }

    #[test]
    fn run_with_verify_and_deps() {
        for deps in ["heuristic", "dag"] {
            let cmd = format!(
                "run --app jacobi --procs 4 --scale 0.05 --iters 1 \
                 --deps {deps} --verify --json"
            );
            let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
            assert!(out.contains("\"races\":0"), "{deps}: {out}");
            assert!(out.contains("excess_edge_pct"), "{deps}: {out}");
        }
        assert!(run(&Cli::parse(&args("run --app jacobi --deps nope")).unwrap()).is_err());
    }

    #[test]
    fn run_with_profile_emits_host_section() {
        let on = run(&Cli::parse(&args(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 --profile --json",
        ))
        .unwrap())
        .unwrap();
        assert!(on.contains("\"host\""), "{on}");
        assert!(on.contains("events_per_sec"), "{on}");
        assert!(on.contains("\"dist\""), "{on}");
        let off = run(&Cli::parse(&args(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(!off.contains("\"host\""), "{off}");
    }

    #[test]
    fn run_with_workers_is_bit_identical_and_profiled() {
        let serial = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 --json",
        ))
        .unwrap())
        .unwrap();
        let sharded = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 --workers 3 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(serial.contains("\"workers\":1"), "{serial}");
        assert!(sharded.contains("\"workers\":3"), "{sharded}");
        // Apart from the metadata key, the reports must match byte for
        // byte: the sharded engine pops events in the serial order.
        assert_eq!(
            serial.replace("\"workers\":1", ""),
            sharded.replace("\"workers\":3", "")
        );
        // With --profile, the host section grows per-worker rows and
        // the steal counter.
        let prof = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 --workers 2 --profile --json",
        ))
        .unwrap())
        .unwrap();
        assert!(prof.contains("steal_count"), "{prof}");
        assert!(prof.contains("pump_secs"), "{prof}");
        // P past the paper machine's 128 cores grows a local spec copy.
        let big = run(&Cli::parse(&args(
            "run --app jacobi --procs 256 --scale 0.05 --iters 1 --workers 2 --json",
        ))
        .unwrap())
        .unwrap();
        assert!(big.contains("makespan"), "{big}");
        assert!(run(&Cli::parse(&args("run --app jacobi --workers 0")).unwrap()).is_err());
        assert!(run(&Cli::parse(&args("run --app jacobi --workers x")).unwrap()).is_err());
    }

    #[test]
    fn compare_gates_regressions() {
        let dir = std::env::temp_dir();
        let base_p = dir.join("distnumpy_cmp_base.json");
        let good_p = dir.join("distnumpy_cmp_good.json");
        let bad_p = dir.join("distnumpy_cmp_bad.json");
        std::fs::write(&base_p, r#"{"makespan":10.0,"wait_pct":20.0}"#).unwrap();
        std::fs::write(&good_p, r#"{"makespan":9.5,"wait_pct":20.5}"#).unwrap();
        std::fs::write(&bad_p, r#"{"makespan":10.0,"wait_pct":30.0}"#).unwrap();
        let base = base_p.to_str().unwrap();
        // Self-compare is always clean.
        let cmd = format!("compare {base} {base}");
        let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
        assert!(out.contains("0 regressed"), "{out}");
        // Small drift within the threshold passes.
        let cmd = format!("compare {base} {}", good_p.to_str().unwrap());
        assert!(run(&Cli::parse(&args(&cmd)).unwrap()).is_ok());
        // A >10% wait_pct regression fails the process and names the
        // differential explainer.
        let cmd = format!("compare {base} {}", bad_p.to_str().unwrap());
        let err = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap_err();
        assert!(err.contains("regressed"), "{err}");
        assert!(err.contains("distnumpy diff "), "{err}");
        let cmd = format!("compare {base} {} --json", bad_p.to_str().unwrap());
        let err = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap_err();
        assert!(err.contains("distnumpy diff "), "{err}");
        // ...unless the threshold is loosened past it.
        let cmd = format!("compare {base} {} --threshold 0.6", bad_p.to_str().unwrap());
        assert!(run(&Cli::parse(&args(&cmd)).unwrap()).is_ok());
        // An empty new report gates nothing the baseline gates: that is
        // a broken bench artifact and must fail, not pass vacuously.
        let empty_p = dir.join("distnumpy_cmp_empty.json");
        std::fs::write(&empty_p, "{}").unwrap();
        let cmd = format!("compare {base} {}", empty_p.to_str().unwrap());
        let err = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap_err();
        assert!(err.contains("vacuous"), "{err}");
        // Bad inputs are reported, not panicked on.
        assert!(run(&Cli::parse(&args("compare /no/such.json /no/such.json"))
            .unwrap())
        .is_err());
        assert!(run(&Cli::parse(&args("compare")).unwrap()).is_err());
    }

    #[test]
    fn diff_subcommand_explains_runs() {
        let dir = std::env::temp_dir();
        let base_p = dir.join("distnumpy_diff_base.json");
        let new_p = dir.join("distnumpy_diff_new.json");
        let base = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 --flow sliding:4 --json",
        ))
        .unwrap())
        .unwrap();
        let new = run(&Cli::parse(&args(
            "run --app jacobi --procs 4 --scale 0.05 --iters 2 --json",
        ))
        .unwrap())
        .unwrap();
        std::fs::write(&base_p, &base).unwrap();
        std::fs::write(&new_p, &new).unwrap();
        let bp = base_p.to_str().unwrap();
        let np = new_p.to_str().unwrap();
        // Self-diff: aligned, full coverage, zero attribution, exit Ok.
        let cmd = format!("diff {bp} {bp} --json");
        let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
        assert!(out.contains("\"aligned\":true"), "{out}");
        assert!(out.contains("\"coverage\":1"), "{out}");
        assert!(out.contains("\"epochs_diverging\":0"), "{out}");
        // Cross-diff (sliding vs batch): a large delta is a successful
        // analysis — exit Ok with a ranked attribution.
        let cmd = format!("diff {bp} {np}");
        let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
        assert!(out.contains("differential run analysis"), "{out}");
        assert!(out.contains("epoch attribution"), "{out}");
        assert!(out.contains("coverage"), "{out}");
        // Malformed/missing inputs are errors.
        assert!(run(&Cli::parse(&args("diff /no/such.json /no/such.json")).unwrap()).is_err());
        assert!(run(&Cli::parse(&args("diff")).unwrap()).is_err());
    }

    #[test]
    fn diff_subcommand_with_traces() {
        let dir = std::env::temp_dir();
        let r_p = dir.join("distnumpy_diff_tr_run.json");
        let t_p = dir.join("distnumpy_diff_tr.json");
        let cmd = format!(
            "run --app jacobi --procs 2 --scale 0.05 --iters 1 --trace {} --json",
            t_p.to_str().unwrap()
        );
        let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
        std::fs::write(&r_p, &out).unwrap();
        let rp = r_p.to_str().unwrap();
        let tp = t_p.to_str().unwrap();
        let cmd = format!("diff {rp} {rp} --trace {tp} {tp} --json");
        let out = run(&Cli::parse(&args(&cmd)).unwrap()).unwrap();
        assert!(out.contains("\"trace\""), "{out}");
        assert!(out.contains("\"matched\""), "{out}");
        assert!(out.contains("base_critical_path"), "{out}");
        // Identical timelines: nothing unmatched, nothing divergent.
        assert!(out.contains("\"unmatched_base\":0"), "{out}");
        assert!(out.contains("\"top_ops\":[]"), "{out}");
        // The comma form parses too.
        let cmd = format!("diff {rp} {rp} --trace {tp},{tp}");
        assert!(run(&Cli::parse(&args(&cmd)).unwrap()).is_ok());
        // Bare --trace and non-trace documents are hard errors.
        let cmd = format!("diff {rp} {rp} --trace");
        assert!(run(&Cli::parse(&args(&cmd)).unwrap()).is_err());
        let cmd = format!("diff {rp} {rp} --trace {rp} {rp}");
        assert!(run(&Cli::parse(&args(&cmd)).unwrap()).is_err());
    }

    #[test]
    fn analyze_single_app_is_clean() {
        let out = run(&Cli::parse(&args(
            "analyze --app jacobi_stencil --procs 4 --scale 0.1 --iters 2",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("sound"), "{out}");
        assert!(out.contains("predicted stalls"), "{out}");
        assert!(out.contains("all schedules sound"), "{out}");
        let json = run(&Cli::parse(&args(
            "analyze --app jacobi_stencil --procs 4 --scale 0.1 --iters 2 --deps dag --json",
        ))
        .unwrap())
        .unwrap();
        assert!(json.contains("\"races\": 0") || json.contains("\"races\":0"), "{json}");
        assert!(json.contains("excess_edge_pct"), "{json}");
        assert!(!json.contains("heuristic"), "--deps dag restricts the sweep: {json}");
    }

    #[test]
    fn analyze_rejects_bad_flags() {
        assert!(run(&Cli::parse(&args("analyze --deps nope")).unwrap()).is_err());
        assert!(run(&Cli::parse(&args("analyze --app nope")).unwrap()).is_err());
    }

    #[test]
    fn pipeline_sweep_renders_json() {
        let out = run(&Cli::parse(&args(
            "pipeline --procs 2 --ks 1,2 --scale 0.05 --iters 2",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("staleness_k"));
        assert!(out.contains("wait_at_cone"));
    }

    #[test]
    fn machine_prints_table1() {
        let out = run(&Cli::parse(&args("machine")).unwrap()).unwrap();
        assert!(out.contains("\"nodes\":16"));
        assert!(out.contains("\"cores_per_node\":8"));
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&Cli::parse(&args("bogus")).unwrap()).is_err());
    }

    #[test]
    fn apps_lists_eight() {
        let out = run(&Cli::parse(&args("apps")).unwrap()).unwrap();
        assert_eq!(out.lines().count(), 8);
    }
}
