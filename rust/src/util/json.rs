//! Minimal JSON emission and parsing (offline environment: no
//! serde_json).
//!
//! Only what the harness needs: objects, arrays, numbers, strings —
//! plus a small recursive-descent parser ([`Json::parse`]) so tests can
//! validate emitted documents (e.g. the trace exporter) by reading them
//! back.

use std::fmt::Write as _;

#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(kv) = self {
            kv.push((key.to_string(), val));
        } else {
            panic!("Json::push on non-object");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse a JSON document. Integral numbers that fit `i64` come back
    /// as [`Json::Int`], everything else numeric as [`Json::Num`].
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing input at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array elements (`None` on non-arrays).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Numeric value of either number representation.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| "unterminated string".to_string())?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.i += 4;
                            // Surrogate pairs are not emitted by our
                            // renderer; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting here.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !s.contains(['.', 'e', 'E']) {
            if let Ok(i) = s.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = Json::obj();
        o.push("name", "jacobi".into());
        o.push("p", 16u64.into());
        o.push("series", Json::Arr(vec![1.0.into(), 2.5.into()]));
        assert_eq!(
            o.render(),
            r#"{"name":"jacobi","p":16,"series":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn parse_round_trips_render() {
        let mut o = Json::obj();
        o.push("name", "jacobi \"16\"".into());
        o.push("p", 16u64.into());
        o.push("neg", (-2.5).into());
        o.push("big", Json::Num(1.5e300));
        o.push("nan", Json::Num(f64::NAN)); // renders as null
        o.push("ok", true.into());
        o.push("series", Json::Arr(vec![1.0.into(), Json::Null, 2.5.into()]));
        let text = o.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.render(), text, "parse∘render is idempotent");
        assert_eq!(back.get("p").and_then(Json::as_f64), Some(16.0));
        assert_eq!(back.get("name").and_then(Json::as_str), Some("jacobi \"16\""));
        assert_eq!(back.get("series").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        assert!(matches!(back.get("nan"), Some(Json::Null)));
    }

    #[test]
    fn parse_scientific_and_unicode() {
        let v = Json::parse(r#"{"x": 1e-3, "y": [ -4E2, 0.25 ], "s": "πA"}"#).unwrap();
        assert_eq!(v.get("x").and_then(Json::as_f64), Some(1e-3));
        assert_eq!(v.get("y").unwrap().as_arr().unwrap()[0].as_f64(), Some(-400.0));
        assert_eq!(v.get("s").and_then(Json::as_str), Some("πA"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }
}
