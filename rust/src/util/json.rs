//! Minimal JSON emission (offline environment: no serde_json).
//!
//! Only what the harness needs: objects, arrays, numbers, strings.

use std::fmt::Write as _;

#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(kv) = self {
            kv.push((key.to_string(), val));
        } else {
            panic!("Json::push on non-object");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = Json::obj();
        o.push("name", "jacobi".into());
        o.push("p", 16u64.into());
        o.push("series", Json::Arr(vec![1.0.into(), 2.5.into()]));
        assert_eq!(
            o.render(),
            r#"{"name":"jacobi","p":16,"series":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
