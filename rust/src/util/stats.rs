//! Summary statistics for the bench harness.

#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Linearly interpolated quantile of an already-sorted sample set
/// (`q` in `[0, 1]`; index `q·(n−1)` between neighbours). Shared by
/// the bench summaries and exact-sample consumers of the distribution
/// metrics; the log2 histograms approximate the same definition at
/// bucket resolution.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let i = pos.floor() as usize;
    let frac = pos - i as f64;
    if i + 1 < sorted.len() {
        sorted[i] * (1.0 - frac) + sorted[i + 1] * frac
    } else {
        sorted[i]
    }
}

pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples
        .iter()
        .map(|x| (x - mean) * (x - mean))
        .sum::<f64>()
        / n as f64;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    Summary {
        n,
        mean,
        median,
        min: sorted[0],
        max: sorted[n - 1],
        stddev: var.sqrt(),
        p90: quantile_sorted(&sorted, 0.90),
        p99: quantile_sorted(&sorted, 0.99),
    }
}

/// Human-readable duration formatting for bench output.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_odd_median() {
        let s = summarize(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn empty_is_default() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn quantiles_single_sample() {
        let s = summarize(&[7.0]);
        assert_eq!(s.p90, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn quantiles_with_ties() {
        let s = summarize(&[2.0, 2.0, 2.0, 2.0, 2.0]);
        assert_eq!(s.p90, 2.0);
        assert_eq!(s.p99, 2.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn quantiles_unsorted_input() {
        // 1..=100 shuffled by stride: p90/p99 must see the sorted order.
        let samples: Vec<f64> = (0..100).map(|i| ((i * 37) % 100 + 1) as f64).collect();
        let s = summarize(&samples);
        // Interpolated at position 0.9·99 = 89.1 → between 90 and 91.
        assert!((s.p90 - 90.1).abs() < 1e-9, "p90 = {}", s.p90);
        assert!((s.p99 - 99.01).abs() < 1e-9, "p99 = {}", s.p99);
        assert!(s.p90 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn quantile_sorted_edges() {
        let sorted = [1.0, 2.0, 3.0];
        assert_eq!(quantile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(quantile_sorted(&sorted, 1.0), 3.0);
        assert_eq!(quantile_sorted(&sorted, 0.5), 2.0);
        assert_eq!(quantile_sorted(&[], 0.5), 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }
}
