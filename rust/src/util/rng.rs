//! Deterministic PRNG: xoshiro256** (Blackman & Vigna).
//!
//! Used for synthetic workload data and the property-test driver. Fully
//! deterministic from the seed so every experiment is reproducible.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without rejection; bias negligible for our uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a vector with uniform f32 values in [lo, hi).
    pub fn fill_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let v = r.range(3, 17);
            assert!((3..17).contains(&v));
        }
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
