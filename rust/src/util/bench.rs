//! Minimal wall-clock bench driver (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` target's `main()` with
//! `harness = false`; targets use [`Bench`] to time closures with warmup,
//! report summary statistics, and emit one line per case.

use std::time::Instant;

use super::stats::{fmt_time, summarize, Summary};

pub struct Bench {
    /// Minimum measured iterations per case.
    pub min_iters: usize,
    /// Wall-clock budget per case in seconds.
    pub budget: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_iters: 5,
            budget: 2.0,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            min_iters: 3,
            budget: 0.5,
        }
    }

    /// Time `f`, printing `name: median ± stddev (n iters)`.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        // Warmup.
        let _ = f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.budget
                && samples.len() < 1000)
        {
            let t0 = Instant::now();
            let out = f();
            samples.push(t0.elapsed().as_secs_f64());
            std::hint::black_box(&out);
        }
        let s = summarize(&samples);
        println!(
            "{name:44} {:>12} ± {:>10}  ({} iters)",
            fmt_time(s.median),
            fmt_time(s.stddev),
            s.n
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench {
            min_iters: 3,
            budget: 0.01,
        };
        let s = b.run("noop", || 1 + 1);
        assert!(s.n >= 3);
        assert!(s.median >= 0.0);
    }
}
