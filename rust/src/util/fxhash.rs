//! Minimal Fx-style hasher (as used by rustc) for the scheduler's hot
//! maps. The keys hashed on the request path — [`crate::ufunc::Loc`],
//! [`crate::types::Tag`] — are tiny (≤ 16 bytes), where SipHash's
//! per-call setup dominates; the multiply-rotate mix below is ~5×
//! cheaper at equivalent distribution for these keys. Not DoS-hardened,
//! which is fine: all keys are generated internally, never attacker-
//! controlled. §Perf-2 in EXPERIMENTS.md records the measured effect.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc Fx mixing function.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// Drop-in `BuildHasher` for `HashMap`.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` with the Fx hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_small_keys() {
        // Sanity: sequential u64 keys spread over buckets.
        let mut buckets = [0u32; 16];
        for i in 0..10_000u64 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            buckets[(h.finish() % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!(b > 300, "bucket underfull: {buckets:?}");
        }
    }

    #[test]
    fn map_works() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.get(&500), Some(&1000));
        assert_eq!(m.len(), 1000);
    }
}
