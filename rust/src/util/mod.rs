//! Small in-repo utilities.
//!
//! The build environment is offline with only the `xla` crate's dependency
//! closure available, so the RNG, JSON emission, CLI parsing and the
//! bench/property-test drivers that would normally come from `rand`,
//! `serde_json`, `clap`, `criterion` and `proptest` live here instead.

pub mod bench;
pub mod fxhash;
pub mod json;
pub mod rng;
pub mod stats;
