//! Strong-scaling experiment harness: regenerates every figure of the
//! paper's evaluation (Figs. 11–19) and the Section 6.1.1 waiting-time
//! table.
//!
//! For each rank count the harness runs the benchmark twice — once with
//! the latency-hiding scheduler, once with blocking communication — and
//! reports speedup against the sequential NumPy baseline plus the
//! waiting-time percentage, i.e. exactly the series the paper plots.

use crate::apps::{record, AppId, AppParams};
use crate::cluster::{MachineSpec, Placement};
use crate::lazy::Context;
use crate::metrics::RunReport;
use crate::sched::{Policy, SchedCfg};
use crate::types::VTime;
use crate::util::json::Json;

/// The rank counts of the paper's figures.
pub const PAPER_PS: [u32; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub makespan: VTime,
    pub speedup: f64,
    pub wait_pct: f64,
    pub utilization: f64,
    pub bytes_inter: u64,
    /// Wire messages posted (post-aggregation).
    pub n_messages: u64,
    /// Wait time of the collective root, rank 0 (s).
    pub wait_root: VTime,
    /// Constituent transfers the aggregation pass packed.
    pub agg_parts: u64,
    /// Flush epochs executed on the persistent timeline.
    pub n_epochs: u64,
    /// Wait paid at explicit barriers (forced scalar reads), summed
    /// over ranks (s).
    pub wait_at_barrier: VTime,
    /// Wait paid at targeted cone settles (forced reads under
    /// `SyncMode::Cone`), summed over ranks (s).
    pub wait_at_cone: VTime,
    /// Wait paid at admission gates (Flow mode: ranks stalled for the
    /// recorder), summed over ranks (s).
    pub wait_at_admission: VTime,
    /// Record/execute overlap achieved by the incremental flush engine
    /// (0 under Batch mode; see `RunReport::overlap_pct`).
    pub overlap_pct: f64,
    /// High-water mark of live staging buffers.
    pub peak_live_stages: u64,
    /// p99 of the per-rank wait intervals (all causes except Admission)
    /// from the always-on distribution metrics — the tail the mean
    /// `wait_pct` hides (s).
    pub wait_p99: VTime,
}

impl RunMetrics {
    fn from(report: &RunReport, baseline: VTime) -> Self {
        RunMetrics {
            makespan: report.makespan,
            speedup: baseline / report.makespan.max(1e-12),
            wait_pct: report.wait_pct(),
            utilization: report.utilization(),
            bytes_inter: report.bytes_inter,
            n_messages: report.n_messages,
            wait_root: report.wait_root(),
            agg_parts: report.agg_parts,
            n_epochs: report.n_epochs,
            wait_at_barrier: report.wait_at_barrier,
            wait_at_cone: report.wait_at_cone,
            wait_at_admission: report.wait_at_admission,
            overlap_pct: report.overlap_pct(),
            peak_live_stages: report.peak_live_stages,
            wait_p99: report.dist.wait_all().p99(),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("makespan", self.makespan.into());
        o.push("speedup", self.speedup.into());
        o.push("wait_pct", self.wait_pct.into());
        o.push("utilization", self.utilization.into());
        o.push("bytes_inter", self.bytes_inter.into());
        o.push("n_messages", self.n_messages.into());
        o.push("wait_root", self.wait_root.into());
        o.push("agg_parts", self.agg_parts.into());
        o.push("n_epochs", self.n_epochs.into());
        o.push("wait_at_barrier", self.wait_at_barrier.into());
        o.push("wait_at_cone", self.wait_at_cone.into());
        o.push("wait_at_admission", self.wait_at_admission.into());
        o.push("overlap_pct", self.overlap_pct.into());
        o.push("peak_live_stages", self.peak_live_stages.into());
        o.push("wait_p99", self.wait_p99.into());
        o
    }
}

/// One point on a strong-scaling curve.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub p: u32,
    pub lh: RunMetrics,
    pub blocking: RunMetrics,
}

/// A whole figure: the two curves of the paper's speedup plots.
#[derive(Clone, Debug)]
pub struct FigureData {
    pub app: AppId,
    pub baseline: VTime,
    pub points: Vec<ScalePoint>,
}

/// Execute one (app, P, policy, placement) cell.
pub fn run_once(
    app: AppId,
    p: u32,
    policy: Policy,
    placement: Placement,
    spec: &MachineSpec,
    params: &AppParams,
) -> (RunReport, VTime) {
    run_once_cfg(app, p, policy, placement, spec, params, false)
}

/// [`run_once`] with the §7 cache-locality scheduling extension toggle.
pub fn run_once_cfg(
    app: AppId,
    p: u32,
    policy: Policy,
    placement: Placement,
    spec: &MachineSpec,
    params: &AppParams,
    locality: bool,
) -> (RunReport, VTime) {
    let mut cfg = SchedCfg::new(spec.clone(), p);
    cfg.placement = placement;
    cfg.locality = locality;
    run_once_full(app, policy, params, cfg)
}

/// The fully-configured cell: every scheduler knob (placement, deps,
/// locality, collective schedule, aggregation threshold) comes from the
/// caller's [`SchedCfg`]. Used by the collective ablation.
pub fn run_once_full(
    app: AppId,
    policy: Policy,
    params: &AppParams,
    cfg: SchedCfg,
) -> (RunReport, VTime) {
    let (report, baseline, _) = run_once_traced(app, policy, params, cfg);
    (report, baseline)
}

/// [`run_once_full`] that also harvests the event-sourced trace
/// ([`crate::trace`]) — an empty sink unless `cfg.trace` enabled it.
/// The `--trace` CLI path uses this to feed the Perfetto exporter and
/// the critical-path analyzer.
pub fn run_once_traced(
    app: AppId,
    policy: Policy,
    params: &AppParams,
    cfg: SchedCfg,
) -> (RunReport, VTime, crate::trace::TraceSink) {
    let mut ctx = Context::sim(cfg, policy);
    record(app, &mut ctx, params);
    let baseline = ctx.baseline;
    let (report, sink) = ctx.finish_traced().expect("benchmark must complete");
    (report, baseline, sink)
}

/// One run rendered the way `distnumpy run --json` emits it: the full
/// report (ledger included) plus the baseline/speedup scalars. The
/// substrate `tests/diff.rs` and the CI diff-smoke feed to
/// [`crate::analyze::diff::diff_runs`] without shelling out.
pub fn run_json(
    app: AppId,
    policy: Policy,
    params: &AppParams,
    cfg: SchedCfg,
) -> (crate::util::json::Json, RunReport, crate::trace::TraceSink) {
    let (report, baseline, sink) = run_once_traced(app, policy, params, cfg);
    let mut o = report.to_json();
    o.push("baseline", baseline.into());
    o.push("speedup", (baseline / report.makespan.max(1e-12)).into());
    (o, report, sink)
}

/// Record `app` under latency-hiding and capture, per scheduler run,
/// the exact post-aggregation op streams the sessions admitted —
/// the input feed of the [`crate::analyze`] pass (`distnumpy analyze`)
/// — together with the admission log's epoch entries for the linter's
/// window rules.
pub fn captured_streams(
    app: AppId,
    params: &AppParams,
    cfg: SchedCfg,
) -> (crate::sched::CapturedStreams, Vec<crate::flow::EpochEntry>) {
    let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
    ctx.state.capture = Some(Vec::new());
    record(app, &mut ctx, params);
    ctx.flush();
    assert!(
        ctx.error.is_none(),
        "capture run must complete: {:?}",
        ctx.error
    );
    let epochs = ctx.state.flow_log.epochs.clone();
    let streams = ctx.state.capture.take().unwrap_or_default();
    (streams, epochs)
}

/// Produce one speedup figure (Figs. 11–18).
pub fn figure(
    app: AppId,
    ps: &[u32],
    spec: &MachineSpec,
    params: &AppParams,
) -> FigureData {
    let mut points = Vec::new();
    let mut baseline = 0.0;
    for &p in ps {
        let (lh_rep, base) = run_once(app, p, Policy::LatencyHiding, Placement::ByNode, spec, params);
        let (bl_rep, _) = run_once(app, p, Policy::Blocking, Placement::ByNode, spec, params);
        baseline = base;
        points.push(ScalePoint {
            p,
            lh: RunMetrics::from(&lh_rep, base),
            blocking: RunMetrics::from(&bl_rep, base),
        });
    }
    FigureData {
        app,
        baseline,
        points,
    }
}

/// Fig. 19: by-node vs by-core placement of the N-body benchmark.
pub fn figure19(
    ps: &[u32],
    spec: &MachineSpec,
    params: &AppParams,
) -> Vec<(u32, RunMetrics, RunMetrics)> {
    ps.iter()
        .filter(|&&p| p <= spec.cores_per_node * spec.nodes)
        .map(|&p| {
            let (by_node, base) = run_once(
                AppId::Nbody,
                p,
                Policy::LatencyHiding,
                Placement::ByNode,
                spec,
                params,
            );
            let (by_core, _) = run_once(
                AppId::Nbody,
                p,
                Policy::LatencyHiding,
                Placement::ByCore,
                spec,
                params,
            );
            (
                p,
                RunMetrics::from(&by_node, base),
                RunMetrics::from(&by_core, base),
            )
        })
        .collect()
}

impl FigureData {
    /// The paper-style text table: one row per P, both schedulers.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "Figure {} — {} (baseline: {:.3}s sequential NumPy)\n",
            self.app.figure(),
            self.app.name(),
            self.baseline
        ));
        s.push_str(
            "    P | speedup(LH) | speedup(blk) | wait%(LH) | wait%(blk) | util(LH)\n",
        );
        s.push_str(
            "  ----+-------------+--------------+-----------+------------+---------\n",
        );
        for pt in &self.points {
            s.push_str(&format!(
                "  {:>3} | {:>11.2} | {:>12.2} | {:>9.1} | {:>10.1} | {:>7.2}\n",
                pt.p,
                pt.lh.speedup,
                pt.blocking.speedup,
                pt.lh.wait_pct,
                pt.blocking.wait_pct,
                pt.lh.utilization,
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("figure", (self.app.figure() as u64).into());
        o.push("app", self.app.name().into());
        o.push("baseline", self.baseline.into());
        let pts = self
            .points
            .iter()
            .map(|pt| {
                let mut p = Json::obj();
                p.push("p", (pt.p as u64).into());
                p.push("lh", pt.lh.to_json());
                p.push("blocking", pt.blocking.to_json());
                p
            })
            .collect();
        o.push("points", Json::Arr(pts));
        o
    }
}

/// The staleness/wait trade-off of pipelined convergence checking:
/// Jacobi (Fig. 17) under `Convergence::Pipelined { every: k }` for
/// each k — a delta observed k iterations late buys ~iters/k forced
/// reads instead of iters. One row per (P, k) with the wait metrics of
/// both synchronization modes (`wait_at_barrier` under the global join,
/// `wait_at_cone` under the targeted settle), so the chart shows how
/// much of the barrier cost deferral removes and how much of the rest
/// the cone wait removes.
pub fn pipelined_sweep(ps: &[u32], ks: &[u32], spec: &MachineSpec, params: &AppParams) -> Json {
    use crate::apps::{record_jacobi_with, Convergence};
    use crate::sched::SyncMode;
    let mut rows = Vec::new();
    for &p in ps {
        for &k in ks {
            let run = |sync: SyncMode| -> RunReport {
                let mut cfg = SchedCfg::new(spec.clone(), p);
                cfg.sync = sync;
                let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
                record_jacobi_with(&mut ctx, params, Convergence::Pipelined { every: k });
                ctx.finish().expect("jacobi completes under latency-hiding")
            };
            let barrier = run(SyncMode::Barrier);
            let cone = run(SyncMode::Cone);
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("staleness_k", (k as u64).into());
            o.push("checks", ((params.iters / k.max(1)) as u64).into());
            o.push("makespan_barrier", barrier.makespan.into());
            o.push("makespan_cone", cone.makespan.into());
            o.push("wait_pct_barrier", barrier.wait_pct().into());
            o.push("wait_pct_cone", cone.wait_pct().into());
            o.push("wait_at_barrier", barrier.wait_at_barrier.into());
            o.push("wait_at_cone", cone.wait_at_cone.into());
            o.push("n_epochs", cone.n_epochs.into());
            o.push("peak_live_stages", cone.peak_live_stages.into());
            rows.push(o);
        }
    }
    Json::Arr(rows)
}

/// The Section 6.1.1 waiting-time summary at P ranks: for each
/// communication-bound app, wait% with blocking vs latency-hiding.
pub fn wait_table(
    p: u32,
    spec: &MachineSpec,
    params: &AppParams,
) -> Vec<(AppId, f64, f64)> {
    [AppId::Lbm2d, AppId::Lbm3d, AppId::Jacobi, AppId::JacobiStencil]
        .into_iter()
        .map(|app| {
            let (bl, _) = run_once(app, p, Policy::Blocking, Placement::ByNode, spec, params);
            let (lh, _) =
                run_once(app, p, Policy::LatencyHiding, Placement::ByNode, spec, params);
            (app, bl.wait_pct(), lh.wait_pct())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Collective;

    #[test]
    fn figure_produces_monotone_ps() {
        let spec = MachineSpec::paper();
        let fig = figure(
            AppId::BlackScholes,
            &[1, 2, 4],
            &spec,
            &AppParams::tiny(),
        );
        assert_eq!(fig.points.len(), 3);
        assert!(fig.points[2].lh.speedup > fig.points[0].lh.speedup);
        assert!(!fig.render_table().is_empty());
    }

    #[test]
    fn stencil_lh_beats_blocking_at_16() {
        let spec = MachineSpec::paper();
        let params = AppParams {
            scale: 0.25,
            iters: 4,
        };
        let fig = figure(AppId::JacobiStencil, &[16], &spec, &params);
        let pt = &fig.points[0];
        assert!(
            pt.lh.speedup > pt.blocking.speedup,
            "LH {} must beat blocking {}",
            pt.lh.speedup,
            pt.blocking.speedup
        );
        assert!(
            pt.lh.wait_pct < pt.blocking.wait_pct,
            "LH wait {} must be below blocking {}",
            pt.lh.wait_pct,
            pt.blocking.wait_pct
        );
    }

    #[test]
    fn fig19_by_node_beats_by_core() {
        let spec = MachineSpec::paper();
        // Large enough that per-panel compute dominates scheduling
        // overhead and hides the broadcast, so the memory-contention
        // penalty of by-core placement is the deciding term (Fig. 19).
        let params = AppParams {
            scale: 2.0,
            iters: 1,
        };
        let rows = figure19(&[8], &spec, &params);
        let (_, by_node, by_core) = &rows[0];
        assert!(
            by_node.speedup > by_core.speedup,
            "by-node {} must beat by-core {}",
            by_node.speedup,
            by_core.speedup
        );
    }

    #[test]
    fn locality_scheduling_helps_memory_bound_apps() {
        // §7 extension: cache-aware ready-queue ordering must shorten
        // the makespan of a memory-bound app and leave a flop-bound app
        // essentially untouched.
        let spec = MachineSpec::paper();
        let params = AppParams {
            scale: 1.0,
            iters: 3,
        };
        let (fifo, _) = run_once_cfg(
            AppId::JacobiStencil,
            16,
            Policy::LatencyHiding,
            Placement::ByNode,
            &spec,
            &params,
            false,
        );
        let (loc, _) = run_once_cfg(
            AppId::JacobiStencil,
            16,
            Policy::LatencyHiding,
            Placement::ByNode,
            &spec,
            &params,
            true,
        );
        assert!(
            loc.makespan < fifo.makespan * 0.98,
            "locality must help the stencil: {} vs {}",
            loc.makespan,
            fifo.makespan
        );
        let (f_fifo, _) = run_once_cfg(
            AppId::Fractal,
            16,
            Policy::LatencyHiding,
            Placement::ByNode,
            &spec,
            &params,
            false,
        );
        let (f_loc, _) = run_once_cfg(
            AppId::Fractal,
            16,
            Policy::LatencyHiding,
            Placement::ByNode,
            &spec,
            &params,
            true,
        );
        let delta = (f_fifo.makespan / f_loc.makespan - 1.0).abs();
        assert!(delta < 0.05, "flop-bound app should barely move: {delta}");
    }

    #[test]
    fn tree_aggregation_beats_flat_fanin_at_32() {
        // The collective-engine acceptance claim: at P >= 32 the
        // binomial-tree reduction plus message aggregation strictly
        // reduces both the root rank's wait time (the flat fan-in hot
        // spot) and the total wire-message count.
        let spec = MachineSpec::paper();
        let params = AppParams {
            scale: 0.25,
            iters: 3,
        };
        let flat_cfg = SchedCfg::new(spec.clone(), 32);
        let (flat, _) = run_once_full(AppId::Jacobi, Policy::LatencyHiding, &params, flat_cfg);
        let mut tree_cfg = SchedCfg::new(spec, 32);
        tree_cfg.collective = Collective::Tree;
        tree_cfg.aggregation = 16;
        let (tree, _) = run_once_full(AppId::Jacobi, Policy::LatencyHiding, &params, tree_cfg);
        assert!(
            tree.wait_root() < flat.wait_root(),
            "tree+agg root wait {} must undercut flat {}",
            tree.wait_root(),
            flat.wait_root()
        );
        assert!(
            tree.n_messages < flat.n_messages,
            "tree+agg messages {} must undercut flat {}",
            tree.n_messages,
            flat.n_messages
        );
        assert!(tree.agg_parts > tree.agg_msgs, "aggregation engaged");
        assert_eq!(flat.agg_msgs, 0, "flat config runs unaggregated");
    }

    #[test]
    fn pipelined_sweep_charts_staleness_and_wait() {
        let spec = MachineSpec::paper();
        let params = AppParams {
            scale: 0.1,
            iters: 8,
        };
        let json = pipelined_sweep(&[4], &[1, 4], &spec, &params).render();
        assert!(json.contains("staleness_k"));
        assert!(json.contains("wait_at_cone"));
        assert!(json.contains("wait_at_barrier"));
        // Two rows: k=1 and k=4.
        assert_eq!(json.matches("staleness_k").count(), 2);
    }

    #[test]
    fn wait_table_has_four_rows() {
        let spec = MachineSpec::paper();
        let rows = wait_table(4, &spec, &AppParams::tiny());
        assert_eq!(rows.len(), 4);
        for (app, blk, lh) in rows {
            assert!(blk >= 0.0 && lh >= 0.0, "{}", app.name());
        }
    }
}
