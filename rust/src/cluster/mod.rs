//! The simulated cluster: machine model, rank placement, and cost model.
//!
//! Substitutes the paper's testbed (Table 1: 16 nodes, 2× Intel Xeon
//! E5345 quad-core per node, 16 GB/node, Gigabit Ethernet, OpenMPI) with
//! a calibrated analytic model. See DESIGN.md §2 for the substitution
//! argument: the paper's findings are properties of the *overlap
//! structure* (which transfers can hide behind which block computations),
//! which a discrete-event simulation with an α–β network and a
//! memory-bandwidth contention model reproduces.

use crate::types::VTime;

/// Hardware description (paper Table 1 defaults).
#[derive(Clone, Debug)]
pub struct MachineSpec {
    pub nodes: u32,
    pub cores_per_node: u32,
    /// Effective scalar f32 compute rate per core (flops/s) for the C
    /// ufunc inner loops NumPy 1.3-era executes (~0.9 GF/s on a 2.33 GHz
    /// Core2: no SIMD in the generic loops).
    pub flops_per_core: f64,
    /// Sustainable memory bandwidth of one core with no contention (B/s).
    pub core_mem_bw: f64,
    /// Total node memory bandwidth shared by all cores (B/s) — the
    /// von Neumann bottleneck of Section 6.1.2 (FSB-era Xeon).
    pub node_mem_bw: f64,
    /// Inter-node latency (s) — GbE + OpenMPI stack.
    pub net_alpha: VTime,
    /// Inter-node inverse bandwidth (s/B) — ~112 MB/s effective GbE.
    pub net_beta: VTime,
    /// Per-message occupancy of the receiving NIC/CPU (s): tag matching,
    /// rendezvous handshake and copy-out of the era's MPI stack. Unlike
    /// `net_alpha` (pipeline latency, overlappable across messages) this
    /// serializes messages draining into one node — the term that makes
    /// flat O(P) fan-ins hot-spot on the root and message aggregation
    /// worthwhile (see `comm`).
    pub net_msg_cost: VTime,
    /// Intra-node (shared-memory transport) latency (s).
    pub smp_alpha: VTime,
    /// Intra-node inverse bandwidth (s/B).
    pub smp_beta: VTime,
    /// Runtime overhead per recorded *fragment* operation (dependency-
    /// list insertion + node allocation, C-level) in the latency-hiding
    /// engine (s). Calibrated from the measured heuristic insert+drain
    /// cost (`cargo bench --bench ablation_deps`: ~0.4 µs/op) plus
    /// scheduling bookkeeping.
    pub lh_op_overhead: VTime,
    /// Runtime overhead per fragment operation in blocking mode (no
    /// dependency system, just the program walk) (s).
    pub blocking_op_overhead: VTime,
    /// Interpreter-side overhead per *array-level* operation (one group
    /// of fragments): the CPython dispatch that records the ufunc. Paid
    /// by every rank under both policies — all processes run the same
    /// Python program (global knowledge, §5.5).
    pub py_op_overhead: VTime,
    /// Per-ufunc interpreter + allocation overhead of the *sequential
    /// NumPy baseline* (s). DistNumPy amortizes allocation by lazily
    /// recycling buffers (Section 6.1.1), which is how the paper sees
    /// super-linear speedups; NumPy 1.3 allocates a fresh temp per ufunc.
    pub numpy_op_overhead: VTime,
    /// NumPy temp-allocation cost per byte (page faults + zeroing on
    /// first touch for large temps) (s/B).
    pub numpy_alloc_per_byte: VTime,
    /// Effective memory bandwidth multiplier when an operation re-uses
    /// the base-block its rank touched last (L2-resident working set).
    /// Drives the §7 cache-locality scheduling extension.
    pub cache_reuse_factor: f64,
}

impl MachineSpec {
    /// The paper's Table 1 cluster, calibrated for NumPy-1.3-era rates.
    pub fn paper() -> Self {
        MachineSpec {
            nodes: 16,
            cores_per_node: 8,
            flops_per_core: 0.9e9,
            core_mem_bw: 2.6e9,
            node_mem_bw: 6.0e9,
            net_alpha: 60e-6,
            net_beta: 1.0 / 112e6,
            net_msg_cost: 20e-6,
            smp_alpha: 1.5e-6,
            smp_beta: 1.0 / 1.8e9,
            lh_op_overhead: 0.8e-6,
            blocking_op_overhead: 0.3e-6,
            py_op_overhead: 6e-6,
            numpy_op_overhead: 6e-6,
            numpy_alloc_per_byte: 0.25e-9,
            // Core2 L2 streams ~3x faster than FSB-bound DRAM traffic.
            cache_reuse_factor: 3.0,
        }
    }

    /// A small loopback machine for unit tests (fast, deterministic).
    pub fn tiny() -> Self {
        MachineSpec {
            nodes: 4,
            cores_per_node: 2,
            flops_per_core: 1e9,
            core_mem_bw: 4e9,
            node_mem_bw: 8e9,
            net_alpha: 10e-6,
            net_beta: 1e-8,
            net_msg_cost: 2e-6,
            smp_alpha: 1e-6,
            smp_beta: 1e-9,
            lh_op_overhead: 0.0,
            blocking_op_overhead: 0.0,
            py_op_overhead: 0.0,
            numpy_op_overhead: 0.0,
            numpy_alloc_per_byte: 0.0,
            cache_reuse_factor: 1.0,
        }
    }

    pub fn max_ranks(&self) -> u32 {
        self.nodes * self.cores_per_node
    }

    /// The same machine grown (never shrunk) to hold at least `nprocs`
    /// ranks, by adding nodes of the same shape. Used by scale studies
    /// that push P past the paper's 128-core testbed while keeping its
    /// per-node calibration.
    pub fn with_capacity(&self, nprocs: u32) -> MachineSpec {
        let mut s = self.clone();
        s.nodes = nprocs.div_ceil(s.cores_per_node.max(1)).max(s.nodes);
        s
    }

    /// Effective memory bandwidth per rank when `ranks_on_node` ranks
    /// share the node (static contention model).
    pub fn mem_bw_per_rank(&self, ranks_on_node: u32) -> f64 {
        (self.node_mem_bw / ranks_on_node.max(1) as f64).min(self.core_mem_bw)
    }

    /// Virtual execution time of one compute op with the given flop and
    /// memory-byte counts, under `ranks_on_node`-way contention.
    ///
    /// Additive (no-overlap) model rather than a `max()` roofline: the
    /// paper's testbed is FSB-era Xeon running NumPy 1.3's generic C
    /// loops, which neither prefetch nor pipeline memory behind ALU work
    /// — so compute time and memory-stall time serialize. This is what
    /// makes the von Neumann bottleneck of Section 6.1.2 visible even
    /// for flop-heavy kernels (Fig. 19: SUMMA by-core loses to by-node
    /// although matmul is nominally compute-bound).
    pub fn compute_time(&self, flops: f64, bytes: f64, ranks_on_node: u32) -> VTime {
        let t_flops = flops / self.flops_per_core;
        let t_mem = bytes / self.mem_bw_per_rank(ranks_on_node);
        t_flops + t_mem
    }

    /// [`Self::compute_time`] when the operand block is L2-resident
    /// (the rank touched it last): the memory term shrinks by
    /// `cache_reuse_factor`. Used by the §7 locality scheduler.
    pub fn compute_time_hot(&self, flops: f64, bytes: f64, ranks_on_node: u32) -> VTime {
        let t_flops = flops / self.flops_per_core;
        let bw = self.mem_bw_per_rank(ranks_on_node) * self.cache_reuse_factor;
        t_flops + bytes / bw
    }
}

/// How ranks map to nodes (paper Fig. 19: *by node* vs *by core*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Round-robin over nodes: rank r on node r mod N (max spread —
    /// the paper's default for ≤16 ranks, one per node).
    ByNode,
    /// Fill each node before using the next: rank r on node r / C.
    ByCore,
}

impl Placement {
    /// node index per rank.
    pub fn assign(self, nprocs: u32, spec: &MachineSpec) -> Vec<usize> {
        assert!(
            nprocs <= spec.max_ranks(),
            "{} ranks exceed machine capacity {}",
            nprocs,
            spec.max_ranks()
        );
        (0..nprocs)
            .map(|r| match self {
                Placement::ByNode => (r % spec.nodes) as usize,
                Placement::ByCore => (r / spec.cores_per_node) as usize,
            })
            .collect()
    }

    /// Number of ranks sharing each rank's node.
    pub fn contention(self, nprocs: u32, spec: &MachineSpec) -> Vec<u32> {
        let nodes = self.assign(nprocs, spec);
        let mut per_node = vec![0u32; spec.nodes as usize];
        for &n in &nodes {
            per_node[n] += 1;
        }
        nodes.iter().map(|&n| per_node[n]).collect()
    }

    pub fn parse(s: &str) -> Option<Placement> {
        match s {
            "by-node" | "bynode" | "node" => Some(Placement::ByNode),
            "by-core" | "bycore" | "core" => Some(Placement::ByCore),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_machine_capacity() {
        let s = MachineSpec::paper();
        assert_eq!(s.max_ranks(), 128);
    }

    #[test]
    fn with_capacity_grows_but_never_shrinks() {
        let s = MachineSpec::paper();
        assert_eq!(s.with_capacity(16).nodes, 16, "within capacity: unchanged");
        assert_eq!(s.with_capacity(4096).nodes, 512);
        assert!(s.with_capacity(4097).max_ranks() >= 4097);
        // Placement must accept the grown machine.
        assert_eq!(Placement::ByNode.assign(4096, &s.with_capacity(4096)).len(), 4096);
    }

    #[test]
    fn by_node_spreads() {
        let s = MachineSpec::paper();
        let n = Placement::ByNode.assign(16, &s);
        // One rank per node at P=16.
        let mut seen = n.clone();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 16);
        // At 32 ranks, two per node.
        let c = Placement::ByNode.contention(32, &s);
        assert!(c.iter().all(|&x| x == 2));
    }

    #[test]
    fn by_core_fills() {
        let s = MachineSpec::paper();
        let n = Placement::ByCore.assign(8, &s);
        assert!(n.iter().all(|&x| x == 0), "8 ranks on one node");
        let c = Placement::ByCore.contention(8, &s);
        assert!(c.iter().all(|&x| x == 8));
    }

    #[test]
    fn contention_slows_memory_bound_compute() {
        let s = MachineSpec::paper();
        // A memory-bound op (ufunc): 1 flop/elem, 12 B/elem.
        let t1 = s.compute_time(1e6, 12e6, 1);
        let t8 = s.compute_time(1e6, 12e6, 8);
        assert!(t8 > 2.0 * t1, "8-way contention must hurt: {t1} vs {t8}");
    }

    #[test]
    fn flop_bound_barely_affected_by_contention() {
        let s = MachineSpec::paper();
        // Fractal-like: 450 flops/elem, 8 B/elem — contention adds only
        // the (small) memory term, so the slowdown stays marginal.
        let t1 = s.compute_time(450e6, 8e6, 1);
        let t8 = s.compute_time(450e6, 8e6, 8);
        assert!(t8 > t1, "additive model: contention always costs");
        assert!(t8 < 1.05 * t1, "flop-bound op must stay flop-bound: {t1} vs {t8}");
    }

    #[test]
    #[should_panic]
    fn too_many_ranks_panics() {
        let s = MachineSpec::paper();
        Placement::ByNode.assign(129, &s);
    }

    #[test]
    fn placement_parse() {
        assert_eq!(Placement::parse("by-node"), Some(Placement::ByNode));
        assert_eq!(Placement::parse("core"), Some(Placement::ByCore));
        assert_eq!(Placement::parse("x"), None);
    }
}
