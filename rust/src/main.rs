//! `distnumpy` — the coordinator CLI (leader entrypoint).
//!
//! See `distnumpy help` for usage; the heavy lifting lives in
//! [`distnumpy::coordinator::cli`].

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(distnumpy::coordinator::main_with_args(&args));
}
