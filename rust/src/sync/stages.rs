//! Reference-counted staging buffers — stage reclamation.
//!
//! Tags are run-unique (see [`crate::ufunc::OpBuilder`]), so staging
//! buffers are never overwritten and — before this module — were never
//! *dropped* either: DESIGN.md §4 documented the resulting unbounded
//! stage accretion on long data-backed runs. The [`StageTable`] fixes it
//! with plain reference counting over information the recorded stream
//! already carries:
//!
//! * every operation that **reads** a stage (`Access::read_stage`) is
//!   registered as a reader when its epoch begins;
//! * every operation that **writes** a stage materializes it when the
//!   operation retires (which also records the stage's completion time —
//!   the datum the cone-wait machinery in [`crate::sync`] settles on);
//! * when the last reader retires, the stage drops — unless a live
//!   future has **pinned** it, in which case it drops at
//!   [`StageTable::unpin`] (the future's `wait`).
//!
//! A stage with *no* registered readers (a delivered gather block, a
//! test oracle's transfer target) is a result, not an intermediate: it
//! is kept until something claims it. Only stages that were read — halo
//! fragments, reduction partials, collective forwarding hops — reclaim,
//! which is exactly the population that grows with run length.
//!
//! Stages are keyed by `(rank, tag)`: the flat reduction fan-in reuses
//! one tag for the sender's partial and the root's received copy, which
//! are distinct buffers on distinct ranks.

use crate::types::{Rank, Tag, VTime};
use crate::util::fxhash::FxHashMap;

/// What is known about one staging buffer.
#[derive(Clone, Copy, Debug)]
struct StageEntry {
    /// Outstanding reader operations (registered at epoch start,
    /// repaid as they retire).
    readers: u32,
    /// The writing operation has retired: the buffer exists and `done`
    /// is meaningful.
    materialized: bool,
    /// Virtual time the writer retired (the stage's completion time).
    done: VTime,
    /// Scheduler run the writer retired in (`ExecState::run_id` at the
    /// time — a Batch epoch or a merged Flow wave).
    run: u64,
    /// The writer's operation id *within that run* — valid for cone
    /// extraction only while `run` is still the live run.
    op: crate::types::OpId,
}

/// A materialized stage's provenance, as the cone-wait machinery needs
/// it: when the value was done, which scheduler run produced it, and
/// which operation-node wrote it.
#[derive(Clone, Copy, Debug)]
pub struct StageWriter {
    pub done: VTime,
    pub run: u64,
    pub op: crate::types::OpId,
}

/// Reference-counted staging-buffer accounting, shared by every backend
/// (the table tracks *liveness*; backends own the bytes).
#[derive(Default)]
pub struct StageTable {
    entries: FxHashMap<(Rank, Tag), StageEntry>,
    /// Stages pinned by live futures (pins may precede materialization:
    /// a deferred read pins its result tag at record time).
    pinned: FxHashMap<(Rank, Tag), u32>,
    /// Whether stages actually reclaim. Stage *lifetime* is owned by
    /// the lazy context — it knows which stages futures pin — so
    /// [`crate::lazy::Context`] enables this; standalone scheduler runs
    /// (`sched::execute`, raw epoch drivers) keep every stage, since
    /// their callers read staged results out-of-band (test oracles).
    /// Completion-time bookkeeping happens either way.
    pub reclaim: bool,
    /// Currently materialized stages.
    pub live: u64,
    /// High-water mark of `live` — the §4 memory-note metric.
    pub peak_live: u64,
    /// Stages ever materialized.
    pub created: u64,
    /// Stages reclaimed (last reader or last pin released).
    pub dropped: u64,
}

impl StageTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one reader of `(rank, tag)` — called for every
    /// `read_stage` access of an epoch's batch before execution starts,
    /// so a stage can never drop while a later operation of the same
    /// epoch still needs it.
    pub fn register_reader(&mut self, rank: Rank, tag: Tag) {
        let e = self.entries.entry((rank, tag)).or_insert(StageEntry {
            readers: 0,
            materialized: false,
            done: 0.0,
            run: 0,
            op: crate::types::OpId(0),
        });
        e.readers += 1;
    }

    /// The writer of `(rank, tag)` retired at `done` in scheduler run
    /// `run` as operation `op`: the stage is now materialized. Under
    /// the lazy context tags are run-unique, so each stage materializes
    /// once; standalone batches built by independent `OpBuilder`s may
    /// reuse tags across epochs, in which case the new buffer simply
    /// replaces the old one (no double-counting).
    pub fn materialized(
        &mut self,
        rank: Rank,
        tag: Tag,
        done: VTime,
        run: u64,
        op: crate::types::OpId,
    ) {
        let e = self.entries.entry((rank, tag)).or_insert(StageEntry {
            readers: 0,
            materialized: false,
            done: 0.0,
            run: 0,
            op: crate::types::OpId(0),
        });
        if !e.materialized {
            e.materialized = true;
            self.live += 1;
            self.created += 1;
            self.peak_live = self.peak_live.max(self.live);
        }
        e.done = done;
        e.run = run;
        e.op = op;
    }

    /// A reader of `(rank, tag)` retired. Returns `true` when this was
    /// the last reader and no future pins the stage — the caller must
    /// then drop the backend buffer.
    pub fn reader_retired(&mut self, rank: Rank, tag: Tag) -> bool {
        let key = (rank, tag);
        let Some(e) = self.entries.get_mut(&key) else {
            return false;
        };
        debug_assert!(e.readers > 0, "reader underflow on ({rank:?},{tag:?})");
        e.readers -= 1;
        if self.reclaim && e.readers == 0 && e.materialized && !self.pinned.contains_key(&key) {
            self.entries.remove(&key);
            self.live -= 1;
            self.dropped += 1;
            return true;
        }
        false
    }

    /// Pin `(rank, tag)` on behalf of a live future: the stage must
    /// survive until [`StageTable::unpin`], whatever its reader count.
    pub fn pin(&mut self, rank: Rank, tag: Tag) {
        *self.pinned.entry((rank, tag)).or_insert(0) += 1;
    }

    /// Release one pin. Returns `true` when the stage is now
    /// reclaimable (materialized, no readers, no remaining pins) — the
    /// caller must then drop the backend buffer.
    pub fn unpin(&mut self, rank: Rank, tag: Tag) -> bool {
        let key = (rank, tag);
        match self.pinned.get_mut(&key) {
            None => return false,
            Some(n) => {
                *n -= 1;
                if *n == 0 {
                    self.pinned.remove(&key);
                } else {
                    return false;
                }
            }
        }
        if self.reclaim {
            if let Some(e) = self.entries.get(&key) {
                if e.materialized && e.readers == 0 {
                    self.entries.remove(&key);
                    self.live -= 1;
                    self.dropped += 1;
                    return true;
                }
            }
        }
        false
    }

    /// Provenance of a materialized stage, if it is still tracked.
    pub fn writer(&self, rank: Rank, tag: Tag) -> Option<StageWriter> {
        self.entries.get(&(rank, tag)).and_then(|e| {
            e.materialized.then_some(StageWriter {
                done: e.done,
                run: e.run,
                op: e.op,
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::OpId;

    fn reclaiming() -> StageTable {
        let mut t = StageTable::new();
        t.reclaim = true;
        t
    }

    #[test]
    fn read_stage_drops_at_last_reader() {
        let mut t = reclaiming();
        t.register_reader(Rank(0), Tag(1));
        t.register_reader(Rank(0), Tag(1));
        t.materialized(Rank(0), Tag(1), 1.0, 1, OpId(0));
        assert_eq!(t.live, 1);
        assert!(!t.reader_retired(Rank(0), Tag(1)), "one reader left");
        assert!(t.reader_retired(Rank(0), Tag(1)), "last reader drops it");
        assert_eq!(t.live, 0);
        assert_eq!(t.dropped, 1);
        assert!(t.writer(Rank(0), Tag(1)).is_none());
    }

    #[test]
    fn unread_stage_persists() {
        let mut t = StageTable::new();
        t.materialized(Rank(1), Tag(2), 2.0, 1, OpId(3));
        assert_eq!(t.live, 1);
        let w = t.writer(Rank(1), Tag(2)).unwrap();
        assert_eq!(w.done, 2.0);
        assert_eq!(w.op, OpId(3));
    }

    #[test]
    fn pin_outlives_last_reader() {
        let mut t = reclaiming();
        t.pin(Rank(0), Tag(5));
        t.register_reader(Rank(0), Tag(5));
        t.materialized(Rank(0), Tag(5), 1.0, 1, OpId(0));
        assert!(!t.reader_retired(Rank(0), Tag(5)), "pin holds the stage");
        assert_eq!(t.live, 1);
        assert!(t.writer(Rank(0), Tag(5)).is_some());
        assert!(t.unpin(Rank(0), Tag(5)), "unpin reclaims it");
        assert_eq!(t.live, 0);
    }

    #[test]
    fn rank_keys_are_distinct() {
        // Flat reduce: sender partial and root copy share the tag.
        let mut t = StageTable::new();
        t.materialized(Rank(1), Tag(9), 1.0, 1, OpId(0));
        t.materialized(Rank(0), Tag(9), 2.0, 1, OpId(1));
        assert_eq!(t.live, 2);
        assert_eq!(t.writer(Rank(0), Tag(9)).unwrap().done, 2.0);
        assert_eq!(t.writer(Rank(1), Tag(9)).unwrap().done, 1.0);
    }

    #[test]
    fn without_reclaim_reads_only_bookkeep() {
        // Standalone scheduler runs: completion times recorded, buffers
        // retained (their callers read staged results out-of-band).
        let mut t = StageTable::new();
        t.register_reader(Rank(0), Tag(1));
        t.materialized(Rank(0), Tag(1), 1.0, 1, OpId(0));
        assert!(!t.reader_retired(Rank(0), Tag(1)), "no drop when gated off");
        assert_eq!(t.live, 1);
        assert!(t.writer(Rank(0), Tag(1)).is_some());
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut t = reclaiming();
        for i in 0..4 {
            t.register_reader(Rank(0), Tag(i));
            t.materialized(Rank(0), Tag(i), 1.0, 1, OpId(i as u32));
        }
        assert_eq!(t.peak_live, 4);
        for i in 0..4 {
            t.reader_retired(Rank(0), Tag(i));
        }
        assert_eq!(t.live, 0);
        assert_eq!(t.peak_live, 4);
    }
}
