//! Targeted synchronization engine — dependency-cone waits, array
//! futures and stage reclamation.
//!
//! The paper's thesis is "aggressively initiate communication, lazily
//! wait" — yet through PR 2 every *forced* value still joined all ranks
//! to the global clock frontier ([`crate::sched::ExecState::barrier`]),
//! so one scalar read paid for communication it never depended on. This
//! module replaces that global join with a **targeted** one:
//!
//! 1. Every operation's retirement time is recorded in the execution
//!    state ([`crate::sched::ExecState::note_retire`], fed by all three
//!    policies); stage-writing retirements also land in the
//!    reference-counted [`stages::StageTable`].
//! 2. Forcing a value extracts the **backward dependency cone** of the
//!    operation that produced it — from [`crate::deps::DagDeps`]'s
//!    retained edges or [`crate::deps::HeuristicDeps`]'s predecessor
//!    hints (exact on epoch streams; conservative prefix fallback for
//!    recycled targets), both behind the [`cone::ConeSource`] trait.
//! 3. [`settle_cone`] joins only the cone's ranks at the cone's
//!    completion frontier, then rides a broadcast of the value back out
//!    to every rank through the persistent [`crate::net::Network`] —
//!    the binomial shape of [`crate::comm::broadcast_tree`] (or a flat
//!    fan-out under [`crate::comm::Collective::Flat`]). The idle time
//!    each rank pays is accounted as `wait_at_cone`, alongside the old
//!    `wait_at_barrier` of the global-join path.
//!
//! Where the old barrier equalized every clock, a cone wait leaves the
//! ranks wherever the broadcast arrival put them: unrelated transfers
//! keep draining and unrelated compute keeps its head start. The value
//! read is **bit-identical** either way — the reduction captured its
//! operands at record position; only the timing differs
//! (`rust/tests/props.rs` asserts it across all three policies and both
//! dependency systems, `benches/ablation_sync.rs` measures the win).
//!
//! [`ScalarFuture`] and [`ArrayFuture`] are the two deferred-read
//! handles: a scalar reduction and a whole-array gather
//! ([`crate::lazy::Context::gather_deferred`] — checkpointing, in-situ
//! analysis) pipelining through the same machinery. Both pin their
//! staging buffers in the [`stages::StageTable`] until forced, which is
//! what lets reclamation drop every *other* stage the moment its last
//! reader retires (DESIGN.md §4's unbounded-accretion fix).
//!
//! Under sliding admission ([`crate::flow::FlowMode::Sliding`]) a
//! future's producing epoch may live inside a scheduler session that
//! is *still accepting injections* when the wait arrives. Forcing
//! drains that session to quiescence first (`flush` = submit + drain),
//! so by settle time the session's whole retirement log is final and
//! the provenance check (`StageWriter::run == ExecState::run_id`, the
//! session's run) works unchanged — one session spans many epochs, but
//! it is still exactly one run.

pub mod cone;
pub mod stages;

pub use cone::{Cone, ConeSource};
pub use stages::{StageTable, StageWriter};

use crate::comm::{bcast_rounds, BcastShape, Collective, RING_BCAST_SEGMENTS};
use crate::sched::ExecState;
use crate::types::{BaseId, OpId, Rank, Tag, VTime};
use crate::ufunc::OpBuilder;

/// A deferred scalar read: the reduction is recorded (and executes with
/// whatever flush epoch it lands in), but the value is only forced — and
/// the (targeted) synchronization only paid — at [`ScalarFuture::wait`].
/// The result stage is pinned until then, so the future stays readable
/// across later flush epochs while every unpinned stage reclaims.
#[must_use = "a deferred read does nothing until .wait(ctx)"]
#[derive(Clone, Copy, Debug)]
pub struct ScalarFuture {
    pub(crate) tag: Tag,
}

impl ScalarFuture {
    pub(crate) fn new(tag: Tag) -> Self {
        ScalarFuture { tag }
    }

    /// Force the value: flush everything recorded so far, settle the
    /// value's dependency cone (or the global barrier, under
    /// [`SyncMode::Barrier`]), read. Fails if any flush epoch has
    /// failed (the context is poisoned). Forcing consumes the pinned
    /// result stage: a second wait on a data backend is an error.
    pub fn wait(&self, ctx: &mut crate::lazy::Context) -> Result<f64, crate::sched::SchedError> {
        ctx.wait_scalar(self)
    }
}

/// A deferred whole-array read ([`crate::lazy::Context::gather_deferred`]):
/// the gather collective is recorded immediately — its transfers drain
/// with the normal flush flow — and the dense result materializes at
/// [`ArrayFuture::wait`], which settles only the gather's cone instead
/// of barriering the timeline. Delivery stages are pinned until then.
#[must_use = "a deferred gather does nothing until .wait(ctx)"]
#[derive(Clone, Debug)]
pub struct ArrayFuture {
    pub(crate) base: BaseId,
    /// Every stage the future pins and settles on: the collective's
    /// per-destination deliveries (root-only under the flat schedule,
    /// every rank under the ring) plus the per-block owner snapshots.
    pub(crate) tags: Vec<(Rank, Tag)>,
    /// Per-block owner snapshots `(block, rank, tag)` — staged copies
    /// taken at record position, which the dense assembly reads so the
    /// forced array reflects the data as of `gather_deferred`, not
    /// whatever later epochs wrote into the base.
    pub(crate) snap: Vec<(u64, Rank, Tag)>,
}

impl ArrayFuture {
    pub(crate) fn new(base: BaseId, tags: Vec<(Rank, Tag)>, snap: Vec<(u64, Rank, Tag)>) -> Self {
        ArrayFuture { base, tags, snap }
    }

    /// Force the gather: flush, settle the cone, assemble the dense
    /// array (`Ok(None)` in pure simulation). Fails on a poisoned
    /// context.
    pub fn wait(
        &self,
        ctx: &mut crate::lazy::Context,
    ) -> Result<Option<Vec<f32>>, crate::sched::SchedError> {
        ctx.wait_array(self)
    }
}

/// How forcing a value synchronizes the timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// PR 2's global join: every rank meets the maximum clock
    /// (`wait_at_barrier`). Kept as the ablation baseline.
    Barrier,
    /// Targeted: join the value's dependency cone at its completion
    /// frontier, broadcast the value back out (`wait_at_cone`).
    Cone,
}

impl SyncMode {
    pub fn parse(s: &str) -> Option<SyncMode> {
        match s {
            "barrier" => Some(SyncMode::Barrier),
            "cone" => Some(SyncMode::Cone),
            _ => None,
        }
    }
}

/// Resolve a cone reported by the dependency system against the current
/// epoch's retirement log: which ranks participated, and when the cone
/// finished. Unretired cone members (only possible on a torn, poisoned
/// epoch) are skipped.
pub fn resolve_cone(st: &ExecState, target: OpId) -> (Vec<bool>, VTime) {
    let nprocs = st.clock.len();
    let mut ranks = vec![false; nprocs];
    let mut frontier: VTime = 0.0;
    let mut visit = |id: OpId| {
        if let Some((rank, t)) = st.retired(id) {
            ranks[rank.idx()] = true;
            frontier = frontier.max(t);
        }
    };
    match st.deps.cone_of(target) {
        Cone::Exact(ids) => ids.into_iter().for_each(&mut visit),
        Cone::Prefix => (0..=target.idx() as u32).map(OpId).for_each(&mut visit),
    }
    (ranks, frontier)
}

/// Time the broadcast of a `bytes`-sized forced value out of `root`
/// (holding it at `frontier`) through the persistent network, along
/// `shape`. Returns per-*virtual-id* arrival times (vid 0 = root = the
/// frontier; vid `v` is rank `(root + v) mod P`). The messages occupy
/// real NIC frontiers (and count as wire traffic), so a congested
/// ingress delays the value's arrival exactly as it would a data
/// transfer; a forwarding hop can only inject once its own copy — or,
/// on the pipelined ring, the segment — arrived.
pub fn broadcast_value(
    st: &mut ExecState,
    bld: &mut OpBuilder,
    shape: BcastShape,
    root: Rank,
    frontier: VTime,
    bytes: u64,
) -> Vec<VTime> {
    let p = st.clock.len() as u32;
    let mut arrival: Vec<VTime> = vec![frontier; p as usize];
    if p == 1 {
        return arrival;
    }
    let rank_of = |vid: u32| Rank((root.0 + vid) % p);
    let hop = |st: &mut ExecState, bld: &mut OpBuilder, from: Rank, to: Rank, t0: VTime, b: u64| {
        let tag = bld.fresh_tag();
        st.net.post_recv(t0, to, tag);
        let ps = st.note_msg_post(tag, from, to, b, t0);
        let rd = ps.recv_done.expect("both halves posted");
        if st.trace.on() {
            st.trace.msg_deliver(tag, from, to, b, rd);
        }
        rd
    };
    match shape {
        BcastShape::Tree => {
            for round in bcast_rounds(p) {
                for (vf, vt) in round {
                    let t0 = arrival[vf as usize];
                    arrival[vt as usize] = hop(st, bld, rank_of(vf), rank_of(vt), t0, bytes);
                }
            }
        }
        BcastShape::Flat => {
            for vid in 1..p {
                arrival[vid as usize] = hop(st, bld, root, rank_of(vid), frontier, bytes);
            }
        }
        BcastShape::Ring => {
            // Pipelined ring (the bandwidth-optimal dense shape): the
            // payload is cut into segments that chase each other
            // around the ring; the NIC FIFO frontiers serialize each
            // rank's consecutive injections, so the pipeline emerges
            // from the network model rather than being scripted here.
            // A rank holds the full value once its *last* segment
            // lands (FIFO ingress keeps segments ordered).
            let segs = RING_BCAST_SEGMENTS.min(bytes).max(1);
            let seg = bytes / segs;
            let last_seg = bytes - seg * (segs - 1);
            for s in 0..segs {
                let b = if s + 1 == segs { last_seg } else { seg };
                let mut t = frontier;
                for vid in 0..p - 1 {
                    t = hop(st, bld, rank_of(vid), rank_of(vid + 1), t, b);
                    if s + 1 == segs {
                        arrival[(vid + 1) as usize] = t;
                    }
                }
            }
        }
    }
    arrival
}

/// The targeted settle: join the cone's ranks at the cone's completion
/// `frontier`, then broadcast the forced value — `bytes` of it — from
/// `root` to every rank through the persistent network
/// ([`broadcast_value`]). The shape is volume-aware
/// ([`crate::comm::bcast_shape_for`]): scalar notifications keep the
/// configured collective's shape (binomial rounds under
/// [`Collective::Tree`], a flat fan-out under [`Collective::Flat`]),
/// while a dense payload — a forced [`ArrayFuture`] whose flat gather
/// delivered to the root only, yet every replicated interpreter (§5.5)
/// consumes the array — rides the bandwidth-optimal pipelined ring.
/// Every join is accounted as `wait_at_cone`. Returns the latest
/// arrival.
///
/// Note on the cone-rank joins: while the replicated interpreter
/// (§5.5) broadcasts to *every* rank, each non-root rank's broadcast
/// arrival is ≥ the frontier, so the cone joins are subsumed in the
/// final clocks — what the cone query observably contributes today is
/// the *frontier itself* (an over-approximate cone can only push it
/// later than the exact DAG cone, never earlier). The rank set is kept
/// because partial forces (a future consumed by a subset of ranks —
/// see ROADMAP) settle the cone without the global broadcast, where
/// the distinction becomes load-bearing.
pub fn settle_cone(
    st: &mut ExecState,
    bld: &mut OpBuilder,
    collective: Collective,
    root: Rank,
    frontier: VTime,
    cone_ranks: &[bool],
    bytes: u64,
) -> VTime {
    let p = st.clock.len() as u32;
    // The cone's ranks cannot observe the value before the cone is
    // complete; the root holds the value at the frontier.
    for r in 0..p {
        if cone_ranks[r as usize] {
            st.join_as(Rank(r), frontier, crate::trace::WaitCause::Cone);
        }
    }
    st.join_as(root, frontier, crate::trace::WaitCause::Cone);
    if p == 1 {
        return frontier;
    }
    let shape = crate::comm::bcast_shape_for(collective, p, bytes);
    let arrival = broadcast_value(st, bld, shape, root, frontier, bytes);
    let rank_of = |vid: u32| Rank((root.0 + vid) % p);
    let mut latest = frontier;
    for vid in 1..p {
        let r = rank_of(vid);
        // Riding the value broadcast back out is a collective round,
        // not a cone-frontier join — the trace distinguishes them.
        st.join_as(r, arrival[vid as usize], crate::trace::WaitCause::Collective);
        latest = latest.max(arrival[vid as usize]);
    }
    latest
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;
    use crate::comm::SCALAR_BYTES;
    use crate::sched::SchedCfg;

    fn state(p: u32) -> ExecState {
        ExecState::new(&SchedCfg::new(MachineSpec::tiny(), p))
    }

    #[test]
    fn settle_joins_cone_and_broadcast_only() {
        let mut st = state(4);
        st.clock = vec![5.0, 1.0, 9.0, 1.0];
        let mut bld = OpBuilder::new();
        // Cone = {0, 1}, frontier 4.0: rank 1 joins the frontier; rank 2
        // (ahead, outside the cone) is never dragged back or forward to
        // anyone else's clock.
        let cone = vec![true, true, false, false];
        let latest = settle_cone(
            &mut st,
            &mut bld,
            Collective::Tree,
            Rank(0),
            4.0,
            &cone,
            SCALAR_BYTES,
        );
        assert!(st.clock[0] >= 5.0, "root already past the frontier");
        assert!(st.clock[1] >= 4.0, "cone rank joined the frontier");
        assert_eq!(st.clock[2], 9.0, "non-cone rank keeps its head start");
        assert!(st.clock[3] > 1.0, "broadcast arrival reached rank 3");
        assert!(st.clock[3] < 9.0, "no global join to the max clock");
        assert!(st.wait_at_cone > 0.0);
        assert_eq!(st.wait_at_barrier, 0.0, "no global barrier was paid");
        assert!(latest >= 4.0);
    }

    #[test]
    fn settle_is_cheaper_than_barrier_when_value_is_old() {
        // The pipelined-futures case: the value finished long ago
        // (frontier 1.0) while clocks ran ahead. The cone settle costs
        // (almost) nothing; a barrier would charge every rank up to the
        // maximum clock.
        let clocks = vec![30.0, 20.0, 40.0, 25.0];
        let mut st = state(4);
        st.clock = clocks.clone();
        let mut bld = OpBuilder::new();
        settle_cone(
            &mut st,
            &mut bld,
            Collective::Tree,
            Rank(0),
            1.0,
            &[false; 4],
            SCALAR_BYTES,
        );
        let cone_wait = st.wait_at_cone;

        let mut stb = state(4);
        stb.clock = clocks;
        stb.barrier();
        assert!(
            cone_wait < stb.wait_at_barrier,
            "cone {cone_wait} must undercut barrier {}",
            stb.wait_at_barrier
        );
        assert_eq!(st.clock[2], 40.0, "fast rank untouched");
    }

    #[test]
    fn flat_and_tree_broadcasts_deliver_everyone() {
        for collective in [Collective::Flat, Collective::Tree] {
            let mut st = state(8);
            let mut bld = OpBuilder::new();
            let latest = settle_cone(
                &mut st,
                &mut bld,
                collective,
                Rank(0),
                1.0,
                &[false; 8],
                SCALAR_BYTES,
            );
            assert!(latest > 1.0, "{collective:?}: arrivals take wire time");
            for r in 0..8 {
                assert!(
                    st.clock[r] >= 1.0,
                    "{collective:?}: rank {r} must hold the value"
                );
            }
            assert_eq!(st.net.n_transfers, 7, "{collective:?}: P-1 messages");
        }
    }

    #[test]
    fn single_rank_settles_at_frontier() {
        let mut st = state(1);
        let mut bld = OpBuilder::new();
        let t = settle_cone(
            &mut st,
            &mut bld,
            Collective::Tree,
            Rank(0),
            2.5,
            &[true],
            SCALAR_BYTES,
        );
        assert_eq!(t, 2.5);
        assert_eq!(st.clock[0], 2.5);
    }

    /// The volume-aware broadcast costing: a dense payload's fan-out is
    /// strictly cheaper on the pipelined ring than on the binomial tree
    /// at P = 16 (bandwidth-bound regime), while a scalar notification
    /// is cheaper on the tree (latency-bound regime).
    #[test]
    fn ring_beats_tree_for_dense_payloads_only() {
        let last = |shape: BcastShape, bytes: u64| -> VTime {
            let mut st = state(16);
            let mut bld = OpBuilder::new();
            let arr = broadcast_value(&mut st, &mut bld, shape, Rank(0), 0.0, bytes);
            arr.iter().cloned().fold(0.0, f64::max)
        };
        let dense = 1u64 << 22; // 4 MiB: β-dominated
        assert!(
            last(BcastShape::Ring, dense) < last(BcastShape::Tree, dense),
            "dense: ring {} must undercut tree {}",
            last(BcastShape::Ring, dense),
            last(BcastShape::Tree, dense)
        );
        assert!(
            last(BcastShape::Tree, SCALAR_BYTES) < last(BcastShape::Ring, SCALAR_BYTES),
            "scalar: tree must undercut the P-1-hop ring"
        );
    }

    /// A forced dense gather routes through the ring automatically and
    /// every rank still ends up holding the value.
    #[test]
    fn dense_settle_rides_the_ring_and_delivers_everyone() {
        let mut st = state(8);
        let mut bld = OpBuilder::new();
        let dense = 1u64 << 20;
        let latest = settle_cone(
            &mut st,
            &mut bld,
            Collective::Flat,
            Rank(0),
            1.0,
            &[false; 8],
            dense,
        );
        assert!(latest > 1.0);
        for r in 0..8 {
            assert!(st.clock[r] >= 1.0, "rank {r} holds the dense value");
        }
        let segs = RING_BCAST_SEGMENTS;
        assert_eq!(
            st.net.n_transfers,
            7 * segs,
            "pipelined ring: (P-1)·segments messages"
        );
    }

    #[test]
    fn sync_mode_parse() {
        assert_eq!(SyncMode::parse("barrier"), Some(SyncMode::Barrier));
        assert_eq!(SyncMode::parse("cone"), Some(SyncMode::Cone));
        assert_eq!(SyncMode::parse("x"), None);
    }
}
