//! Dependency-cone extraction — what a targeted wait actually settles.
//!
//! A forced value depends on a *backward cone* of operations: the
//! transitive predecessors of the operation that produced it. Joining
//! only that cone's ranks at the cone's completion frontier — instead of
//! joining every rank to the global clock frontier — is the whole point
//! of the `sync/` engine (Eijkhout's task-graph-transformation framing,
//! arXiv:1811.05077: a wait is a graph transformation local to the
//! value's cone, not a program-wide barrier).
//!
//! Both dependency systems answer the cone query through one trait:
//!
//! * [`crate::deps::DagDeps`] keeps the full conflict graph, so it walks
//!   retained predecessor edges and returns the **exact** cone;
//! * [`crate::deps::HeuristicDeps`] stores no graph — the paper's point
//!   — but its insert scan walks the conflicting access-nodes anyway,
//!   and since the "cheaper exact cones" upgrade it keeps those ids as
//!   location-level **predecessor hints**: cone queries walk the hints
//!   transitively and match the DAG's exact cone on insert-then-drain
//!   streams. Targets the system no longer knows (recycled epochs) fall
//!   back to the **conservative over-approximation** [`Cone::Prefix`]:
//!   every operation recorded up to and including the target. Insertion
//!   order bounds the true cone from above (conflict edges always point
//!   forward in recording order), so the prefix can only *delay* a
//!   wait, never settle it too early. Values produced by *earlier*
//!   scheduler runs (the pipelined-futures case that matters) bypass
//!   the cone query entirely: their whole cone has retired, so the
//!   frontier is just the recorded completion time.

use crate::types::OpId;

/// A backward dependency cone, as precisely as the dependency system
/// can report it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Cone {
    /// Exactly the transitive predecessors of the target (target
    /// included) — the full-DAG answer.
    Exact(Vec<OpId>),
    /// Every operation with an id ≤ the target's — the heuristic's
    /// conservative over-approximation (ids follow recording order, so
    /// this is a superset of the exact cone).
    Prefix,
}

/// How a dependency system reports the backward cone of an operation it
/// has seen this epoch. Supertrait of [`crate::deps::DepSystem`], so the
/// scheduler's boxed system answers cone queries without downcasting.
pub trait ConeSource {
    /// The backward cone of `target` among the operations inserted this
    /// epoch. Implementations may over-approximate (up to
    /// [`Cone::Prefix`]) but must never under-approximate.
    fn cone_of(&self, target: OpId) -> Cone;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deps::{DagDeps, DepSystem, HeuristicDeps};
    use crate::types::BaseId;
    use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpNode, OpPayload, Operand, Region};
    use crate::types::{Rank, Tag};

    fn op(id: u32, accesses: Vec<Access>) -> OpNode {
        OpNode {
            id: OpId(id),
            rank: Rank(0),
            group: 0,
            payload: OpPayload::Compute(ComputeTask {
                kernel: Kernel::Add,
                inputs: vec![Operand::Local(Region::scalar())],
                dst: Dst::Stage(Tag(u64::MAX)),
                elems: 1,
            }),
            accesses,
        }
    }

    /// Two independent chains; the exact cone of one chain's tail must
    /// exclude the other chain entirely — from the DAG's retained edges
    /// *and* from the heuristic's predecessor hints, which shrink the
    /// old whole-prefix answer down to the same exact cone. An unknown
    /// target still degrades to the safe prefix.
    #[test]
    fn both_systems_answer_exact_cones_heuristic_via_hints() {
        let a = BaseId(0);
        let b = BaseId(1);
        let ops = vec![
            op(0, vec![Access::write_block(a, 0, (0, 10))]),
            op(1, vec![Access::write_block(b, 0, (0, 10))]),
            op(2, vec![Access::read_block(a, 0, (0, 10))]),
            op(3, vec![Access::read_block(b, 0, (0, 10))]),
        ];
        let mut dag = DagDeps::new();
        let mut heu = HeuristicDeps::new();
        for o in &ops {
            dag.insert(o);
            heu.insert(o);
        }
        for system in [&dag.cone_of(OpId(2)), &heu.cone_of(OpId(2))] {
            match system {
                Cone::Exact(ids) => {
                    let mut ids = ids.clone();
                    ids.sort();
                    assert_eq!(ids, vec![OpId(0), OpId(2)], "chain B excluded");
                }
                other => panic!("expected an exact cone, got {other:?}"),
            }
        }
        assert_eq!(
            heu.cone_of(OpId(99)),
            Cone::Prefix,
            "unknown targets degrade to the conservative prefix"
        );
    }

    /// The exact cone is transitive: w -> r -> w chains pull in every
    /// ancestor, not just direct predecessors.
    #[test]
    fn dag_cone_is_transitive() {
        let a = BaseId(0);
        let ops = vec![
            op(0, vec![Access::write_block(a, 0, (0, 10))]),
            op(1, vec![Access::write_block(a, 0, (0, 10))]),
            op(2, vec![Access::read_block(a, 0, (0, 10))]),
        ];
        let mut dag = DagDeps::new();
        for o in &ops {
            dag.insert(o);
        }
        match dag.cone_of(OpId(2)) {
            Cone::Exact(mut ids) => {
                ids.sort();
                assert_eq!(ids, vec![OpId(0), OpId(1), OpId(2)]);
            }
            other => panic!("expected exact cone, got {other:?}"),
        }
    }

    /// The cone survives completion: cone queries happen at wait time,
    /// after the epoch drained.
    #[test]
    fn dag_cone_survives_drain() {
        let a = BaseId(0);
        let ops = vec![
            op(0, vec![Access::write_block(a, 0, (0, 10))]),
            op(1, vec![Access::read_block(a, 0, (0, 10))]),
        ];
        let mut dag = DagDeps::new();
        for o in &ops {
            dag.insert(o);
        }
        for id in [OpId(0), OpId(1)] {
            dag.take_ready();
            dag.complete(id);
        }
        match dag.cone_of(OpId(1)) {
            Cone::Exact(mut ids) => {
                ids.sort();
                assert_eq!(ids, vec![OpId(0), OpId(1)]);
            }
            other => panic!("expected exact cone post-drain, got {other:?}"),
        }
    }
}
