//! Log2-bucketed distribution metrics.
//!
//! Scalar totals hide tail behavior: a mean admission latency looks
//! healthy while one stalled epoch eats the pipeline. These histograms
//! capture the *distribution* of the quantities the paper's headline
//! measurement is made of — wait intervals (keyed by the
//! [`crate::trace::WaitCause`] taxonomy), per-epoch admission latency,
//! wire-message sizes, and the per-epoch wait series — at the same
//! choke points the trace sink already instruments. They are always on:
//! recording is pure bookkeeping (no `VTime` arithmetic is touched), so
//! the simulated timeline stays bit-identical with or without them.
//!
//! Buckets are powers of two: bucket `i` covers `[2^(i+LO_EXP),
//! 2^(i+1+LO_EXP))`, with everything `<= 2^LO_EXP` folded into bucket 0
//! and everything above the top folded into the last bucket. With
//! `LO_EXP = -30` (≈ 1 ns) and 64 buckets the range spans to `2^34`
//! (≈ 1.7e10) — wide enough for both second-scale waits and byte-scale
//! message sizes. Alongside the buckets each histogram keeps *exact*
//! `n`/`sum`/`min`/`max`, so reconciliation against the scalar
//! accounting (`wait`, `wait_at_*`, `n_messages`) compares exact sums
//! to floating-point tolerance; only the quantiles are bucket-resolved
//! (interpolated within a bucket, clamped to `[min, max]`).

use crate::trace::WaitCause;
use crate::types::VTime;
use crate::util::json::Json;

/// Number of log2 buckets.
pub const HIST_BUCKETS: usize = 64;
/// Exponent of the lower edge of bucket 1 (bucket 0 absorbs everything
/// at or below `2^LO_EXP`).
pub const LO_EXP: i32 = -30;

/// A log2-bucketed histogram with exact n/sum/min/max side counters.
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
    buckets: [u64; HIST_BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

impl Hist {
    /// Record one sample. Non-finite samples are ignored (they cannot
    /// be bucketed and would poison the exact sum).
    #[inline]
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.n += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        self.buckets[Self::bucket(v)] += 1;
    }

    /// Bucket index for a sample value.
    #[inline]
    fn bucket(v: f64) -> usize {
        if v <= 0.0 {
            return 0;
        }
        let e = v.log2().floor() as i32 - LO_EXP;
        e.clamp(0, HIST_BUCKETS as i32 - 1) as usize
    }

    /// Lower edge of bucket `i` (0 for bucket 0, which absorbs the
    /// sub-`2^LO_EXP` tail).
    fn bucket_lo(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            (2.0f64).powi(i as i32 + LO_EXP)
        }
    }

    /// Upper edge of bucket `i`.
    fn bucket_hi(i: usize) -> f64 {
        (2.0f64).powi(i as i32 + 1 + LO_EXP)
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Exact sum of all recorded samples — the reconciliation anchor
    /// against the scalar wait accounting.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Exact minimum (0.0 when empty, for clean JSON).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Bucket-resolved quantile: walk the cumulative counts to the
    /// bucket containing the q-th sample, interpolate linearly within
    /// its edges, clamp to the exact `[min, max]` envelope. `q` in
    /// `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.n as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= target {
                let lo = Self::bucket_lo(i);
                let hi = Self::bucket_hi(i);
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                return (lo + (hi - lo) * frac).clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Bucket-wise merge (for [`crate::metrics::RunReport::absorb`]).
    pub fn merge(&mut self, other: &Hist) {
        self.n += other.n;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Compact JSON: exact side counters, bucket-resolved quantiles,
    /// and only the non-empty buckets as `[lo_exp, count]` pairs.
    /// Quantiles of an *empty* histogram render as `null` — a 0.0
    /// sentinel would read as "measured a zero-length tail" and corrupt
    /// naive p50/p90/p99 comparisons downstream.
    pub fn to_json(&self) -> Json {
        let quant = |v: f64| {
            if self.n == 0 {
                Json::Null
            } else {
                v.into()
            }
        };
        let mut o = Json::obj();
        o.push("n", self.n.into());
        o.push("sum", self.sum.into());
        o.push("mean", self.mean().into());
        o.push("min", self.min().into());
        o.push("max", self.max().into());
        o.push("p50", quant(self.p50()));
        o.push("p90", quant(self.p90()));
        o.push("p99", quant(self.p99()));
        let mut buckets = Vec::new();
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                buckets.push(Json::Arr(vec![
                    (i as i64 + LO_EXP as i64).into(),
                    c.into(),
                ]));
            }
        }
        o.push("buckets", Json::Arr(buckets));
        o
    }
}

/// The distribution metrics carried on [`crate::sched::ExecState`] and
/// snapshotted into [`crate::metrics::RunReport`]: wait-interval
/// histograms per [`WaitCause`], the wire-message size histogram, and
/// the per-epoch wait series.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DistMetrics {
    /// One wait-interval histogram per cause, indexed by
    /// [`WaitCause::index`]. The Admission histogram mirrors
    /// `wait_at_admission` (reported separately from the per-rank
    /// `wait` vectors); all other causes together reconcile against the
    /// per-rank `wait` sum.
    pub wait_by_cause: [Hist; WaitCause::N],
    /// Wire-message sizes (bytes) at every `Network::post_send`; its
    /// count reconciles against `n_messages`.
    pub msg_bytes: Hist,
    /// Wait charged per flush epoch (all causes except Admission,
    /// mirroring the per-rank `wait` semantics), indexed by the epoch
    /// current at charge time.
    pub epoch_wait: Vec<VTime>,
}

impl DistMetrics {
    /// Record one wait interval: into the cause histogram always, and
    /// into the per-epoch series for every cause that also lands in the
    /// per-rank `wait` vectors (i.e. everything but Admission).
    #[inline]
    pub fn record_wait(&mut self, cause: WaitCause, epoch: u64, d: VTime) {
        self.wait_by_cause[cause.index()].record(d);
        if !matches!(cause, WaitCause::Admission) {
            let i = epoch as usize;
            if self.epoch_wait.len() <= i {
                self.epoch_wait.resize(i + 1, 0.0);
            }
            self.epoch_wait[i] += d;
        }
    }

    /// All-cause wait histogram *excluding* Admission — the distribution
    /// of the intervals that make up the per-rank `wait` vectors.
    pub fn wait_all(&self) -> Hist {
        let mut all = Hist::default();
        for (i, h) in self.wait_by_cause.iter().enumerate() {
            if i != WaitCause::Admission.index() {
                all.merge(h);
            }
        }
        all
    }

    /// Merge another run's distributions (bucket-wise hists; the other
    /// run's epoch series appends, matching how `n_epochs` adds).
    pub fn merge(&mut self, other: &DistMetrics) {
        for (a, b) in self.wait_by_cause.iter_mut().zip(&other.wait_by_cause) {
            a.merge(b);
        }
        self.msg_bytes.merge(&other.msg_bytes);
        self.epoch_wait.extend_from_slice(&other.epoch_wait);
    }

    /// The `dist.wait` JSON object: one histogram per cause label,
    /// empty causes skipped.
    pub fn wait_to_json(&self) -> Json {
        let mut o = Json::obj();
        for (i, h) in self.wait_by_cause.iter().enumerate() {
            if h.n() > 0 {
                o.push(WaitCause::LABELS[i], h.to_json());
            }
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_hist_is_clean() {
        let h = Hist::default();
        assert_eq!(h.n(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
        let s = h.to_json().render();
        assert!(!s.contains("inf"), "no infinities leak into JSON: {s}");
        // n=0 quantiles are *null*, not a 0.0 sentinel; the exact
        // min/max keep their clean 0.0 (documented empty-value).
        assert!(s.contains("\"p50\":null"), "{s}");
        assert!(s.contains("\"p90\":null"), "{s}");
        assert!(s.contains("\"p99\":null"), "{s}");
        assert!(s.contains("\"min\":0"), "{s}");
    }

    #[test]
    fn merging_empty_hist_does_not_poison_min_max() {
        let mut a = Hist::default();
        a.record(2.0);
        a.record(8.0);
        // Empty into non-empty: the empty side's ±INF sentinels must not
        // leak through the comparisons.
        a.merge(&Hist::default());
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 8.0);
        assert!(a.min.is_finite() && a.max.is_finite());
        // Non-empty into empty: the samples' envelope wins outright.
        let mut b = Hist::default();
        b.merge(&a);
        assert_eq!(b.min(), 2.0);
        assert_eq!(b.max(), 8.0);
        assert_eq!(b.n(), 2);
        // Empty into empty stays empty and renders null quantiles.
        let mut c = Hist::default();
        c.merge(&Hist::default());
        assert_eq!(c.n(), 0);
        assert!(c.to_json().render().contains("\"p50\":null"));
    }

    #[test]
    fn nonempty_quantiles_render_as_numbers() {
        let mut h = Hist::default();
        h.record(4.0);
        let s = h.to_json().render();
        assert!(!s.contains("null"), "no null fields once populated: {s}");
        assert!(s.contains("\"p50\":4"), "{s}");
    }

    #[test]
    fn exact_side_counters() {
        let mut h = Hist::default();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record(v);
        }
        assert_eq!(h.n(), 4);
        assert!((h.sum() - 10.0).abs() < 1e-12);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 4.0);
        assert!((h.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_sample_quantiles_collapse() {
        let mut h = Hist::default();
        h.record(3.5);
        // Clamped to the exact [min, max] envelope: every quantile of a
        // single sample is that sample.
        assert_eq!(h.p50(), 3.5);
        assert_eq!(h.p90(), 3.5);
        assert_eq!(h.p99(), 3.5);
    }

    #[test]
    fn quantiles_bracket_the_distribution() {
        let mut h = Hist::default();
        // 99 small samples in one bucket, one huge outlier.
        for _ in 0..99 {
            h.record(1.0);
        }
        h.record(1024.0);
        let p50 = h.p50();
        let p99 = h.p99();
        assert!(p50 >= 1.0 && p50 < 2.0, "median in the small bucket: {p50}");
        assert!(p99 <= 1024.0 && p99 >= 1.0);
        assert!(h.quantile(1.0) == 1024.0, "q=1 is the max");
        assert!(p50 <= h.p90() && h.p90() <= p99, "quantiles are monotone");
    }

    #[test]
    fn zero_and_subnormal_fold_into_bucket_zero() {
        let mut h = Hist::default();
        h.record(0.0);
        h.record(1e-12); // below 2^LO_EXP ≈ 9.3e-10
        assert_eq!(h.n(), 2);
        assert_eq!(h.min(), 0.0);
        // Both land in bucket 0; quantiles stay within [min, max].
        assert!(h.p99() <= h.max());
    }

    #[test]
    fn non_finite_ignored() {
        let mut h = Hist::default();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.n(), 0);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let mut a = Hist::default();
        let mut b = Hist::default();
        let mut both = Hist::default();
        for v in [0.5, 2.0, 8.0] {
            a.record(v);
            both.record(v);
        }
        for v in [1.0, 64.0] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
    }

    #[test]
    fn huge_values_clamp_to_top_bucket() {
        let mut h = Hist::default();
        h.record(1e300);
        assert_eq!(h.n(), 1);
        assert_eq!(h.max(), 1e300);
        // Quantile clamps to the exact max even though the bucket edge
        // is far below it.
        assert_eq!(h.quantile(1.0), 1e300);
    }

    #[test]
    fn dist_metrics_epoch_series_excludes_admission() {
        let mut d = DistMetrics::default();
        d.record_wait(WaitCause::Barrier, 0, 1.0);
        d.record_wait(WaitCause::Admission, 0, 5.0);
        d.record_wait(WaitCause::Cone, 2, 0.5);
        assert_eq!(d.epoch_wait, vec![1.0, 0.0, 0.5]);
        assert_eq!(d.wait_by_cause[WaitCause::Admission.index()].n(), 1);
        let all = d.wait_all();
        assert_eq!(all.n(), 2, "wait_all excludes the admission cause");
        assert!((all.sum() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn dist_json_skips_empty_causes() {
        let mut d = DistMetrics::default();
        d.record_wait(WaitCause::Barrier, 0, 1.0);
        let s = d.wait_to_json().render();
        assert!(s.contains("barrier"));
        assert!(!s.contains("transfer"));
    }
}
