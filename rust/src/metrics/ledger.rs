//! The per-epoch run ledger: the compact always-on accounting that
//! makes two runs *diffable* (DESIGN.md §12).
//!
//! `RunReport` scalars say how much time a run spent waiting; the PR-8
//! histograms say how that time was distributed; neither says **where
//! in the schedule** it sat. The ledger adds the missing axis: one row
//! per flush epoch (admission-log index), recording at the existing
//! choke points ([`crate::sched::ExecState::charge_wait`],
//! `gate_admission`, `note_msg_post`, `note_retire`) so every row
//! reconciles exactly with the scalar accounting:
//!
//! * `Σ rows.wait[cause]` = the per-cause histogram sums
//!   ([`crate::metrics::hist::DistMetrics::wait_by_cause`]);
//! * `Σ rows.wait[≠admission]` = the per-rank `wait` vector sum;
//! * `Σ rows.msgs` = `n_messages`; `Σ rows.bytes` =
//!   `bytes_inter + bytes_intra`; `Σ rows.ops` = `ops_executed`;
//! * `Σ rows.advance + residual(makespan)` = `makespan` — the row
//!   *makespan-advance* is how far the retirement high-water mark moved
//!   while the epoch was the most recently admitted one, so the rows
//!   partition the makespan and a diff can attribute a makespan delta
//!   to named epochs.
//!
//! Recording is pure bookkeeping — no `VTime` arithmetic is touched —
//! so the simulated timeline stays bit-identical with the ledger on
//! (it is always on), exactly like the PR-8 histograms.
//!
//! Rows are keyed by the epoch tag current at charge time ("latest
//! submitted" under pipelined admission — deliberate: execution of
//! earlier epochs overlaps later recording, and the tag names the
//! pipeline state the charge happened under; both runs of a diff key
//! the same way, and the splice renumbering
//! ([`crate::flow::Splicer`]) is deterministic, so epoch indices are
//! comparable across runs of the same program).

use crate::flow::AdmissionLog;
use crate::trace::WaitCause;
use crate::types::VTime;
use crate::util::json::Json;

/// One flush epoch's accounting row.
#[derive(Clone, Debug)]
pub struct LedgerRow {
    /// How far the retirement high-water mark advanced while this epoch
    /// was current — the epoch's share of the makespan (s).
    pub advance: VTime,
    /// Wait charged while this epoch was current, per
    /// [`WaitCause::index`] (admission included — reported separately
    /// from per-rank wait, same convention as the scalar report).
    pub wait: [VTime; WaitCause::N],
    /// Wire messages posted.
    pub msgs: u64,
    /// Bytes of those messages.
    pub bytes: u64,
    /// Operations retired.
    pub ops: u64,
    /// Admission-pipeline depth when the epoch entered the log
    /// (annotated from [`AdmissionLog`] at snapshot time).
    pub in_flight: u64,
    /// The epoch's streamed admission latency; `NaN` (renders null)
    /// for Batch-mode epochs, which have no recorder clock.
    pub admit_latency: VTime,
    /// When the epoch's last operation retired; `NaN` until drained.
    pub retired: VTime,
}

impl Default for LedgerRow {
    fn default() -> Self {
        LedgerRow {
            advance: 0.0,
            wait: [0.0; WaitCause::N],
            msgs: 0,
            bytes: 0,
            ops: 0,
            in_flight: 0,
            admit_latency: f64::NAN,
            retired: f64::NAN,
        }
    }
}

impl LedgerRow {
    /// Total wait of the row, all causes (admission included).
    pub fn wait_total(&self) -> VTime {
        self.wait.iter().sum()
    }

    /// Total wait excluding the admission gate — the part that also
    /// lands in the per-rank `wait` vectors.
    pub fn wait_rank(&self) -> VTime {
        let adm = WaitCause::Admission.index();
        self.wait
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != adm)
            .map(|(_, w)| w)
            .sum()
    }

    /// Parse one row back from its JSON form (the `ledger.epochs[i]`
    /// object) — the read side used by `analyze::diff`.
    pub fn from_json(j: &Json) -> Result<LedgerRow, String> {
        let num = |key: &str| j.get(key).and_then(Json::as_f64);
        let mut row = LedgerRow {
            advance: num("advance").ok_or("ledger row missing 'advance'")?,
            msgs: num("msgs").unwrap_or(0.0) as u64,
            bytes: num("bytes").unwrap_or(0.0) as u64,
            ops: num("ops").unwrap_or(0.0) as u64,
            in_flight: num("in_flight").unwrap_or(0.0) as u64,
            admit_latency: num("admit_latency").unwrap_or(f64::NAN),
            retired: num("retired").unwrap_or(f64::NAN),
            ..LedgerRow::default()
        };
        if let Some(w) = j.get("wait") {
            for (i, label) in WaitCause::LABELS.iter().enumerate() {
                if let Some(v) = w.get(label).and_then(Json::as_f64) {
                    row.wait[i] = v;
                }
            }
        }
        Ok(row)
    }

    fn to_json(&self, epoch: usize) -> Json {
        let mut o = Json::obj();
        o.push("epoch", epoch.into());
        o.push("advance", self.advance.into());
        let mut w = Json::obj();
        for (i, label) in WaitCause::LABELS.iter().enumerate() {
            if self.wait[i] != 0.0 {
                w.push(label, self.wait[i].into());
            }
        }
        o.push("wait", w);
        o.push("wait_total", self.wait_total().into());
        o.push("msgs", self.msgs.into());
        o.push("bytes", self.bytes.into());
        o.push("ops", self.ops.into());
        o.push("in_flight", self.in_flight.into());
        // NaN renders as null: a Batch epoch has no admission latency
        // and an undrained epoch has no retirement yet.
        o.push("admit_latency", self.admit_latency.into());
        o.push("retired", self.retired.into());
        o
    }
}

/// The per-epoch run ledger, carried on [`crate::sched::ExecState`]
/// and snapshotted (annotated) into [`crate::metrics::RunReport`].
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub rows: Vec<LedgerRow>,
    /// Retirement high-water mark: the latest retirement time seen.
    /// `Σ rows.advance == clock_hi` by construction (the increments
    /// telescope), so `makespan − clock_hi` is the *residual* — trailing
    /// joins and final-epoch overhead no retirement covers.
    clock_hi: VTime,
}

impl Ledger {
    fn row_mut(&mut self, epoch: u64) -> &mut LedgerRow {
        let i = epoch as usize;
        if self.rows.len() <= i {
            self.rows.resize_with(i + 1, LedgerRow::default);
        }
        &mut self.rows[i]
    }

    /// Record a wait interval charged while `epoch` was current.
    #[inline]
    pub fn record_wait(&mut self, epoch: u64, cause: WaitCause, d: VTime) {
        self.row_mut(epoch).wait[cause.index()] += d;
    }

    /// Record one posted wire message.
    #[inline]
    pub fn record_msg(&mut self, epoch: u64, bytes: u64) {
        let row = self.row_mut(epoch);
        row.msgs += 1;
        row.bytes += bytes;
    }

    /// Record one op retirement at time `t`: counts the op and
    /// attributes any advance of the retirement high-water mark to the
    /// current epoch.
    #[inline]
    pub fn record_retire(&mut self, epoch: u64, t: VTime) {
        let hi = self.clock_hi;
        let row = self.row_mut(epoch);
        row.ops += 1;
        if t.is_finite() && t > hi {
            row.advance += t - hi;
            self.clock_hi = t;
        }
    }

    /// The retirement high-water mark (= `Σ rows.advance`).
    pub fn clock_hi(&self) -> VTime {
        self.clock_hi
    }

    /// The share of `makespan` no epoch's advance covers: trailing
    /// joins / final overhead after the last retirement. Non-negative
    /// on a real run (retirements drive the clocks).
    pub fn residual(&self, makespan: VTime) -> VTime {
        (makespan - self.clock_hi).max(0.0)
    }

    /// Sum of one cause across all rows — the reconciliation anchor
    /// against the per-cause histogram sums.
    pub fn cause_sum(&self, cause: WaitCause) -> VTime {
        self.rows.iter().map(|r| r.wait[cause.index()]).sum()
    }

    /// Clone of the ledger with the admission-log annotations filled
    /// in (pipeline depth at admit, streamed latency, retirement) —
    /// the snapshot [`crate::sched::ExecState::report`] takes.
    pub fn annotated(&self, log: &AdmissionLog) -> Ledger {
        let mut out = self.clone();
        if out.rows.len() < log.epochs.len() {
            out.rows.resize_with(log.epochs.len(), LedgerRow::default);
        }
        for (row, e) in out.rows.iter_mut().zip(&log.epochs) {
            row.in_flight = e.in_flight_at_admit;
            row.admit_latency = e.latency;
            row.retired = e.retired;
        }
        out
    }

    /// Merge another run's ledger (for [`crate::metrics::RunReport::absorb`]:
    /// back-to-back independent runs). Rows append — epoch indices
    /// continue, matching how `n_epochs` and the epoch-wait series add —
    /// and the high-water marks add because the makespans add.
    pub fn merge(&mut self, other: &Ledger) {
        self.rows.extend(other.rows.iter().cloned());
        self.clock_hi += other.clock_hi;
    }

    /// The run JSON `ledger` section.
    pub fn to_json(&self, makespan: VTime) -> Json {
        let mut o = Json::obj();
        o.push("clock_hi", self.clock_hi.into());
        o.push("residual", self.residual(makespan).into());
        o.push(
            "epochs",
            Json::Arr(
                self.rows
                    .iter()
                    .enumerate()
                    .map(|(i, r)| r.to_json(i))
                    .collect(),
            ),
        );
        o
    }

    /// Parse the rows (and residual) back from a run JSON's `ledger`
    /// section. Returns `None` when the report carries no ledger (e.g.
    /// a `BENCH_*.json` ablation artifact).
    pub fn parse_section(report: &Json) -> Option<Result<(Vec<LedgerRow>, VTime), String>> {
        let sec = report.get("ledger")?;
        Some((|| {
            let rows = sec
                .get("epochs")
                .and_then(Json::as_arr)
                .ok_or("ledger section missing 'epochs' array")?
                .iter()
                .map(LedgerRow::from_json)
                .collect::<Result<Vec<_>, String>>()?;
            let residual = sec
                .get("residual")
                .and_then(Json::as_f64)
                .ok_or("ledger section missing 'residual'")?;
            Ok((rows, residual))
        })())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_telescope_to_clock_hi() {
        let mut l = Ledger::default();
        l.record_retire(0, 1.0);
        l.record_retire(0, 0.5); // no rewind
        l.record_retire(1, 2.5);
        l.record_retire(2, 2.5); // ties advance nothing
        assert_eq!(l.clock_hi(), 2.5);
        let total: f64 = l.rows.iter().map(|r| r.advance).sum();
        assert!((total - 2.5).abs() < 1e-12);
        assert_eq!(l.rows[0].ops, 2);
        assert!((l.rows[0].advance - 1.0).abs() < 1e-12);
        assert!((l.rows[1].advance - 1.5).abs() < 1e-12);
        assert_eq!(l.rows[2].advance, 0.0);
        assert!((l.residual(3.0) - 0.5).abs() < 1e-12);
        assert_eq!(l.residual(2.0), 0.0, "residual never negative");
    }

    #[test]
    fn wait_and_msgs_accumulate_per_epoch() {
        let mut l = Ledger::default();
        l.record_wait(0, WaitCause::Barrier, 1.0);
        l.record_wait(0, WaitCause::Admission, 0.25);
        l.record_wait(2, WaitCause::Barrier, 0.5);
        l.record_msg(1, 4096);
        l.record_msg(1, 1024);
        assert_eq!(l.rows.len(), 3);
        assert!((l.cause_sum(WaitCause::Barrier) - 1.5).abs() < 1e-12);
        assert!((l.rows[0].wait_total() - 1.25).abs() < 1e-12);
        assert!((l.rows[0].wait_rank() - 1.0).abs() < 1e-12, "admission excluded");
        assert_eq!(l.rows[1].msgs, 2);
        assert_eq!(l.rows[1].bytes, 5120);
    }

    #[test]
    fn json_round_trips() {
        let mut l = Ledger::default();
        l.record_wait(0, WaitCause::Transfer { peer: crate::types::Rank(1) }, 0.75);
        l.record_msg(0, 512);
        l.record_retire(0, 1.5);
        l.record_retire(1, 2.0);
        let j = l.to_json(2.25);
        let text = j.render();
        assert!(text.contains("\"residual\":0.25"), "{text}");
        let back = Json::parse(&text).unwrap();
        let mut doc = Json::obj();
        doc.push("ledger", back);
        let (rows, residual) = Ledger::parse_section(&doc).unwrap().unwrap();
        assert_eq!(rows.len(), 2);
        assert!((rows[0].wait[0] - 0.75).abs() < 1e-12);
        assert_eq!(rows[0].msgs, 1);
        assert_eq!(rows[0].bytes, 512);
        assert!((rows[0].advance - 1.5).abs() < 1e-12);
        assert!((residual - 0.25).abs() < 1e-12);
        assert!(rows[0].admit_latency.is_nan(), "null parses back to NaN");
    }

    #[test]
    fn parse_section_absent_on_plain_reports() {
        let doc = Json::parse(r#"{"makespan":1.0}"#).unwrap();
        assert!(Ledger::parse_section(&doc).is_none());
    }

    #[test]
    fn merge_appends_rows_and_adds_marks() {
        let mut a = Ledger::default();
        a.record_retire(0, 1.0);
        let mut b = Ledger::default();
        b.record_retire(0, 2.0);
        a.merge(&b);
        assert_eq!(a.rows.len(), 2);
        assert_eq!(a.clock_hi(), 3.0);
    }
}
