//! Perf-regression comparator: `distnumpy compare <baseline> <new>`.
//!
//! Walks two JSON reports (run JSON or the ablation `BENCH_*.json`
//! artifacts) in lockstep and gates a whitelist of *virtual-time*
//! metrics with direction-aware relative thresholds. Only metrics the
//! simulator computes deterministically are gated — the committed
//! baselines under `bench/baselines/` reproduce exactly on any machine.
//! Host wall-clock sections (`host`, bench `secs`/`median`/`stddev`)
//! are machine-dependent and never gated; unknown keys are counted as
//! ignored rather than failed, so adding a report field cannot break
//! the gate retroactively.
//!
//! A metric regresses when it moves in its bad direction by more than
//! `threshold` (relative to the baseline magnitude, default 10%).
//! Near-zero pairs (both sides under an absolute floor) always pass:
//! a 0 → 1e-15 wobble is noise, while 0 → anything material is an
//! infinite relative regression and fails, which is exactly right for
//! a deterministic simulator.

use crate::util::json::Json;

/// Default relative threshold (10%).
pub const DEFAULT_THRESHOLD: f64 = 0.10;

/// Both sides below this magnitude compare equal.
const ABS_FLOOR: f64 = 1e-9;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    LowerBetter,
    HigherBetter,
}

/// The gated-metric whitelist, keyed on the JSON leaf name.
fn direction(key: &str) -> Option<Direction> {
    use Direction::*;
    match key {
        "makespan" | "total_wait" | "wait_pct" | "wait_root" | "wait_at_barrier"
        | "wait_at_cone" | "wait_at_admission" | "admission_latency" | "overhead"
        | "n_messages" | "bytes_inter" | "bytes_intra" | "excess_edge_pct"
        | "predicted_stalls" | "lints" | "races" | "trace_dropped" | "wait_p99" => {
            Some(LowerBetter)
        }
        "speedup" | "overlap_pct" | "utilization" => Some(HigherBetter),
        // `events_per_sec` is deliberately absent: it is host wall-clock
        // throughput and must never be gated, even if it ever appears
        // outside the skipped `host` subtree.
        _ => None,
    }
}

/// One gated metric's comparison.
#[derive(Clone, Debug)]
pub struct Row {
    /// Dotted path into the report, e.g. `ablation.3.wait_pct`.
    pub path: String,
    pub base: f64,
    pub new: f64,
    /// Signed relative change in the *bad* direction: positive means
    /// worse, and `rel > threshold` is a regression.
    pub rel: f64,
    pub regressed: bool,
}

/// The full comparison outcome.
#[derive(Clone, Debug, Default)]
pub struct CompareOutcome {
    pub rows: Vec<Row>,
    /// Numeric leaves present in both reports but not on the gated
    /// whitelist (host wall clock, config identity, unknown fields).
    pub ignored: usize,
    /// Gated leaves present in the *baseline* alone, whether or not the
    /// new report matched them. When this is nonzero but `rows` is
    /// empty, the new report checked nothing the baseline gates — an
    /// empty/renamed/truncated bench artifact, not a clean pass.
    pub baseline_gated: usize,
    pub threshold: f64,
    /// Informational context: non-whitelisted *virtual-time* numeric
    /// leaves that moved by more than the threshold (host subtree and
    /// wall-clock bench fields still excluded). Never gates — surfaced
    /// in `--json` so a gate report carries the surrounding movement.
    pub ungated: Vec<Row>,
    /// `meta.commit` stamped into the baseline by
    /// `bench/baselines/refresh.sh`, so a failure names what it gated
    /// against.
    pub meta_commit: Option<String>,
    /// `meta.date` of the baseline refresh.
    pub meta_date: Option<String>,
}

impl CompareOutcome {
    pub fn regressions(&self) -> impl Iterator<Item = &Row> {
        self.rows.iter().filter(|r| r.regressed)
    }

    pub fn n_regressed(&self) -> usize {
        self.regressions().count()
    }

    /// True when the baseline contains gated metrics but none were
    /// actually compared — a broken new report must not read as green.
    pub fn is_vacuous(&self) -> bool {
        self.baseline_gated > 0 && self.rows.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut rows = Vec::new();
        for r in &self.rows {
            let mut o = Json::obj();
            o.push("metric", r.path.as_str().into());
            o.push("base", r.base.into());
            o.push("new", r.new.into());
            o.push("rel", r.rel.into());
            o.push("regressed", r.regressed.into());
            rows.push(o);
        }
        let mut o = Json::obj();
        o.push("threshold", self.threshold.into());
        o.push("checked", self.rows.len().into());
        o.push("ignored", self.ignored.into());
        o.push("baseline_gated", self.baseline_gated.into());
        o.push("vacuous", self.is_vacuous().into());
        o.push("regressions", self.n_regressed().into());
        if self.meta_commit.is_some() || self.meta_date.is_some() {
            let mut m = Json::obj();
            if let Some(c) = &self.meta_commit {
                m.push("commit", c.as_str().into());
            }
            if let Some(d) = &self.meta_date {
                m.push("date", d.as_str().into());
            }
            o.push("baseline_meta", m);
        }
        o.push("rows", Json::Arr(rows));
        // Context, not gate: the largest non-whitelisted movements,
        // biggest first, capped so a reshaped report can't flood the
        // gate output.
        let mut ungated: Vec<&Row> = self.ungated.iter().collect();
        ungated.sort_by(|a, b| b.rel.abs().total_cmp(&a.rel.abs()));
        ungated.truncate(50);
        let mut uj = Vec::new();
        for r in ungated {
            let mut u = Json::obj();
            u.push("metric", r.path.as_str().into());
            u.push("base", r.base.into());
            u.push("new", r.new.into());
            u.push("rel", r.rel.into());
            uj.push(u);
        }
        o.push("ungated", Json::Arr(uj));
        o
    }

    /// Human-readable report: regressions first, then the verdict line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        if self.meta_commit.is_some() || self.meta_date.is_some() {
            out.push_str(&format!(
                "baseline: commit {} ({})\n",
                self.meta_commit.as_deref().unwrap_or("unknown"),
                self.meta_date.as_deref().unwrap_or("undated"),
            ));
        }
        for r in self.regressions() {
            out.push_str(&format!(
                "REGRESSION {:<40} {:>14.6e} -> {:>14.6e}  ({:+.1}%)\n",
                r.path,
                r.base,
                r.new,
                r.rel * 100.0
            ));
        }
        if self.is_vacuous() {
            out.push_str(&format!(
                "VACUOUS baseline gates {} metric(s) but none were found in the new report\n",
                self.baseline_gated
            ));
        }
        out.push_str(&format!(
            "{} metrics gated, {} ignored, {} regressed (threshold {:.0}%)\n",
            self.rows.len(),
            self.ignored,
            self.n_regressed(),
            self.threshold * 100.0
        ));
        out
    }
}

/// Compare two parsed reports. Walks objects by shared key and arrays
/// by index; leaves present on only one side are skipped (a renamed or
/// added metric is not a regression). As a backstop, the outcome is
/// flagged [`CompareOutcome::is_vacuous`] when the baseline contains
/// gated metrics but the new report matched none of them.
pub fn compare(base: &Json, new: &Json, threshold: f64) -> CompareOutcome {
    let mut out = CompareOutcome {
        threshold,
        baseline_gated: count_gated(base),
        ..Default::default()
    };
    if let Some(meta) = base.get("meta") {
        out.meta_commit = meta
            .get("commit")
            .and_then(Json::as_str)
            .map(str::to_string);
        out.meta_date = meta.get("date").and_then(Json::as_str).map(str::to_string);
    }
    walk(base, new, "", &mut out);
    out
}

/// The follow-up command a failing gate names: attribute the regression
/// per epoch/cause with the differential analyzer.
pub fn diff_hint(base_path: &str, new_path: &str) -> String {
    format!("distnumpy diff {base_path} {new_path}")
}

/// Wall-clock bench fields: machine-dependent, excluded from the
/// informational `ungated` section just like the gate excludes them.
fn wall_clock(key: &str) -> bool {
    matches!(key, "secs" | "median" | "stddev" | "events_per_sec")
}

/// Count the gated numeric leaves a report contains on its own,
/// skipping the never-gated `host` subtree — used to detect a vacuous
/// comparison where the new report matched none of them.
fn count_gated(j: &Json) -> usize {
    match j {
        Json::Obj(fields) => fields
            .iter()
            .filter(|(k, _)| k != "host")
            .map(|(k, v)| match numeric(v) {
                Some(_) => usize::from(direction(k).is_some()),
                None => count_gated(v),
            })
            .sum(),
        Json::Arr(items) => items.iter().map(count_gated).sum(),
        _ => 0,
    }
}

fn numeric(j: &Json) -> Option<f64> {
    match j {
        Json::Num(v) => Some(*v),
        Json::Int(v) => Some(*v as f64),
        _ => None,
    }
}

fn walk(base: &Json, new: &Json, path: &str, out: &mut CompareOutcome) {
    match (base, new) {
        (Json::Obj(bs), Json::Obj(_)) => {
            for (k, bv) in bs {
                // Host wall clock is machine-dependent: skip the whole
                // subtree without even counting its leaves as ignored.
                if k == "host" {
                    continue;
                }
                if let Some(nv) = new.get(k) {
                    let sub = join(path, k);
                    walk(bv, nv, &sub, out);
                }
            }
        }
        (Json::Arr(bs), Json::Arr(ns)) => {
            for (i, (bv, nv)) in bs.iter().zip(ns).enumerate() {
                let sub = join(path, &i.to_string());
                walk(bv, nv, &sub, out);
            }
        }
        _ => {
            let (Some(b), Some(n)) = (numeric(base), numeric(new)) else {
                return;
            };
            let key = path.rsplit('.').next().unwrap_or(path);
            let Some(dir) = direction(key) else {
                out.ignored += 1;
                // Informational only: record material movement of
                // virtual-time leaves the gate doesn't cover (direction
                // unknown, so `rel` is the raw signed relative change).
                if !wall_clock(key) && !(b.abs() < ABS_FLOOR && n.abs() < ABS_FLOOR) {
                    let rel = (n - b) / b.abs().max(ABS_FLOOR);
                    if rel.abs() > out.threshold {
                        out.ungated.push(Row {
                            path: path.to_string(),
                            base: b,
                            new: n,
                            rel,
                            regressed: false,
                        });
                    }
                }
                return;
            };
            if b.abs() < ABS_FLOOR && n.abs() < ABS_FLOOR {
                out.rows.push(Row {
                    path: path.to_string(),
                    base: b,
                    new: n,
                    rel: 0.0,
                    regressed: false,
                });
                return;
            }
            // Positive delta = moved in the bad direction.
            let delta = match dir {
                Direction::LowerBetter => n - b,
                Direction::HigherBetter => b - n,
            };
            let rel = delta / b.abs().max(ABS_FLOOR);
            out.rows.push(Row {
                path: path.to_string(),
                base: b,
                new: n,
                rel,
                regressed: rel > out.threshold,
            });
        }
    }
}

fn join(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_host(wait_pct: f64, speedup: f64, host_eps: f64) -> Json {
        let mut row = Json::obj();
        row.push("p", 16u64.into());
        row.push("wait_pct", wait_pct.into());
        row.push("speedup", speedup.into());
        let mut host = Json::obj();
        host.push("events_per_sec", host_eps.into());
        let mut o = Json::obj();
        o.push("ablation", Json::Arr(vec![row]));
        o.push("host", host);
        o
    }

    fn report(wait_pct: f64, speedup: f64) -> Json {
        report_host(wait_pct, speedup, 1e6)
    }

    #[test]
    fn self_compare_is_clean() {
        let a = report(12.0, 3.0);
        let out = compare(&a, &a, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert!(out.rows.len() >= 2, "wait_pct and speedup gated");
    }

    #[test]
    fn wait_pct_regression_flags() {
        let base = report(10.0, 3.0);
        let new = report(11.5, 3.0); // +15% > 10% threshold
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 1);
        let r = out.regressions().next().unwrap();
        assert_eq!(r.path, "ablation.0.wait_pct");
        assert!((r.rel - 0.15).abs() < 1e-9);
    }

    #[test]
    fn wait_pct_improvement_passes() {
        let base = report(10.0, 3.0);
        let new = report(2.0, 3.0);
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
    }

    #[test]
    fn speedup_drop_flags_higher_better() {
        let base = report(10.0, 4.0);
        let new = report(10.0, 3.0); // -25%
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 1);
        assert!(out.regressions().next().unwrap().path.ends_with("speedup"));
    }

    #[test]
    fn within_threshold_passes() {
        let base = report(10.0, 3.0);
        let new = report(10.5, 3.0); // +5% < 10%
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
    }

    #[test]
    fn host_section_never_gated() {
        let base = report_host(10.0, 3.0, 1e6);
        // Tanked host throughput: must not gate (machine-dependent).
        let new = report_host(10.0, 3.0, 1.0);
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert!(out.rows.iter().all(|r| !r.path.starts_with("host")));
    }

    #[test]
    fn events_per_sec_never_gated_even_outside_host() {
        // Wall-clock throughput is machine-dependent; even if it ever
        // escapes the skipped `host` subtree it must stay off the gate.
        let mut base = Json::obj();
        base.push("events_per_sec", 1e6.into());
        base.push("wait_pct", 10.0.into());
        let mut new = Json::obj();
        new.push("events_per_sec", 1.0.into());
        new.push("wait_pct", 10.0.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert_eq!(out.ignored, 1);
    }

    #[test]
    fn near_zero_pairs_pass_but_material_growth_fails() {
        let mut base = Json::obj();
        base.push("wait_at_admission", 0.0.into());
        let mut ok = Json::obj();
        ok.push("wait_at_admission", 1e-15.into());
        assert_eq!(compare(&base, &ok, DEFAULT_THRESHOLD).n_regressed(), 0);
        let mut bad = Json::obj();
        bad.push("wait_at_admission", 0.5.into());
        assert_eq!(compare(&base, &bad, DEFAULT_THRESHOLD).n_regressed(), 1);
    }

    #[test]
    fn unknown_keys_ignored_not_failed() {
        let mut base = Json::obj();
        base.push("n_epochs", 4u64.into());
        let mut new = Json::obj();
        new.push("n_epochs", 400u64.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert_eq!(out.ignored, 1);
    }

    #[test]
    fn missing_keys_skipped_but_flagged_vacuous() {
        let base = report(10.0, 3.0);
        let mut new = Json::obj();
        new.push("something_else", 1.0.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert!(out.rows.is_empty());
        // The baseline gates wait_pct and speedup yet nothing was
        // compared: that is a broken artifact, not a clean pass.
        assert_eq!(out.baseline_gated, 2);
        assert!(out.is_vacuous());
        assert!(out.render_text().contains("VACUOUS"));
    }

    #[test]
    fn partial_overlap_is_not_vacuous() {
        // One shared gated metric is enough to make the compare real;
        // the renamed/missing one is skipped as before.
        let base = report(10.0, 3.0);
        let mut row = Json::obj();
        row.push("wait_pct", 10.0.into());
        let mut new = Json::obj();
        new.push("ablation", Json::Arr(vec![row]));
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.rows.len(), 1);
        assert!(!out.is_vacuous());
    }

    #[test]
    fn ungated_section_carries_context_without_gating() {
        let mut base = Json::obj();
        base.push("wait_pct", 10.0.into());
        base.push("n_epochs", 4u64.into()); // not whitelisted
        base.push("secs", 1.0.into()); // wall clock: excluded
        let mut new = Json::obj();
        new.push("wait_pct", 10.0.into());
        new.push("n_epochs", 400u64.into());
        new.push("secs", 50.0.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0, "ungated movement never gates");
        assert_eq!(out.ungated.len(), 1, "n_epochs moved, secs excluded");
        let u = &out.ungated[0];
        assert_eq!(u.path, "n_epochs");
        assert!((u.rel - 99.0).abs() < 1e-9, "raw signed relative change");
        assert!(!u.regressed);
        let j = out.to_json().render();
        assert!(j.contains("\"ungated\""));
        assert!(j.contains("n_epochs"));
        assert!(!j.contains("secs"));
        // Sub-threshold wobble stays out of the section entirely.
        let mut close = Json::obj();
        close.push("wait_pct", 10.0.into());
        close.push("n_epochs", 4u64.into());
        close.push("secs", 1.0.into());
        assert!(compare(&base, &close, DEFAULT_THRESHOLD).ungated.is_empty());
    }

    #[test]
    fn baseline_meta_surfaces_in_text_and_json() {
        let mut meta = Json::obj();
        meta.push("commit", "abc1234".into());
        meta.push("date", "2026-08-08T00:00:00Z".into());
        let mut base = report(10.0, 3.0);
        base.push("meta", meta);
        let new = report(10.0, 3.0);
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.meta_commit.as_deref(), Some("abc1234"));
        assert!(out.render_text().contains("baseline: commit abc1234"));
        let j = out.to_json().render();
        assert!(j.contains("\"baseline_meta\""));
        assert!(j.contains("abc1234"));
        // A meta-less baseline keeps the old output shape.
        let bare = compare(&new, &new, DEFAULT_THRESHOLD);
        assert!(bare.meta_commit.is_none());
        assert!(!bare.render_text().contains("baseline:"));
        assert!(!bare.to_json().render().contains("baseline_meta"));
    }

    #[test]
    fn diff_hint_names_the_command() {
        let h = diff_hint("bench/baselines/BENCH_flow.json", "BENCH_flow.json");
        assert!(h.starts_with("distnumpy diff "));
        assert!(h.contains("bench/baselines/BENCH_flow.json"));
    }

    #[test]
    fn ungated_baseline_never_vacuous() {
        // A baseline with no gated leaves (e.g. config identity only)
        // cannot produce a vacuous verdict.
        let mut base = Json::obj();
        base.push("n_epochs", 4u64.into());
        let empty = Json::obj();
        let out = compare(&base, &empty, DEFAULT_THRESHOLD);
        assert_eq!(out.baseline_gated, 0);
        assert!(!out.is_vacuous());
    }
}
