//! Perf-regression comparator: `distnumpy compare <baseline> <new>`.
//!
//! Walks two JSON reports (run JSON or the ablation `BENCH_*.json`
//! artifacts) in lockstep and gates a whitelist of *virtual-time*
//! metrics with direction-aware relative thresholds. Only metrics the
//! simulator computes deterministically are gated — the committed
//! baselines under `bench/baselines/` reproduce exactly on any machine.
//! Host wall-clock sections (`host`, bench `secs`/`median`/`stddev`)
//! are machine-dependent and never gated; unknown keys are counted as
//! ignored rather than failed, so adding a report field cannot break
//! the gate retroactively.
//!
//! A metric regresses when it moves in its bad direction by more than
//! `threshold` (relative to the baseline magnitude, default 10%).
//! Near-zero pairs (both sides under an absolute floor) always pass:
//! a 0 → 1e-15 wobble is noise, while 0 → anything material is an
//! infinite relative regression and fails, which is exactly right for
//! a deterministic simulator.

use crate::util::json::Json;

/// Default relative threshold (10%).
pub const DEFAULT_THRESHOLD: f64 = 0.10;

/// Both sides below this magnitude compare equal.
const ABS_FLOOR: f64 = 1e-9;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    LowerBetter,
    HigherBetter,
}

/// The gated-metric whitelist, keyed on the JSON leaf name.
fn direction(key: &str) -> Option<Direction> {
    use Direction::*;
    match key {
        "makespan" | "total_wait" | "wait_pct" | "wait_root" | "wait_at_barrier"
        | "wait_at_cone" | "wait_at_admission" | "admission_latency" | "overhead"
        | "n_messages" | "bytes_inter" | "bytes_intra" | "excess_edge_pct"
        | "predicted_stalls" | "lints" | "races" | "trace_dropped" | "wait_p99" => {
            Some(LowerBetter)
        }
        "speedup" | "overlap_pct" | "utilization" => Some(HigherBetter),
        // `events_per_sec` is deliberately absent: it is host wall-clock
        // throughput and must never be gated, even if it ever appears
        // outside the skipped `host` subtree.
        _ => None,
    }
}

/// One gated metric's comparison.
#[derive(Clone, Debug)]
pub struct Row {
    /// Dotted path into the report, e.g. `ablation.3.wait_pct`.
    pub path: String,
    pub base: f64,
    pub new: f64,
    /// Signed relative change in the *bad* direction: positive means
    /// worse, and `rel > threshold` is a regression.
    pub rel: f64,
    pub regressed: bool,
}

/// The full comparison outcome.
#[derive(Clone, Debug, Default)]
pub struct CompareOutcome {
    pub rows: Vec<Row>,
    /// Numeric leaves present in both reports but not on the gated
    /// whitelist (host wall clock, config identity, unknown fields).
    pub ignored: usize,
    /// Gated leaves present in the *baseline* alone, whether or not the
    /// new report matched them. When this is nonzero but `rows` is
    /// empty, the new report checked nothing the baseline gates — an
    /// empty/renamed/truncated bench artifact, not a clean pass.
    pub baseline_gated: usize,
    pub threshold: f64,
}

impl CompareOutcome {
    pub fn regressions(&self) -> impl Iterator<Item = &Row> {
        self.rows.iter().filter(|r| r.regressed)
    }

    pub fn n_regressed(&self) -> usize {
        self.regressions().count()
    }

    /// True when the baseline contains gated metrics but none were
    /// actually compared — a broken new report must not read as green.
    pub fn is_vacuous(&self) -> bool {
        self.baseline_gated > 0 && self.rows.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut rows = Vec::new();
        for r in &self.rows {
            let mut o = Json::obj();
            o.push("metric", r.path.as_str().into());
            o.push("base", r.base.into());
            o.push("new", r.new.into());
            o.push("rel", r.rel.into());
            o.push("regressed", r.regressed.into());
            rows.push(o);
        }
        let mut o = Json::obj();
        o.push("threshold", self.threshold.into());
        o.push("checked", self.rows.len().into());
        o.push("ignored", self.ignored.into());
        o.push("baseline_gated", self.baseline_gated.into());
        o.push("vacuous", self.is_vacuous().into());
        o.push("regressions", self.n_regressed().into());
        o.push("rows", Json::Arr(rows));
        o
    }

    /// Human-readable report: regressions first, then the verdict line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for r in self.regressions() {
            out.push_str(&format!(
                "REGRESSION {:<40} {:>14.6e} -> {:>14.6e}  ({:+.1}%)\n",
                r.path,
                r.base,
                r.new,
                r.rel * 100.0
            ));
        }
        if self.is_vacuous() {
            out.push_str(&format!(
                "VACUOUS baseline gates {} metric(s) but none were found in the new report\n",
                self.baseline_gated
            ));
        }
        out.push_str(&format!(
            "{} metrics gated, {} ignored, {} regressed (threshold {:.0}%)\n",
            self.rows.len(),
            self.ignored,
            self.n_regressed(),
            self.threshold * 100.0
        ));
        out
    }
}

/// Compare two parsed reports. Walks objects by shared key and arrays
/// by index; leaves present on only one side are skipped (a renamed or
/// added metric is not a regression). As a backstop, the outcome is
/// flagged [`CompareOutcome::is_vacuous`] when the baseline contains
/// gated metrics but the new report matched none of them.
pub fn compare(base: &Json, new: &Json, threshold: f64) -> CompareOutcome {
    let mut out = CompareOutcome {
        threshold,
        baseline_gated: count_gated(base),
        ..Default::default()
    };
    walk(base, new, "", &mut out);
    out
}

/// Count the gated numeric leaves a report contains on its own,
/// skipping the never-gated `host` subtree — used to detect a vacuous
/// comparison where the new report matched none of them.
fn count_gated(j: &Json) -> usize {
    match j {
        Json::Obj(fields) => fields
            .iter()
            .filter(|(k, _)| k != "host")
            .map(|(k, v)| match numeric(v) {
                Some(_) => usize::from(direction(k).is_some()),
                None => count_gated(v),
            })
            .sum(),
        Json::Arr(items) => items.iter().map(count_gated).sum(),
        _ => 0,
    }
}

fn numeric(j: &Json) -> Option<f64> {
    match j {
        Json::Num(v) => Some(*v),
        Json::Int(v) => Some(*v as f64),
        _ => None,
    }
}

fn walk(base: &Json, new: &Json, path: &str, out: &mut CompareOutcome) {
    match (base, new) {
        (Json::Obj(bs), Json::Obj(_)) => {
            for (k, bv) in bs {
                // Host wall clock is machine-dependent: skip the whole
                // subtree without even counting its leaves as ignored.
                if k == "host" {
                    continue;
                }
                if let Some(nv) = new.get(k) {
                    let sub = join(path, k);
                    walk(bv, nv, &sub, out);
                }
            }
        }
        (Json::Arr(bs), Json::Arr(ns)) => {
            for (i, (bv, nv)) in bs.iter().zip(ns).enumerate() {
                let sub = join(path, &i.to_string());
                walk(bv, nv, &sub, out);
            }
        }
        _ => {
            let (Some(b), Some(n)) = (numeric(base), numeric(new)) else {
                return;
            };
            let key = path.rsplit('.').next().unwrap_or(path);
            let Some(dir) = direction(key) else {
                out.ignored += 1;
                return;
            };
            if b.abs() < ABS_FLOOR && n.abs() < ABS_FLOOR {
                out.rows.push(Row {
                    path: path.to_string(),
                    base: b,
                    new: n,
                    rel: 0.0,
                    regressed: false,
                });
                return;
            }
            // Positive delta = moved in the bad direction.
            let delta = match dir {
                Direction::LowerBetter => n - b,
                Direction::HigherBetter => b - n,
            };
            let rel = delta / b.abs().max(ABS_FLOOR);
            out.rows.push(Row {
                path: path.to_string(),
                base: b,
                new: n,
                rel,
                regressed: rel > out.threshold,
            });
        }
    }
}

fn join(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_host(wait_pct: f64, speedup: f64, host_eps: f64) -> Json {
        let mut row = Json::obj();
        row.push("p", 16u64.into());
        row.push("wait_pct", wait_pct.into());
        row.push("speedup", speedup.into());
        let mut host = Json::obj();
        host.push("events_per_sec", host_eps.into());
        let mut o = Json::obj();
        o.push("ablation", Json::Arr(vec![row]));
        o.push("host", host);
        o
    }

    fn report(wait_pct: f64, speedup: f64) -> Json {
        report_host(wait_pct, speedup, 1e6)
    }

    #[test]
    fn self_compare_is_clean() {
        let a = report(12.0, 3.0);
        let out = compare(&a, &a, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert!(out.rows.len() >= 2, "wait_pct and speedup gated");
    }

    #[test]
    fn wait_pct_regression_flags() {
        let base = report(10.0, 3.0);
        let new = report(11.5, 3.0); // +15% > 10% threshold
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 1);
        let r = out.regressions().next().unwrap();
        assert_eq!(r.path, "ablation.0.wait_pct");
        assert!((r.rel - 0.15).abs() < 1e-9);
    }

    #[test]
    fn wait_pct_improvement_passes() {
        let base = report(10.0, 3.0);
        let new = report(2.0, 3.0);
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
    }

    #[test]
    fn speedup_drop_flags_higher_better() {
        let base = report(10.0, 4.0);
        let new = report(10.0, 3.0); // -25%
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 1);
        assert!(out.regressions().next().unwrap().path.ends_with("speedup"));
    }

    #[test]
    fn within_threshold_passes() {
        let base = report(10.0, 3.0);
        let new = report(10.5, 3.0); // +5% < 10%
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
    }

    #[test]
    fn host_section_never_gated() {
        let base = report_host(10.0, 3.0, 1e6);
        // Tanked host throughput: must not gate (machine-dependent).
        let new = report_host(10.0, 3.0, 1.0);
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert!(out.rows.iter().all(|r| !r.path.starts_with("host")));
    }

    #[test]
    fn events_per_sec_never_gated_even_outside_host() {
        // Wall-clock throughput is machine-dependent; even if it ever
        // escapes the skipped `host` subtree it must stay off the gate.
        let mut base = Json::obj();
        base.push("events_per_sec", 1e6.into());
        base.push("wait_pct", 10.0.into());
        let mut new = Json::obj();
        new.push("events_per_sec", 1.0.into());
        new.push("wait_pct", 10.0.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert_eq!(out.ignored, 1);
    }

    #[test]
    fn near_zero_pairs_pass_but_material_growth_fails() {
        let mut base = Json::obj();
        base.push("wait_at_admission", 0.0.into());
        let mut ok = Json::obj();
        ok.push("wait_at_admission", 1e-15.into());
        assert_eq!(compare(&base, &ok, DEFAULT_THRESHOLD).n_regressed(), 0);
        let mut bad = Json::obj();
        bad.push("wait_at_admission", 0.5.into());
        assert_eq!(compare(&base, &bad, DEFAULT_THRESHOLD).n_regressed(), 1);
    }

    #[test]
    fn unknown_keys_ignored_not_failed() {
        let mut base = Json::obj();
        base.push("n_epochs", 4u64.into());
        let mut new = Json::obj();
        new.push("n_epochs", 400u64.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert_eq!(out.ignored, 1);
    }

    #[test]
    fn missing_keys_skipped_but_flagged_vacuous() {
        let base = report(10.0, 3.0);
        let mut new = Json::obj();
        new.push("something_else", 1.0.into());
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.n_regressed(), 0);
        assert!(out.rows.is_empty());
        // The baseline gates wait_pct and speedup yet nothing was
        // compared: that is a broken artifact, not a clean pass.
        assert_eq!(out.baseline_gated, 2);
        assert!(out.is_vacuous());
        assert!(out.render_text().contains("VACUOUS"));
    }

    #[test]
    fn partial_overlap_is_not_vacuous() {
        // One shared gated metric is enough to make the compare real;
        // the renamed/missing one is skipped as before.
        let base = report(10.0, 3.0);
        let mut row = Json::obj();
        row.push("wait_pct", 10.0.into());
        let mut new = Json::obj();
        new.push("ablation", Json::Arr(vec![row]));
        let out = compare(&base, &new, DEFAULT_THRESHOLD);
        assert_eq!(out.rows.len(), 1);
        assert!(!out.is_vacuous());
    }

    #[test]
    fn ungated_baseline_never_vacuous() {
        // A baseline with no gated leaves (e.g. config identity only)
        // cannot produce a vacuous verdict.
        let mut base = Json::obj();
        base.push("n_epochs", 4u64.into());
        let empty = Json::obj();
        let out = compare(&base, &empty, DEFAULT_THRESHOLD);
        assert_eq!(out.baseline_gated, 0);
        assert!(!out.is_vacuous());
    }
}
