//! Run metrics: the quantities the paper reports.
//!
//! The headline metric is **waiting time** — "the communication latency
//! not hidden behind computation" (Section 6) — as a percentage of total
//! execution time, plus speedup against the sequential NumPy baseline.

pub mod compare;
pub mod hist;
pub mod ledger;

use crate::profile::Profiler;
use crate::types::VTime;
use crate::util::json::Json;
use hist::{DistMetrics, Hist};
use ledger::Ledger;

/// Outcome of executing one flushed batch (or a whole run) on the
/// simulated cluster.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Virtual makespan of the run (s).
    pub makespan: VTime,
    /// Per-rank time spent blocked waiting for communication (s).
    pub wait: Vec<VTime>,
    /// Per-rank busy compute time (s).
    pub busy: Vec<VTime>,
    /// Runtime overhead charged (recording + dependency management) (s).
    pub overhead: VTime,
    pub ops_executed: u64,
    pub n_compute: u64,
    pub n_comm: u64,
    pub bytes_inter: u64,
    pub bytes_intra: u64,
    /// Wire messages actually posted to the network (after aggregation
    /// packed constituent transfers into envelopes).
    pub n_messages: u64,
    /// Packed envelopes emitted by `comm::aggregate`.
    pub agg_msgs: u64,
    /// Constituent transfers absorbed into those envelopes; the
    /// messages saved are `agg_parts - agg_msgs`.
    pub agg_parts: u64,
    /// Flush epochs executed on the persistent
    /// [`crate::sched::ExecState`] timeline.
    pub n_epochs: u64,
    /// Wait accumulated at explicit global barriers — the cost of
    /// *forcing* reads under [`crate::sync::SyncMode::Barrier`],
    /// already included in the per-rank `wait` vectors.
    pub wait_at_barrier: VTime,
    /// Wait accumulated at targeted cone settles — the cost of forcing
    /// reads under [`crate::sync::SyncMode::Cone`] (joining the value's
    /// dependency cone plus riding its broadcast), also included in the
    /// per-rank `wait` vectors.
    pub wait_at_cone: VTime,
    /// Stall accumulated at admission gates: ranks waiting because the
    /// recorder had not yet admitted an operation ([`crate::flow`] Flow
    /// mode). The unhidden share of the streamed recording overhead —
    /// reported separately from the per-rank `wait` vectors, which keep
    /// the paper's meaning (communication latency not hidden), exactly
    /// as Batch mode's serialized recording is not counted there.
    pub wait_at_admission: VTime,
    /// Recording overhead charged on the concurrent recorder clock
    /// (Flow mode) instead of as per-epoch lumps on the rank clocks.
    /// Included in `overhead`.
    pub overhead_streamed: VTime,
    /// Staging buffers alive when the report was taken.
    pub live_stages: u64,
    /// High-water mark of live staging buffers — bounded by
    /// reference-counted reclamation ([`crate::sync::StageTable`])
    /// where it previously grew with run length.
    pub peak_live_stages: u64,
    /// High-water mark of epochs simultaneously in flight in the
    /// admission pipeline (submitted, not yet retired) — how deep the
    /// flow engine actually streamed ([`crate::flow::AdmissionLog`]).
    pub max_in_flight: u64,
    /// Epochs still pending in the flow engine when the report was
    /// taken (queued for a wave, or spliced into the live sliding
    /// session and not yet retired). 0 after every drain. A non-zero
    /// value flags an *in-flight snapshot*: the operation counters
    /// (`ops_executed`, `n_compute`, `n_comm`) fold in at drain, so
    /// under sliding admission they lag the clocks/busy/wait of work
    /// the live session has already executed until the next drain.
    pub flow_pending: u64,
    /// The concurrent recorder clock when the report was taken: when
    /// the last streamed epoch finished recording (0.0 under Batch,
    /// whose recording rides the rank clocks).
    pub recorder_clock: VTime,
    /// Mean per-epoch admission latency of the streamed epochs: from
    /// "the recorder could have started the epoch" to its admission —
    /// recording cost plus any window-gate stall.
    pub admission_latency: VTime,
    /// The admission window in effect at the end of the run under
    /// [`crate::flow::FlowWindow::Auto`] steering; 0 when no adaptive
    /// decision was ever taken (fixed windows, Batch).
    pub flow_window_final: u64,
    /// Adaptive-window decisions taken over the run.
    pub window_decisions: u64,
    /// Data races found by the [`crate::analyze`] hazard oracle under
    /// `SchedCfg::verify_deps` (always 0 on a completed run — a race
    /// aborts it). 0 when verification was off.
    pub races: u64,
    /// Direct dependency edges the oracle checked.
    pub dep_edges: u64,
    /// Checked direct edges no conflict path justifies (lost overlap).
    pub excess_edges: u64,
    /// Conflict-free op pairs the dependency closure serialized.
    pub serialized_pairs: u64,
    /// Scheduler runs the static stall predictor flagged.
    pub predicted_stalls: u64,
    /// Linter diagnostics across the verified runs.
    pub lints: u64,
    /// Trace-ring events dropped because the bounded sink wrapped —
    /// previously only visible in the Perfetto export's `otherData`,
    /// now surfaced here so a truncated trace is caught from the run
    /// JSON alone. Always 0 when tracing is off.
    pub trace_dropped: u64,
    /// Distribution metrics: per-cause wait histograms, the
    /// wire-message size histogram, and the per-epoch wait series
    /// ([`hist::DistMetrics`]). Always populated.
    pub dist: DistMetrics,
    /// Distribution of the streamed per-epoch admission latencies whose
    /// mean is `admission_latency` ([`crate::flow::AdmissionLog`]).
    pub admission_hist: Hist,
    /// The per-epoch run ledger ([`ledger::Ledger`]): one accounting
    /// row per flush epoch, reconciling exactly with the scalars above
    /// — the alignment substrate `distnumpy diff` attributes regressions
    /// on. Always populated.
    pub ledger: Ledger,
    /// Host-side self-profile (`--profile`): phase wall timers and DES
    /// events/sec. `None` unless profiling was enabled.
    pub host: Option<Profiler>,
}

impl RunReport {
    pub fn new(nprocs: usize) -> Self {
        RunReport {
            wait: vec![0.0; nprocs],
            busy: vec![0.0; nprocs],
            ..Default::default()
        }
    }

    /// Merge a subsequent batch's report (batch after batch).
    ///
    /// **Invariant:** both reports must describe the same rank count —
    /// merging reports of different widths would silently truncate the
    /// per-rank vectors to the shorter one. Debug builds assert it.
    ///
    /// Note the makespans *add*: absorbing models back-to-back runs with
    /// a barrier in between. The epoch model ([`crate::sched::ExecState`])
    /// does not absorb per-flush reports any more — it keeps one
    /// continuous timeline and snapshots it — so this is only for
    /// combining genuinely independent runs.
    pub fn absorb(&mut self, other: &RunReport) {
        debug_assert_eq!(
            self.wait.len(),
            other.wait.len(),
            "absorb: rank-count mismatch"
        );
        debug_assert_eq!(
            self.busy.len(),
            other.busy.len(),
            "absorb: rank-count mismatch"
        );
        self.makespan += other.makespan;
        // `admission_latency` is a *mean* (per streamed epoch); combine
        // as an op-weighted mean of the two runs (per-mode epoch counts
        // are not carried here, and op counts track how much work each
        // run's admission latency governed). Two zero-op reports keep
        // the larger value rather than dividing by zero.
        let self_ops = self.ops_executed as f64;
        let other_ops = other.ops_executed as f64;
        self.admission_latency = if self_ops + other_ops > 0.0 {
            (self.admission_latency * self_ops + other.admission_latency * other_ops)
                / (self_ops + other_ops)
        } else {
            self.admission_latency.max(other.admission_latency)
        };
        for (a, b) in self.wait.iter_mut().zip(&other.wait) {
            *a += b;
        }
        for (a, b) in self.busy.iter_mut().zip(&other.busy) {
            *a += b;
        }
        self.overhead += other.overhead;
        self.ops_executed += other.ops_executed;
        self.n_compute += other.n_compute;
        self.n_comm += other.n_comm;
        self.bytes_inter += other.bytes_inter;
        self.bytes_intra += other.bytes_intra;
        self.n_messages += other.n_messages;
        self.agg_msgs += other.agg_msgs;
        self.agg_parts += other.agg_parts;
        self.n_epochs += other.n_epochs;
        self.wait_at_barrier += other.wait_at_barrier;
        self.wait_at_cone += other.wait_at_cone;
        self.wait_at_admission += other.wait_at_admission;
        self.overhead_streamed += other.overhead_streamed;
        // Back-to-back independent runs: leftover live stages add up;
        // the combined peak is whichever run's was higher.
        self.live_stages += other.live_stages;
        self.peak_live_stages = self.peak_live_stages.max(other.peak_live_stages);
        // Pipeline-depth metrics combine as worst-case across the runs;
        // pending epochs and steering decisions accumulate.
        self.max_in_flight = self.max_in_flight.max(other.max_in_flight);
        self.flow_pending += other.flow_pending;
        self.recorder_clock = self.recorder_clock.max(other.recorder_clock);
        self.flow_window_final = self.flow_window_final.max(other.flow_window_final);
        self.window_decisions += other.window_decisions;
        self.races += other.races;
        self.dep_edges += other.dep_edges;
        self.excess_edges += other.excess_edges;
        self.serialized_pairs += other.serialized_pairs;
        self.predicted_stalls += other.predicted_stalls;
        self.lints += other.lints;
        self.trace_dropped += other.trace_dropped;
        self.dist.merge(&other.dist);
        self.admission_hist.merge(&other.admission_hist);
        self.ledger.merge(&other.ledger);
        // Host profiles merge only when both runs carried one; a report
        // without a profile contributes nothing to phase timings.
        match (&mut self.host, &other.host) {
            (Some(a), Some(b)) => a.merge(b),
            (None, Some(b)) => self.host = Some(b.clone()),
            _ => {}
        }
    }

    /// Wait time of the collective root (rank 0) — the hot spot flat
    /// fan-ins serialize on.
    pub fn wait_root(&self) -> f64 {
        self.wait.first().copied().unwrap_or(0.0)
    }

    /// Mean over ranks of wait time / total time — the paper's
    /// "time spent on waiting for communication" percentage.
    pub fn wait_pct(&self) -> f64 {
        if self.makespan <= 0.0 || self.wait.is_empty() {
            return 0.0;
        }
        let total: f64 = self.wait.iter().sum();
        100.0 * total / (self.makespan * self.wait.len() as f64)
    }

    /// Share of the streamed recording overhead that execution actually
    /// hid — the record/execute overlap of the incremental flush engine
    /// ([`crate::flow::overlap`]). Batch mode streams nothing (its
    /// recording is serialized onto the rank clocks by construction),
    /// so it reports 0; Flow mode reports
    /// `100 · (1 − wait_at_admission / (P · overhead_streamed))`,
    /// clamped to [0, 100] — 100 means no rank ever stalled for the
    /// recorder.
    pub fn overlap_pct(&self) -> f64 {
        let p = self.wait.len() as f64;
        let streamed = self.overhead_streamed * p;
        if streamed <= 0.0 {
            return 0.0;
        }
        (100.0 * (1.0 - self.wait_at_admission / streamed)).clamp(0.0, 100.0)
    }

    /// CPU utilization: busy / (P × makespan).
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 || self.busy.is_empty() {
            return 0.0;
        }
        let total: f64 = self.busy.iter().sum();
        total / (self.makespan * self.busy.len() as f64)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("makespan", self.makespan.into());
        o.push("wait_pct", self.wait_pct().into());
        o.push("utilization", self.utilization().into());
        o.push("overhead", self.overhead.into());
        o.push("ops", self.ops_executed.into());
        o.push("n_compute", self.n_compute.into());
        o.push("n_comm", self.n_comm.into());
        o.push("bytes_inter", self.bytes_inter.into());
        o.push("bytes_intra", self.bytes_intra.into());
        o.push("n_messages", self.n_messages.into());
        o.push("agg_msgs", self.agg_msgs.into());
        o.push("agg_parts", self.agg_parts.into());
        o.push("wait_root", self.wait_root().into());
        o.push("n_epochs", self.n_epochs.into());
        o.push("wait_at_barrier", self.wait_at_barrier.into());
        o.push("wait_at_cone", self.wait_at_cone.into());
        o.push("wait_at_admission", self.wait_at_admission.into());
        o.push("overlap_pct", self.overlap_pct().into());
        o.push("live_stages", self.live_stages.into());
        o.push("peak_live_stages", self.peak_live_stages.into());
        o.push("max_in_flight", self.max_in_flight.into());
        o.push("flow_pending", self.flow_pending.into());
        o.push("recorder_clock", self.recorder_clock.into());
        o.push("admission_latency", self.admission_latency.into());
        o.push("flow_window_final", self.flow_window_final.into());
        o.push("window_decisions", self.window_decisions.into());
        o.push("races", self.races.into());
        // The raw oracle counters alongside the derived percentage, so
        // a consumer can recompute or re-weight it.
        o.push("dep_edges", self.dep_edges.into());
        o.push("excess_edges", self.excess_edges.into());
        o.push("serialized_pairs", self.serialized_pairs.into());
        o.push("excess_edge_pct", self.excess_edge_pct().into());
        o.push("predicted_stalls", self.predicted_stalls.into());
        o.push("lints", self.lints.into());
        o.push("trace_dropped", self.trace_dropped.into());
        // p99 of the per-rank wait intervals (all causes except
        // Admission) — the tail the scalar wait_pct hides.
        o.push("wait_p99", self.dist.wait_all().p99().into());
        let mut dist = Json::obj();
        dist.push("wait", self.dist.wait_to_json());
        dist.push("msg_bytes", self.dist.msg_bytes.to_json());
        dist.push("admission_latency", self.admission_hist.to_json());
        dist.push(
            "epoch_wait",
            Json::Arr(self.dist.epoch_wait.iter().map(|&w| w.into()).collect()),
        );
        o.push("dist", dist);
        o.push("ledger", self.ledger.to_json(self.makespan));
        if let Some(host) = &self.host {
            o.push("host", host.to_json());
        }
        o
    }

    /// Share of oracle-checked direct edges no conflict justifies (%);
    /// 0 when verification never ran.
    pub fn excess_edge_pct(&self) -> f64 {
        if self.dep_edges == 0 {
            0.0
        } else {
            self.excess_edges as f64 / self.dep_edges as f64 * 100.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_pct_basic() {
        let mut r = RunReport::new(2);
        r.makespan = 10.0;
        r.wait = vec![2.0, 4.0];
        assert!((r.wait_pct() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn absorb_accumulates() {
        let mut a = RunReport::new(2);
        a.makespan = 1.0;
        a.wait = vec![0.5, 0.0];
        a.ops_executed = 3;
        let mut b = RunReport::new(2);
        b.makespan = 2.0;
        b.wait = vec![0.5, 1.0];
        b.ops_executed = 4;
        a.absorb(&b);
        assert_eq!(a.makespan, 3.0);
        assert_eq!(a.wait, vec![1.0, 1.0]);
        assert_eq!(a.ops_executed, 7);
    }

    #[test]
    fn empty_report_no_nan() {
        let r = RunReport::default();
        assert_eq!(r.wait_pct(), 0.0);
        assert_eq!(r.utilization(), 0.0);
    }

    #[test]
    fn json_renders() {
        let r = RunReport::new(1);
        let s = r.to_json().render();
        assert!(s.contains("wait_pct"));
        assert!(s.contains("n_messages"));
        assert!(s.contains("agg_msgs"));
        assert!(s.contains("wait_root"));
        assert!(s.contains("n_epochs"));
        assert!(s.contains("wait_at_barrier"));
        assert!(s.contains("wait_at_cone"));
        assert!(s.contains("wait_at_admission"));
        assert!(s.contains("overlap_pct"));
        assert!(s.contains("peak_live_stages"));
        assert!(s.contains("max_in_flight"));
        assert!(s.contains("flow_pending"));
        assert!(s.contains("recorder_clock"));
        assert!(s.contains("admission_latency"));
        assert!(s.contains("flow_window_final"));
        assert!(s.contains("window_decisions"));
        assert!(s.contains("races"));
        assert!(s.contains("dep_edges"));
        assert!(s.contains("excess_edges"));
        assert!(s.contains("serialized_pairs"));
        assert!(s.contains("excess_edge_pct"));
        assert!(s.contains("predicted_stalls"));
        assert!(s.contains("lints"));
        assert!(s.contains("trace_dropped"));
        assert!(s.contains("wait_p99"));
        assert!(s.contains("\"dist\""));
        assert!(s.contains("msg_bytes"));
        assert!(s.contains("epoch_wait"));
        assert!(s.contains("\"ledger\""));
        assert!(
            !s.contains("\"host\""),
            "no host section unless profiling ran"
        );
    }

    #[test]
    fn json_host_section_when_profiled() {
        use crate::profile::{Phase, ProfCfg, Profiler};
        let mut r = RunReport::new(1);
        let mut p = Profiler::new(ProfCfg { enabled: true });
        p.add_nanos(Phase::Drain, 1000);
        r.host = Some(p);
        let s = r.to_json().render();
        assert!(s.contains("\"host\""));
        assert!(s.contains("events_per_sec"));
    }

    #[test]
    fn absorb_merges_distributions() {
        use crate::trace::WaitCause;
        let mut a = RunReport::new(1);
        a.dist.record_wait(WaitCause::Barrier, 0, 1.0);
        a.trace_dropped = 2;
        let mut b = RunReport::new(1);
        b.dist.record_wait(WaitCause::Barrier, 0, 3.0);
        b.dist.msg_bytes.record(4096.0);
        b.trace_dropped = 1;
        a.absorb(&b);
        assert_eq!(a.trace_dropped, 3);
        assert_eq!(a.dist.msg_bytes.n(), 1);
        let h = &a.dist.wait_by_cause[WaitCause::Barrier.index()];
        assert_eq!(h.n(), 2);
        assert!((h.sum() - 4.0).abs() < 1e-12);
        // Epoch series append (independent back-to-back runs).
        assert_eq!(a.dist.epoch_wait, vec![1.0, 3.0]);
    }

    #[test]
    fn absorb_admission_latency_op_weighted_mean() {
        let mut a = RunReport::new(1);
        a.ops_executed = 3;
        a.admission_latency = 2.0;
        let mut b = RunReport::new(1);
        b.ops_executed = 1;
        b.admission_latency = 6.0;
        a.absorb(&b);
        // (2.0·3 + 6.0·1) / 4 — a mean, not a max.
        assert!((a.admission_latency - 3.0).abs() < 1e-12);

        // Two zero-op reports: keep the larger value, never divide by 0.
        let mut c = RunReport::new(1);
        c.admission_latency = 1.5;
        let mut d = RunReport::new(1);
        d.admission_latency = 0.5;
        c.absorb(&d);
        assert!((c.admission_latency - 1.5).abs() < 1e-12);
        assert!(c.admission_latency.is_finite());
    }

    #[test]
    fn overlap_pct_semantics() {
        let mut r = RunReport::new(4);
        assert_eq!(r.overlap_pct(), 0.0, "batch mode streams nothing");
        r.overhead_streamed = 1.0; // ×4 ranks = 4.0 streamed
        assert_eq!(r.overlap_pct(), 100.0, "no admission stall: fully hidden");
        r.wait_at_admission = 2.0;
        assert!((r.overlap_pct() - 50.0).abs() < 1e-9);
        r.wait_at_admission = 100.0;
        assert_eq!(r.overlap_pct(), 0.0, "clamped");
    }

    #[test]
    fn absorb_accumulates_message_counters() {
        let mut a = RunReport::new(1);
        a.n_messages = 3;
        a.agg_msgs = 1;
        a.agg_parts = 4;
        let mut b = RunReport::new(1);
        b.n_messages = 2;
        b.agg_parts = 2;
        b.agg_msgs = 1;
        a.absorb(&b);
        assert_eq!(a.n_messages, 5);
        assert_eq!(a.agg_msgs, 2);
        assert_eq!(a.agg_parts, 6);
    }
}
