//! Lazy evaluation front-end (paper Section 5.6).
//!
//! A [`Context`] plays the role of the Python interpreter boundary in
//! DistNumPy: array operations are **recorded**, not executed. A flush —
//! executing every recorded operation through the configured scheduler —
//! is triggered by the paper's three conditions:
//!
//! 1. the program *reads* distributed data (a reduction result, a
//!    gather, …) — [`Context::sum`], [`Context::sum_absdiff`],
//!    [`Context::gather`];
//! 2. the number of recorded operations reaches a threshold —
//!    [`Context::flush_threshold`];
//! 3. the program ends — [`Context::flush`] called by the apps at exit.
//!
//! ## Epochs and scalar futures
//!
//! A flush is *not* a barrier: every flush executes as one epoch of a
//! persistent [`ExecState`] — per-rank clocks, NIC frontiers and the
//! dependency system resume across epochs, so communication initiated in
//! epoch *k* keeps draining while epoch *k+1* records and computes. The
//! only global synchronization is *forcing* a scalar: an immediate
//! [`Context::sum`] barriers every rank (the interpreter is replicated,
//! §5.5 — every rank needs the value to take the branch), whereas the
//! deferred forms ([`Context::sum_deferred`],
//! [`Context::sum_absdiff_deferred`]) return a [`ScalarFuture`] whose
//! recorded reduction flows through the normal schedule and whose value
//! — and barrier — materialize only at [`ScalarFuture::wait`].
//!
//! ## Error handling
//!
//! A failed flush (e.g. a naive-policy deadlock) **poisons** the
//! context: the error is latched, later batches are dropped unexecuted,
//! and every subsequent scalar read returns `Err` instead of a silent
//! `0.0` — a deadlocked convergence loop can no longer masquerade as
//! converged at delta 0.0.

use crate::array::Registry;
use crate::comm::Collective;
use crate::exec::Backend;
use crate::layout::ViewSpec;
use crate::metrics::RunReport;
use crate::sched::{execute_epoch, ExecState, Policy, SchedCfg, SchedError};
use crate::types::{BaseId, DType, Rank, Tag};
use crate::ufunc::{Kernel, OpBuilder};

/// Default flush threshold (paper: "a user-defined threshold").
pub const DEFAULT_FLUSH_THRESHOLD: usize = 50_000;

/// A deferred scalar read: the reduction is recorded (and executes with
/// whatever flush epoch it lands in), but the value is only forced — and
/// the global barrier only paid — at [`ScalarFuture::wait`]. Staging
/// buffers are keyed by run-unique tags, so a future stays readable
/// across later flushes until it is waited on.
#[must_use = "a deferred read does nothing until .wait(ctx)"]
#[derive(Clone, Copy, Debug)]
pub struct ScalarFuture {
    tag: Tag,
}

impl ScalarFuture {
    /// Force the value: flush everything recorded so far, barrier, read.
    /// Fails if any flush epoch has failed (the context is poisoned).
    pub fn wait(&self, ctx: &mut Context) -> Result<f64, SchedError> {
        ctx.wait_scalar(self)
    }
}

/// The DistNumPy programming context: array registry + lazy recorder +
/// persistent execution state + backend.
pub struct Context {
    pub reg: Registry,
    pub builder: OpBuilder,
    pub cfg: SchedCfg,
    pub policy: Policy,
    pub backend: Box<dyn Backend>,
    /// Execution state persisting across flush epochs (clocks, NIC
    /// frontiers, dependency system, accumulated wait/busy).
    pub state: ExecState,
    /// Snapshot of `state` after the most recent flush/barrier.
    pub report: RunReport,
    pub flush_threshold: usize,
    pub flushes: u64,
    /// Accumulated virtual time of the sequential NumPy baseline for the
    /// same program (Section 6: the denominator of every speedup curve).
    /// Derived from the recorded compute payloads, so any-P runs yield
    /// the same baseline as a P=1 run (fragmentation cancels out).
    pub baseline: f64,
    array_ops_since_flush: u64,
    /// First scheduling error (the naive policy can deadlock). Once set
    /// the context is poisoned: later batches are dropped and every
    /// scalar read fails.
    pub error: Option<SchedError>,
}

impl Context {
    pub fn new(cfg: SchedCfg, policy: Policy, backend: Box<dyn Backend>) -> Self {
        let n = cfg.nprocs as usize;
        let state = ExecState::new(&cfg);
        Context {
            reg: Registry::new(cfg.nprocs),
            builder: OpBuilder::new(),
            cfg,
            policy,
            backend,
            state,
            report: RunReport::new(n),
            flush_threshold: DEFAULT_FLUSH_THRESHOLD,
            flushes: 0,
            baseline: 0.0,
            array_ops_since_flush: 0,
            error: None,
        }
    }

    /// Simulation-only context (no real data).
    pub fn sim(cfg: SchedCfg, policy: Policy) -> Self {
        Context::new(cfg, policy, Box::new(crate::exec::SimBackend))
    }

    // -- array creation (the only API difference from NumPy, Section 5) --

    /// Allocate a distributed array (zeros), returning its full view.
    pub fn zeros(&mut self, shape: &[u64], block_rows: u64) -> ViewSpec {
        let id = self.reg.alloc(shape.to_vec(), block_rows, DType::F32);
        self.backend.alloc_base(self.reg.layout(id));
        self.reg.full_view(id)
    }

    /// Allocate and fill from a dense row-major buffer (real backends).
    pub fn array(&mut self, shape: &[u64], block_rows: u64, data: &[f32]) -> ViewSpec {
        let v = self.zeros(shape, block_rows);
        self.backend.scatter(self.reg.layout(v.base), data);
        v
    }

    // -- recording --

    /// Record an elementwise ufunc `out = kernel(ins…)`.
    pub fn ufunc(&mut self, kernel: Kernel, out: &ViewSpec, ins: &[&ViewSpec]) {
        self.builder.ufunc(&self.reg, kernel, out, ins);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
    }

    /// Record `c = a + b`.
    pub fn add(&mut self, c: &ViewSpec, a: &ViewSpec, b: &ViewSpec) {
        self.ufunc(Kernel::Add, c, &[a, b]);
    }

    /// Record `dst = src` (copy between views).
    pub fn copy(&mut self, dst: &ViewSpec, src: &ViewSpec) {
        self.ufunc(Kernel::Copy, dst, &[src]);
    }

    // -- flush triggers --

    fn maybe_flush(&mut self) {
        if self.builder.n_recorded() >= self.flush_threshold {
            self.flush();
        }
    }

    /// Trigger 3 (and trigger 2's worker): execute everything recorded
    /// so far as one more epoch of the persistent timeline. No barrier —
    /// ranks resume wherever the epoch's dependency structure lets them.
    /// On a poisoned context the batch is dropped unexecuted.
    pub fn flush(&mut self) {
        let ops = self.builder.take();
        if ops.is_empty() {
            return;
        }
        if self.error.is_some() {
            // Poisoned: executing further epochs on torn state would
            // produce garbage timing/numerics. Drop the batch.
            self.array_ops_since_flush = 0;
            return;
        }
        self.flushes += 1;
        self.baseline += crate::sched::numpy_baseline(&ops, &self.cfg.spec)
            + self.array_ops_since_flush as f64 * self.cfg.spec.numpy_op_overhead;
        self.array_ops_since_flush = 0;
        match execute_epoch(
            self.policy,
            &ops,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.state,
        ) {
            Ok(()) => self.report = self.state.report(),
            Err(e) => {
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
    }

    /// Record a deferred `sum(view)`: the reduction executes with the
    /// normal flush flow; the value (and the barrier) wait for
    /// [`ScalarFuture::wait`]. The cross-rank fan-in is scheduled by
    /// `cfg.collective` (flat gather or binomial tree, see
    /// [`crate::comm`]).
    pub fn sum_deferred(&mut self, v: &ViewSpec) -> ScalarFuture {
        let collective = self.cfg.collective;
        let tag = self
            .builder
            .reduce(&self.reg, Kernel::PartialSum, &[v], collective);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
        ScalarFuture { tag }
    }

    /// Deferred `sum(|a - b|)` — the Jacobi convergence delta, checkable
    /// every *k* iterations without erecting a barrier per iteration.
    pub fn sum_absdiff_deferred(&mut self, a: &ViewSpec, b: &ViewSpec) -> ScalarFuture {
        let collective = self.cfg.collective;
        let tag =
            self.builder
                .reduce(&self.reg, Kernel::PartialAbsDiffSum, &[a, b], collective);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
        ScalarFuture { tag }
    }

    /// Force a deferred scalar: flush, check for poisoning, barrier
    /// (every rank joins the timeline frontier — the interpreter is
    /// replicated, so the value gates every rank's control flow), read.
    /// Returns the real value under a data backend, 0.0 in simulation.
    /// A data backend with *no* staged value for the future's tag is an
    /// error (e.g. the future was waited on a different context), never
    /// a silent 0.0.
    pub fn wait_scalar(&mut self, f: &ScalarFuture) -> Result<f64, SchedError> {
        self.flush();
        if let Some(e) = &self.error {
            return Err(e.clone());
        }
        self.state.barrier();
        self.report = self.state.report();
        match self.backend.staged_scalar(Rank(0), f.tag) {
            Some(v) => Ok(v),
            None if !self.backend.materializes_data() => Ok(0.0),
            None => Err(SchedError::Stall(format!(
                "scalar future {:?} has no staged value on rank 0 \
                 (waited on the wrong context?)",
                f.tag
            ))),
        }
    }

    /// Trigger 1: read a scalar — `sum(view)`. Forces a flush *and* a
    /// barrier; equivalent to `self.sum_deferred(v).wait(self)`.
    /// Fails loudly if any flush epoch failed (poisoned context).
    pub fn sum(&mut self, v: &ViewSpec) -> Result<f64, SchedError> {
        let f = self.sum_deferred(v);
        self.wait_scalar(&f)
    }

    /// Trigger 1: `sum(|a - b|)` — the Jacobi convergence delta, forced.
    pub fn sum_absdiff(&mut self, a: &ViewSpec, b: &ViewSpec) -> Result<f64, SchedError> {
        let f = self.sum_absdiff_deferred(a, b);
        self.wait_scalar(&f)
    }

    /// Trigger 1: gather a whole base to a dense buffer.
    ///
    /// The data movement is recorded as a first-class collective — a
    /// flat fan-in to rank 0 or a ring allgather, per `cfg.collective` —
    /// so it is dependency-tracked, scheduled and timed like every other
    /// operation. The dense assembly below then reads the block contents
    /// through the store oracle (bit-identical to the staged copies the
    /// collective delivered). A gather is a forced read: it flushes,
    /// fails on a poisoned context, and barriers. `Ok(None)` means the
    /// backend holds no real data (simulation).
    pub fn gather(&mut self, base: BaseId) -> Result<Option<Vec<f32>>, SchedError> {
        if self.cfg.nprocs > 1 {
            match self.cfg.collective {
                Collective::Flat => {
                    let _ = crate::comm::gather_flat(&mut self.builder, &self.reg, base, Rank(0));
                }
                Collective::Tree => {
                    let _ = crate::comm::allgather_ring(&mut self.builder, &self.reg, base);
                }
            }
            self.array_ops_since_flush += 1;
        }
        self.flush();
        if let Some(e) = &self.error {
            return Err(e.clone());
        }
        self.state.barrier();
        self.report = self.state.report();
        Ok(self.backend.gather(self.reg.layout(base)))
    }

    /// Finish the program: final flush, return the accumulated report of
    /// the whole continuous timeline (makespan = latest rank clock).
    pub fn finish(mut self) -> Result<RunReport, SchedError> {
        self.flush();
        match self.error {
            Some(e) => Err(e),
            None => Ok(self.state.report()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;

    fn ctx(p: u32) -> Context {
        Context::sim(SchedCfg::new(MachineSpec::tiny(), p), Policy::LatencyHiding)
    }

    #[test]
    fn records_without_executing() {
        let mut c = ctx(2);
        let x = c.zeros(&[16], 4);
        let y = c.zeros(&[16], 4);
        c.add(&y.clone(), &x, &y);
        assert_eq!(c.flushes, 0, "lazy: nothing executed yet");
        assert!(c.builder.n_recorded() > 0);
        c.flush();
        assert_eq!(c.flushes, 1);
        assert!(c.report.ops_executed > 0);
        assert_eq!(c.report.n_epochs, 1);
    }

    #[test]
    fn threshold_triggers_flush() {
        let mut c = ctx(2);
        c.flush_threshold = 8;
        let x = c.zeros(&[16], 4);
        for _ in 0..4 {
            c.add(&x.clone(), &x, &x); // 4 fragments per call
        }
        assert!(c.flushes >= 1, "threshold flush fired");
    }

    #[test]
    fn sum_triggers_flush_and_counts_ops() {
        let mut c = ctx(2);
        let x = c.zeros(&[16], 4);
        let _ = c.sum(&x);
        assert_eq!(c.flushes, 1);
        assert!(c.report.ops_executed >= 5);
    }

    #[test]
    fn empty_flush_is_noop() {
        let mut c = ctx(1);
        c.flush();
        assert_eq!(c.flushes, 0);
    }

    #[test]
    fn finish_returns_report() {
        let mut c = ctx(2);
        let x = c.zeros(&[8], 2);
        c.copy(&x.slice(&[(0, 4)]), &x.slice(&[(4, 8)]));
        let rep = c.finish().unwrap();
        assert!(rep.ops_executed > 0);
    }

    #[test]
    fn flushes_accumulate_one_continuous_timeline() {
        // Two flushes: the report's makespan is the frontier of one
        // continuous timeline, strictly less than the sum of two
        // independent runs (no barrier between epochs), and epochs count.
        let mut c = ctx(2);
        let x = c.zeros(&[32], 4);
        c.add(&x.clone(), &x, &x);
        c.flush();
        let m1 = c.report.makespan;
        c.add(&x.clone(), &x, &x);
        c.flush();
        assert_eq!(c.report.n_epochs, 2);
        assert!(c.report.makespan > m1, "timeline extends");
        assert_eq!(c.flushes, 2);
    }

    #[test]
    fn deferred_sum_postpones_the_barrier() {
        let mut c = ctx(4);
        let x = c.zeros(&[64], 4);
        let f = c.sum_deferred(&x);
        c.flush();
        // The reduce executed, but no barrier was paid: flushing is not
        // a global join any more.
        assert_eq!(c.flushes, 1, "deferred read flushed the epoch");
        assert_eq!(
            c.state.wait_at_barrier, 0.0,
            "no barrier wait before the future is forced"
        );
        let v = f.wait(&mut c).unwrap();
        assert_eq!(v, 0.0, "simulation backends read 0.0");
        // Forcing the value joined every rank to the frontier; the
        // fan-in leaves the clocks unequal, so the join costs wait.
        assert!(
            c.state.wait_at_barrier > 0.0,
            "the barrier is paid at wait()"
        );
        let t = c.state.max_clock();
        assert!(c.state.clock.iter().all(|&cl| cl == t));
    }

    #[test]
    fn immediate_sum_barriers_the_timeline() {
        let mut c = ctx(4);
        let x = c.zeros(&[64], 4);
        let _ = c.sum(&x).unwrap();
        let t = c.state.max_clock();
        assert!(c.state.clock.iter().all(|&cl| (cl - t).abs() < 1e-15));
    }

    /// The headline regression: a naive-policy deadlock must surface as
    /// an error from the convergence read — not as delta = 0.0, which a
    /// convergence loop would take as "converged".
    #[test]
    fn failed_flush_poisons_scalar_reads() {
        let mut c = Context::sim(SchedCfg::new(MachineSpec::tiny(), 2), Policy::Naive);
        let rows = 12u64;
        let m = c.zeros(&[rows], 3);
        let nv = c.zeros(&[rows], 3);
        // The Fig. 6 ping-pong stream: naive deadlocks in iteration 1.
        for _ in 0..2 {
            c.add(
                &nv.slice(&[(1, rows - 1)]),
                &m.slice(&[(2, rows)]),
                &m.slice(&[(0, rows - 2)]),
            );
            c.add(
                &m.slice(&[(1, rows - 1)]),
                &nv.slice(&[(2, rows)]),
                &nv.slice(&[(0, rows - 2)]),
            );
        }
        let delta = c.sum_absdiff(&m, &nv);
        assert!(
            matches!(delta, Err(SchedError::Deadlock { .. })),
            "deadlock must not masquerade as convergence: {delta:?}"
        );
        // Poisoned: subsequent reads and gathers keep failing loudly.
        assert!(c.sum(&m).is_err());
        assert!(c.gather(m.base).is_err());
        assert!(c.finish().is_err());
    }

    /// Same regression through the ring collective: `gather` under the
    /// tree schedule records a multi-round ring, which the naive
    /// evaluator deadlocks on (Fig. 6 restated) — the gather must error.
    #[test]
    fn naive_ring_collective_gather_errors() {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), 3);
        cfg.collective = Collective::Tree;
        let mut c = Context::sim(cfg, Policy::Naive);
        let x = c.zeros(&[3], 1);
        let got = c.gather(x.base);
        assert!(
            matches!(got, Err(SchedError::Deadlock { .. })),
            "ring gather under naive must deadlock loudly: {got:?}"
        );
    }

    #[test]
    fn poisoned_context_drops_later_batches() {
        let mut c = Context::sim(SchedCfg::new(MachineSpec::tiny(), 2), Policy::Naive);
        let rows = 12u64;
        let m = c.zeros(&[rows], 3);
        let nv = c.zeros(&[rows], 3);
        for _ in 0..2 {
            c.add(
                &nv.slice(&[(1, rows - 1)]),
                &m.slice(&[(2, rows)]),
                &m.slice(&[(0, rows - 2)]),
            );
            c.add(
                &m.slice(&[(1, rows - 1)]),
                &nv.slice(&[(2, rows)]),
                &nv.slice(&[(0, rows - 2)]),
            );
        }
        c.flush();
        assert!(c.error.is_some(), "deadlock latched");
        let flushes = c.flushes;
        let executed = c.state.ops_executed;
        c.add(&m.clone(), &m, &m);
        c.flush();
        assert_eq!(c.flushes, flushes, "poisoned flush drops the batch");
        assert_eq!(c.state.ops_executed, executed, "nothing else executed");
    }
}
