//! Lazy evaluation front-end (paper Section 5.6).
//!
//! A [`Context`] plays the role of the Python interpreter boundary in
//! DistNumPy: array operations are **recorded**, not executed. A flush —
//! executing every recorded operation through the configured scheduler —
//! is triggered by the paper's three conditions:
//!
//! 1. the program *reads* distributed data (a reduction result, a
//!    gather, …) — [`Context::sum`], [`Context::sum_absdiff`],
//!    [`Context::gather`];
//! 2. the number of recorded operations reaches a threshold —
//!    [`Context::flush_threshold`] (CLI `--flush-threshold`). This
//!    trigger is a non-blocking [`Context::submit`]: under
//!    [`crate::flow::FlowMode::Flow`] the batch enters the incremental
//!    flush engine's admission window and executes while recording
//!    continues ([`crate::flow`]); under
//!    [`crate::flow::FlowMode::Sliding`] it is spliced straight into
//!    the *live* resumable scheduler session
//!    ([`crate::sched::SchedSession`]) the moment the admission log
//!    allows — no wave boundary at all; under the default Batch mode
//!    it executes immediately, stop-the-world;
//! 3. the program ends — [`Context::flush`] (= submit + drain) called
//!    by the apps at exit.
//!
//! ## Epochs, futures and targeted waits
//!
//! A flush is *not* a barrier: every flush executes as one epoch of a
//! persistent [`ExecState`] — per-rank clocks, NIC frontiers and the
//! dependency system resume across epochs, so communication initiated in
//! epoch *k* keeps draining while epoch *k+1* records and computes. The
//! only synchronization is *forcing* a value — an immediate
//! [`Context::sum`] or [`Context::gather`], or the deferred forms
//! ([`Context::sum_deferred`], [`Context::sum_absdiff_deferred`],
//! [`Context::gather_deferred`]) whose [`ScalarFuture`] /
//! [`ArrayFuture`] postpone the cost to `.wait()`. Every rank consumes
//! the forced value (the interpreter is replicated, §5.5), but under
//! the default [`crate::sync::SyncMode::Cone`] that costs only a settle
//! of the value's *dependency cone* plus a broadcast of the value back
//! out ([`crate::sync`]) — not the global clock join of
//! [`crate::sync::SyncMode::Barrier`].
//!
//! ## Error handling
//!
//! A failed flush (e.g. a naive-policy deadlock) **poisons** the
//! context: the error is latched, later batches are dropped unexecuted,
//! and every subsequent scalar read returns `Err` instead of a silent
//! `0.0` — a deadlocked convergence loop can no longer masquerade as
//! converged at delta 0.0.

use crate::array::Registry;
use crate::comm::{Collective, SCALAR_BYTES};
use crate::exec::Backend;
use crate::flow::FlowEngine;
use crate::layout::ViewSpec;
use crate::metrics::RunReport;
use crate::profile::Phase;
use crate::sched::{execute_epoch, ExecState, Policy, SchedCfg, SchedError, SyncMode};
use crate::types::{BaseId, DType, OpId, Rank, Tag, VTime};
use crate::ufunc::{Access, ComputeTask, Dst, Kernel, OpBuilder, Operand};

pub use crate::sync::{ArrayFuture, ScalarFuture};

/// Default flush threshold (paper: "a user-defined threshold"). The
/// canonical constant lives with the scheduler configuration so the
/// CLI and harness can carry it (`SchedCfg::flush_threshold`).
pub use crate::sched::DEFAULT_FLUSH_THRESHOLD;

/// The DistNumPy programming context: array registry + lazy recorder +
/// persistent execution state + backend.
pub struct Context {
    pub reg: Registry,
    pub builder: OpBuilder,
    pub cfg: SchedCfg,
    pub policy: Policy,
    pub backend: Box<dyn Backend>,
    /// Execution state persisting across flush epochs (clocks, NIC
    /// frontiers, dependency system, accumulated wait/busy).
    pub state: ExecState,
    /// The incremental flush engine ([`crate::flow`]): under
    /// `FlowMode::Flow` threshold triggers become non-blocking submits
    /// into its admission window; under the default Batch mode it is
    /// dormant (every submit executes immediately).
    pub flow: FlowEngine,
    /// Snapshot of `state` after the most recent flush/barrier.
    pub report: RunReport,
    pub flush_threshold: usize,
    pub flushes: u64,
    /// Accumulated virtual time of the sequential NumPy baseline for the
    /// same program (Section 6: the denominator of every speedup curve).
    /// Derived from the recorded compute payloads, so any-P runs yield
    /// the same baseline as a P=1 run (fragmentation cancels out).
    pub baseline: f64,
    array_ops_since_flush: u64,
    /// First scheduling error (the naive policy can deadlock). Once set
    /// the context is poisoned: later batches are dropped and every
    /// scalar read fails.
    pub error: Option<SchedError>,
}

impl Context {
    pub fn new(cfg: SchedCfg, policy: Policy, backend: Box<dyn Backend>) -> Self {
        let n = cfg.nprocs as usize;
        let mut state = ExecState::new(&cfg);
        // The lazy context owns stage lifetime (it pins future results),
        // so reference-counted reclamation is safe — and on. Standalone
        // scheduler runs leave it off: their callers read staged
        // results out-of-band (see sync/stages.rs).
        state.stages.reclaim = true;
        let flow = FlowEngine::new(cfg.flow);
        let flush_threshold = cfg.flush_threshold;
        Context {
            reg: Registry::new(cfg.nprocs),
            builder: OpBuilder::new(),
            cfg,
            policy,
            backend,
            state,
            flow,
            report: RunReport::new(n),
            flush_threshold,
            flushes: 0,
            baseline: 0.0,
            array_ops_since_flush: 0,
            error: None,
        }
    }

    /// Simulation-only context (no real data).
    pub fn sim(cfg: SchedCfg, policy: Policy) -> Self {
        Context::new(cfg, policy, Box::new(crate::exec::SimBackend))
    }

    // -- array creation (the only API difference from NumPy, Section 5) --

    /// Allocate a distributed array (zeros), returning its full view.
    pub fn zeros(&mut self, shape: &[u64], block_rows: u64) -> ViewSpec {
        let id = self.reg.alloc(shape.to_vec(), block_rows, DType::F32);
        self.backend.alloc_base(self.reg.layout(id));
        self.reg.full_view(id)
    }

    /// Allocate and fill from a dense row-major buffer (real backends).
    pub fn array(&mut self, shape: &[u64], block_rows: u64, data: &[f32]) -> ViewSpec {
        let v = self.zeros(shape, block_rows);
        self.backend.scatter(self.reg.layout(v.base), data);
        v
    }

    // -- recording --

    /// Record an elementwise ufunc `out = kernel(ins…)`.
    pub fn ufunc(&mut self, kernel: Kernel, out: &ViewSpec, ins: &[&ViewSpec]) {
        // Profiler phase `Record`: fragment split + op-node build (the
        // flush it may trigger bills to the admit/drain phases).
        let t0 = self.state.prof.start();
        self.builder.ufunc(&self.reg, kernel, out, ins);
        self.state.prof.stop(Phase::Record, t0);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
    }

    /// Record `c = a + b`.
    pub fn add(&mut self, c: &ViewSpec, a: &ViewSpec, b: &ViewSpec) {
        self.ufunc(Kernel::Add, c, &[a, b]);
    }

    /// Record `dst = src` (copy between views).
    pub fn copy(&mut self, dst: &ViewSpec, src: &ViewSpec) {
        self.ufunc(Kernel::Copy, dst, &[src]);
    }

    // -- flush triggers --

    fn maybe_flush(&mut self) {
        if self.builder.n_recorded() >= self.flush_threshold {
            self.submit();
        }
    }

    /// Snapshot the execution state as the context's current report,
    /// folding in what only the flow engine knows (pending-epoch count;
    /// the recorder clock and pipeline-depth metrics come from the
    /// admission log inside the state).
    fn sync_report(&mut self) {
        self.report = self.state.report();
        self.report.flow_pending = self.flow.pending() as u64;
    }

    /// Trigger 2's worker: a **non-blocking submit** of everything
    /// recorded so far. Under the default Batch mode the batch executes
    /// immediately as one epoch (the stop-the-world flush); under
    /// [`crate::flow::FlowMode::Flow`] it is priced on the recorder
    /// clock and admitted into the incremental flush engine's window —
    /// execution of the merged wave overlaps continued recording, so a
    /// threshold trigger no longer stops the world; under
    /// [`crate::flow::FlowMode::Sliding`] it is spliced into the live
    /// resumable scheduler session mid-wave, the moment the admission
    /// log shows the window's oldest epoch retired. On a poisoned
    /// context the batch (and anything still queued) is dropped
    /// unexecuted.
    pub fn submit(&mut self) {
        let ops = self.builder.take();
        if ops.is_empty() {
            return;
        }
        if self.error.is_some() {
            // Poisoned: executing further epochs on torn state would
            // produce garbage timing/numerics. Drop the batch.
            self.array_ops_since_flush = 0;
            self.flow.clear();
            return;
        }
        self.flushes += 1;
        self.baseline += crate::sched::numpy_baseline(&ops, &self.cfg.spec)
            + self.array_ops_since_flush as f64 * self.cfg.spec.numpy_op_overhead;
        self.array_ops_since_flush = 0;
        let res = if self.cfg.flow.is_flow() {
            self.flow.submit(
                ops,
                self.policy,
                &self.cfg,
                self.backend.as_mut(),
                &mut self.state,
            )
        } else {
            execute_epoch(
                self.policy,
                &ops,
                &self.cfg,
                self.backend.as_mut(),
                &mut self.state,
            )
        };
        match res {
            Ok(()) => self.sync_report(),
            Err(e) => {
                self.flow.clear();
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
    }

    /// Trigger 3 (and the synchronous half of every forced read):
    /// **submit + drain**. Everything recorded so far executes as one
    /// or more epochs of the persistent timeline, and any epochs still
    /// in flight in the flow engine's window drain. No barrier — ranks
    /// resume wherever the epochs' dependency structure lets them. On a
    /// poisoned context batches are dropped unexecuted.
    pub fn flush(&mut self) {
        self.submit();
        if self.error.is_some() {
            return;
        }
        match self.flow.drain(
            self.policy,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.state,
        ) {
            Ok(()) => self.sync_report(),
            Err(e) => {
                self.flow.clear();
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
    }

    /// Record a deferred `sum(view)`: the reduction executes with the
    /// normal flush flow; the value (and the barrier) wait for
    /// [`ScalarFuture::wait`]. The cross-rank fan-in is scheduled by
    /// `cfg.collective` (flat gather or binomial tree, see
    /// [`crate::comm`]).
    pub fn sum_deferred(&mut self, v: &ViewSpec) -> ScalarFuture {
        let collective = self.cfg.collective;
        let t0 = self.state.prof.start();
        let tag = self
            .builder
            .reduce(&self.reg, Kernel::PartialSum, &[v], collective);
        self.state.prof.stop(Phase::Record, t0);
        self.state.stages.pin(Rank(0), tag);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
        ScalarFuture::new(tag)
    }

    /// Deferred `sum(|a - b|)` — the Jacobi convergence delta, checkable
    /// every *k* iterations without erecting a barrier per iteration.
    pub fn sum_absdiff_deferred(&mut self, a: &ViewSpec, b: &ViewSpec) -> ScalarFuture {
        let collective = self.cfg.collective;
        let t0 = self.state.prof.start();
        let tag =
            self.builder
                .reduce(&self.reg, Kernel::PartialAbsDiffSum, &[a, b], collective);
        self.state.prof.stop(Phase::Record, t0);
        self.state.stages.pin(Rank(0), tag);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
        ScalarFuture::new(tag)
    }

    /// Synchronize the timeline for a forced read whose results live in
    /// the given delivery stages, per the configured
    /// [`crate::sync::SyncMode`]:
    ///
    /// * `Barrier` — every rank joins the global clock frontier
    ///   (`wait_at_barrier`), PR 2's semantics;
    /// * `Cone` — each delivery rank joins its stage's completion time,
    ///   the value's dependency cone ([`crate::sync::ConeSource`]: the
    ///   DAG's retained edges, or the heuristic's predecessor hints —
    ///   exact on epoch streams, conservative prefix for recycled
    ///   targets) joins the cone frontier, and the value rides a
    ///   broadcast back out to every rank (`wait_at_cone`). A stage
    ///   with no recorded provenance (already reclaimed — e.g. a future
    ///   waited twice — or a foreign context) synchronizes nothing: the
    ///   timeline already settled when the value was first forced, and
    ///   the read itself errors on data backends.
    ///
    /// `bytes` is the payload the value broadcast carries back out —
    /// scalar-sized for [`ScalarFuture`]s, the dense volume for a
    /// root-delivered [`ArrayFuture`] (the broadcast shape is chosen
    /// per volume, [`crate::comm::bcast_shape_for`]).
    fn settle(&mut self, root: Rank, tags: &[(Rank, Tag)], bytes: u64) {
        if self.cfg.sync == SyncMode::Barrier {
            self.state.barrier();
            return;
        }
        let mut writers = Vec::with_capacity(tags.len());
        for (rank, tag) in tags {
            match self.state.stages.writer(*rank, *tag) {
                Some(w) => writers.push((*rank, w)),
                None => return,
            }
        }
        let mut frontier: VTime = 0.0;
        let mut target: Option<OpId> = None;
        for (rank, w) in writers {
            self.state.join_at(rank, w.done);
            if w.done >= frontier {
                frontier = w.done;
                // Provenance is valid for the current scheduler *run*
                // (a Batch epoch or a whole merged Flow wave) — a
                // future may target any epoch of the wave that just
                // drained.
                target = (w.run == self.state.run_id).then_some(w.op);
            }
        }
        let nprocs = self.cfg.nprocs as usize;
        // A value produced by an *earlier* run has a fully retired
        // cone: nothing to join beyond the frontier itself. For the
        // current run the dependency system reports the cone; an
        // over-approximate cone (the heuristic's prefix fallback) may
        // push the frontier later than the value's completion —
        // conservative, never early.
        let cone = match target {
            Some(op) => {
                let (ranks, cone_frontier) = crate::sync::resolve_cone(&self.state, op);
                frontier = frontier.max(cone_frontier);
                ranks
            }
            None => vec![false; nprocs],
        };
        crate::sync::settle_cone(
            &mut self.state,
            &mut self.builder,
            self.cfg.collective,
            root,
            frontier,
            &cone,
            bytes,
        );
    }

    /// Force a deferred scalar: flush, check for poisoning, settle the
    /// value's cone (or barrier, per [`crate::sync::SyncMode`]), read.
    /// Returns the real value under a data backend, 0.0 in simulation.
    /// Forcing releases the future's pin on its result stage — the
    /// buffer reclaims, so a second wait on a data backend errors
    /// rather than reading stale data. (A future carried to a *different*
    /// context is detected only when its tag names no stage there; tags
    /// are per-context counters, so a collision can go unnoticed — keep
    /// futures with the context that made them.)
    pub fn wait_scalar(&mut self, f: &ScalarFuture) -> Result<f64, SchedError> {
        self.flush();
        if let Some(e) = &self.error {
            // The poisoned run never delivers; release the pin so the
            // stage accounting does not leak.
            self.unpin_all(&[(Rank(0), f.tag)]);
            return Err(e.clone());
        }
        self.settle(Rank(0), &[(Rank(0), f.tag)], SCALAR_BYTES);
        self.sync_report();
        let value = match self.backend.staged_scalar(Rank(0), f.tag) {
            Some(v) => Ok(v),
            None if !self.backend.materializes_data() => Ok(0.0),
            None => Err(SchedError::Stall(format!(
                "scalar future {:?} has no staged value on rank 0 \
                 (waited on the wrong context, or twice?)",
                f.tag
            ))),
        };
        if self.state.stages.unpin(Rank(0), f.tag) {
            self.backend.drop_stage(Rank(0), f.tag);
        }
        value
    }

    /// Trigger 1: read a scalar — `sum(view)`. Forces a flush *and* the
    /// configured synchronization; equivalent to
    /// `self.sum_deferred(v).wait(self)`. Fails loudly if any flush
    /// epoch failed (poisoned context).
    pub fn sum(&mut self, v: &ViewSpec) -> Result<f64, SchedError> {
        let f = self.sum_deferred(v);
        self.wait_scalar(&f)
    }

    /// Trigger 1: `sum(|a - b|)` — the Jacobi convergence delta, forced.
    pub fn sum_absdiff(&mut self, a: &ViewSpec, b: &ViewSpec) -> Result<f64, SchedError> {
        let f = self.sum_absdiff_deferred(a, b);
        self.wait_scalar(&f)
    }

    /// Record a deferred whole-base gather and return its
    /// [`ArrayFuture`] — the "deferred gathers" of the ROADMAP:
    /// checkpointing and in-situ analysis pipeline whole-array reads
    /// through the same cone machinery as scalar futures.
    ///
    /// The data movement is recorded immediately as a first-class
    /// collective — a flat fan-in to rank 0 or a ring allgather, per
    /// `cfg.collective` — so it is dependency-tracked, scheduled and
    /// timed like every other operation, and its transfers drain behind
    /// whatever the program records next. Additionally every block is
    /// snapshotted into a staging buffer on its owner: the dependency
    /// system orders those copies against later overwrites, so the
    /// forced array observes the data *as of this record position*
    /// (sequential semantics) even when later epochs rewrite the base.
    /// All stages are pinned until the future is forced.
    pub fn gather_deferred(&mut self, base: BaseId) -> ArrayFuture {
        let mut tags: Vec<(Rank, Tag)> = Vec::new();
        if self.cfg.nprocs > 1 {
            let bld = &mut self.builder;
            match self.cfg.collective {
                Collective::Flat => {
                    let root = Rank(0);
                    let delivered = crate::comm::gather_flat(bld, &self.reg, base, root);
                    for t in delivered.into_iter().flatten() {
                        tags.push((root, t));
                    }
                }
                Collective::Tree => {
                    let per_rank = crate::comm::allgather_ring(bld, &self.reg, base);
                    for (r, blocks) in per_rank.into_iter().enumerate() {
                        for t in blocks.into_iter().flatten() {
                            tags.push((Rank(r as u32), t));
                        }
                    }
                }
            }
        }
        // Record-position snapshots: one local copy per block, staged
        // on its owner (its own §5.3 group; pure local compute, so it
        // is deadlock-free under every policy).
        self.builder.begin_group();
        let layout = self.reg.layout(base).clone();
        let mut snap: Vec<(u64, Rank, Tag)> = Vec::new();
        for b in 0..layout.nblocks() {
            let owner = layout.owner(b);
            let (region, intra) = crate::comm::block_region(&self.reg, base, b);
            let tag = self.builder.fresh_tag();
            let elems = region.elems();
            self.builder.compute(
                owner,
                ComputeTask {
                    kernel: Kernel::Copy,
                    inputs: vec![Operand::Local(region)],
                    dst: Dst::Stage(tag),
                    elems,
                },
                vec![Access::read_block(base, b, intra), Access::write_stage(tag)],
            );
            snap.push((b, owner, tag));
        }
        for &(_, r, t) in &snap {
            tags.push((r, t));
        }
        for (r, t) in &tags {
            self.state.stages.pin(*r, *t);
        }
        // No `array_ops_since_flush` charge: a gather is runtime-internal
        // data movement with no NumPy counterpart (the sequential array
        // is already dense), so it must not enter the speedup baseline —
        // matching `numpy_baseline`'s exclusion of the snapshot copies.
        self.maybe_flush();
        ArrayFuture::new(base, tags, snap)
    }

    /// Force a deferred gather: flush, check for poisoning, settle the
    /// gather's cone (each delivery rank joins its own arrival; the
    /// completion rides the value broadcast), assemble the dense array
    /// from the record-position block snapshots — bit-identical to what
    /// an immediate gather at the record point would have returned.
    /// Forcing releases the pins, so the delivery and snapshot stages
    /// reclaim; a second wait on a data backend errors. `Ok(None)`
    /// means the backend holds no real data (simulation).
    pub fn wait_array(&mut self, f: &ArrayFuture) -> Result<Option<Vec<f32>>, SchedError> {
        self.flush();
        if let Some(e) = &self.error {
            // The poisoned run never delivers; release the pins so the
            // stage accounting does not leak.
            self.unpin_all(&f.tags);
            return Err(e.clone());
        }
        // Cone-aware dense costing: the flat gather delivered the
        // payload to the root only, and every replicated interpreter
        // (§5.5) consumes the forced array — so the settle broadcasts
        // the whole dense volume (ring vs tree chosen per volume in
        // [`crate::comm::bcast_shape_for`]). The ring allgather already
        // delivered every block to every rank; only the scalar-sized
        // completion notification rides its settle.
        let bytes = match self.cfg.collective {
            Collective::Flat => {
                let layout = self.reg.layout(f.base);
                layout.rows() * layout.row_elems() * layout.dtype.size()
            }
            Collective::Tree => SCALAR_BYTES,
        };
        self.settle(Rank(0), &f.tags, bytes);
        self.sync_report();
        let out = if self.backend.materializes_data() {
            let layout = self.reg.layout(f.base).clone();
            let re = layout.row_elems();
            let mut dense = vec![0.0f32; (layout.rows() * re) as usize];
            for &(block, rank, tag) in &f.snap {
                let Some(data) = self.backend.staged_data(rank, tag) else {
                    self.unpin_all(&f.tags);
                    return Err(SchedError::Stall(format!(
                        "gather future for {:?} has no staged snapshot for \
                         block {block} (waited twice?)",
                        f.base
                    )));
                };
                let (lo, hi) = layout.block_rows_range(block);
                dense[(lo * re) as usize..(hi * re) as usize].copy_from_slice(&data);
            }
            Some(dense)
        } else {
            None
        };
        self.unpin_all(&f.tags);
        Ok(out)
    }

    /// Release a future's pins, dropping any stage this leaves
    /// reader-free.
    fn unpin_all(&mut self, tags: &[(Rank, Tag)]) {
        for (r, t) in tags {
            if self.state.stages.unpin(*r, *t) {
                self.backend.drop_stage(*r, *t);
            }
        }
    }

    /// Trigger 1: gather a whole base to a dense buffer — a forced
    /// read, equivalent to `self.gather_deferred(base)` followed
    /// immediately by `.wait()`.
    pub fn gather(&mut self, base: BaseId) -> Result<Option<Vec<f32>>, SchedError> {
        let f = self.gather_deferred(base);
        self.wait_array(&f)
    }

    /// Finish the program: final flush, return the accumulated report of
    /// the whole continuous timeline (makespan = latest rank clock).
    pub fn finish(self) -> Result<RunReport, SchedError> {
        self.finish_traced().map(|(rep, _)| rep)
    }

    /// [`Context::finish`] that additionally harvests the event-sourced
    /// trace recorded on the execution state (an empty no-op sink unless
    /// `SchedCfg::trace` enabled it — see [`crate::trace`]).
    pub fn finish_traced(mut self) -> Result<(RunReport, crate::trace::TraceSink), SchedError> {
        self.flush();
        match self.error {
            Some(e) => Err(e),
            None => {
                self.sync_report();
                let sink = std::mem::take(&mut self.state.trace);
                Ok((self.report, sink))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;

    fn ctx(p: u32) -> Context {
        Context::sim(SchedCfg::new(MachineSpec::tiny(), p), Policy::LatencyHiding)
    }

    #[test]
    fn records_without_executing() {
        let mut c = ctx(2);
        let x = c.zeros(&[16], 4);
        let y = c.zeros(&[16], 4);
        c.add(&y.clone(), &x, &y);
        assert_eq!(c.flushes, 0, "lazy: nothing executed yet");
        assert!(c.builder.n_recorded() > 0);
        c.flush();
        assert_eq!(c.flushes, 1);
        assert!(c.report.ops_executed > 0);
        assert_eq!(c.report.n_epochs, 1);
    }

    #[test]
    fn threshold_triggers_flush() {
        let mut c = ctx(2);
        c.flush_threshold = 8;
        let x = c.zeros(&[16], 4);
        for _ in 0..4 {
            c.add(&x.clone(), &x, &x); // 4 fragments per call
        }
        assert!(c.flushes >= 1, "threshold flush fired");
    }

    #[test]
    fn sum_triggers_flush_and_counts_ops() {
        let mut c = ctx(2);
        let x = c.zeros(&[16], 4);
        let _ = c.sum(&x);
        assert_eq!(c.flushes, 1);
        assert!(c.report.ops_executed >= 5);
    }

    #[test]
    fn empty_flush_is_noop() {
        let mut c = ctx(1);
        c.flush();
        assert_eq!(c.flushes, 0);
    }

    #[test]
    fn finish_returns_report() {
        let mut c = ctx(2);
        let x = c.zeros(&[8], 2);
        c.copy(&x.slice(&[(0, 4)]), &x.slice(&[(4, 8)]));
        let rep = c.finish().unwrap();
        assert!(rep.ops_executed > 0);
    }

    #[test]
    fn flushes_accumulate_one_continuous_timeline() {
        // Two flushes: the report's makespan is the frontier of one
        // continuous timeline, strictly less than the sum of two
        // independent runs (no barrier between epochs), and epochs count.
        let mut c = ctx(2);
        let x = c.zeros(&[32], 4);
        c.add(&x.clone(), &x, &x);
        c.flush();
        let m1 = c.report.makespan;
        c.add(&x.clone(), &x, &x);
        c.flush();
        assert_eq!(c.report.n_epochs, 2);
        assert!(c.report.makespan > m1, "timeline extends");
        assert_eq!(c.flushes, 2);
    }

    fn ctx_sync(p: u32, sync: SyncMode) -> Context {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
        cfg.sync = sync;
        Context::sim(cfg, Policy::LatencyHiding)
    }

    #[test]
    fn deferred_sum_postpones_the_barrier() {
        let mut c = ctx_sync(4, SyncMode::Barrier);
        let x = c.zeros(&[64], 4);
        let f = c.sum_deferred(&x);
        c.flush();
        // The reduce executed, but no barrier was paid: flushing is not
        // a global join any more.
        assert_eq!(c.flushes, 1, "deferred read flushed the epoch");
        assert_eq!(
            c.state.wait_at_barrier, 0.0,
            "no barrier wait before the future is forced"
        );
        let v = f.wait(&mut c).unwrap();
        assert_eq!(v, 0.0, "simulation backends read 0.0");
        // Forcing the value joined every rank to the frontier; the
        // fan-in leaves the clocks unequal, so the join costs wait.
        assert!(
            c.state.wait_at_barrier > 0.0,
            "the barrier is paid at wait()"
        );
        let t = c.state.max_clock();
        assert!(c.state.clock.iter().all(|&cl| cl == t));
    }

    #[test]
    fn immediate_sum_barriers_the_timeline() {
        let mut c = ctx_sync(4, SyncMode::Barrier);
        let x = c.zeros(&[64], 4);
        let _ = c.sum(&x).unwrap();
        let t = c.state.max_clock();
        assert!(c.state.clock.iter().all(|&cl| (cl - t).abs() < 1e-15));
    }

    /// The tentpole behaviour, in the shape that matters (pipelined
    /// futures): a value produced epochs ago costs *nothing* to force
    /// under cone sync — its broadcast arrived long before anyone asks
    /// — while the barrier it replaces still charges every rank a join
    /// to the global frontier.
    #[test]
    fn cone_wait_replaces_the_global_barrier() {
        let run = |sync: SyncMode| {
            let mut c = ctx_sync(4, sync);
            // Big enough that one epoch's compute dwarfs the value
            // broadcast's wire latency.
            let x = c.zeros(&[1 << 14], 64);
            let f = c.sum_deferred(&x);
            c.flush();
            // Several epochs of unrelated work the wait must NOT settle.
            for _ in 0..10 {
                c.add(&x.clone(), &x, &x);
                c.flush();
            }
            let v = f.wait(&mut c).unwrap();
            assert_eq!(v, 0.0, "simulation backends read 0.0");
            c
        };
        let cone = run(SyncMode::Cone);
        assert_eq!(cone.state.wait_at_barrier, 0.0, "no global join paid");
        assert_eq!(
            cone.state.wait_at_cone, 0.0,
            "an old value's broadcast already arrived: the force is free"
        );
        let barrier = run(SyncMode::Barrier);
        assert!(
            barrier.state.wait_at_barrier > 0.0,
            "the global join the cone wait removes was a real cost"
        );
    }

    /// Forcing a *fresh* value pays the targeted cost: non-root ranks
    /// wait for the value's broadcast arrival (`wait_at_cone`), and the
    /// timeline is NOT equalized — ranks keep their own clocks.
    #[test]
    fn fresh_force_pays_cone_wait_without_equalizing_clocks() {
        let mut c = ctx(4);
        let x = c.zeros(&[64], 4);
        let f = c.sum_deferred(&x);
        let _ = f.wait(&mut c).unwrap();
        assert!(
            c.state.wait_at_cone > 0.0,
            "non-root ranks wait for the value to arrive"
        );
        assert_eq!(c.state.wait_at_barrier, 0.0);
        let t = c.state.max_clock();
        assert!(
            c.state.clock.iter().any(|&cl| cl < t),
            "no global clock join: ranks keep distinct clocks {:?}",
            c.state.clock
        );
    }

    /// Forcing a future consumes its pinned result stage; every other
    /// read stage of the epoch reclaims as its last reader retires.
    #[test]
    fn futures_pin_stages_until_forced() {
        let mut c = ctx(4);
        let x = c.zeros(&[64], 4);
        let f = c.sum_deferred(&x);
        c.flush();
        assert!(
            c.state.stages.writer(Rank(0), f.tag).is_some(),
            "pinned result survives the flush"
        );
        let _ = f.wait(&mut c).unwrap();
        assert!(
            c.state.stages.writer(Rank(0), f.tag).is_none(),
            "forcing reclaims the result stage"
        );
        assert!(c.state.stages.dropped > 0, "intermediates reclaimed");
    }

    /// `gather_deferred` pipelines a whole-array read: recording it does
    /// not synchronize, forcing it does — through the same cone
    /// machinery as scalars.
    #[test]
    fn deferred_gather_postpones_synchronization() {
        let mut c = ctx(3);
        let x = c.zeros(&[24], 4);
        c.add(&x.clone(), &x, &x);
        let f = c.gather_deferred(x.base);
        c.flush();
        assert_eq!(c.state.wait_at_cone + c.state.wait_at_barrier, 0.0);
        let got = c.wait_array(&f).unwrap();
        assert!(got.is_none(), "simulation holds no data");
        assert!(c.state.wait_at_cone > 0.0, "forcing settles the gather");
    }

    /// The headline regression: a naive-policy deadlock must surface as
    /// an error from the convergence read — not as delta = 0.0, which a
    /// convergence loop would take as "converged".
    #[test]
    fn failed_flush_poisons_scalar_reads() {
        let mut c = Context::sim(SchedCfg::new(MachineSpec::tiny(), 2), Policy::Naive);
        let rows = 12u64;
        let m = c.zeros(&[rows], 3);
        let nv = c.zeros(&[rows], 3);
        // The Fig. 6 ping-pong stream: naive deadlocks in iteration 1.
        for _ in 0..2 {
            c.add(
                &nv.slice(&[(1, rows - 1)]),
                &m.slice(&[(2, rows)]),
                &m.slice(&[(0, rows - 2)]),
            );
            c.add(
                &m.slice(&[(1, rows - 1)]),
                &nv.slice(&[(2, rows)]),
                &nv.slice(&[(0, rows - 2)]),
            );
        }
        let delta = c.sum_absdiff(&m, &nv);
        assert!(
            matches!(delta, Err(SchedError::Deadlock { .. })),
            "deadlock must not masquerade as convergence: {delta:?}"
        );
        // Poisoned: subsequent reads and gathers keep failing loudly.
        assert!(c.sum(&m).is_err());
        assert!(c.gather(m.base).is_err());
        assert!(c.finish().is_err());
    }

    /// Same regression through the ring collective: `gather` under the
    /// tree schedule records a multi-round ring, which the naive
    /// evaluator deadlocks on (Fig. 6 restated) — the gather must error.
    #[test]
    fn naive_ring_collective_gather_errors() {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), 3);
        cfg.collective = Collective::Tree;
        let mut c = Context::sim(cfg, Policy::Naive);
        let x = c.zeros(&[3], 1);
        let got = c.gather(x.base);
        assert!(
            matches!(got, Err(SchedError::Deadlock { .. })),
            "ring gather under naive must deadlock loudly: {got:?}"
        );
    }

    fn ctx_flow(p: u32, window: usize) -> Context {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
        cfg.flow = crate::flow::FlowCfg::flow(window);
        Context::sim(cfg, Policy::LatencyHiding)
    }

    /// The tentpole behaviour: a threshold trigger under Flow mode is a
    /// non-blocking submit — the batch sits in the admission window,
    /// nothing executes — and `flush` drains it.
    #[test]
    fn flow_submit_is_nonblocking_until_window_fills() {
        let mut c = ctx_flow(2, 2);
        let x = c.zeros(&[16], 4);
        c.add(&x.clone(), &x, &x);
        c.submit();
        assert_eq!(c.flushes, 1, "the epoch was recorded and admitted");
        assert_eq!(c.flow.pending(), 1, "…but is still in flight");
        assert_eq!(c.state.ops_executed, 0, "nothing executed yet");
        c.add(&x.clone(), &x, &x);
        c.submit();
        assert_eq!(c.flow.pending(), 0, "window of 2 drained as one wave");
        assert!(c.state.ops_executed > 0);
        assert_eq!(c.state.n_epochs, 2, "both submits count as epochs");
        assert_eq!(c.state.run_id, 1, "…executed in one scheduler run");
    }

    #[test]
    fn flow_flush_drains_in_flight_epochs() {
        let mut c = ctx_flow(2, 4);
        let x = c.zeros(&[16], 4);
        c.add(&x.clone(), &x, &x);
        c.submit();
        assert_eq!(c.flow.pending(), 1);
        c.flush();
        assert_eq!(c.flow.pending(), 0);
        assert!(c.report.ops_executed > 0);
        assert!(
            c.state.overhead_streamed > 0.0,
            "flow charges recording on the recorder clock"
        );
    }

    /// A future forced against a still-in-flight epoch (submitted,
    /// sitting in the flow window, not yet executed) settles correctly:
    /// the wait drains the window first, then settles the cone.
    #[test]
    fn future_forced_against_in_flight_epoch_settles() {
        let mut c = ctx_flow(4, 8);
        let x = c.zeros(&[64], 4);
        let f = c.sum_deferred(&x);
        c.submit();
        assert!(c.flow.pending() > 0, "the reduction's epoch is in flight");
        let v = f.wait(&mut c).unwrap();
        assert_eq!(v, 0.0, "simulation backends read 0.0");
        assert_eq!(c.flow.pending(), 0, "forcing drained the window");
        assert!(
            c.state.wait_at_cone > 0.0,
            "a fresh value still pays the targeted settle"
        );
        assert!(
            c.state.stages.writer(Rank(0), f.tag).is_none(),
            "forcing reclaims the result stage"
        );
    }

    fn ctx_sliding(p: u32, window: usize) -> Context {
        let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
        cfg.flow = crate::flow::FlowCfg::sliding(window);
        Context::sim(cfg, Policy::LatencyHiding)
    }

    /// The PR-5 tentpole behaviour: under sliding admission a threshold
    /// trigger splices the epoch into ONE live scheduler session — no
    /// wave boundary — and `flush` runs the session to quiescence.
    #[test]
    fn sliding_submit_splices_into_live_session() {
        let mut c = ctx_sliding(2, 4);
        let x = c.zeros(&[16], 4);
        c.add(&x.clone(), &x, &x);
        c.submit();
        assert_eq!(c.flushes, 1);
        assert_eq!(c.state.n_epochs, 1, "sliding admits the epoch immediately");
        c.add(&x.clone(), &x, &x);
        c.submit();
        assert_eq!(c.state.n_epochs, 2);
        assert_eq!(c.state.run_id, 1, "both epochs share one live session");
        c.flush();
        assert!(c.state.ops_executed > 0, "drain ran the session");
        assert_eq!(c.report.flow_pending, 0, "drained: no pending epochs");
        assert!(c.report.recorder_clock > 0.0, "recorder clock surfaced");
        assert!(c.report.max_in_flight >= 1, "pipeline depth surfaced");
        assert!(c.state.overhead_streamed > 0.0, "recording rode the recorder clock");
    }

    /// A future forced against a live sliding session settles: the wait
    /// drains the session to quiescence, then settles the value's cone
    /// against the session-run's stage provenance.
    #[test]
    fn sliding_future_forced_against_live_session_settles() {
        let mut c = ctx_sliding(4, 8);
        let x = c.zeros(&[64], 4);
        let f = c.sum_deferred(&x);
        c.submit();
        assert!(c.flow.pending() > 0, "the reduction's epoch is live");
        let v = f.wait(&mut c).unwrap();
        assert_eq!(v, 0.0, "simulation backends read 0.0");
        assert_eq!(c.flow.pending(), 0, "forcing drained the session");
        assert!(
            c.state.wait_at_cone > 0.0,
            "a fresh value still pays the targeted settle"
        );
        assert!(
            c.state.stages.writer(Rank(0), f.tag).is_none(),
            "forcing reclaims the result stage"
        );
    }

    #[test]
    fn poisoned_context_drops_later_batches() {
        let mut c = Context::sim(SchedCfg::new(MachineSpec::tiny(), 2), Policy::Naive);
        let rows = 12u64;
        let m = c.zeros(&[rows], 3);
        let nv = c.zeros(&[rows], 3);
        for _ in 0..2 {
            c.add(
                &nv.slice(&[(1, rows - 1)]),
                &m.slice(&[(2, rows)]),
                &m.slice(&[(0, rows - 2)]),
            );
            c.add(
                &m.slice(&[(1, rows - 1)]),
                &nv.slice(&[(2, rows)]),
                &nv.slice(&[(0, rows - 2)]),
            );
        }
        c.flush();
        assert!(c.error.is_some(), "deadlock latched");
        let flushes = c.flushes;
        let executed = c.state.ops_executed;
        c.add(&m.clone(), &m, &m);
        c.flush();
        assert_eq!(c.flushes, flushes, "poisoned flush drops the batch");
        assert_eq!(c.state.ops_executed, executed, "nothing else executed");
    }
}
