//! Lazy evaluation front-end (paper Section 5.6).
//!
//! A [`Context`] plays the role of the Python interpreter boundary in
//! DistNumPy: array operations are **recorded**, not executed. A flush —
//! executing every recorded operation through the configured scheduler —
//! is triggered by the paper's three conditions:
//!
//! 1. the program *reads* distributed data (a reduction result, a
//!    gather, …) — [`Context::sum`], [`Context::sum_absdiff`],
//!    [`Context::gather`];
//! 2. the number of recorded operations reaches a threshold —
//!    [`Context::flush_threshold`];
//! 3. the program ends — [`Context::flush`] called by the apps at exit.

use crate::array::Registry;
use crate::comm::Collective;
use crate::exec::Backend;
use crate::layout::ViewSpec;
use crate::metrics::RunReport;
use crate::sched::{execute, Policy, SchedCfg, SchedError};
use crate::types::{BaseId, DType, Rank};
use crate::ufunc::{Kernel, OpBuilder};

/// Default flush threshold (paper: "a user-defined threshold").
pub const DEFAULT_FLUSH_THRESHOLD: usize = 50_000;

/// The DistNumPy programming context: array registry + lazy recorder +
/// scheduler + backend.
pub struct Context {
    pub reg: Registry,
    pub builder: OpBuilder,
    pub cfg: SchedCfg,
    pub policy: Policy,
    pub backend: Box<dyn Backend>,
    pub report: RunReport,
    pub flush_threshold: usize,
    pub flushes: u64,
    /// Accumulated virtual time of the sequential NumPy baseline for the
    /// same program (Section 6: the denominator of every speedup curve).
    /// Derived from the recorded compute payloads, so any-P runs yield
    /// the same baseline as a P=1 run (fragmentation cancels out).
    pub baseline: f64,
    array_ops_since_flush: u64,
    /// First scheduling error (the naive policy can deadlock).
    pub error: Option<SchedError>,
}

impl Context {
    pub fn new(cfg: SchedCfg, policy: Policy, backend: Box<dyn Backend>) -> Self {
        let n = cfg.nprocs as usize;
        Context {
            reg: Registry::new(cfg.nprocs),
            builder: OpBuilder::new(),
            cfg,
            policy,
            backend,
            report: RunReport::new(n),
            flush_threshold: DEFAULT_FLUSH_THRESHOLD,
            flushes: 0,
            baseline: 0.0,
            array_ops_since_flush: 0,
            error: None,
        }
    }

    /// Simulation-only context (no real data).
    pub fn sim(cfg: SchedCfg, policy: Policy) -> Self {
        Context::new(cfg, policy, Box::new(crate::exec::SimBackend))
    }

    // -- array creation (the only API difference from NumPy, Section 5) --

    /// Allocate a distributed array (zeros), returning its full view.
    pub fn zeros(&mut self, shape: &[u64], block_rows: u64) -> ViewSpec {
        let id = self.reg.alloc(shape.to_vec(), block_rows, DType::F32);
        self.backend.alloc_base(self.reg.layout(id));
        self.reg.full_view(id)
    }

    /// Allocate and fill from a dense row-major buffer (real backends).
    pub fn array(&mut self, shape: &[u64], block_rows: u64, data: &[f32]) -> ViewSpec {
        let v = self.zeros(shape, block_rows);
        self.backend.scatter(self.reg.layout(v.base), data);
        v
    }

    // -- recording --

    /// Record an elementwise ufunc `out = kernel(ins…)`.
    pub fn ufunc(&mut self, kernel: Kernel, out: &ViewSpec, ins: &[&ViewSpec]) {
        self.builder.ufunc(&self.reg, kernel, out, ins);
        self.array_ops_since_flush += 1;
        self.maybe_flush();
    }

    /// Record `c = a + b`.
    pub fn add(&mut self, c: &ViewSpec, a: &ViewSpec, b: &ViewSpec) {
        self.ufunc(Kernel::Add, c, &[a, b]);
    }

    /// Record `dst = src` (copy between views).
    pub fn copy(&mut self, dst: &ViewSpec, src: &ViewSpec) {
        self.ufunc(Kernel::Copy, dst, &[src]);
    }

    // -- flush triggers --

    fn maybe_flush(&mut self) {
        if self.builder.n_recorded() >= self.flush_threshold {
            self.flush();
        }
    }

    /// Trigger 3 (and the explicit form of trigger 1): execute everything
    /// recorded so far.
    pub fn flush(&mut self) {
        let ops = self.builder.take();
        if ops.is_empty() {
            return;
        }
        self.backend.clear_stages();
        self.flushes += 1;
        self.baseline += crate::sched::numpy_baseline(&ops, &self.cfg.spec)
            + self.array_ops_since_flush as f64 * self.cfg.spec.numpy_op_overhead;
        self.array_ops_since_flush = 0;
        match execute(self.policy, &ops, &self.cfg, self.backend.as_mut()) {
            Ok(rep) => self.report.absorb(&rep),
            Err(e) => {
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
    }

    /// Trigger 1: read a scalar — `sum(view)`. Forces a flush. The
    /// cross-rank fan-in is scheduled by `cfg.collective` (flat gather
    /// or binomial tree, see [`crate::comm`]).
    /// Returns the real value under a data backend, 0.0 in simulation.
    pub fn sum(&mut self, v: &ViewSpec) -> f64 {
        let collective = self.cfg.collective;
        let tag = self
            .builder
            .reduce(&self.reg, Kernel::PartialSum, &[v], collective);
        self.array_ops_since_flush += 1;
        self.flush();
        self.backend.staged_scalar(Rank(0), tag).unwrap_or(0.0)
    }

    /// Trigger 1: `sum(|a - b|)` — the Jacobi convergence delta.
    pub fn sum_absdiff(&mut self, a: &ViewSpec, b: &ViewSpec) -> f64 {
        let collective = self.cfg.collective;
        let tag =
            self.builder
                .reduce(&self.reg, Kernel::PartialAbsDiffSum, &[a, b], collective);
        self.array_ops_since_flush += 1;
        self.flush();
        self.backend.staged_scalar(Rank(0), tag).unwrap_or(0.0)
    }

    /// Trigger 1: gather a whole base to a dense buffer (real backends).
    ///
    /// The data movement is recorded as a first-class collective — a
    /// flat fan-in to rank 0 or a ring allgather, per `cfg.collective` —
    /// so it is dependency-tracked, scheduled and timed like every other
    /// operation. The dense assembly below then reads the block contents
    /// through the store oracle (bit-identical to the staged copies the
    /// collective delivered).
    pub fn gather(&mut self, base: BaseId) -> Option<Vec<f32>> {
        if self.cfg.nprocs > 1 {
            match self.cfg.collective {
                Collective::Flat => {
                    let _ = crate::comm::gather_flat(&mut self.builder, &self.reg, base, Rank(0));
                }
                Collective::Tree => {
                    let _ = crate::comm::allgather_ring(&mut self.builder, &self.reg, base);
                }
            }
            self.array_ops_since_flush += 1;
        }
        self.flush();
        self.backend.gather(self.reg.layout(base))
    }

    /// Finish the program: final flush, return the accumulated report.
    pub fn finish(mut self) -> Result<RunReport, SchedError> {
        self.flush();
        match self.error {
            Some(e) => Err(e),
            None => Ok(self.report),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;

    fn ctx(p: u32) -> Context {
        Context::sim(SchedCfg::new(MachineSpec::tiny(), p), Policy::LatencyHiding)
    }

    #[test]
    fn records_without_executing() {
        let mut c = ctx(2);
        let x = c.zeros(&[16], 4);
        let y = c.zeros(&[16], 4);
        c.add(&y.clone(), &x, &y);
        assert_eq!(c.flushes, 0, "lazy: nothing executed yet");
        assert!(c.builder.n_recorded() > 0);
        c.flush();
        assert_eq!(c.flushes, 1);
        assert!(c.report.ops_executed > 0);
    }

    #[test]
    fn threshold_triggers_flush() {
        let mut c = ctx(2);
        c.flush_threshold = 8;
        let x = c.zeros(&[16], 4);
        for _ in 0..4 {
            c.add(&x.clone(), &x, &x); // 4 fragments per call
        }
        assert!(c.flushes >= 1, "threshold flush fired");
    }

    #[test]
    fn sum_triggers_flush_and_counts_ops() {
        let mut c = ctx(2);
        let x = c.zeros(&[16], 4);
        let _ = c.sum(&x);
        assert_eq!(c.flushes, 1);
        assert!(c.report.ops_executed >= 5);
    }

    #[test]
    fn empty_flush_is_noop() {
        let mut c = ctx(1);
        c.flush();
        assert_eq!(c.flushes, 0);
    }

    #[test]
    fn finish_returns_report() {
        let mut c = ctx(2);
        let x = c.zeros(&[8], 2);
        c.copy(&x.slice(&[(0, 4)]), &x.slice(&[(4, 8)]));
        let rep = c.finish().unwrap();
        assert!(rep.ops_executed > 0);
    }
}
