//! Host-side self-profiler: where does the *simulator's own* wall
//! clock go?
//!
//! The distribution metrics ([`crate::metrics::hist`]) describe the
//! simulated timeline; this module describes the machine running it —
//! the baseline a future parallel discrete-event engine (ROADMAP item
//! 1) must beat, following rustasim's practice of treating host
//! events/sec as the first-class engine metric.
//!
//! The profiler is phase-scoped: each [`Phase`] accumulates wall time
//! from explicit `start()`/`stop()` pairs placed at the session choke
//! points (record, admit, inject, pump, drain, verify, trace-export).
//! Phases may *nest* — `Admit` (the flow engine's whole submit path)
//! contains `Inject`, and `Drain` contains `Verify` — so phase times
//! are not disjoint and do not sum to the run's wall time; the
//! throughput denominator below uses only the non-overlapping DES
//! phases (`Inject + Pump + Drain`).
//!
//! Disabled (the default), `start()` returns `None` and `stop()`
//! returns immediately — no `Instant::now()` is ever taken — and the
//! simulated timeline is bit-identical either way, since the profiler
//! never touches `VTime` arithmetic. Enabled via `--profile` on the CLI
//! or [`ProfCfg`] on `SchedCfg`.

use crate::util::json::Json;
use std::time::Instant;

/// Profiler configuration, carried on [`crate::sched::SchedCfg`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ProfCfg {
    /// Take wall timers at the phase choke points. Off by default.
    pub enabled: bool,
}

/// The instrumented phases of a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Lazy-interface op recording (`Context::ufunc` / reductions).
    Record,
    /// Flow-engine admission: pricing, window gating, splicing.
    /// Contains `Inject` (nested).
    Admit,
    /// Feeding admitted ops into the live scheduler session.
    Inject,
    /// Engine-driven event pumping outside inject/drain
    /// (`pump_next` / `pump_until` from the flow engine).
    Pump,
    /// Session drain: pump-to-completion, finish checks, op counting.
    /// Contains `Verify` (nested).
    Drain,
    /// Hazard-oracle verification of drained waves.
    Verify,
    /// Serializing and writing the Perfetto trace (CLI only).
    TraceExport,
}

impl Phase {
    pub const N: usize = 7;

    pub const ALL: [Phase; Phase::N] = [
        Phase::Record,
        Phase::Admit,
        Phase::Inject,
        Phase::Pump,
        Phase::Drain,
        Phase::Verify,
        Phase::TraceExport,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Phase::Record => "record",
            Phase::Admit => "admit",
            Phase::Inject => "inject",
            Phase::Pump => "pump",
            Phase::Drain => "drain",
            Phase::Verify => "verify",
            Phase::TraceExport => "trace_export",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::Record => 0,
            Phase::Admit => 1,
            Phase::Inject => 2,
            Phase::Pump => 3,
            Phase::Drain => 4,
            Phase::Verify => 5,
            Phase::TraceExport => 6,
        }
    }
}

/// Phase-scoped wall-time accumulator plus the events-processed
/// counter. Lives on [`crate::sched::ExecState`]; snapshotted into the
/// run report's `host` section when enabled.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Profiler {
    enabled: bool,
    nanos: [u64; Phase::N],
    calls: [u64; Phase::N],
    /// DES events processed — one per op retirement (`note_retire`),
    /// the single choke point every policy's event loop passes through.
    events: u64,
    /// Per-worker (events, nanos) tallies from the sharded engine's
    /// worker pool (`--workers N`, N ≥ 2). Empty on serial runs.
    workers: Vec<(u64, u64)>,
    /// Actor reassignments made by the deterministic work-stealing
    /// balancer across the run.
    steals: u64,
}

impl Profiler {
    pub fn new(cfg: ProfCfg) -> Self {
        Profiler {
            enabled: cfg.enabled,
            ..Default::default()
        }
    }

    #[inline]
    pub fn on(&self) -> bool {
        self.enabled
    }

    /// Begin a phase interval: `None` (free) when disabled.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// End a phase interval begun by [`Profiler::start`].
    #[inline]
    pub fn stop(&mut self, phase: Phase, t0: Option<Instant>) {
        if let Some(t0) = t0 {
            self.add_nanos(phase, t0.elapsed().as_nanos() as u64);
        }
    }

    /// Credit a phase directly (used by the CLI for trace export, which
    /// happens after the state has been torn down into the report).
    pub fn add_nanos(&mut self, phase: Phase, nanos: u64) {
        self.nanos[phase.index()] += nanos;
        self.calls[phase.index()] += 1;
    }

    /// Count one processed DES event.
    #[inline]
    pub fn count_event(&mut self) {
        if self.enabled {
            self.events += 1;
        }
    }

    pub fn events(&self) -> u64 {
        self.events
    }

    pub fn secs(&self, phase: Phase) -> f64 {
        self.nanos[phase.index()] as f64 * 1e-9
    }

    pub fn calls(&self, phase: Phase) -> u64 {
        self.calls[phase.index()]
    }

    /// Wall time in the non-overlapping DES phases — the events/sec
    /// denominator. `Admit` is excluded (it contains `Inject`) and
    /// `Verify` is excluded (it is contained in `Drain`).
    pub fn sim_secs(&self) -> f64 {
        self.secs(Phase::Inject) + self.secs(Phase::Pump) + self.secs(Phase::Drain)
    }

    /// Host throughput: DES events processed per wall second.
    pub fn events_per_sec(&self) -> f64 {
        let s = self.sim_secs();
        if s <= 0.0 {
            0.0
        } else {
            self.events as f64 / s
        }
    }

    /// Fold one drain's worker-pool tallies (per-worker `(events,
    /// nanos)` pairs plus the steal count) into the host section. The
    /// sharded engine hands these over with take semantics, so repeated
    /// drains of a live session accumulate without double counting.
    pub fn absorb_pool(&mut self, workers: &[(u64, u64)], steals: u64) {
        if self.workers.len() < workers.len() {
            self.workers.resize(workers.len(), (0, 0));
        }
        for (a, b) in self.workers.iter_mut().zip(workers) {
            a.0 += b.0;
            a.1 += b.1;
        }
        self.steals += steals;
    }

    /// Merge another profiler's accumulators (independent runs).
    pub fn merge(&mut self, other: &Profiler) {
        self.enabled |= other.enabled;
        for (a, b) in self.nanos.iter_mut().zip(&other.nanos) {
            *a += b;
        }
        for (a, b) in self.calls.iter_mut().zip(&other.calls) {
            *a += b;
        }
        self.events += other.events;
        self.absorb_pool(&other.workers, other.steals);
    }

    /// The `host` section of the run JSON. Wall-clock numbers are
    /// machine-dependent; the regression comparator never gates on
    /// them.
    pub fn to_json(&self) -> Json {
        let mut phases = Json::obj();
        for ph in Phase::ALL {
            if self.calls(ph) == 0 {
                continue;
            }
            let mut p = Json::obj();
            p.push("secs", self.secs(ph).into());
            p.push("calls", self.calls(ph).into());
            phases.push(ph.label(), p);
        }
        let mut o = Json::obj();
        o.push("phases", phases);
        o.push("events", self.events.into());
        o.push("sim_secs", self.sim_secs().into());
        o.push("events_per_sec", self.events_per_sec().into());
        // Sharded runs only: per-worker throughput and the steal count.
        // Serial runs omit the keys entirely so their host sections are
        // unchanged from previous releases.
        if !self.workers.is_empty() {
            let mut ws = Vec::new();
            for &(events, nanos) in &self.workers {
                let secs = nanos as f64 * 1e-9;
                let mut w = Json::obj();
                w.push("events", events.into());
                w.push("pump_secs", secs.into());
                let eps = if secs > 0.0 { events as f64 / secs } else { 0.0 };
                w.push("events_per_sec", eps.into());
                ws.push(w);
            }
            o.push("workers", Json::Arr(ws));
            o.push("steal_count", self.steals.into());
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_takes_no_timers() {
        let p = Profiler::new(ProfCfg::default());
        assert!(!p.on());
        assert!(p.start().is_none());
    }

    #[test]
    fn start_stop_accumulates() {
        let mut p = Profiler::new(ProfCfg { enabled: true });
        let t0 = p.start();
        assert!(t0.is_some());
        p.stop(Phase::Pump, t0);
        assert_eq!(p.calls(Phase::Pump), 1);
        assert_eq!(p.calls(Phase::Drain), 0);
    }

    #[test]
    fn events_counted_only_when_enabled() {
        let mut off = Profiler::new(ProfCfg::default());
        off.count_event();
        assert_eq!(off.events(), 0);
        let mut on = Profiler::new(ProfCfg { enabled: true });
        on.count_event();
        on.count_event();
        assert_eq!(on.events(), 2);
    }

    #[test]
    fn events_per_sec_guards_zero_denominator() {
        let mut p = Profiler::new(ProfCfg { enabled: true });
        p.count_event();
        assert_eq!(p.events_per_sec(), 0.0);
        p.add_nanos(Phase::Drain, 2_000_000_000);
        assert!((p.events_per_sec() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn sim_secs_excludes_nested_phases() {
        let mut p = Profiler::new(ProfCfg { enabled: true });
        p.add_nanos(Phase::Admit, 5_000_000_000);
        p.add_nanos(Phase::Verify, 3_000_000_000);
        p.add_nanos(Phase::Inject, 1_000_000_000);
        assert!((p.sim_secs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_counters() {
        let mut a = Profiler::new(ProfCfg { enabled: true });
        a.add_nanos(Phase::Pump, 100);
        a.count_event();
        let mut b = Profiler::new(ProfCfg { enabled: true });
        b.add_nanos(Phase::Pump, 50);
        b.count_event();
        a.merge(&b);
        assert_eq!(a.calls(Phase::Pump), 2);
        assert_eq!(a.events(), 2);
    }

    #[test]
    fn absorb_pool_accumulates_and_emits_worker_section() {
        let mut p = Profiler::new(ProfCfg { enabled: true });
        // Serial shape: no worker keys at all.
        assert!(!p.to_json().render().contains("steal_count"));
        p.absorb_pool(&[(3, 1_000_000_000), (1, 0)], 2);
        p.absorb_pool(&[(1, 1_000_000_000)], 1);
        let j = p.to_json();
        assert_eq!(j.get("steal_count").and_then(Json::as_f64), Some(3.0));
        let ws = j.get("workers").and_then(Json::as_arr).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].get("events").and_then(Json::as_f64), Some(4.0));
        assert_eq!(ws[0].get("events_per_sec").and_then(Json::as_f64), Some(2.0));
        assert_eq!(ws[1].get("events_per_sec").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn merge_carries_worker_tallies() {
        let mut a = Profiler::new(ProfCfg { enabled: true });
        let mut b = Profiler::new(ProfCfg { enabled: true });
        b.absorb_pool(&[(5, 10)], 1);
        a.merge(&b);
        a.merge(&b);
        let j = a.to_json();
        assert_eq!(j.get("steal_count").and_then(Json::as_f64), Some(2.0));
        let ws = j.get("workers").and_then(Json::as_arr).unwrap();
        assert_eq!(ws[0].get("events").and_then(Json::as_f64), Some(10.0));
    }

    #[test]
    fn json_has_host_fields() {
        let mut p = Profiler::new(ProfCfg { enabled: true });
        p.add_nanos(Phase::Record, 1000);
        let s = p.to_json().render();
        assert!(s.contains("events_per_sec"));
        assert!(s.contains("record"));
        assert!(!s.contains("trace_export"), "zero-call phases skipped");
    }
}
