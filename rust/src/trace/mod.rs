//! Event-sourced tracing: per-op timelines, wait attribution, and
//! critical-path analysis (DESIGN.md §9).
//!
//! The runtime's headline claim is an *attribution* claim — the share of
//! execution time ranks spend waiting — but `metrics::RunReport` only
//! carries aggregate scalars. This module records the underlying events:
//! every op start/retire, message post/deliver, wait interval (tagged
//! with its [`WaitCause`]), stage alloc/free, and adaptive-window
//! decision, as they happen inside the session engines.
//!
//! Design constraints (ISSUE 6):
//! * **zero-cost when disabled** — the sink defaults to disabled and
//!   every `push` is an `#[inline]` early-return on a bool; engines guard
//!   any non-trivial argument computation behind [`TraceSink::on`]. All
//!   wait accounting goes through [`crate::sched::ExecState::charge_wait`]
//!   so the arithmetic is bit-identical with tracing on or off.
//! * **bounded when enabled** — a fixed-capacity ring that overwrites the
//!   oldest events and counts what it dropped, so a long run can never
//!   exhaust memory.
//!
//! Consumers: [`export::perfetto`] renders a Chrome-trace-event /
//! Perfetto JSON timeline; [`critical::critical_path`] walks the longest
//! dependency chain backwards from the makespan and classifies it into
//! compute / comm / wait / overhead; [`critical::epoch_series`] folds a
//! per-epoch time-series (wait %, overlap %, in-flight depth) for the
//! run JSON.

use crate::types::{OpId, Rank, Tag, VTime};
use crate::ufunc::{OpNode, OpPayload};

pub mod critical;
pub mod export;

/// Why a rank's virtual clock was advanced without doing useful work.
///
/// The taxonomy mirrors the accounting buckets on `RunReport`: every
/// cause except [`WaitCause::Admission`] accrues into the per-rank
/// `wait` vector (admission stalls are charged to the *frontend*
/// recorder, not the simulated ranks — see DESIGN.md §7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WaitCause {
    /// Blocked on a point-to-point transfer to/from `peer` (send
    /// completion or receive arrival).
    Transfer { peer: Rank },
    /// Blocked on a collective round: joining the arrival frontier of a
    /// value broadcast.
    Collective,
    /// Global barrier (`SyncMode::Barrier` or an explicit fence).
    Barrier,
    /// Dependency-cone settle: joining the completion frontier of the
    /// producing cone on a targeted sync.
    Cone,
    /// Admission gate: an op stalled until its epoch finished recording.
    /// Charged to `wait_at_admission`, **not** to per-rank `wait`.
    Admission,
    /// Idle in the event loop until a local compute completion (or a
    /// fresh injection) made a successor runnable.
    Dependency,
}

impl WaitCause {
    /// Number of cause variants — the width of per-cause tables such as
    /// [`crate::metrics::hist::DistMetrics::wait_by_cause`].
    pub const N: usize = 6;

    /// Labels indexed by [`WaitCause::index`].
    pub const LABELS: [&'static str; WaitCause::N] = [
        "transfer",
        "collective",
        "barrier",
        "cone",
        "admission",
        "dependency",
    ];

    /// Short stable label, used by the exporter and JSON reports.
    pub fn label(self) -> &'static str {
        WaitCause::LABELS[self.index()]
    }

    /// Dense table index (Transfer collapses all peers into one slot).
    pub fn index(self) -> usize {
        match self {
            WaitCause::Transfer { .. } => 0,
            WaitCause::Collective => 1,
            WaitCause::Barrier => 2,
            WaitCause::Cone => 3,
            WaitCause::Admission => 4,
            WaitCause::Dependency => 5,
        }
    }
}

/// What kind of op a timeline slice represents.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Compute,
    Send,
    Recv,
}

impl OpKind {
    pub fn label(self) -> &'static str {
        match self {
            OpKind::Compute => "compute",
            OpKind::Send => "send",
            OpKind::Recv => "recv",
        }
    }
}

/// Classify an op node and estimate the bytes it moves (transfer size
/// for comm ops, output footprint for compute ops).
pub fn op_kind_bytes(op: &OpNode) -> (OpKind, u64) {
    match &op.payload {
        OpPayload::Compute(t) => (OpKind::Compute, t.elems * 4),
        OpPayload::Send { bytes, .. } => (OpKind::Send, *bytes),
        OpPayload::Recv { bytes, .. } => (OpKind::Recv, *bytes),
    }
}

/// One timestamped event. Times are virtual seconds ([`VTime`]); epochs
/// are admission-log indices captured at emission time (exact in batch
/// mode, "latest submitted" under pipelined admission).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// An op became runnable and started executing on `rank`.
    OpStart {
        op: OpId,
        rank: Rank,
        kind: OpKind,
        epoch: u64,
        t: VTime,
    },
    /// An op retired (central emission point: `ExecState::note_retire`).
    OpRetire {
        op: OpId,
        rank: Rank,
        kind: OpKind,
        bytes: u64,
        epoch: u64,
        t: VTime,
        /// Human-readable provenance ([`OpNode::describe`]) — carried
        /// into the Perfetto export (`args.desc`) so `distnumpy diff`
        /// can name divergent ops in source terms.
        desc: String,
    },
    /// A message envelope was posted to the network (`post_send`); one
    /// event per `Network::post_send`, so counts reconcile with
    /// `RunReport::n_messages` exactly.
    MsgPost {
        tag: Tag,
        from: Rank,
        to: Rank,
        bytes: u64,
        t: VTime,
    },
    /// The matching receive completed on the destination rank.
    MsgDeliver {
        tag: Tag,
        from: Rank,
        to: Rank,
        bytes: u64,
        t: VTime,
    },
    /// `rank` stalled over `[t0, t1)` for the given cause.
    Wait {
        rank: Rank,
        cause: WaitCause,
        epoch: u64,
        t0: VTime,
        t1: VTime,
    },
    /// A staging buffer was materialized on `rank`.
    StageAlloc { rank: Rank, tag: Tag, t: VTime },
    /// The last reader retired and the stage was reclaimed.
    StageFree { rank: Rank, tag: Tag, t: VTime },
    /// The adaptive controller steered the admission window.
    Window { epoch: u64, window: u64, t: VTime },
    /// An epoch finished recording and entered the admission log
    /// (`start`/`done` are NaN in stop-the-world batch mode, which has
    /// no recorder clock).
    Admit {
        epoch: u64,
        start: VTime,
        done: VTime,
        n_ops: u64,
    },
    /// All ops of an epoch retired.
    EpochRetired { epoch: u64, t: VTime },
}

impl TraceEvent {
    /// Event timestamp (interval events report their start).
    pub fn t(&self) -> VTime {
        match *self {
            TraceEvent::OpStart { t, .. }
            | TraceEvent::OpRetire { t, .. }
            | TraceEvent::MsgPost { t, .. }
            | TraceEvent::MsgDeliver { t, .. }
            | TraceEvent::StageAlloc { t, .. }
            | TraceEvent::StageFree { t, .. }
            | TraceEvent::Window { t, .. }
            | TraceEvent::EpochRetired { t, .. } => t,
            TraceEvent::Wait { t0, .. } => t0,
            TraceEvent::Admit { done, .. } => done,
        }
    }
}

/// Tracing configuration, carried on `SchedCfg`. Defaults to disabled.
#[derive(Clone, Copy, Debug)]
pub struct TraceCfg {
    pub enabled: bool,
    /// Ring capacity in events; the sink overwrites the oldest events
    /// beyond this and counts them in [`TraceSink::dropped`].
    pub capacity: usize,
}

impl Default for TraceCfg {
    fn default() -> Self {
        TraceCfg {
            enabled: false,
            capacity: 1 << 20,
        }
    }
}

/// Bounded event log: a no-op when disabled, an overwrite-oldest ring
/// when enabled. Recorded on `ExecState`, harvested by
/// `Context::finish_traced`.
#[derive(Clone, Debug, Default)]
pub struct TraceSink {
    enabled: bool,
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    /// Events overwritten because the ring was full.
    dropped: u64,
}

impl TraceSink {
    pub fn new(cfg: TraceCfg) -> TraceSink {
        TraceSink {
            enabled: cfg.enabled,
            cap: cfg.capacity.max(1),
            buf: Vec::new(),
            head: 0,
            dropped: 0,
        }
    }

    /// Whether events are being recorded. Engines use this to guard any
    /// argument computation that isn't free.
    #[inline(always)]
    pub fn on(&self) -> bool {
        self.enabled
    }

    /// Number of retained events.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            // Overwrite the oldest slot; `head` is the ring's oldest.
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    #[inline]
    pub fn op_start(&mut self, op: OpId, rank: Rank, kind: OpKind, epoch: u64, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::OpStart {
            op,
            rank,
            kind,
            epoch,
            t,
        });
    }

    #[inline]
    pub fn op_retire(
        &mut self,
        op: OpId,
        rank: Rank,
        kind: OpKind,
        bytes: u64,
        epoch: u64,
        t: VTime,
        desc: String,
    ) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::OpRetire {
            op,
            rank,
            kind,
            bytes,
            epoch,
            t,
            desc,
        });
    }

    #[inline]
    pub fn msg_post(&mut self, tag: Tag, from: Rank, to: Rank, bytes: u64, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::MsgPost {
            tag,
            from,
            to,
            bytes,
            t,
        });
    }

    #[inline]
    pub fn msg_deliver(&mut self, tag: Tag, from: Rank, to: Rank, bytes: u64, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::MsgDeliver {
            tag,
            from,
            to,
            bytes,
            t,
        });
    }

    #[inline]
    pub fn wait(&mut self, rank: Rank, cause: WaitCause, epoch: u64, t0: VTime, t1: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::Wait {
            rank,
            cause,
            epoch,
            t0,
            t1,
        });
    }

    #[inline]
    pub fn stage_alloc(&mut self, rank: Rank, tag: Tag, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::StageAlloc { rank, tag, t });
    }

    #[inline]
    pub fn stage_free(&mut self, rank: Rank, tag: Tag, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::StageFree { rank, tag, t });
    }

    #[inline]
    pub fn window(&mut self, epoch: u64, window: u64, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::Window { epoch, window, t });
    }

    #[inline]
    pub fn admit(&mut self, epoch: u64, start: VTime, done: VTime, n_ops: u64) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::Admit {
            epoch,
            start,
            done,
            n_ops,
        });
    }

    #[inline]
    pub fn epoch_retired(&mut self, epoch: u64, t: VTime) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::EpochRetired { epoch, t });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent::EpochRetired {
            epoch: i,
            t: i as f64,
        }
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let mut s = TraceSink::default();
        assert!(!s.on());
        for i in 0..100 {
            s.push(ev(i));
        }
        assert_eq!(s.len(), 0);
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut s = TraceSink::new(TraceCfg {
            enabled: true,
            capacity: 8,
        });
        for i in 0..20 {
            s.push(ev(i));
        }
        assert_eq!(s.len(), 8);
        assert_eq!(s.dropped(), 12);
        // Oldest-first iteration yields the 8 most recent events in order.
        let epochs: Vec<u64> = s
            .events()
            .map(|e| match e {
                TraceEvent::EpochRetired { epoch, .. } => *epoch,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(epochs, (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn insertion_order_before_wrap() {
        let mut s = TraceSink::new(TraceCfg {
            enabled: true,
            capacity: 64,
        });
        for i in 0..5 {
            s.push(ev(i));
        }
        assert_eq!(s.dropped(), 0);
        let ts: Vec<f64> = s.events().map(|e| e.t()).collect();
        assert_eq!(ts, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }
}
