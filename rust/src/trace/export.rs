//! Chrome-trace-event / Perfetto JSON exporter.
//!
//! Layout: one process group per rank (`pid = rank`), with a `cpu`
//! thread (`tid 0`: compute slices and wait slices) and a `comm` thread
//! (`tid 1`: send/recv op spans, which overlap compute under latency
//! hiding and would render as nested slices on one track). Messages
//! become flow arrows (`ph:"s"` → `ph:"f"`) keyed by envelope tag, from
//! the sender's comm track to the receiver's. Runtime-global counters
//! (`pid = nprocs`) track admission in-flight depth, the adaptive
//! window, and live staging buffers.
//!
//! Timestamps are virtual seconds scaled to microseconds (the unit the
//! trace-event format expects); non-finite times (batch-mode admission
//! has no recorder clock) are skipped.

use super::{OpKind, TraceEvent, TraceSink, WaitCause};
use crate::types::VTime;
use crate::util::json::Json;
use std::collections::HashMap;

const US: f64 = 1e6;

fn slice(name: String, cat: &str, pid: i64, tid: i64, t0: VTime, t1: VTime) -> Json {
    let mut o = Json::obj();
    o.push("name", Json::Str(name));
    o.push("cat", cat.into());
    o.push("ph", "X".into());
    o.push("pid", Json::Int(pid));
    o.push("tid", Json::Int(tid));
    o.push("ts", Json::Num(t0 * US));
    o.push("dur", Json::Num((t1 - t0).max(0.0) * US));
    o
}

fn meta(name: &str, value: &str, pid: i64, tid: Option<i64>) -> Json {
    let mut o = Json::obj();
    o.push("name", name.into());
    o.push("ph", "M".into());
    o.push("pid", Json::Int(pid));
    if let Some(tid) = tid {
        o.push("tid", Json::Int(tid));
    }
    let mut args = Json::obj();
    args.push("name", value.into());
    o.push("args", args);
    o
}

fn counter(name: &str, key: &str, pid: i64, t: VTime, v: f64) -> Json {
    let mut o = Json::obj();
    o.push("name", name.into());
    o.push("ph", "C".into());
    o.push("pid", Json::Int(pid));
    o.push("ts", Json::Num(t * US));
    let mut args = Json::obj();
    args.push(key, Json::Num(v));
    o.push("args", args);
    o
}

fn instant(name: String, cat: &str, pid: i64, tid: i64, t: VTime) -> Json {
    let mut o = Json::obj();
    o.push("name", Json::Str(name));
    o.push("cat", cat.into());
    o.push("ph", "i".into());
    o.push("s", "t".into());
    o.push("pid", Json::Int(pid));
    o.push("tid", Json::Int(tid));
    o.push("ts", Json::Num(t * US));
    o
}

fn flow(ph: &str, id: u64, pid: i64, t: VTime) -> Json {
    let mut o = Json::obj();
    o.push("name", "msg".into());
    o.push("cat", "msg".into());
    o.push("ph", ph.into());
    if ph == "f" {
        // Bind the finish to the enclosing slice's end, so arrows land
        // on the recv span even when delivery coincides with its edge.
        o.push("bp", "e".into());
    }
    o.push("id", Json::Int(id as i64));
    o.push("pid", Json::Int(pid));
    o.push("tid", Json::Int(1));
    o.push("ts", Json::Num(t * US));
    o
}

/// Render the sink as a Chrome-trace-event JSON object
/// (`{"traceEvents": [...]}`), loadable in Perfetto / `chrome://tracing`.
pub fn perfetto(sink: &TraceSink, nprocs: usize) -> Json {
    let mut evs: Vec<Json> = Vec::with_capacity(sink.len() + 3 * nprocs + 4);
    let runtime_pid = nprocs as i64;

    for r in 0..nprocs {
        evs.push(meta("process_name", &format!("rank p{r}"), r as i64, None));
        evs.push(meta("thread_name", "cpu", r as i64, Some(0)));
        evs.push(meta("thread_name", "comm", r as i64, Some(1)));
    }
    evs.push(meta("process_name", "runtime", runtime_pid, None));

    // Pair OpStart with the following OpRetire for the same op id (ids
    // are unique within a session run; across runs the entry is consumed
    // before the id recycles).
    let mut open: HashMap<u32, VTime> = HashMap::new();
    let mut in_flight: i64 = 0;
    let mut live_stages: i64 = 0;

    for ev in sink.events() {
        match *ev {
            TraceEvent::OpStart { op, t, .. } => {
                open.insert(op.0, t);
            }
            TraceEvent::OpRetire {
                op,
                rank,
                kind,
                bytes,
                epoch,
                t,
                ref desc,
            } => {
                let t0 = open.remove(&op.0).unwrap_or(t);
                if !t0.is_finite() || !t.is_finite() {
                    continue;
                }
                let tid = match kind {
                    OpKind::Compute => 0,
                    OpKind::Send | OpKind::Recv => 1,
                };
                let mut s = slice(
                    format!("{} #{}", kind.label(), op.0),
                    kind.label(),
                    rank.0 as i64,
                    tid,
                    t0,
                    t,
                );
                let mut args = Json::obj();
                args.push("op", Json::from(op.0 as u64));
                args.push("bytes", Json::from(bytes));
                args.push("epoch", Json::from(epoch));
                if !desc.is_empty() {
                    // Provenance for diff/inspection tooling: what the
                    // op was in source terms (`OpNode::describe`).
                    args.push("desc", desc.as_str().into());
                }
                s.push("args", args);
                evs.push(s);
            }
            TraceEvent::Wait {
                rank,
                cause,
                epoch,
                t0,
                t1,
            } => {
                if !t0.is_finite() || !t1.is_finite() {
                    continue;
                }
                let name = match cause {
                    WaitCause::Transfer { peer } => format!("wait:transfer({peer})"),
                    c => format!("wait:{}", c.label()),
                };
                let mut s = slice(name, "wait", rank.0 as i64, 0, t0, t1);
                let mut args = Json::obj();
                args.push("epoch", Json::from(epoch));
                s.push("args", args);
                evs.push(s);
            }
            TraceEvent::MsgPost { tag, from, t, .. } => {
                if t.is_finite() {
                    evs.push(flow("s", tag.0, from.0 as i64, t));
                }
            }
            TraceEvent::MsgDeliver { tag, to, t, .. } => {
                if t.is_finite() {
                    evs.push(flow("f", tag.0, to.0 as i64, t));
                }
            }
            TraceEvent::StageAlloc { rank, tag, t } => {
                if t.is_finite() {
                    evs.push(instant(format!("stage+ {}", tag.0), "stage", rank.0 as i64, 0, t));
                    live_stages += 1;
                    evs.push(counter("live_stages", "stages", runtime_pid, t, live_stages as f64));
                }
            }
            TraceEvent::StageFree { rank, tag, t } => {
                if t.is_finite() {
                    evs.push(instant(format!("stage- {}", tag.0), "stage", rank.0 as i64, 0, t));
                    live_stages -= 1;
                    evs.push(counter("live_stages", "stages", runtime_pid, t, live_stages as f64));
                }
            }
            TraceEvent::Window { window, t, .. } => {
                if t.is_finite() {
                    evs.push(counter("window", "ops", runtime_pid, t, window as f64));
                }
            }
            TraceEvent::Admit { done, .. } => {
                in_flight += 1;
                if done.is_finite() {
                    evs.push(counter("in_flight", "epochs", runtime_pid, done, in_flight as f64));
                }
            }
            TraceEvent::EpochRetired { t, .. } => {
                in_flight -= 1;
                if t.is_finite() {
                    evs.push(counter("in_flight", "epochs", runtime_pid, t, in_flight as f64));
                }
            }
        }
    }

    // Ring-dropped starts leave dangling opens; surface them as
    // zero-length markers rather than losing them silently.
    let mut dangling: Vec<(u32, VTime)> = open.into_iter().collect();
    dangling.sort_unstable();
    for (op, t0) in dangling {
        if t0.is_finite() {
            evs.push(instant(
                format!("unretired #{op}"),
                "op",
                runtime_pid,
                0,
                t0,
            ));
        }
    }
    let mut root = Json::obj();
    root.push("traceEvents", Json::Arr(evs));
    root.push("displayTimeUnit", "ms".into());
    let mut about = Json::obj();
    about.push("tool", "distnumpy --trace".into());
    about.push("dropped_events", Json::from(sink.dropped()));
    root.push("otherData", about);
    root
}
