//! Critical-path analysis and per-epoch time-series over the event log.
//!
//! The critical path is recovered by walking *backwards* from the
//! makespan through each rank's occupancy timeline (compute-op spans
//! plus wait intervals — comm-op spans are excluded because under
//! latency hiding they overlap compute on the same rank). At every step
//! the walk clips the segment covering the current time, classifies the
//! clipped span, and jumps to the stalling peer when the segment is a
//! transfer wait; uncovered gaps are charged to runtime overhead. The
//! clipped spans telescope, so compute + comm + wait + overhead covers
//! the makespan exactly (to fp rounding) — the acceptance invariant.

use super::{OpKind, TraceEvent, TraceSink, WaitCause};
use crate::types::{Rank, VTime};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Class {
    Compute,
    Comm,
    Wait,
    Overhead,
}

#[derive(Clone, Copy, Debug)]
struct Seg {
    t0: VTime,
    t1: VTime,
    class: Class,
    /// Op id + kind when the segment is a compute-op span.
    op: Option<(u32, OpKind)>,
    /// Rank to jump to when the segment is a transfer wait.
    jump: Option<Rank>,
}

/// One op's contribution to the critical path.
#[derive(Clone, Debug)]
pub struct TopOp {
    pub op: u32,
    pub kind: OpKind,
    pub rank: Rank,
    pub span: VTime,
}

/// Classified decomposition of the longest dependency chain.
#[derive(Clone, Debug, Default)]
pub struct CriticalPath {
    pub makespan: VTime,
    pub compute: VTime,
    pub comm: VTime,
    pub wait: VTime,
    pub overhead: VTime,
    /// Segments visited by the backward walk.
    pub steps: usize,
    /// Top ops by critical-path contribution, largest first.
    pub top_ops: Vec<TopOp>,
}

impl CriticalPath {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("makespan", Json::Num(self.makespan));
        o.push("compute", Json::Num(self.compute));
        o.push("comm", Json::Num(self.comm));
        o.push("wait", Json::Num(self.wait));
        o.push("overhead", Json::Num(self.overhead));
        let pct = |x: VTime| {
            if self.makespan > 0.0 {
                Json::Num(100.0 * x / self.makespan)
            } else {
                Json::Num(0.0)
            }
        };
        o.push("compute_pct", pct(self.compute));
        o.push("comm_pct", pct(self.comm));
        o.push("wait_pct", pct(self.wait));
        o.push("overhead_pct", pct(self.overhead));
        o.push("steps", Json::from(self.steps));
        let tops = self
            .top_ops
            .iter()
            .map(|t| {
                let mut e = Json::obj();
                e.push("op", Json::from(t.op as u64));
                e.push("kind", t.kind.label().into());
                e.push("rank", Json::from(t.rank.0 as u64));
                e.push("span", Json::Num(t.span));
                e
            })
            .collect();
        o.push("top_ops", Json::Arr(tops));
        o
    }
}

fn classify_wait(cause: WaitCause) -> (Class, Option<Rank>) {
    match cause {
        // Unhidden communication latency — the paper's target quantity.
        WaitCause::Transfer { peer } => (Class::Comm, Some(peer)),
        WaitCause::Collective => (Class::Comm, None),
        // Synchronization structure.
        WaitCause::Barrier | WaitCause::Cone | WaitCause::Dependency => (Class::Wait, None),
        // Frontend/runtime cost, not simulated-rank work.
        WaitCause::Admission => (Class::Overhead, None),
    }
}

/// Walk the retire log's longest dependency chain backwards from
/// `makespan`, classifying its span. `nprocs` bounds the rank index
/// space; events for ranks beyond it are ignored.
pub fn critical_path(sink: &TraceSink, nprocs: usize, makespan: VTime) -> CriticalPath {
    let mut segs: Vec<Vec<Seg>> = vec![Vec::new(); nprocs.max(1)];
    let mut open: std::collections::HashMap<u32, VTime> = std::collections::HashMap::new();
    let mut last_end: Vec<VTime> = vec![0.0; nprocs.max(1)];

    for ev in sink.events() {
        match *ev {
            TraceEvent::OpStart { op, t, .. } => {
                open.insert(op.0, t);
            }
            TraceEvent::OpRetire {
                op, rank, kind, t, ..
            } => {
                let r = rank.0 as usize;
                if r >= segs.len() || !t.is_finite() {
                    continue;
                }
                last_end[r] = last_end[r].max(t);
                if kind != OpKind::Compute {
                    // Comm spans overlap compute under LH; transfer
                    // stalls already appear as Transfer waits.
                    open.remove(&op.0);
                    continue;
                }
                let t0 = open.remove(&op.0).unwrap_or(t);
                if t0.is_finite() {
                    segs[r].push(Seg {
                        t0,
                        t1: t,
                        class: Class::Compute,
                        op: Some((op.0, kind)),
                        jump: None,
                    });
                }
            }
            TraceEvent::Wait {
                rank, cause, t0, t1, ..
            } => {
                let r = rank.0 as usize;
                if r >= segs.len() || !t0.is_finite() || !t1.is_finite() || t1 <= t0 {
                    continue;
                }
                last_end[r] = last_end[r].max(t1);
                let (class, jump) = classify_wait(cause);
                segs[r].push(Seg {
                    t0,
                    t1,
                    class,
                    op: None,
                    jump,
                });
            }
            _ => {}
        }
    }

    let mut cp = CriticalPath {
        makespan,
        ..CriticalPath::default()
    };
    if !makespan.is_finite() || makespan <= 0.0 || segs.iter().all(|s| s.is_empty()) {
        cp.overhead = makespan.max(0.0);
        return cp;
    }

    // Start on the rank whose timeline ends last (it determines the
    // makespan under a continuous per-rank clock).
    let mut cur = last_end
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(r, _)| r)
        .unwrap_or(0);

    let eps = 1e-9 * makespan.max(1e-9);
    let mut tc = makespan;
    let mut ops: std::collections::HashMap<u32, TopOp> = std::collections::HashMap::new();
    let total_segs: usize = segs.iter().map(Vec::len).sum();
    let max_steps = 4 * total_segs + 1024;

    while tc > eps && cp.steps < max_steps {
        cp.steps += 1;
        // Innermost segment on `cur` covering (or touching) tc.
        let covering = segs[cur]
            .iter()
            .filter(|s| s.t0 < tc - eps && s.t1 >= tc - eps)
            .max_by(|a, b| a.t0.total_cmp(&b.t0))
            .copied();
        match covering {
            Some(seg) => {
                let lo = seg.t0.max(0.0);
                let span = tc - lo;
                match seg.class {
                    Class::Compute => cp.compute += span,
                    Class::Comm => cp.comm += span,
                    Class::Wait => cp.wait += span,
                    Class::Overhead => cp.overhead += span,
                }
                if let Some((op, kind)) = seg.op {
                    let e = ops.entry(op).or_insert(TopOp {
                        op,
                        kind,
                        rank: Rank(cur as u32),
                        span: 0.0,
                    });
                    e.span += span;
                }
                if let Some(peer) = seg.jump {
                    if (peer.0 as usize) < segs.len() {
                        cur = peer.0 as usize;
                    }
                }
                tc = lo;
            }
            None => {
                // Gap on this rank's timeline: runtime/scheduler
                // overhead back to the latest earlier segment end.
                let te = segs[cur]
                    .iter()
                    .map(|s| s.t1)
                    .filter(|&t1| t1 <= tc - eps)
                    .fold(0.0_f64, f64::max);
                cp.overhead += tc - te;
                tc = te;
            }
        }
    }
    if tc > 0.0 {
        // Step cap hit (degenerate fp ordering): charge the remainder.
        cp.overhead += tc;
    }

    let mut tops: Vec<TopOp> = ops.into_values().collect();
    tops.sort_by(|a, b| b.span.total_cmp(&a.span));
    tops.truncate(10);
    cp.top_ops = tops;
    cp
}

/// Per-epoch time-series: one entry per admitted epoch, keyed by
/// admission-log index. `wait_pct` is the share of the epoch's execution
/// span its ranks spent stalled; `overlap_pct` is how much of the
/// epoch's recording cost was hidden behind execution (100 = fully
/// overlapped, only meaningful under streaming admission); `in_flight`
/// is the admission pipeline depth when the epoch entered.
pub fn epoch_series(sink: &TraceSink, nprocs: usize) -> Json {
    #[derive(Clone, Default)]
    struct Acc {
        n_ops: u64,
        record_start: VTime,
        record_done: VTime,
        retired: VTime,
        in_flight: i64,
        wait: VTime,
        admission_wait: VTime,
        first_start: VTime,
        last_retire: VTime,
        seen: bool,
    }
    fn at(accs: &mut Vec<Acc>, e: u64) -> &mut Acc {
        let i = e as usize;
        if i >= accs.len() {
            accs.resize(i + 1, Acc::default());
        }
        &mut accs[i]
    }
    let mut accs: Vec<Acc> = Vec::new();
    let mut depth: i64 = 0;

    for ev in sink.events() {
        match *ev {
            TraceEvent::Admit {
                epoch,
                start,
                done,
                n_ops,
            } => {
                depth += 1;
                let a = at(&mut accs, epoch);
                a.seen = true;
                a.n_ops = n_ops;
                a.record_start = start;
                a.record_done = done;
                a.in_flight = depth;
            }
            TraceEvent::EpochRetired { epoch, t } => {
                depth -= 1;
                let a = at(&mut accs, epoch);
                a.retired = t;
            }
            TraceEvent::Wait {
                epoch,
                cause,
                t0,
                t1,
                ..
            } => {
                if t0.is_finite() && t1 > t0 {
                    let a = at(&mut accs, epoch);
                    if cause == WaitCause::Admission {
                        a.admission_wait += t1 - t0;
                    } else {
                        a.wait += t1 - t0;
                    }
                }
            }
            TraceEvent::OpStart { epoch, t, .. } => {
                if t.is_finite() {
                    let a = at(&mut accs, epoch);
                    a.first_start = if a.first_start == 0.0 && a.last_retire == 0.0 {
                        t
                    } else {
                        a.first_start.min(t)
                    };
                }
            }
            TraceEvent::OpRetire { epoch, t, .. } => {
                if t.is_finite() {
                    let a = at(&mut accs, epoch);
                    a.last_retire = a.last_retire.max(t);
                }
            }
            _ => {}
        }
    }

    let p = nprocs.max(1) as f64;
    let series = accs
        .iter()
        .enumerate()
        .filter(|(_, a)| a.seen || a.last_retire > 0.0)
        .map(|(e, a)| {
            let span = (a.last_retire - a.first_start).max(0.0);
            let wait_pct = if span > 0.0 {
                100.0 * a.wait / (p * span)
            } else {
                0.0
            };
            let record_cost = a.record_done - a.record_start;
            let overlap_pct = if record_cost.is_finite() && record_cost > 0.0 {
                (100.0 * (1.0 - a.admission_wait / (p * record_cost))).clamp(0.0, 100.0)
            } else {
                f64::NAN // renders as null: no recorder clock (batch mode)
            };
            let mut o = Json::obj();
            o.push("epoch", Json::from(e));
            o.push("n_ops", Json::from(a.n_ops));
            o.push("in_flight", Json::Int(a.in_flight));
            o.push("wait", Json::Num(a.wait));
            o.push("wait_pct", Json::Num(wait_pct));
            o.push("overlap_pct", Json::Num(overlap_pct));
            o.push("span", Json::Num(span));
            o.push("retired", Json::Num(a.retired));
            o
        })
        .collect();
    Json::Arr(series)
}
